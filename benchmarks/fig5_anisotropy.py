"""Paper Figure 5 / Appendix B: anisotropy masking — pairwise cosine
similarity distribution of Value states vs attention outputs. Attention
outputs collapse toward a common direction (mean similarity >> 0),
masking per-token drift signals."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.models import common as mcommon, transformer


def run(quick: bool = False):
    cfg = common.bench_model(n_layers=4)
    params = common.trained_bench_model(cfg, steps=10 if quick else 30)
    key = jax.random.PRNGKey(0)
    tokens = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size - 1, (2, 128)), jnp.int32)
    h = transformer.embed_inputs(params, cfg, {"tokens": tokens})

    rows = []
    for l in range(cfg.n_layers):
        bp = jax.tree.map(lambda a: a[l], params["blocks"]["attn"])
        x = mcommon.rms_norm(h, bp["norm1"], cfg.norm_eps)
        v = np.asarray(x @ bp["wv"])[0]
        h, _, _ = transformer.apply_block_dense(cfg, "attn", bp, h)
        attn_out = np.asarray(h)[0]

        def mean_pair_cos(m):
            m = m / (np.linalg.norm(m, axis=-1, keepdims=True) + 1e-8)
            sims = m @ m.T
            iu = np.triu_indices(len(m), 1)
            return float(sims[iu].mean())

        rows.append({
            "layer": l + 1,
            "value_mean_cos": round(mean_pair_cos(v), 4),
            "attnout_mean_cos": round(mean_pair_cos(attn_out), 4),
        })
    common.print_table(
        "Fig 5 — anisotropy: pairwise cos (value vs attn-out)", rows,
        ["layer", "value_mean_cos", "attnout_mean_cos"])
    return rows


if __name__ == "__main__":
    run()

"""Benchmark entry point: one function per paper table/figure.

``python -m benchmarks.run``            — full pass
``python -m benchmarks.run --quick``    — reduced iteration counts
``python -m benchmarks.run --only t2``  — single benchmark
``python -m benchmarks.run --smoke``    — CI wiring check: table2+table3
                                          at the tiniest configs plus the
                                          kernel microbench (fails fast
                                          on strategy/scheduler/backend
                                          plumbing regressions)
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="t1|t2|t3|t4|t5|fig2|fig4|fig5|roofline")
    ap.add_argument("--smoke", action="store_true",
                    help="CI: quick table2+table3 only (numbers are "
                         "meaningless; exercises decode wiring)")
    args = ap.parse_args(argv)
    if args.smoke:
        args.quick = True

    from benchmarks import (bench_kernels, bench_serving, fig2_drift,
                            fig4_latency, fig5_anisotropy, roofline,
                            table1_identifiers, table2_main,
                            table3_parallel, table4_ablation, table5_rank)
    registry = {
        "t1": ("Table 1 identifiers", table1_identifiers.run),
        "t2": ("Table 2 main speedups", table2_main.run),
        "t3": ("Table 3 parallel decoding", table3_parallel.run),
        "t4": ("Table 4 ablation", table4_ablation.run),
        "t5": ("Table 5 rank sweep", table5_rank.run),
        "fig2": ("Fig 2 drift profile", fig2_drift.run),
        "fig4": ("Fig 4 latency decomposition", fig4_latency.run),
        "fig5": ("Fig 5 anisotropy", fig5_anisotropy.run),
        "roofline": ("Roofline table", roofline.run),
        "kernels": ("Kernel microbench (BENCH_kernels.json)",
                    bench_kernels.run),
        "serving": ("Serving runtime: paged pool, prefix cache, online "
                    "goodput-under-SLO + front-end smoke, host-tier "
                    "hit-rate gain (BENCH_serving.json)",
                    bench_serving.run),
    }
    if args.smoke:
        names = ["t2", "t3", "kernels", "serving"]
    elif args.only:
        names = [args.only]
    else:
        names = list(registry)
    for name in names:
        title, fn = registry[name]
        t0 = time.time()
        print(f"\n##### {title} #####", flush=True)
        try:
            fn(quick=args.quick)
        except Exception as e:  # noqa: BLE001
            print(f"BENCH {name} FAILED: {e!r}")
            raise
        print(f"[{name} done in {time.time() - t0:.1f}s]", flush=True)


if __name__ == "__main__":
    main()

"""Paper Table 5: singular-proxy rank sweep (identification fidelity vs
throughput trade-off) + Theorem 3.4 spectral bounds per rank."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core.svd_proxy import cosine_similarity, spectral_bound
from repro.dlm import decoding


def run(quick: bool = False):
    cfg0 = common.bench_model(d_model=128)
    params = common.trained_bench_model(cfg0, steps=10 if quick else 30)
    prompt = jnp.asarray(np.random.default_rng(4).integers(
        0, cfg0.vocab_size - 1, (2, 16)), jnp.int32)
    gen_len = 8 if quick else 24

    # identification fidelity: correlation of proxy scores with full
    # value-space scores on random drifted states
    wv = np.asarray(params["blocks"]["attn"]["wv"][0], np.float32)
    s = np.linalg.svd(wv, compute_uv=False)
    rng = np.random.default_rng(0)
    h0 = rng.standard_normal((256, wv.shape[0])).astype(np.float32)
    h1 = h0 + 0.1 * rng.standard_normal(h0.shape).astype(np.float32)
    v_sim = np.asarray(cosine_similarity(jnp.asarray(h0 @ wv),
                                         jnp.asarray(h1 @ wv)))

    ref_tokens, _ = decoding.decode(
        params, common.with_spa(cfg0, identifier="none"), prompt, gen_len)
    rows = []
    for rank in (128, 64, 32, 16, 8, 4):
        rank = min(rank, wv.shape[1])
        from repro.core.svd_proxy import build_proxy
        proxy, bound = build_proxy(wv, rank)
        p_sim = np.asarray(cosine_similarity(
            jnp.asarray(h0 @ np.asarray(proxy)),
            jnp.asarray(h1 @ np.asarray(proxy))))
        corr = float(np.corrcoef(v_sim, p_sim)[0, 1])

        cfg = common.with_spa(cfg0, identifier="singular", rank=rank,
                              schedule="uniform", rho_peak=0.25)
        stats = common.time_decode(cfg, params, prompt, gen_len)
        toks, _ = decoding.decode(params, cfg, prompt, gen_len)
        agree = float((np.asarray(toks) == np.asarray(ref_tokens)).mean())
        rows.append({
            "rank": rank,
            "thm34_bound": round(bound, 4),
            "score_corr_vs_value": round(corr, 4),
            "tps": round(stats["tps"], 2),
            "agreement": round(agree, 4),
        })
    common.print_table("Table 5 — proxy rank sweep", rows,
                       ["rank", "thm34_bound", "score_corr_vs_value",
                        "tps", "agreement"])
    return rows


if __name__ == "__main__":
    run()

"""Paper Table 1: identifier-type comparison (Query/Key/Value/attn-in/
attn-out vs baseline) on a trained scaled-down model.

Reported per identifier: decode throughput (TPS), time-to-first-token,
and agreement with vanilla decoding (the CPU-scale stand-in for GSM8K
accuracy — identical commits == identical answers)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.dlm import decoding

IDENTIFIERS = ["none", "query", "key", "value", "attn_in", "attn_out"]


def run(quick: bool = False):
    cfg0 = common.bench_model()
    params = common.trained_bench_model(cfg0, steps=10 if quick else 30)
    prompt = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg0.vocab_size - 1, (2, 16)), jnp.int32)
    gen_len = 8 if quick else 24

    cfg_v = common.with_spa(cfg0, identifier="none")
    ref_tokens, _ = decoding.decode(params, cfg_v, prompt, gen_len)

    rows = []
    for ident in IDENTIFIERS:
        cfg = common.with_spa(
            cfg0, identifier=ident, rank=16, schedule="uniform",
            rho_peak=1.0 if ident == "none" else 0.25)
        stats = common.time_decode(cfg, params, prompt, gen_len)
        toks, _ = decoding.decode(params, cfg, prompt, gen_len)
        agree = float((np.asarray(toks) == np.asarray(ref_tokens)).mean())
        rows.append({
            "identifier": ident,
            "tps": round(stats["tps"], 2),
            "ttft_ms": round(stats["ttft_ms"], 1),
            "step_ms": round(stats["step_ms"], 2),
            "agreement_vs_vanilla": round(agree, 4),
        })
    common.print_table("Table 1 — identifier comparison", rows,
                       ["identifier", "tps", "ttft_ms", "step_ms",
                        "agreement_vs_vanilla"])
    return rows


if __name__ == "__main__":
    run()

"""Paper Table 3: SPA-Cache composed with confidence-parallel decoding
(Fast-dLLM style) — the speedups multiply.

The commit policy is a call-time ``UnmaskScheduler`` (mirroring how the
caching policy is a call-time ``CacheStrategy``): sequential vs
parallel vs semi-AR block schedules run on ONE ModelConfig.  The last
row times the same spa+parallel combo through the device-resident
``run_compiled`` loop (a single ``lax.while_loop``) instead of the
host step loop."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.dlm.scheduler import (BlockScheduler, ConfidenceScheduler,
                                 ParallelThresholdScheduler)


def run(quick: bool = False):
    cfg0 = common.bench_model()
    params = common.trained_bench_model(cfg0, steps=10 if quick else 30)
    prompt = jnp.asarray(np.random.default_rng(2).integers(
        0, cfg0.vocab_size - 1, (2, 16)), jnp.int32)
    gen_len = 8 if quick else 24

    spa = common.with_spa(cfg0, identifier="singular", rank=16,
                          schedule="adaptive", rho_peak=0.25,
                          rho_first=0.03, rho_last=0.13)
    vanilla = common.with_spa(cfg0, identifier="none")
    seq = ConfidenceScheduler()
    par = ParallelThresholdScheduler(threshold=0.05, max_parallel=4)
    blk = BlockScheduler(block_len=4, threshold=0.05, max_parallel=4)

    combos = [
        ("baseline", vanilla, seq, False),
        ("spa", spa, seq, False),
        ("parallel_only", vanilla, par, False),
        ("spa+parallel", spa, par, False),
        ("spa+semi_ar_block", spa, blk, False),
        ("spa+parallel_compiled", spa, par, True),
    ]
    base = None
    rows = []
    for name, cfg, scheduler, compiled in combos:
        stats = common.time_decode(cfg, params, prompt, gen_len,
                                   scheduler=scheduler,
                                   compiled=compiled)
        if name == "baseline":
            base = stats["tps"]
        rows.append({"method": name, "tps": round(stats["tps"], 2),
                     "speedup": round(stats["tps"] / max(base, 1e-9), 2),
                     "steps": stats["steps"]})
    common.print_table("Table 3 — SPA x parallel decoding", rows,
                       ["method", "tps", "speedup", "steps"])
    return rows


if __name__ == "__main__":
    run()

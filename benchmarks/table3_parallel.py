"""Paper Table 3: SPA-Cache composed with confidence-parallel decoding
(Fast-dLLM style) — the speedups multiply."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.dlm import decoding


def run(quick: bool = False):
    cfg0 = common.bench_model()
    params = common.trained_bench_model(cfg0, steps=10 if quick else 30)
    prompt = jnp.asarray(np.random.default_rng(2).integers(
        0, cfg0.vocab_size - 1, (2, 16)), jnp.int32)
    gen_len = 8 if quick else 24

    spa = common.with_spa(cfg0, identifier="singular", rank=16,
                          schedule="adaptive", rho_peak=0.25,
                          rho_first=0.03, rho_last=0.13)
    vanilla = common.with_spa(cfg0, identifier="none")
    seq = decoding.DecodeSettings()
    par = decoding.DecodeSettings(parallel_threshold=0.05, max_parallel=4)

    combos = [
        ("baseline", vanilla, seq),
        ("spa", spa, seq),
        ("parallel_only", vanilla, par),
        ("spa+parallel", spa, par),
    ]
    base = None
    rows = []
    for name, cfg, settings in combos:
        stats = common.time_decode(cfg, params, prompt, gen_len,
                                   settings=settings)
        if name == "baseline":
            base = stats["tps"]
        rows.append({"method": name, "tps": round(stats["tps"], 2),
                     "speedup": round(stats["tps"] / max(base, 1e-9), 2),
                     "steps": stats["steps"]})
    common.print_table("Table 3 — SPA x parallel decoding", rows,
                       ["method", "tps", "speedup", "steps"])
    return rows


if __name__ == "__main__":
    run()

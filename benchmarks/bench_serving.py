"""Serving-runtime benchmark: paged cache pool vs dense slabs, and the
shared-prefix radix cache vs cold prefills.

Part 1 serves one mixed-``gen_len`` workload through the
``ServingEngine`` twice over: once with the legacy dense per-lane cache
slabs, then with the paged pool (DESIGN.md §5) at several
oversubscription ratios (aggregate page demand / pool capacity).  At 1x
the pool fits the whole workload — throughput should be within ~10% of
the dense slab (the paged step adds one page-gather + page-scatter per
step).  At 2-3x admission control + preemption carry the same workload
through a pool a fraction of the size.

Part 2 serves a shared-system-prompt workload (every request opens with
the same long system prompt; questions repeat, as retries/samples do)
with the prefix cache ON vs OFF (DESIGN.md §6): full hits skip the
prefill forward entirely, partial hits recompute only the unmatched
suffix, and the recorded hit rate / prefill-tokens-saved / speedup land
in ``BENCH_serving.json``:

    {"config": {...},
     "dense": {...}, "paged": {"1x": {...}, ...},
     "paged_over_dense_tok_s_at_1x": 0.97,
     "prefix": {"on": {...}, "off": {...},
                "hit_rate": 0.88, "full_hit_rate": 0.5,
                "prefill_tokens_saved": 264,
                "prefix_over_cold_tok_s": 1.6}}

Wired into ``benchmarks/run.py --smoke`` (CI bench-smoke job).
"""
from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

PAGE = 4
CANVAS = 32


def _build():
    from repro.configs import get_arch, reduced
    from repro.models import transformer
    cfg = reduced(get_arch("internlm2-1.8b"), n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=256)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _workload(cfg, n_requests: int):
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(n_requests):
        p_len = int(rng.integers(4, 10))
        gen = int(rng.integers(6, CANVAS - p_len + 1))
        prompt = rng.integers(0, cfg.vocab_size - 1, p_len).astype(np.int32)
        reqs.append((prompt, gen, int(rng.integers(0, 3))))  # priority 0-2
    return reqs


def _engine(cfg, params, pool_pages):
    from repro.core.strategy import SPACache
    from repro.serving.engine import ServingEngine
    return ServingEngine(
        cfg, params, max_batch=4, canvas_len=CANVAS,
        strategy=SPACache(rank=16, schedule="uniform", rho_peak=0.3),
        pool_pages=pool_pages, page_size=PAGE)


def _serve(cfg, params, reqs, pool_pages, mid_run_arrivals=False) -> dict:
    eng = _engine(cfg, params, pool_pages)
    # warm the lane executable at the MEASURED batch shape (dense lanes
    # size the canvas to the actual batch, so a 1-request warm-up would
    # leave the b=4 compile inside the timed region)
    for _ in range(4):
        eng.submit(reqs[0][0], reqs[0][1])
    eng.run()
    eng.done.clear()
    eng.stats = type(eng.stats)()
    if eng.pool is not None:        # drop the warm-up's util samples
        eng.pool.reset_telemetry()
    # overhead comparisons (dense vs paged-at-1x) enqueue everything
    # upfront; the oversubscribed ratios deliver half the workload as
    # mid-run arrivals two steps apart — high-priority arrivals landing
    # on a full pool are what exercises preemption
    if mid_run_arrivals:
        upfront = reqs[: len(reqs) // 2]
        arrivals = list(reqs[len(reqs) // 2:])
    else:
        upfront, arrivals = reqs, []

    def on_step(e):
        if arrivals and e.stats.steps % 2 == 0:
            prompt, gen, pri = arrivals.pop(0)
            e.submit(prompt, gen, priority=pri)

    t0 = time.time()
    for prompt, gen, pri in upfront:
        eng.submit(prompt, gen, priority=pri)
    stats = eng.run(on_step=on_step)
    while arrivals:                          # drained before steps ran out
        prompt, gen, pri = arrivals.pop(0)
        eng.submit(prompt, gen, priority=pri)
        stats = eng.run(on_step=on_step)
    wall = time.time() - t0
    assert stats.requests_done == len(reqs), "admission lost requests"
    pct = stats.percentiles()
    out = {
        "pool_pages": pool_pages,
        "wall_s": round(wall, 4),
        "tok_s": round(stats.tps(wall), 2),
        "steps": stats.steps,
        "p50_e2e_s": round(pct["e2e_p50"], 4),
        "p95_e2e_s": round(pct["e2e_p95"], 4),
        "p95_wait_s": round(pct["wait_p95"], 4),
        "preemptions": stats.preemptions,
        "admission_stalls": stats.admission_stalls,
    }
    if pool_pages:
        out["peak_pool_util"] = round(stats.peak_pool_util, 3)
        out["steady_pool_util"] = round(stats.steady_pool_util, 3)
    return out


def _prefix_workload(cfg, n_requests: int):
    """Shared-system-prompt traffic: one 20-token system prompt, a few
    distinct 4-token questions, each question asked more than once
    (retries / n>1 sampling).  All requests share one canvas layout, so
    repeats are FULL index hits and first-of-a-question requests
    partial-hit the system-prompt pages."""
    rng = np.random.default_rng(7)
    system = rng.integers(0, cfg.vocab_size - 1, 20).astype(np.int32)
    questions = [rng.integers(0, cfg.vocab_size - 1, 4).astype(np.int32)
                 for _ in range(max(2, n_requests // 2))]
    reqs = []
    for i in range(n_requests):
        q = questions[i % len(questions)]
        reqs.append((np.concatenate([system, q]), 6))
    return reqs


def _serve_prefix(cfg, params, reqs, prefix_cache: bool) -> dict:
    from repro.core.strategy import SPACache
    from repro.serving.engine import ServingEngine
    demand = sum(-(-min(len(p) + g, CANVAS) // PAGE) for p, g in reqs)
    eng = ServingEngine(
        cfg, params, max_batch=4, canvas_len=CANVAS,
        strategy=SPACache(rank=16, schedule="uniform", rho_peak=0.3),
        pool_pages=demand + 2 * (CANVAS // PAGE) + 1, page_size=PAGE,
        prefix_cache=prefix_cache)
    # one full UNTIMED pass first: it compiles every executable the
    # measured pass will use (lane step, cold prefill shapes, the
    # partial-prefill suffix function, COW/publication page copies) —
    # the timed pass then measures warm serving throughput.  The index
    # is reset in between so the measured hit pattern matches a fresh
    # engine rather than an all-full-hit replay.
    for prompt, gen in reqs:
        eng.submit(prompt, gen)
    eng.run()
    eng.done.clear()
    eng.stats = type(eng.stats)()
    eng.pool.reset_telemetry()
    if eng.prefix is not None:
        eng.drop_prefix_cache()
    t0 = time.time()
    for prompt, gen in reqs:
        eng.submit(prompt, gen)
    stats = eng.run()
    wall = time.time() - t0
    assert stats.requests_done == len(reqs)
    out = {
        "wall_s": round(wall, 4),
        "tok_s": round(stats.tps(wall), 2),
        "steps": stats.steps,
        "prefix_hits": stats.prefix_hits,
        "prefix_full_hits": stats.prefix_full_hits,
        "prefill_tokens_saved": stats.prefix_tokens_saved,
        "pages_published": stats.prefix_published,
    }
    return out


def run(quick: bool = False) -> dict:
    cfg, params = _build()
    n_requests = 6 if quick else 16
    reqs = _workload(cfg, n_requests)
    demand = sum(-(-min(len(p) + g, CANVAS) // PAGE) for p, g, _ in reqs)
    batch_pages = 4 * (CANVAS // PAGE)      # what max_batch rows can hold

    results = {"config": {
        "arch": cfg.name, "canvas": CANVAS, "page_size": PAGE,
        "max_batch": 4, "requests": n_requests,
        "aggregate_pages": demand,
    }}
    results["dense"] = _serve(cfg, params, reqs, 0)
    results["paged"] = {}
    for ratio in (1, 2, 3):
        cap = max(-(-demand // ratio), CANVAS // PAGE)  # >= 1 full row
        cap = min(cap, demand)
        if ratio == 1:
            cap = max(cap, batch_pages)     # 1x: the live batch fits
        results["paged"][f"{ratio}x"] = _serve(
            cfg, params, reqs, cap + 1, mid_run_arrivals=(ratio > 1))
    r1 = results["paged"]["1x"]["tok_s"] / max(
        results["dense"]["tok_s"], 1e-9)
    results["paged_over_dense_tok_s_at_1x"] = round(r1, 3)

    # Part 2: shared-prefix radix cache vs cold prefills (DESIGN.md §6)
    preqs = _prefix_workload(cfg, 8 if quick else 16)
    on = _serve_prefix(cfg, params, preqs, True)
    off = _serve_prefix(cfg, params, preqs, False)
    speed = on["tok_s"] / max(off["tok_s"], 1e-9)
    results["prefix"] = {
        "on": on, "off": off,
        "requests": len(preqs),
        "hit_rate": round(on["prefix_hits"] / len(preqs), 3),
        "full_hit_rate": round(on["prefix_full_hits"] / len(preqs), 3),
        "prefill_tokens_saved": on["prefill_tokens_saved"],
        "prefix_over_cold_tok_s": round(speed, 3),
    }

    out_path = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_serving.json")
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(json.dumps(results, indent=2))
    print(f"[BENCH_serving.json written; paged/dense throughput at 1x = "
          f"{r1:.2f}; prefix-cache speedup = {speed:.2f} at "
          f"{results['prefix']['hit_rate']:.0%} hit rate]")
    return results


if __name__ == "__main__":
    run(quick="--quick" in sys.argv)

"""Serving-runtime benchmark: paged cache pool vs dense slabs, and the
shared-prefix radix cache vs cold prefills.

Part 1 serves one mixed-``gen_len`` workload through the
``ServingEngine`` twice over: once with the legacy dense per-lane cache
slabs, then with the paged pool (DESIGN.md §5) at several
oversubscription ratios (aggregate page demand / pool capacity).  At 1x
the pool fits the whole workload — throughput should be within ~10% of
the dense slab (the paged step adds one page-gather + page-scatter per
step).  At 2-3x admission control + preemption carry the same workload
through a pool a fraction of the size.

Part 2 serves a shared-system-prompt workload (every request opens with
the same long system prompt; questions repeat, as retries/samples do)
with the prefix cache ON vs OFF (DESIGN.md §6): full hits skip the
prefill forward entirely, partial hits recompute only the unmatched
suffix, and the recorded hit rate / prefill-tokens-saved / speedup land
in ``BENCH_serving.json``:

    {"config": {...},
     "dense": {...}, "paged": {"1x": {...}, ...},
     "paged_over_dense_tok_s_at_1x": 0.97,
     "prefix": {"on": {...}, "off": {...},
                "hit_rate": 0.88, "full_hit_rate": 0.5,
                "prefill_tokens_saved": 264,
                "prefix_over_cold_tok_s": 1.6}}

Part 3 is the online serving benchmark (DESIGN.md §8): arrival-process
workloads — Poisson, bursty, and a closed-loop multi-turn chat trace —
served under per-request latency SLOs, with **goodput** (SLO-met
completions per unit time) as the headline metric.  Time is virtual: a
``StepClock`` advances one tick per engine step, so TTFT/TPOT/e2e and
goodput count engine steps and the numbers are machine-independent.
Each open-loop workload runs twice over the same arrivals: an *offline*
baseline (FIFO admission, no SLO policy — the old batch loop's
behaviour) and the *SLO-aware* front-end policy (urgency boost + EDF
ordering + hopeless-request shedding).  The bench asserts the headline
claim: at a load where the offline loop misses >=30% of TTFT deadlines,
the SLO-aware policy achieves strictly higher goodput while every
request completed by both runs decodes byte-identically.  A final
section pushes a short Poisson workload through the in-process
``AsyncFrontend`` (real engine thread + asyncio bridge, no sockets) so
CI exercises the full online stack.

Part 4 is the hierarchical-cache benchmark (DESIGN.md §9): a prefix
working set sized to >= 2x the device pool is served twice through the
same fixed-HBM engine, host tier OFF (evictions drop pages — the rigid
single-tier limit) vs ON (evictions demote to host RAM and warm
requests promote them back).  The headline is the measured-pass full
prefix hit rate vs host-tier capacity at fixed HBM; the bench asserts
host-on strictly beats host-off and lands the numbers in
``BENCH_serving.json``'s ``hier`` section.

Part 5 is the fault-storm benchmark (DESIGN.md §10): the Part-1
workload served once clean and once under a seeded chaos plan
(alloc failures, lane stalls, NaN poison, host-tier store refusals and
bit-flips) with the supervisor attached.  The bench asserts the
robustness headline — every request that completes under the storm is
byte-identical to its fault-free twin, aborts are bounded by the retry
budget, and both tiers drain to zero — and records goodput under chaos
relative to fault-free in ``BENCH_serving.json``'s ``faults`` section.

Part 6 is the telemetry benchmark (DESIGN.md §11): the Part-1 workload
served with full telemetry (lifecycle tracer + per-step cache-dynamics
sampling + metrics registry) vs none.  Telemetry is host-side only, so
the bench asserts completed outputs are byte-identical and gates the
measured throughput overhead at 10% (the DESIGN budget is 5%); the
telemetry-on registry snapshot is embedded in ``BENCH_serving.json``
and written with a Perfetto trace to ``BENCH_artifacts/`` for the CI
job to upload.

Wired into ``benchmarks/run.py --smoke`` (CI bench-smoke job), so the
seeded chaos storm replays on every CI run.
"""
from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

PAGE = 4
CANVAS = 32


def _build():
    from repro.configs import get_arch, reduced
    from repro.models import transformer
    cfg = reduced(get_arch("internlm2-1.8b"), n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=256)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _workload(cfg, n_requests: int):
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(n_requests):
        p_len = int(rng.integers(4, 10))
        gen = int(rng.integers(6, CANVAS - p_len + 1))
        prompt = rng.integers(0, cfg.vocab_size - 1, p_len).astype(np.int32)
        reqs.append((prompt, gen, int(rng.integers(0, 3))))  # priority 0-2
    return reqs


def _engine(cfg, params, pool_pages):
    from repro.core.strategy import SPACache
    from repro.serving.engine import ServingEngine
    return ServingEngine(
        cfg, params, max_batch=4, canvas_len=CANVAS,
        strategy=SPACache(rank=16, schedule="uniform", rho_peak=0.3),
        pool_pages=pool_pages, page_size=PAGE)


def _serve(cfg, params, reqs, pool_pages, mid_run_arrivals=False) -> dict:
    eng = _engine(cfg, params, pool_pages)
    # warm the lane executable at the MEASURED batch shape (dense lanes
    # size the canvas to the actual batch, so a 1-request warm-up would
    # leave the b=4 compile inside the timed region)
    for _ in range(4):
        eng.submit(reqs[0][0], reqs[0][1])
    eng.run()
    eng.done.clear()
    eng.stats = type(eng.stats)()
    if eng.pool is not None:        # drop the warm-up's util samples
        eng.pool.reset_telemetry()
    # overhead comparisons (dense vs paged-at-1x) enqueue everything
    # upfront; the oversubscribed ratios deliver half the workload as
    # mid-run arrivals two steps apart — high-priority arrivals landing
    # on a full pool are what exercises preemption
    if mid_run_arrivals:
        upfront = reqs[: len(reqs) // 2]
        arrivals = list(reqs[len(reqs) // 2:])
    else:
        upfront, arrivals = reqs, []

    def on_step(e):
        if arrivals and e.stats.steps % 2 == 0:
            prompt, gen, pri = arrivals.pop(0)
            e.submit(prompt, gen, priority=pri)

    t0 = time.time()
    for prompt, gen, pri in upfront:
        eng.submit(prompt, gen, priority=pri)
    stats = eng.run(on_step=on_step)
    while arrivals:                          # drained before steps ran out
        prompt, gen, pri = arrivals.pop(0)
        eng.submit(prompt, gen, priority=pri)
        stats = eng.run(on_step=on_step)
    wall = time.time() - t0
    assert stats.requests_done == len(reqs), "admission lost requests"
    pct = stats.percentiles()
    out = {
        "pool_pages": pool_pages,
        "wall_s": round(wall, 4),
        "tok_s": round(stats.tps(wall), 2),
        "steps": stats.steps,
        "p50_e2e_s": round(pct["e2e_p50"], 4),
        "p95_e2e_s": round(pct["e2e_p95"], 4),
        "p95_wait_s": round(pct["wait_p95"], 4),
        "preemptions": stats.preemptions,
        "admission_stalls": stats.admission_stalls,
    }
    if pool_pages:
        out["peak_pool_util"] = round(stats.peak_pool_util, 3)
        out["steady_pool_util"] = round(stats.steady_pool_util, 3)
    return out


def _prefix_workload(cfg, n_requests: int):
    """Shared-system-prompt traffic: one 20-token system prompt, a few
    distinct 4-token questions, each question asked more than once
    (retries / n>1 sampling).  All requests share one canvas layout, so
    repeats are FULL index hits and first-of-a-question requests
    partial-hit the system-prompt pages."""
    rng = np.random.default_rng(7)
    system = rng.integers(0, cfg.vocab_size - 1, 20).astype(np.int32)
    questions = [rng.integers(0, cfg.vocab_size - 1, 4).astype(np.int32)
                 for _ in range(max(2, n_requests // 2))]
    reqs = []
    for i in range(n_requests):
        q = questions[i % len(questions)]
        reqs.append((np.concatenate([system, q]), 6))
    return reqs


def _serve_prefix(cfg, params, reqs, prefix_cache: bool) -> dict:
    from repro.core.strategy import SPACache
    from repro.serving.engine import ServingEngine
    demand = sum(-(-min(len(p) + g, CANVAS) // PAGE) for p, g in reqs)
    eng = ServingEngine(
        cfg, params, max_batch=4, canvas_len=CANVAS,
        strategy=SPACache(rank=16, schedule="uniform", rho_peak=0.3),
        pool_pages=demand + 2 * (CANVAS // PAGE) + 1, page_size=PAGE,
        prefix_cache=prefix_cache)
    # one full UNTIMED pass first: it compiles every executable the
    # measured pass will use (lane step, cold prefill shapes, the
    # partial-prefill suffix function, COW/publication page copies) —
    # the timed pass then measures warm serving throughput.  The index
    # is reset in between so the measured hit pattern matches a fresh
    # engine rather than an all-full-hit replay.
    for prompt, gen in reqs:
        eng.submit(prompt, gen)
    eng.run()
    eng.done.clear()
    eng.stats = type(eng.stats)()
    eng.pool.reset_telemetry()
    if eng.prefix is not None:
        eng.drop_prefix_cache()
    t0 = time.time()
    for prompt, gen in reqs:
        eng.submit(prompt, gen)
    stats = eng.run()
    wall = time.time() - t0
    assert stats.requests_done == len(reqs)
    out = {
        "wall_s": round(wall, 4),
        "tok_s": round(stats.tps(wall), 2),
        "steps": stats.steps,
        "prefix_hits": stats.prefix_hits,
        "prefix_full_hits": stats.prefix_full_hits,
        "prefill_tokens_saved": stats.prefix_tokens_saved,
        "pages_published": stats.prefix_published,
    }
    return out


def _hier_workload(cfg, n_reqs):
    """Distinct MIXED-SIZE requests (prompt 4-11 tokens, gen 4-16), one
    prefix-cache entry each.  Size variance is load-bearing: uniform
    requests pack the pool perfectly — each admission exactly fits the
    pages a drained request freed, so neither eviction pressure
    (admission-time shortage) nor publication slack (free pages after
    the batch fill) ever materializes.  Mixed rows produce both,
    stochastically, the way real traffic does."""
    rng = np.random.default_rng(29)
    out = []
    for _ in range(n_reqs):
        p_len = int(rng.integers(4, 12))
        gen = int(rng.integers(4, 17))
        out.append((rng.integers(0, cfg.vocab_size - 1,
                                 p_len).astype(np.int32), gen))
    return out


def _serve_hier(cfg, params, reqs, host_pages,
                host_dtype="f32") -> dict:
    """Two passes of the full request set through a fixed-HBM engine
    (pool 15, far below the aggregate working set): an untimed
    warm/compile pass that also populates + pressure-evicts the index,
    then a measured pass whose full-hit rate is the §9 headline.
    Traffic arrives in BATCH-SIZED WAVES with a drain in between: the
    drain gives publications the slack they need (publish yields to
    admission under pressure), and the next wave's concurrent batch
    fill is what forces index eviction — a fully saturating queue
    starves publication instead and never grows the index."""
    from repro.core.strategy import SPACache
    from repro.serving.engine import ServingEngine

    def waves():
        stats = None
        for i in range(0, len(reqs), 2):
            for prompt, gen in reqs[i:i + 2]:
                eng.submit(prompt, gen)
            stats = eng.run()
        return stats

    eng = ServingEngine(
        cfg, params, max_batch=2, canvas_len=CANVAS,
        strategy=SPACache(rank=16, schedule="uniform", rho_peak=0.3),
        pool_pages=15, page_size=PAGE, prefix_cache=True,
        host_pages=host_pages, host_dtype=host_dtype)
    waves()                                 # warm pass
    eng.done.clear()
    eng.stats = type(eng.stats)()
    eng.pool.reset_telemetry()
    if eng.host_pool is not None:
        eng.host_pool.reset_telemetry()
    t0 = time.time()
    stats = waves()                         # measured pass
    wall = time.time() - t0
    assert stats.requests_done == len(reqs)
    out = {
        "host_pages": host_pages,
        "wall_s": round(wall, 4),
        "tok_s": round(stats.tps(wall), 2),
        "hits": stats.prefix_hits,
        "full_hits": stats.prefix_full_hits,
        "full_hit_rate": round(stats.prefix_full_hits / len(reqs), 3),
        "prefill_tokens_saved": stats.prefix_tokens_saved,
        "evicted_pages": stats.prefix_evicted_pages,
        "demoted_pages": stats.prefix_demoted_pages,
        "dropped_pages": stats.prefix_dropped_pages,
    }
    if host_pages:
        out.update({
            "host_dtype": host_dtype,
            "promoted_pages": stats.prefix_promoted_pages,
            "promotions": stats.prefix_promotions,
            "promotion_stalls": stats.promotion_stalls,
            "peak_host_util": round(stats.peak_host_util, 3),
        })
    return out


def _online_classes(cfg, rng):
    """Two request classes (DESIGN.md §8): *interactive* — short gen,
    tight TTFT target; *batch* — long gen, loose e2e-only deadline.
    SLO targets are in virtual seconds (= engine steps)."""
    from repro.serving.slo import SLO
    interactive = dict(p_len=6, gen=6, slo=SLO(ttft=8.0, deadline=60.0))
    batch = dict(p_len=8, gen=16, slo=SLO(deadline=400.0))
    def draw(i):
        cls = interactive if rng.random() < 0.6 else batch
        prompt = rng.integers(0, cfg.vocab_size - 1,
                              cls["p_len"]).astype(np.int32)
        return (i, prompt, cls["gen"], cls["slo"])
    return draw


def _poisson_arrivals(cfg, n, rate, seed=11):
    """Open-loop Poisson process: exponential inter-arrival gaps at
    ``rate`` requests per virtual second (engine step)."""
    rng = np.random.default_rng(seed)
    draw = _online_classes(cfg, rng)
    t, out = 0.0, []
    for i in range(n):
        t += float(rng.exponential(1.0 / rate))
        out.append((t,) + draw(i))
    return out


def _bursty_arrivals(cfg, n, burst, gap, seed=13):
    """Bursty arrivals: ``burst`` requests land together every ``gap``
    virtual seconds (think: a page load fanning out, or synchronized
    retries)."""
    rng = np.random.default_rng(seed)
    draw = _online_classes(cfg, rng)
    out = []
    for i in range(n):
        out.append((float((i // burst) * gap),) + draw(i))
    return out


def _online_engine(cfg, params, slo_aware, clock):
    from repro.core.strategy import SPACache
    from repro.serving.engine import ServingEngine
    from repro.serving.slo import SLOPolicy
    # pool sized to the live batch: an overloaded arrival process must
    # queue, which is exactly what separates FIFO from SLO-aware
    # admission.  refresh_interval=1 keeps preemption/resume
    # byte-identical (DESIGN.md §5), so both runs decode the same
    # tokens per request no matter how scheduling interleaves them.
    return ServingEngine(
        cfg, params, max_batch=4, canvas_len=CANVAS,
        strategy=SPACache(rank=16, schedule="uniform", rho_peak=0.3,
                          refresh_interval=1),
        pool_pages=4 * (CANVAS // PAGE) + 2, page_size=PAGE,
        prefix_cache=True,
        slo_policy=(SLOPolicy(boost=2, urgency_frac=0.6)
                    if slo_aware else None),
        clock=clock)


def _serve_online(cfg, params, arrivals, slo_aware) -> dict:
    """Serve one arrival trace to completion; time is virtual (one
    clock tick per engine step, idle gaps jump to the next arrival)."""
    from repro.serving.slo import StepClock
    clock = StepClock(tick=1.0)
    eng = _online_engine(cfg, params, slo_aware, clock)
    # untimed warm-up: compile the lane step + both prefill shapes
    for _, _, prompt, gen, _ in arrivals[:2] + arrivals[-2:]:
        eng.submit(prompt, gen)
    eng.run()
    eng.done.clear()
    eng.stats = type(eng.stats)()
    eng.pool.reset_telemetry()
    clock.t = 0.0

    pending = sorted(arrivals, key=lambda a: (a[0], a[1]))
    uid_to_idx = {}

    def feed(e):
        while pending and pending[0][0] <= clock.t + 1e-9:
            _, idx, prompt, gen, slo = pending.pop(0)
            uid_to_idx[e.submit(prompt, gen, slo=slo)] = idx

    def on_step(e):
        clock.advance()
        feed(e)

    t0 = time.time()
    feed(eng)
    while True:
        stats = eng.run(max_steps=100_000, on_step=on_step)
        if not pending:
            break
        clock.t = max(clock.t, pending[0][0])   # idle to next arrival
        feed(eng)
    wall = time.time() - t0

    outputs, ttft_n, ttft_miss = {}, 0, 0
    import math
    for r in eng.done:
        if r.output is not None and not (r.shed or r.canceled):
            outputs[uid_to_idx[r.uid]] = r.output.tobytes()
        if r.slo is not None and math.isfinite(r.slo.ttft):
            ttft_n += 1
            late = (r.first_token_at is None
                    or r.first_token_at - r.submitted_at > r.slo.ttft)
            ttft_miss += int(late)
    pct = stats.percentiles()
    return {
        "metrics": {
            "requests": len(arrivals),
            "completed": len(outputs),
            "shed": stats.requests_shed,
            "virtual_s": round(clock.t, 1),
            "wall_s": round(wall, 4),
            "steps": stats.steps,
            "slo_met": stats.slo_met,
            "slo_missed": stats.slo_missed,
            "goodput_per_s": round(stats.goodput(clock.t), 4),
            "ttft_deadline_miss_rate": round(ttft_miss / max(ttft_n, 1),
                                             3),
            "ttft_p50_s": round(pct["ttft_p50"], 2),
            "ttft_p95_s": round(pct["ttft_p95"], 2),
            "tpot_p50_s": round(pct["tpot_p50"], 2),
            "tpot_p95_s": round(pct["tpot_p95"], 2),
            "preemptions": stats.preemptions,
        },
        "outputs": outputs,
    }


def _serve_chat(cfg, params, n_clients, turns) -> dict:
    """Closed-loop multi-turn chat: each client fires turn k+1 a fixed
    think time after turn k completes, with the conversation so far
    (previous prompt + generated tokens + a fresh user message)
    prepended.  Per-turn interactive SLOs; SLO-aware policy on."""
    from repro.serving.slo import SLO, StepClock
    clock = StepClock(tick=1.0)
    eng = _online_engine(cfg, params, True, clock)
    rng = np.random.default_rng(17)
    slo = SLO(ttft=10.0, deadline=80.0)
    gen, think = 5, 4.0
    first = {c: rng.integers(0, cfg.vocab_size - 1, 5).astype(np.int32)
             for c in range(n_clients)}
    eng.submit(first[0], gen)               # untimed compile warm-up
    eng.run()
    eng.done.clear()
    eng.stats = type(eng.stats)()
    eng.pool.reset_telemetry()
    clock.t = 0.0

    pending = [(float(c), c, first[c]) for c in range(n_clients)]
    uid_client, turn_of, harvested = {}, {c: 1 for c in range(n_clients)}, 0

    def feed(e):
        while pending and pending[0][0] <= clock.t + 1e-9:
            _, c, prompt = pending.pop(0)
            uid_client[e.submit(prompt, gen, slo=slo)] = (c, prompt)

    def harvest_turns():
        # closed loop: a finished turn schedules the client's next one
        nonlocal harvested
        while harvested < len(eng.done):
            r = eng.done[harvested]
            harvested += 1
            if r.uid not in uid_client or r.output is None:
                continue
            c, prompt = uid_client[r.uid]
            if turn_of[c] >= turns:
                continue
            turn_of[c] += 1
            user = rng.integers(0, cfg.vocab_size - 1, 2).astype(np.int32)
            nxt = np.concatenate([prompt, r.output, user]).astype(np.int32)
            if len(nxt) + gen <= CANVAS:
                pending.append((clock.t + think, c, nxt))
                pending.sort(key=lambda a: a[0])

    def on_step(e):
        clock.advance()
        harvest_turns()
        feed(e)

    feed(eng)
    while True:
        stats = eng.run(max_steps=100_000, on_step=on_step)
        # requests finishing on the last step are harvested after run()
        harvest_turns()
        if not pending:
            break
        clock.t = max(clock.t, pending[0][0])
        feed(eng)
    pct = stats.percentiles()
    return {
        "clients": n_clients, "turns_per_client": turns,
        "turns_served": stats.requests_done,
        "virtual_s": round(clock.t, 1),
        "slo_met": stats.slo_met,
        "goodput_per_s": round(stats.goodput(clock.t), 4),
        "ttft_p95_s": round(pct["ttft_p95"], 2),
        "prefix_hits": stats.prefix_hits,
    }


def _frontend_smoke(cfg, params, n_requests) -> dict:
    """Push a short Poisson workload through the in-process
    ``AsyncFrontend`` — real engine thread + asyncio event bridge, no
    sockets — so the bench-smoke CI job exercises the online stack
    end to end (ISSUE satellite: CI/tooling)."""
    import asyncio
    from repro.serving.frontend import AsyncFrontend
    from repro.serving.slo import SLO, SLOPolicy
    from repro.serving.engine import ServingEngine
    from repro.core.strategy import SPACache
    eng = ServingEngine(
        cfg, params, max_batch=4, canvas_len=CANVAS,
        strategy=SPACache(rank=16, schedule="uniform", rho_peak=0.3,
                          refresh_interval=1),
        pool_pages=4 * (CANVAS // PAGE) + 2, page_size=PAGE,
        slo_policy=SLOPolicy(boost=2, urgency_frac=0.6))
    rng = np.random.default_rng(23)

    async def client(front, i):
        await asyncio.sleep(float(rng.exponential(0.05)))
        prompt = rng.integers(0, cfg.vocab_size - 1, 6).astype(np.int32)
        toks, terminal = [], None
        async for ev in front.generate(prompt, 6,
                                       slo=SLO(ttft=30.0, deadline=120.0)):
            if ev.kind == "token":
                toks.extend(ev.tokens)
            else:
                terminal = ev.kind
        return terminal, len(toks)

    async def main():
        front = AsyncFrontend(eng, max_steps=4096)
        async with front:
            results = await asyncio.gather(
                *(client(front, i) for i in range(n_requests)))
        return results

    t0 = time.time()
    results = asyncio.run(main())
    wall = time.time() - t0
    done = sum(1 for kind, _ in results if kind == "done")
    tokens = sum(n for kind, n in results if kind == "done")
    assert done + eng.stats.requests_shed >= n_requests
    for kind, n in results:
        assert kind != "done" or n == 6, "stream lost tokens"
    return {
        "requests": n_requests, "completed": done,
        "shed": eng.stats.requests_shed,
        "streamed_tokens": tokens,
        "wall_s": round(wall, 3),
        "slo_met": eng.stats.slo_met,
    }


def _serve_chaos(cfg, params, reqs, plan) -> dict:
    """Serve ``reqs`` with the §10 supervisor attached — optionally
    under a seeded chaos ``plan`` — and report completion/containment
    counters plus a machine-independent steps-based goodput.

    refresh_interval=1 makes outputs a pure function of the canvas, so
    chaos-driven preemption/quarantine/fallback reordering never shifts
    survivor bits — the byte-parity assertion is exact, not luck."""
    from repro.core.strategy import SPACache
    from repro.serving.engine import ServingEngine
    eng = ServingEngine(
        cfg, params, max_batch=4, canvas_len=CANVAS,
        strategy=SPACache(rank=16, schedule="uniform", rho_peak=0.3,
                          refresh_interval=1),
        pool_pages=4 * (CANVAS // PAGE) + 9, page_size=PAGE,
        prefix_cache=True, host_pages=16, host_dtype="f32",
        fault_plan=plan, supervise=True)
    t0 = time.time()
    uids = [eng.submit(p, g, priority=pri) for p, g, pri in reqs]
    stats = eng.run()
    wall = time.time() - t0
    by_uid = {r.uid: r for r in eng.done}
    outputs = {i: np.asarray(by_uid[u].output).tobytes()
               for i, u in enumerate(uids)
               if by_uid[u].output is not None}
    # aborted work drained: the only held pages belong to the index,
    # and the host tier is in lockstep with the trie's refs
    assert eng.pool.used == eng.prefix.held_pages
    assert eng.host_pool.used_pages == eng.prefix.host_held_pages
    eng.drop_prefix_cache()
    assert eng.pool.used == 0 and eng.host_pool.used_pages == 0
    return {
        "outputs": outputs,
        "wall_s": round(wall, 4),
        "steps": stats.steps,
        "done": stats.requests_done,
        "faulted": stats.requests_faulted,
        "faults_injected": stats.faults_injected,
        "alloc_faults": stats.alloc_faults,
        "nan_quarantines": stats.nan_quarantines,
        "watchdog_fires": stats.watchdog_fires,
        "checksum_failures": stats.host_checksum_failures,
        "cold_prefill_fallbacks": stats.cold_prefill_fallbacks,
        "max_degrade_level": max(
            [lvl for _, lvl in stats.degradation_events], default=0),
        "tok_s": round(stats.tps(wall), 2),
        "done_per_step": round(stats.requests_done
                               / max(stats.steps, 1), 4),
    }


def _serve_telemetry(cfg, params, reqs, telemetry, profiler=None) -> dict:
    """Part 6 (DESIGN.md §11): the Part-1 mid-run-arrival workload
    through an oversubscribed pool + host tier, with telemetry
    optionally attached.  The engine config is identical either way, so
    the completed outputs must be byte-identical — telemetry is
    host-side only and never perturbs the compiled step.  An untimed
    pass compiles every executable (and seeds the prefix index, so the
    measured pass exercises hits/demotions/promotions); the timed
    throughput is best-of-2 to keep the overhead ratio low-noise."""
    from repro.core.strategy import SPACache
    from repro.serving.engine import ServingEngine
    demand = sum(-(-min(len(p) + g, CANVAS) // PAGE) for p, g, _ in reqs)
    eng = ServingEngine(
        cfg, params, max_batch=4, canvas_len=CANVAS,
        strategy=SPACache(rank=16, schedule="uniform", rho_peak=0.3,
                          refresh_interval=1),
        pool_pages=max(demand // 2, 4 * (CANVAS // PAGE)) + 1,
        page_size=PAGE, prefix_cache=True, host_pages=16,
        host_dtype="f32", telemetry=telemetry, profiler=profiler)

    def serve_once():
        upfront = reqs[: len(reqs) // 2]
        arrivals = list(reqs[len(reqs) // 2:])

        def on_step(e):
            if arrivals and e.stats.steps % 2 == 0:
                prompt, gen, pri = arrivals.pop(0)
                e.submit(prompt, gen, priority=pri)

        uid_of = {}
        for i, (prompt, gen, pri) in enumerate(upfront):
            uid_of[eng.submit(prompt, gen, priority=pri)] = i
        stats = eng.run(on_step=on_step)
        while arrivals:
            prompt, gen, pri = arrivals.pop(0)
            eng.submit(prompt, gen, priority=pri)
            stats = eng.run(on_step=on_step)
        return stats

    serve_once()                            # untimed compile/warm pass
    best_wall, stats = float("inf"), None
    for _ in range(2):
        eng.done.clear()
        eng.stats = type(eng.stats)()
        eng.pool.reset_telemetry()
        t0 = time.time()
        stats = serve_once()
        best_wall = min(best_wall, time.time() - t0)
    assert stats.requests_done == len(reqs)
    outputs = {}
    for i, r in enumerate(sorted(eng.done, key=lambda r: r.uid)):
        if r.output is not None:
            outputs[i] = np.asarray(r.output).tobytes()
    return {
        "eng": eng,
        "outputs": outputs,
        "wall_s": round(best_wall, 4),
        "tok_s": round(stats.tps(best_wall), 2),
        "steps": stats.steps,
        "preemptions": stats.preemptions,
        "promotions": stats.prefix_promotions,
    }


def _budget_util_table(cfg, params, reqs) -> dict:
    """Per-layer refresh-budget utilization (mean fraction of the layer
    budget k_l actually rewritten per step) for two cache strategies,
    sampled by the engine's cache-dynamics hook (DESIGN.md §11) — the
    EXPERIMENTS.md telemetry table.  k(l) rounds up to a multiple of 16
    (budget.k_schedule), so at CANVAS=32 the rhos are chosen to straddle
    that boundary — otherwise every strategy flattens to k=[16, 16] and
    the table degenerates."""
    import re
    from repro.core.strategy import SPACache, ValueProxyCache
    from repro.serving.engine import ServingEngine
    from repro.serving.telemetry import Telemetry
    table = {}
    for name, strategy in (
            ("singular", SPACache(rank=16, schedule="adaptive",
                                  rho_peak=0.6, rho_first=0.03,
                                  rho_last=0.55)),
            ("value", ValueProxyCache(rho=0.6))):
        tel = Telemetry(dynamics_every=1)   # registry + dynamics sampling
        eng = ServingEngine(cfg, params, max_batch=4, canvas_len=CANVAS,
                            strategy=strategy, telemetry=tel)
        for prompt, gen, _ in reqs:
            eng.submit(prompt, gen)
        eng.run()
        layers = {}
        for key, v in tel.registry.snapshot().items():
            if not key.startswith("spa_cache_budget_utilization_ratio"):
                continue
            lay = re.search(r'layer="(\d+)"', key).group(1)
            layers[f"layer_{lay}"] = round(v["mean"], 4)
        assert layers, f"{name}: no budget-utilization samples recorded"
        table[name] = layers
    return table


def _drop_executables(part: str = "") -> None:
    """Drop jitted executables between parts.  Accumulated lane/prefill
    executables across six parts deterministically crash XLA's CPU JIT
    late in a full run (LLVM "Cannot allocate memory" then a segfault in
    libgcc) — the same failure tests/conftest.py clears at module
    boundaries.  Each part re-warms its own executables untimed.
    Delegates to the one shared dropper (repro.core.runtime), which
    also reports the live-executable count it cleared."""
    from repro.core import runtime
    runtime.drop_executables(f"bench_serving: {part}" if part else "")


def run(quick: bool = False) -> dict:
    cfg, params = _build()
    n_requests = 6 if quick else 16
    reqs = _workload(cfg, n_requests)
    demand = sum(-(-min(len(p) + g, CANVAS) // PAGE) for p, g, _ in reqs)
    batch_pages = 4 * (CANVAS // PAGE)      # what max_batch rows can hold

    results = {"config": {
        "arch": cfg.name, "canvas": CANVAS, "page_size": PAGE,
        "max_batch": 4, "requests": n_requests,
        "aggregate_pages": demand,
    }}
    results["dense"] = _serve(cfg, params, reqs, 0)
    results["paged"] = {}
    for ratio in (1, 2, 3):
        cap = max(-(-demand // ratio), CANVAS // PAGE)  # >= 1 full row
        cap = min(cap, demand)
        if ratio == 1:
            cap = max(cap, batch_pages)     # 1x: the live batch fits
        results["paged"][f"{ratio}x"] = _serve(
            cfg, params, reqs, cap + 1, mid_run_arrivals=(ratio > 1))
    r1 = results["paged"]["1x"]["tok_s"] / max(
        results["dense"]["tok_s"], 1e-9)
    results["paged_over_dense_tok_s_at_1x"] = round(r1, 3)

    # Part 2: shared-prefix radix cache vs cold prefills (DESIGN.md §6)
    _drop_executables('part 2: prefix cache')
    preqs = _prefix_workload(cfg, 8 if quick else 16)
    on = _serve_prefix(cfg, params, preqs, True)
    off = _serve_prefix(cfg, params, preqs, False)
    speed = on["tok_s"] / max(off["tok_s"], 1e-9)
    results["prefix"] = {
        "on": on, "off": off,
        "requests": len(preqs),
        "hit_rate": round(on["prefix_hits"] / len(preqs), 3),
        "full_hit_rate": round(on["prefix_full_hits"] / len(preqs), 3),
        "prefill_tokens_saved": on["prefill_tokens_saved"],
        "prefix_over_cold_tok_s": round(speed, 3),
    }

    # Part 3: online serving under SLOs (DESIGN.md §8) — goodput is
    # the headline.  Same arrivals served twice: offline FIFO baseline
    # vs SLO-aware (boost + EDF + shed); completed outputs must match
    # byte-for-byte (same strategy/scheduler/backend, row-independent
    # decode + byte-identical preemption resume).
    _drop_executables('part 3: online SLO')
    n_online = 12 if quick else 24
    results["online"] = {
        "slo_policy": {"boost": 2, "urgency_frac": 0.6, "shed": True},
        "classes": {
            "interactive": {"gen": 6, "ttft_s": 8.0, "deadline_s": 60.0,
                            "share": 0.6},
            "batch": {"gen": 16, "deadline_s": 400.0, "share": 0.4},
        },
    }
    for name, arrivals in (
            ("poisson", _poisson_arrivals(cfg, n_online, rate=0.5)),
            ("bursty", _bursty_arrivals(cfg, n_online, burst=12,
                                        gap=12.0))):
        off = _serve_online(cfg, params, arrivals, slo_aware=False)
        slo = _serve_online(cfg, params, arrivals, slo_aware=True)
        common = sorted(set(off["outputs"]) & set(slo["outputs"]))
        byte_ok = all(off["outputs"][i] == slo["outputs"][i]
                      for i in common)
        assert byte_ok, f"{name}: completed outputs diverged"
        m_off, m_slo = off["metrics"], slo["metrics"]
        assert m_off["ttft_deadline_miss_rate"] >= 0.30, \
            f"{name}: offline baseline not saturated " \
            f"({m_off['ttft_deadline_miss_rate']:.0%} TTFT misses)"
        assert m_slo["goodput_per_s"] > m_off["goodput_per_s"], \
            f"{name}: SLO-aware goodput not strictly higher"
        results["online"][name] = {
            "offline": m_off, "slo_aware": m_slo,
            "common_completed": len(common),
            "byte_identical_completed": byte_ok,
            "goodput_gain": round(m_slo["goodput_per_s"]
                                  / max(m_off["goodput_per_s"], 1e-9),
                                  3),
        }
    # Part 4: hierarchical cache — prefix hit rate vs host-tier
    # capacity at fixed HBM (DESIGN.md §9).  The aggregate prefix
    # working set is >= 2x the device pool, so single-tier eviction has
    # to drop most of it; the host tier keeps the overflow promotable.
    _drop_executables('part 4: host tier')
    hreqs = _hier_workload(cfg, 8)
    total_pages = sum(-(-(len(p) + g) // PAGE) for p, g in hreqs)
    tiers = [("host_off", 0), ("host_on", total_pages)]
    if not quick:
        tiers.insert(1, ("host_half", total_pages // 2))
    results["hier"] = {"config": {
        "pool_pages": 15, "requests": len(hreqs),
        "prefix_set_pages": total_pages, "host_dtype": "f32",
    }}
    for label, hp in tiers:
        results["hier"][label] = _serve_hier(cfg, params, hreqs, hp)
    h_on = results["hier"]["host_on"]
    h_off = results["hier"]["host_off"]
    assert h_on["full_hit_rate"] > h_off["full_hit_rate"], \
        "host tier must strictly raise the full-hit rate at fixed HBM"
    assert h_on["promoted_pages"] > 0, "host-on run never promoted"
    results["hier"]["full_hit_rate_gain"] = round(
        h_on["full_hit_rate"] - h_off["full_hit_rate"], 3)

    # Part 5: fault storm (DESIGN.md §10) — same workload, clean vs a
    # seeded chaos plan with the supervisor attached.  Survivors must
    # be byte-identical to their fault-free twins; the seed makes the
    # storm replay exactly on every CI run.
    from repro.serving.faults import FaultPlan
    _drop_executables('part 5: fault storm')
    creqs = _workload(cfg, 6 if quick else 12)
    storm_plan = FaultPlan(seed=7, rates={
        "pool_alloc": 0.03, "lane_stall": 0.02, "step_nan": 0.02,
        "host_store": 0.3, "host_corrupt": 0.3})
    clean = _serve_chaos(cfg, params, creqs, None)
    storm = _serve_chaos(cfg, params, creqs, storm_plan)
    assert storm["done"] + storm["faulted"] == len(creqs), \
        "chaos run lost requests"
    assert storm["faults_injected"] > 0, "the storm never hit"
    common = sorted(set(clean["outputs"]) & set(storm["outputs"]))
    assert all(clean["outputs"][i] == storm["outputs"][i]
               for i in common), "chaos survivors diverged"
    results["faults"] = {
        "plan": {"seed": 7, "rates": dict(storm_plan.rates)},
        "clean": {k: v for k, v in clean.items() if k != "outputs"},
        "storm": {k: v for k, v in storm.items() if k != "outputs"},
        "survivors_byte_identical": True,
        "survivor_count": len(common),
        "goodput_vs_clean": round(
            storm["done_per_step"] / max(clean["done_per_step"], 1e-9),
            3),
    }

    _drop_executables('part 3b: chat + frontend')
    results["online"]["chat"] = _serve_chat(
        cfg, params, n_clients=3 if quick else 4, turns=3)
    results["online"]["frontend_smoke"] = _frontend_smoke(
        cfg, params, 4 if quick else 8)

    # Part 6: telemetry overhead + parity (DESIGN.md §11/§12) — the
    # same workload with full telemetry (tracer + cache-dynamics
    # sampling + registry) AND the step profiler vs none.  Outputs must
    # be byte-identical (telemetry/profiling are host-side only); the
    # CI gate fails a >10% throughput regression, so the overhead
    # budget now covers profiling-on too.
    from repro.core import runtime
    from repro.serving.profiling import StepProfiler
    from repro.serving.telemetry import Telemetry
    _drop_executables('part 6: telemetry')
    tracker = runtime.compile_tracker()
    tracker.reset()     # scope the retrace-budget gate to this part
    treqs = _workload(cfg, 6 if quick else 12)
    t_off = _serve_telemetry(cfg, params, treqs, None)
    tel_on = Telemetry.enabled(dynamics_every=1)
    t_on = _serve_telemetry(cfg, params, treqs, tel_on,
                            profiler=StepProfiler(tel_on))
    assert set(t_on["outputs"]) == set(t_off["outputs"]), \
        "telemetry changed which requests completed"
    assert all(t_on["outputs"][i] == t_off["outputs"][i]
               for i in t_on["outputs"]), \
        "telemetry-on outputs diverged from telemetry-off"
    t_ratio = t_on["tok_s"] / max(t_off["tok_s"], 1e-9)
    assert t_ratio >= 0.90, \
        f"telemetry overhead gate: {1 - t_ratio:.1%} regression > 10%"
    eng_on = t_on.pop("eng")
    t_off.pop("eng")
    results["telemetry"] = {
        "off": t_off, "on": t_on,
        "on_over_off_tok_s": round(t_ratio, 3),
        "overhead_frac": round(max(0.0, 1.0 - t_ratio), 3),
        "outputs_byte_identical": True,
        "budget_utilization": _budget_util_table(
            cfg, params, treqs[: 4 if quick else 6]),
        "registry_snapshot": eng_on.telemetry.registry.snapshot(),
    }
    for d in (t_off, t_on):
        d.pop("outputs")

    # Retrace-budget gate (DESIGN.md §12): Part 6 traces each jitted
    # entry point a fixed number of times — one trace per distinct lane
    # shape, independent of request count.  A PR that introduces
    # per-shape (or per-step) retraces blows the recorded budget and
    # fails here before it ever ships a 10x compile regression.
    compile_snapshot = tracker.snapshot()
    budget_path = os.path.join(os.path.dirname(__file__),
                               "retrace_budget.json")
    with open(budget_path) as f:
        budgets = json.load(f)["quick" if quick else "full"]
    for fn_name, budget in budgets.items():
        n = compile_snapshot["traces"].get(fn_name, 0)
        assert n <= budget, \
            f"retrace budget gate: {fn_name} traced {n}x > " \
            f"budget {budget} (see benchmarks/retrace_budget.json)"
    results["telemetry"]["compile"] = compile_snapshot
    results["telemetry"]["retrace_budget_ok"] = True

    art_dir = os.path.join(os.path.dirname(__file__), "..",
                           "BENCH_artifacts")
    os.makedirs(art_dir, exist_ok=True)
    with open(os.path.join(art_dir, "metrics_snapshot.json"), "w") as f:
        json.dump({"registry": results["telemetry"]["registry_snapshot"],
                   "compile": compile_snapshot}, f, indent=2)
    eng_on.export_trace(os.path.join(art_dir, "trace.json"))

    out_path = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_serving.json")
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(json.dumps(results, indent=2))
    gp = results["online"]["poisson"]["goodput_gain"]
    gb = results["online"]["bursty"]["goodput_gain"]
    print(f"[BENCH_serving.json written; paged/dense throughput at 1x = "
          f"{r1:.2f}; prefix-cache speedup = {speed:.2f} at "
          f"{results['prefix']['hit_rate']:.0%} hit rate; "
          f"SLO goodput gain = {gp:.2f}x (poisson) / {gb:.2f}x (bursty); "
          f"hier full-hit rate {h_off['full_hit_rate']:.0%} -> "
          f"{h_on['full_hit_rate']:.0%} with the host tier; "
          f"chaos goodput = "
          f"{results['faults']['goodput_vs_clean']:.2f}x of clean at "
          f"{storm['faults_injected']} injected faults, "
          f"{storm['faulted']} aborted; telemetry overhead = "
          f"{results['telemetry']['overhead_frac']:.1%}]")
    return results


if __name__ == "__main__":
    run(quick="--quick" in sys.argv)

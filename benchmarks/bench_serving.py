"""Serving-runtime benchmark: paged cache pool vs dense slabs.

Serves one mixed-``gen_len`` workload through the ``ServingEngine``
twice over: once with the legacy dense per-lane cache slabs, then with
the paged pool (DESIGN.md §5) at several oversubscription ratios
(aggregate page demand / pool capacity).  At 1x the pool fits the whole
workload — throughput should be within ~10% of the dense slab (the paged
step adds one page-gather + page-scatter per step).  At 2-3x admission
control + preemption carry the same workload through a pool a fraction
of the size.

Emits ``BENCH_serving.json`` next to the repo root:

    {"config": {...},
     "dense": {"tok_s": ..., "p95_e2e_s": ..., ...},
     "paged": {"1x": {...}, "2x": {...}, "3x": {...}},
     "paged_over_dense_tok_s_at_1x": 0.97}

Wired into ``benchmarks/run.py --smoke`` (CI bench-smoke job).
"""
from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

PAGE = 4
CANVAS = 32


def _build():
    from repro.configs import get_arch, reduced
    from repro.models import transformer
    cfg = reduced(get_arch("internlm2-1.8b"), n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=256)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _workload(cfg, n_requests: int):
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(n_requests):
        p_len = int(rng.integers(4, 10))
        gen = int(rng.integers(6, CANVAS - p_len + 1))
        prompt = rng.integers(0, cfg.vocab_size - 1, p_len).astype(np.int32)
        reqs.append((prompt, gen, int(rng.integers(0, 3))))  # priority 0-2
    return reqs


def _engine(cfg, params, pool_pages):
    from repro.core.strategy import SPACache
    from repro.serving.engine import ServingEngine
    return ServingEngine(
        cfg, params, max_batch=4, canvas_len=CANVAS,
        strategy=SPACache(rank=16, schedule="uniform", rho_peak=0.3),
        pool_pages=pool_pages, page_size=PAGE)


def _serve(cfg, params, reqs, pool_pages, mid_run_arrivals=False) -> dict:
    eng = _engine(cfg, params, pool_pages)
    # warm the lane executable at the MEASURED batch shape (dense lanes
    # size the canvas to the actual batch, so a 1-request warm-up would
    # leave the b=4 compile inside the timed region)
    for _ in range(4):
        eng.submit(reqs[0][0], reqs[0][1])
    eng.run()
    eng.done.clear()
    eng.stats = type(eng.stats)()
    if eng.pool is not None:        # drop the warm-up's util samples
        eng.pool.reset_telemetry()
    # overhead comparisons (dense vs paged-at-1x) enqueue everything
    # upfront; the oversubscribed ratios deliver half the workload as
    # mid-run arrivals two steps apart — high-priority arrivals landing
    # on a full pool are what exercises preemption
    if mid_run_arrivals:
        upfront = reqs[: len(reqs) // 2]
        arrivals = list(reqs[len(reqs) // 2:])
    else:
        upfront, arrivals = reqs, []

    def on_step(e):
        if arrivals and e.stats.steps % 2 == 0:
            prompt, gen, pri = arrivals.pop(0)
            e.submit(prompt, gen, priority=pri)

    t0 = time.time()
    for prompt, gen, pri in upfront:
        eng.submit(prompt, gen, priority=pri)
    stats = eng.run(on_step=on_step)
    while arrivals:                          # drained before steps ran out
        prompt, gen, pri = arrivals.pop(0)
        eng.submit(prompt, gen, priority=pri)
        stats = eng.run(on_step=on_step)
    wall = time.time() - t0
    assert stats.requests_done == len(reqs), "admission lost requests"
    pct = stats.percentiles()
    out = {
        "pool_pages": pool_pages,
        "wall_s": round(wall, 4),
        "tok_s": round(stats.tps(wall), 2),
        "steps": stats.steps,
        "p50_e2e_s": round(pct["e2e_p50"], 4),
        "p95_e2e_s": round(pct["e2e_p95"], 4),
        "p95_wait_s": round(pct["wait_p95"], 4),
        "preemptions": stats.preemptions,
        "admission_stalls": stats.admission_stalls,
    }
    if pool_pages:
        out["peak_pool_util"] = round(stats.peak_pool_util, 3)
        out["steady_pool_util"] = round(stats.steady_pool_util, 3)
    return out


def run(quick: bool = False) -> dict:
    cfg, params = _build()
    n_requests = 6 if quick else 16
    reqs = _workload(cfg, n_requests)
    demand = sum(-(-min(len(p) + g, CANVAS) // PAGE) for p, g, _ in reqs)
    batch_pages = 4 * (CANVAS // PAGE)      # what max_batch rows can hold

    results = {"config": {
        "arch": cfg.name, "canvas": CANVAS, "page_size": PAGE,
        "max_batch": 4, "requests": n_requests,
        "aggregate_pages": demand,
    }}
    results["dense"] = _serve(cfg, params, reqs, 0)
    results["paged"] = {}
    for ratio in (1, 2, 3):
        cap = max(-(-demand // ratio), CANVAS // PAGE)  # >= 1 full row
        cap = min(cap, demand)
        if ratio == 1:
            cap = max(cap, batch_pages)     # 1x: the live batch fits
        results["paged"][f"{ratio}x"] = _serve(
            cfg, params, reqs, cap + 1, mid_run_arrivals=(ratio > 1))
    r1 = results["paged"]["1x"]["tok_s"] / max(
        results["dense"]["tok_s"], 1e-9)
    results["paged_over_dense_tok_s_at_1x"] = round(r1, 3)

    out_path = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_serving.json")
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(json.dumps(results, indent=2))
    print(f"[BENCH_serving.json written; paged/dense throughput at 1x = "
          f"{r1:.2f}]")
    return results


if __name__ == "__main__":
    run(quick="--quick" in sys.argv)

"""§Roofline: aggregate the dry-run records into the per-(arch x shape)
roofline table — three terms in seconds, dominant bottleneck, MODEL_FLOPS
ratio, and a one-line lever suggestion. Reads results/dryrun.jsonl."""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List

from repro.configs import get_arch, get_shape
from repro.launch import mesh as mesh_lib

LEVERS = {
    "t_compute_s": ("raise arithmetic intensity: larger per-device tiles, "
                    "bf16 everywhere, fuse identification into the "
                    "attention pass"),
    "t_memory_s": ("cut HBM streams: int8 caches, fuse dequant into "
                   "attention, avoid re-materializing the residual"),
    "t_collective_s": ("re-shard: move partial-sum all-reduces out of "
                       "inner loops, gather weights once per step, "
                       "expert-parallel all-to-all instead of TP"),
}


def load(path="results/dryrun.jsonl") -> List[Dict]:
    rows = []
    if not os.path.exists(path):
        return rows
    with open(path) as f:
        for line in f:
            try:
                rows.append(json.loads(line))
            except json.JSONDecodeError:
                pass
    return rows


def run(quick: bool = False, path="results/dryrun.jsonl"):
    rows = load(path)
    singles = [r for r in rows if r.get("mesh") == "single"
               and r.get("status") == "ok"]
    print("\n== Roofline (single pod, per device, seconds/step) ==")
    print("arch,shape,t_compute,t_memory,t_collective,bottleneck,"
          "model_flops_ratio,mem_gb")
    out = []
    for r in sorted(singles, key=lambda x: (x["arch"], x["shape"])):
        ratio = r.get("useful_flop_ratio", "")
        print(f"{r['arch']},{r['shape']},"
              f"{r['t_compute_s']:.4f},{r['t_memory_s']:.4f},"
              f"{r['t_collective_s']:.4f},{r['bottleneck']},"
              f"{ratio},{r['memory']['per_device_total_gb']}")
        out.append(r)
    skips = [r for r in rows if r.get("status") == "skipped"
             and r.get("mesh") == "single"]
    for r in skips:
        print(f"{r['arch']},{r['shape']},SKIPPED({r['reason']})")
    errs = [r for r in rows if r.get("status") == "error"]
    for r in errs:
        print(f"ERROR {r['arch']} x {r['shape']} x {r['mesh']}: "
              f"{r.get('error', '')[:120]}")
    if out:
        worst = max(out, key=lambda r: max(
            r["t_compute_s"], r["t_memory_s"], r["t_collective_s"]) /
            max(min(r["t_compute_s"] + 1e-12, 1e9), r["t_compute_s"]
                + 1e-12))
        print(f"\nlever hints: {json.dumps(LEVERS, indent=1)}")
    return out


if __name__ == "__main__":
    run(path=sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.jsonl")

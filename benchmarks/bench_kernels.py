"""Per-kernel microbench: Pallas kernels vs their jnp oracles.

Times each serve-hot-path kernel (proxy_score, cosine_drift,
gather_norm, sparse_attention, scatter_update_multi) against the
equivalent XLA-op implementation at paper-flavoured shapes, and emits
``BENCH_kernels.json`` to seed the perf trajectory.

On this CPU container the Pallas side runs in INTERPRET mode, so its
wall-clock is a correctness-wiring check, not a speed claim — the
meaningful CPU numbers are the XLA-side baselines and the recorded
shapes; on a TPU backend the same file reports real Mosaic timings.
The JSON records which flavor ran (``pallas_mode``).
"""
from __future__ import annotations

import json
import time
from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.core import selection
from repro.kernels import ops
from repro.models import common
from repro.models.attention import flash_attention
from repro.core.svd_proxy import cosine_similarity

OUT_PATH = "BENCH_kernels.json"


def _time_us(fn: Callable, *args, reps: int = 5) -> float:
    out = fn(*args)                      # warm-up / compile
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _shapes(quick: bool) -> Dict[str, int]:
    if quick:
        return dict(b=2, n=256, d=128, r=32, h=4, kvh=2, hd=32, k=64)
    # LLaDA-8B-flavoured serve step: 4k canvas, rank-128 proxy, k=rho*N
    return dict(b=2, n=4096, d=2048, r=128, h=16, kvh=16, hd=128, k=1024)


def run(quick: bool = False) -> None:
    s = _shapes(quick)
    b, n, d, r, h, kvh, hd, k = (s[x] for x in
                                 "b n d r h kvh hd k".split())
    ks = jax.random.split(jax.random.PRNGKey(0), 8)
    x = jax.random.normal(ks[0], (b, n, d))
    w_r = jax.random.normal(ks[1], (d, r))
    pc = jax.random.normal(ks[2], (b, n, r))
    q = jax.random.normal(ks[3], (b, k, h, hd))
    kv_k = jax.random.normal(ks[4], (b, n, kvh, hd))
    kv_v = jax.random.normal(ks[5], (b, n, kvh, hd))
    idx = jnp.sort(jax.random.randint(ks[6], (b, k), 0, n))
    norm_w = jax.random.normal(ks[7], (d,)) * 0.1
    h_rows = jax.random.normal(ks[0], (b, k, d))
    kv_rows = jax.random.normal(ks[1], (b, k, kvh, hd))

    # Arrays go in as jit ARGUMENTS on both sides: a nullary closure
    # bakes them into the HLO as constants and XLA folds the whole op at
    # compile time (the "timing" is then a constant fetch, ~45x off).
    xla: Dict[str, tuple] = {
        "proxy_score": (jax.jit(lambda a, w, p: (
            cosine_similarity((a @ w).astype(a.dtype), p))), (x, w_r, pc)),
        "cosine_drift": (jax.jit(lambda a, p: cosine_similarity(a, p)),
                         (pc, pc)),
        "gather_norm": (jax.jit(lambda a, i, w: common.rms_norm(
            selection.gather_rows(a, i), w)), (x, idx, norm_w)),
        "sparse_attention": (jax.jit(lambda qq, kk, vv, i: flash_attention(
            qq, kk, vv, q_positions=i)), (q, kv_k, kv_v, idx)),
        "scatter_update_multi": (jax.jit(lambda ck, cv, ch, i, rk, rv, rh: (
            selection.scatter_rows(ck, i, rk),
            selection.scatter_rows(cv, i, rv),
            selection.scatter_rows(ch, i, rh))),
            (kv_k, kv_v, x, idx, kv_rows, kv_rows, h_rows)),
    }
    pallas: Dict[str, tuple] = {
        "proxy_score": (ops.proxy_score, (x, w_r, pc)),
        "cosine_drift": (ops.cosine_drift, (pc, pc)),
        "gather_norm": (ops.gather_norm, (x, idx, norm_w)),
        "sparse_attention": (ops.sparse_attention, (q, kv_k, kv_v, idx)),
        "scatter_update_multi": (
            lambda ck, cv, ch, i, rk, rv, rh: ops.scatter_update_multi(
                [ck, cv, ch], i, [rk, rv, rh]),
            (kv_k, kv_v, x, idx, kv_rows, kv_rows, h_rows)),
    }

    mode = "mosaic" if jax.default_backend() == "tpu" else "interpret"
    results: Dict[str, Dict] = {
        "_meta": {"backend": jax.default_backend(), "pallas_mode": mode,
                  "quick": quick, "shapes": s}}
    print(f"{'kernel':24s} {'xla_us':>12s} {'pallas_us':>12s}   "
          f"(pallas={mode})")
    for name in xla:
        fn_x, args_x = xla[name]
        fn_p, args_p = pallas[name]
        t_x = _time_us(fn_x, *args_x)
        t_p = _time_us(fn_p, *args_p)
        results[name] = {"xla_us": round(t_x, 1),
                         "pallas_us": round(t_p, 1)}
        print(f"{name:24s} {t_x:12.1f} {t_p:12.1f}")
    with open(OUT_PATH, "w") as f:
        json.dump(results, f, indent=1)
    print(f"wrote {OUT_PATH}")


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv or "-q" in sys.argv)

"""Per-kernel microbench: Pallas kernels vs their jnp oracles.

Times each serve-hot-path kernel (proxy_score, cosine_drift,
gather_norm, sparse_attention, scatter_update_multi) against the
equivalent XLA-op implementation at paper-flavoured shapes, and emits
``BENCH_kernels.json`` to seed the perf trajectory.

On this CPU container the Pallas side runs in INTERPRET mode, so its
wall-clock is a correctness-wiring check, not a speed claim — the
meaningful CPU numbers are the XLA-side baselines and the recorded
shapes; on a TPU backend the same file reports real Mosaic timings.
The JSON records which flavor ran (``pallas_mode``).

Timing separates FIRST-CALL (compile) from STEADY-STATE wall time —
the old warm-up-and-discard loop silently threw the compile number
away, which is exactly what the §12 retrace accounting wants on
record.  Every (kernel, shape, backend, block-config) measurement is
also persisted into the shared ProfileStore
(``BENCH_artifacts/kernel_profiles.json``) that
``launch/hillclimb.py`` warm-starts from.
"""
from __future__ import annotations

import json
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import selection
from repro.kernels import ops
from repro.serving.profiling import ProfileStore, time_compile_steady
from repro.models import common
from repro.models.attention import flash_attention
from repro.core.svd_proxy import cosine_similarity

OUT_PATH = "BENCH_kernels.json"

# Pallas grid tiling the kernel suite defaults to (sparse_attention
# block_q/block_k=512, scatter block_k=128); recorded per-measurement
# so a future autotuner can distinguish configs in the store.
BLOCK_CONFIG = "bq512_bk512_sc128"


def _shapes(quick: bool) -> Dict[str, int]:
    if quick:
        return dict(b=2, n=256, d=128, r=32, h=4, kvh=2, hd=32, k=64)
    # LLaDA-8B-flavoured serve step: 4k canvas, rank-128 proxy, k=rho*N
    return dict(b=2, n=4096, d=2048, r=128, h=16, kvh=16, hd=128, k=1024)


def run(quick: bool = False) -> None:
    s = _shapes(quick)
    b, n, d, r, h, kvh, hd, k = (s[x] for x in
                                 "b n d r h kvh hd k".split())
    ks = jax.random.split(jax.random.PRNGKey(0), 8)
    x = jax.random.normal(ks[0], (b, n, d))
    w_r = jax.random.normal(ks[1], (d, r))
    pc = jax.random.normal(ks[2], (b, n, r))
    q = jax.random.normal(ks[3], (b, k, h, hd))
    kv_k = jax.random.normal(ks[4], (b, n, kvh, hd))
    kv_v = jax.random.normal(ks[5], (b, n, kvh, hd))
    idx = jnp.sort(jax.random.randint(ks[6], (b, k), 0, n))
    norm_w = jax.random.normal(ks[7], (d,)) * 0.1
    h_rows = jax.random.normal(ks[0], (b, k, d))
    kv_rows = jax.random.normal(ks[1], (b, k, kvh, hd))

    # Arrays go in as jit ARGUMENTS on both sides: a nullary closure
    # bakes them into the HLO as constants and XLA folds the whole op at
    # compile time (the "timing" is then a constant fetch, ~45x off).
    xla: Dict[str, tuple] = {
        "proxy_score": (jax.jit(lambda a, w, p: (
            cosine_similarity((a @ w).astype(a.dtype), p))), (x, w_r, pc)),
        "cosine_drift": (jax.jit(lambda a, p: cosine_similarity(a, p)),
                         (pc, pc)),
        "gather_norm": (jax.jit(lambda a, i, w: common.rms_norm(
            selection.gather_rows(a, i), w)), (x, idx, norm_w)),
        "sparse_attention": (jax.jit(lambda qq, kk, vv, i: flash_attention(
            qq, kk, vv, q_positions=i)), (q, kv_k, kv_v, idx)),
        "scatter_update_multi": (jax.jit(lambda ck, cv, ch, i, rk, rv, rh: (
            selection.scatter_rows(ck, i, rk),
            selection.scatter_rows(cv, i, rv),
            selection.scatter_rows(ch, i, rh))),
            (kv_k, kv_v, x, idx, kv_rows, kv_rows, h_rows)),
    }
    pallas: Dict[str, tuple] = {
        "proxy_score": (ops.proxy_score, (x, w_r, pc)),
        "cosine_drift": (ops.cosine_drift, (pc, pc)),
        "gather_norm": (ops.gather_norm, (x, idx, norm_w)),
        "sparse_attention": (ops.sparse_attention, (q, kv_k, kv_v, idx)),
        "scatter_update_multi": (
            lambda ck, cv, ch, i, rk, rv, rh: ops.scatter_update_multi(
                [ck, cv, ch], i, [rk, rv, rh]),
            (kv_k, kv_v, x, idx, kv_rows, kv_rows, h_rows)),
    }

    mode = "mosaic" if jax.default_backend() == "tpu" else "interpret"
    results: Dict[str, Dict] = {
        "_meta": {"backend": jax.default_backend(), "pallas_mode": mode,
                  "quick": quick, "shapes": s}}
    store = ProfileStore()
    store.load()
    shape_tag = "x".join(f"{k2}{v}" for k2, v in sorted(s.items()))
    print(f"{'kernel':24s} {'xla_us':>12s} {'pallas_us':>12s} "
          f"{'xla_compile_us':>15s} {'pallas_compile_us':>18s}   "
          f"(pallas={mode})")
    for name in xla:
        fn_x, args_x = xla[name]
        fn_p, args_p = pallas[name]
        c_x, t_x = time_compile_steady(fn_x, *args_x)
        c_p, t_p = time_compile_steady(fn_p, *args_p)
        t_x, t_p, c_x, c_p = (v * 1e6 for v in (t_x, t_p, c_x, c_p))
        results[name] = {"xla_us": round(t_x, 1),
                         "pallas_us": round(t_p, 1),
                         "xla_compile_us": round(c_x, 1),
                         "pallas_compile_us": round(c_p, 1)}
        print(f"{name:24s} {t_x:12.1f} {t_p:12.1f} "
              f"{c_x:15.1f} {c_p:18.1f}")
        for backend, steady, compile_ in (
                ("xla", t_x, c_x), (f"pallas-{mode}", t_p, c_p)):
            store.put(
                {"steady_us": round(steady, 1),
                 "compile_us": round(compile_, 1),
                 "device": jax.default_backend()},
                kind="kernel", kernel=name, shape=shape_tag,
                backend=backend, block=BLOCK_CONFIG)
    with open(OUT_PATH, "w") as f:
        json.dump(results, f, indent=1)
    store.save()
    print(f"wrote {OUT_PATH} and {len(store)} profile records "
          f"-> {store.path}")


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv or "-q" in sys.argv)

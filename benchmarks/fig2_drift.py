"""Paper Figure 2: layer-wise drift distribution — the fraction of tokens
whose adjacent-step identifier similarity falls below tau, per layer,
measured during real decoding of a trained model; plus the fitted Eq. 5
schedule for comparison."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import budget, identifiers, spa_layer
from repro.dlm import decoding
from repro.models import common as mcommon, transformer


def measure_drift(cfg, params, prompt, gen_len=16, tau=0.95):
    """Vanilla-decode while probing per-layer input drift between steps."""
    cfg_v = common.with_spa(cfg, identifier="none")
    state = decoding.init_decode_state(cfg_v, params, prompt, gen_len,
                                       use_cache=False)
    prev_proxies = None
    frac = np.zeros(cfg.n_layers)
    steps = 0
    step_fn = jax.jit(functools.partial(
        decoding.serve_step, params, cfg_v,
        settings=decoding.DecodeSettings()))

    wv = params["blocks"]["attn"]["wv"]
    norm1 = params["blocks"]["attn"]["norm1"]

    def layer_proxies(tokens):
        h = transformer.embed_inputs(params, cfg, {"tokens": tokens})
        outs = []
        for l in range(cfg.n_layers):
            bp = jax.tree.map(lambda a: a[l], params["blocks"]["attn"])
            x = mcommon.rms_norm(h, bp["norm1"], cfg.norm_eps)
            outs.append(x @ bp["wv"])
            h, _, _ = transformer.apply_block_dense(cfg, "attn", bp, h)
        return outs

    probe = jax.jit(layer_proxies)
    for _ in range(gen_len):
        cur = probe(state.tokens)
        if prev_proxies is not None:
            for l in range(cfg.n_layers):
                sim = identifiers.drift_scores(cur[l], prev_proxies[l])
                frac[l] += float((np.asarray(sim) < tau).mean())
            steps += 1
        prev_proxies = cur
        state, _ = step_fn(state)
        if int(jax.device_get(jnp.max(state.n_masked))) <= 0:
            break
    return frac / max(steps, 1)


def run(quick: bool = False):
    cfg = common.bench_model(n_layers=6)
    params = common.trained_bench_model(cfg, steps=10 if quick else 40)
    prompt = jnp.asarray(np.random.default_rng(5).integers(
        0, cfg.vocab_size - 1, (2, 12)), jnp.int32)
    drift = measure_drift(cfg, params, prompt,
                          gen_len=6 if quick else 16)
    spa = common.with_spa(cfg, identifier="singular", rank=16,
                          schedule="adaptive", rho_peak=0.25,
                          rho_first=0.03, rho_last=0.13).spa
    fitted = budget.rho_schedule(spa, cfg.n_layers)
    rows = [{"layer": l + 1, "drift_frac": round(float(drift[l]), 4),
             "eq5_rho": round(float(fitted[l]), 4)}
            for l in range(cfg.n_layers)]
    common.print_table("Fig 2 — layer-wise drift vs Eq.5 schedule", rows,
                       ["layer", "drift_frac", "eq5_rho"])
    return rows


if __name__ == "__main__":
    run()

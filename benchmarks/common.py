"""Shared benchmark utilities.

All speed benchmarks run scaled-down models on this CPU container; the
meaningful quantities are RATIOS (speedups vs the vanilla baseline) and
counted work (rows updated, identification FLOPs), which transfer to the
paper's setting. Wall-clock is measured around jitted steps after a
warm-up call.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Iterable, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.configs.base import ModelConfig, SPAConfig
from repro.data.synthetic import token_batches
from repro.models import transformer
from repro.training.optimizer import AdamWConfig
from repro.training.trainer import Trainer


def bench_model(n_layers=4, d_model=128, vocab=512, seq=256,
                arch="llada-8b") -> ModelConfig:
    """Scaled-down LLaDA-family model used across benchmarks."""
    return reduced(get_arch(arch), n_layers=n_layers, d_model=d_model,
                   n_heads=4, n_kv_heads=4, head_dim=32,
                   d_ff=4 * d_model, vocab_size=vocab)


def trained_bench_model(cfg: ModelConfig, steps=30, seed=0):
    trainer = Trainer(cfg, AdamWConfig(lr=3e-3, warmup_steps=5,
                                       total_steps=steps + 10)).init(
        jax.random.PRNGKey(seed))
    data = token_batches(cfg, batch_size=4, seq_len=64, seed=seed)
    trainer.fit(data, n_steps=steps, rng=jax.random.PRNGKey(seed + 1),
                log_every=0)
    return trainer.params


def with_spa(cfg: ModelConfig, **kw) -> ModelConfig:
    return dataclasses.replace(cfg, spa=SPAConfig(**kw))


def time_decode(cfg, params, prompt, gen_len, settings=None, reps=1,
                strategy=None, scheduler=None,
                compiled: bool = False) -> Dict[str, float]:
    """Returns tokens/s and time-to-first-step for a decode run.

    ``strategy`` (a ``CacheStrategy``) overrides ``cfg.spa`` and
    ``scheduler`` (an ``UnmaskScheduler``) overrides the settings
    commit knobs at call time — the benchmarks compare caching and
    commit policies on ONE ModelConfig.  ``compiled=True`` times the
    device-resident ``run_compiled`` loop instead of the host loop."""
    from repro.dlm.session import DecodeSession
    sess = DecodeSession(params, cfg, strategy=strategy,
                         settings=settings, scheduler=scheduler)
    if compiled:
        t0 = time.perf_counter()
        sess.prefill(prompt, gen_len)
        sess.run_compiled(max_steps=1)     # compile + first step
        jax.block_until_ready(sess.tokens)
        ttft = time.perf_counter() - t0
        t0 = time.perf_counter()
        _, info = sess.run_compiled(max_steps=gen_len * 2)
        jax.block_until_ready(sess.tokens)
        dt = time.perf_counter() - t0
        n_steps = info["steps"]
    else:
        t0 = time.perf_counter()
        sess.prefill(prompt, gen_len)
        sess.step()                        # compile + first step
        jax.block_until_ready(sess.tokens)
        ttft = time.perf_counter() - t0

        n_steps = 0
        t0 = time.perf_counter()
        while not sess.done and n_steps < gen_len * 2:
            sess.step()
            n_steps += 1
        jax.block_until_ready(sess.tokens)
        dt = time.perf_counter() - t0
    committed = gen_len * prompt.shape[0] - int(
        jnp.sum(jnp.maximum(sess.state.n_masked, 0)))
    return {
        "tps": committed / max(dt, 1e-9),
        "ttft_ms": ttft * 1e3,
        "steps": n_steps + 1,
        "step_ms": dt * 1e3 / max(n_steps, 1),
    }


def print_table(title: str, rows: List[Dict], cols: Iterable[str]):
    cols = list(cols)
    print(f"\n== {title} ==")
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r.get(c, "")) for c in cols))

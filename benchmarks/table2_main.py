"""Paper Table 2/8: main speedup comparison — vanilla vs dLLM-Cache
(value proxy, uniform rho) vs Fast-dLLM-style parallel decoding vs
SPA-Cache (singular proxy + adaptive budget).

All methods share ONE ModelConfig; the caching policy is a call-time
``CacheStrategy`` (what the model is vs how it is cached)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core.strategy import NoCache, SPACache, ValueProxyCache
from repro.dlm import decoding


def run(quick: bool = False):
    cfg = common.bench_model()
    params = common.trained_bench_model(cfg, steps=10 if quick else 30)
    prompt = jnp.asarray(np.random.default_rng(1).integers(
        0, cfg.vocab_size - 1, (2, 16)), jnp.int32)
    gen_len = 8 if quick else 24

    methods = {
        "baseline": (NoCache(), decoding.DecodeSettings()),
        "dllm_cache": (ValueProxyCache(rho=0.25, refresh_interval=8),
                       decoding.DecodeSettings()),
        "fast_dllm": (NoCache(),
                      decoding.DecodeSettings(parallel_threshold=0.05,
                                              max_parallel=4)),
        "spa_cache": (SPACache(rank=16, schedule="adaptive",
                               rho_peak=0.25, rho_first=0.03,
                               rho_last=0.13),
                      decoding.DecodeSettings()),
    }
    base_tps = None
    rows = []
    ref_tokens, _ = decoding.decode(params, cfg, prompt, gen_len,
                                    strategy=NoCache())
    for name, (strategy, settings) in methods.items():
        stats = common.time_decode(cfg, params, prompt, gen_len,
                                   settings=settings, strategy=strategy)
        toks, _ = decoding.decode(params, cfg, prompt, gen_len,
                                  settings=settings, strategy=strategy)
        agree = float((np.asarray(toks) == np.asarray(ref_tokens)).mean())
        if name == "baseline":
            base_tps = stats["tps"]
        rows.append({
            "method": name,
            "tps": round(stats["tps"], 2),
            "speedup": round(stats["tps"] / max(base_tps, 1e-9), 2),
            "ttft_ms": round(stats["ttft_ms"], 1),
            "agreement": round(agree, 4),
        })
    common.print_table("Table 2 — method comparison", rows,
                       ["method", "tps", "speedup", "ttft_ms",
                        "agreement"])
    return rows


if __name__ == "__main__":
    run()

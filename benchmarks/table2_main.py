"""Paper Table 2/8: main speedup comparison — vanilla vs dLLM-Cache
(value proxy, uniform rho) vs Fast-dLLM-style parallel decoding vs
SPA-Cache (singular proxy + adaptive budget)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.dlm import decoding


def run(quick: bool = False):
    cfg0 = common.bench_model()
    params = common.trained_bench_model(cfg0, steps=10 if quick else 30)
    prompt = jnp.asarray(np.random.default_rng(1).integers(
        0, cfg0.vocab_size - 1, (2, 16)), jnp.int32)
    gen_len = 8 if quick else 24

    methods = {
        "baseline": (common.with_spa(cfg0, identifier="none"),
                     decoding.DecodeSettings()),
        "dllm_cache": (common.with_spa(
            cfg0, identifier="value", schedule="uniform", rho_peak=0.25,
            refresh_interval=8), decoding.DecodeSettings()),
        "fast_dllm": (common.with_spa(cfg0, identifier="none"),
                      decoding.DecodeSettings(parallel_threshold=0.05,
                                              max_parallel=4)),
        "spa_cache": (common.with_spa(
            cfg0, identifier="singular", rank=16, schedule="adaptive",
            rho_peak=0.25, rho_first=0.03, rho_last=0.13),
            decoding.DecodeSettings()),
    }
    base_tps = None
    rows = []
    ref_tokens, _ = decoding.decode(
        params, methods["baseline"][0], prompt, gen_len)
    for name, (cfg, settings) in methods.items():
        stats = common.time_decode(cfg, params, prompt, gen_len,
                                   settings=settings)
        toks, _ = decoding.decode(params, cfg, prompt, gen_len,
                                  settings=settings)
        agree = float((np.asarray(toks) == np.asarray(ref_tokens)).mean())
        if name == "baseline":
            base_tps = stats["tps"]
        rows.append({
            "method": name,
            "tps": round(stats["tps"], 2),
            "speedup": round(stats["tps"] / max(base_tps, 1e-9), 2),
            "ttft_ms": round(stats["ttft_ms"], 1),
            "agreement": round(agree, 4),
        })
    common.print_table("Table 2 — method comparison", rows,
                       ["method", "tps", "speedup", "ttft_ms",
                        "agreement"])
    return rows


if __name__ == "__main__":
    run()

"""Paper Figure 4: component-wise latency decomposition of one serve
layer — identification vs attention vs FFN — for the vanilla / value-proxy
/ singular-proxy variants. Measured on jitted per-component functions.

Also measures the decode-LOOP overhead: per-step latency of the host
step loop (one jitted step dispatch + one ``n_masked`` host sync per
step) vs ``DecodeSession.run_compiled`` (the whole loop as a single
``lax.while_loop``).  The delta is pure dispatch/sync cost — recorded
in EXPERIMENTS.md §Perf."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import identifiers, selection
from repro.core.svd_proxy import build_proxy
from repro.models import common as mcommon
from repro.models.attention import flash_attention
from repro.models.transformer import apply_ffn_or_moe, qkv_project


def timeit(fn, *args, reps=20):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e3  # ms


def loop_overhead(cfg, params, quick: bool = False):
    """Mean per-step ms of the host run() loop vs run_compiled(), on
    both kernel backends (DESIGN.md §4.5).  Off-TPU the pallas row runs
    the kernels in interpret mode — a wiring/latency sanity row, not a
    speed claim (real Mosaic timings appear on a TPU backend)."""
    from repro.core.strategy import SPACache
    from repro.dlm.session import DecodeSession

    gen_len = 16 if quick else 32
    prompt = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size - 1, (2, 16)),
        jnp.int32)
    strat = SPACache(rank=16, schedule="uniform", rho_peak=0.25)
    out = []
    for name, runner, backend in (
            ("decode_loop_host", "run", None),
            ("decode_loop_compiled", "run_compiled", None),
            ("decode_loop_compiled_pallas", "run_compiled", "pallas")):
        sess = DecodeSession(params, cfg, strategy=strat, backend=backend)
        sess.prefill(prompt, gen_len)
        getattr(sess, runner)()            # compile + warm caches
        sess.prefill(prompt, gen_len)
        t0 = time.perf_counter()
        _, info = getattr(sess, runner)()
        jax.block_until_ready(sess.tokens)
        dt = time.perf_counter() - t0
        out.append({"component": name,
                    "ms": round(dt * 1e3 / max(info["steps"], 1), 3)})
    return out


def run(quick: bool = False):
    d, n, b = 256, 1024, 2
    kq = max(1, int(0.05 * n))          # paper Fig. 4 uses rho = 5%
    cfg = common.bench_model(n_layers=2, d_model=d, seq=n)
    params = jax.tree.map(
        lambda a: a, common.trained_bench_model(cfg, steps=2))
    bp = jax.tree.map(lambda a: a[0], params["blocks"]["attn"])
    key = jax.random.PRNGKey(0)
    h = jax.random.normal(key, (b, n, d))
    idx = jnp.sort(jax.random.randint(key, (b, kq), 0, n), axis=-1)
    kvh, hd = cfg.n_kv_heads, cfg.head_dim
    kv = jax.random.normal(key, (b, n, kvh, hd))
    pc_full = jax.random.normal(key, (b, n, cfg.kv_dim))
    proxy_mat, _ = build_proxy(np.asarray(bp["wv"], np.float32), 16)
    proxy_mat = jnp.asarray(proxy_mat)
    pc_small = jax.random.normal(key, (b, n, 16))

    @jax.jit
    def ident_value(h):
        p = h @ bp["wv"]
        return identifiers.drift_scores(p, pc_full)

    @jax.jit
    def ident_singular(h):
        p = h @ proxy_mat
        return identifiers.drift_scores(p, pc_small)

    @jax.jit
    def attn_sparse(h):
        rows = selection.gather_rows(h, idx)
        q, _, _ = qkv_project(bp, rows, cfg, idx)
        return flash_attention(q, kv, kv, q_positions=idx, block_q=128,
                               block_k=256)

    @jax.jit
    def attn_full(h):
        pos = jnp.broadcast_to(jnp.arange(n)[None], (b, n))
        q, _, _ = qkv_project(bp, h, cfg, pos)
        return flash_attention(q, kv, kv, block_q=128, block_k=256)

    @jax.jit
    def ffn_sparse(h):
        return apply_ffn_or_moe(bp, selection.gather_rows(h, idx), cfg)[0]

    @jax.jit
    def ffn_full(h):
        return apply_ffn_or_moe(bp, h, cfg)[0]

    loop_rows = loop_overhead(cfg, params, quick=quick)

    reps = 5 if quick else 20
    rows = loop_rows + [
        {"component": "identify_value_proxy",
         "ms": round(timeit(ident_value, h, reps=reps), 3)},
        {"component": "identify_singular_proxy",
         "ms": round(timeit(ident_singular, h, reps=reps), 3)},
        {"component": "attention_full",
         "ms": round(timeit(attn_full, h, reps=reps), 3)},
        {"component": "attention_sparse_rho5",
         "ms": round(timeit(attn_sparse, h, reps=reps), 3)},
        {"component": "ffn_full",
         "ms": round(timeit(ffn_full, h, reps=reps), 3)},
        {"component": "ffn_sparse_rho5",
         "ms": round(timeit(ffn_sparse, h, reps=reps), 3)},
    ]
    common.print_table("Fig 4 — per-component latency", rows,
                       ["component", "ms"])
    return rows


if __name__ == "__main__":
    run()

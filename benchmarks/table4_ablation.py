"""Paper Table 4: component ablation — value proxy vs singular proxy,
uniform vs adaptive budget (incl. the uniform-16% control).

One ModelConfig, five call-time ``CacheStrategy`` variants."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import budget
from repro.core.strategy import NoCache, SPACache, ValueProxyCache
from repro.dlm import decoding


def run(quick: bool = False):
    cfg = common.bench_model()
    params = common.trained_bench_model(cfg, steps=10 if quick else 30)
    prompt = jnp.asarray(np.random.default_rng(3).integers(
        0, cfg.vocab_size - 1, (2, 16)), jnp.int32)
    gen_len = 8 if quick else 24

    variants = [
        ("none_rho100", NoCache()),
        ("value_uniform25", ValueProxyCache(rho=0.25)),
        ("singular_uniform25", SPACache(rank=16, schedule="uniform",
                                        rho_peak=0.25)),
        ("singular_adaptive", SPACache(rank=16, schedule="adaptive",
                                       rho_peak=0.25, rho_first=0.03,
                                       rho_last=0.13)),
        ("singular_uniform16", SPACache(rank=16, schedule="uniform",
                                        rho_peak=0.16)),
    ]
    ref_tokens, _ = decoding.decode(params, cfg, prompt, gen_len,
                                    strategy=variants[0][1])
    rows = []
    for name, strategy in variants:
        stats = common.time_decode(cfg, params, prompt, gen_len,
                                   strategy=strategy)
        toks, _ = decoding.decode(params, cfg, prompt, gen_len,
                                  strategy=strategy)
        agree = float((np.asarray(toks) == np.asarray(ref_tokens)).mean())
        avg_rho = (budget.average_rho(strategy.spec, cfg.n_layers)
                   if strategy.uses_cache else 1.0)
        rows.append({
            "variant": name,
            "avg_rho": round(avg_rho, 3),
            "tps": round(stats["tps"], 2),
            "agreement": round(agree, 4),
        })
    common.print_table("Table 4 — component ablation", rows,
                       ["variant", "avg_rho", "tps", "agreement"])
    return rows


if __name__ == "__main__":
    run()

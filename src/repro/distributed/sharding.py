"""Sharding rules: map every tensor of the system onto the production mesh.

Baseline scheme (DESIGN.md §7):
  * weights     — last dim over "model" when divisible (tensor dim), and,
                  for zero3 configs, another dim over the batch axes
                  (ZeRO-3 / FSDP); stacked-layer leading dims never shard.
  * activations — batch over ("pod","data"); for long_500k (batch=1) the
                  SEQUENCE dim shards over the batch axes instead.
  * caches      — [Lk, B, N, ...]: batch over batch axes, sequence over
                  "model" (keeps TB-scale DLM caches within HBM; attention
                  all-gathers one layer's KV at a time).

Everything is expressed as PartitionSpecs chosen per-leaf with divisibility
checks, so every (arch x shape x mesh) combination lowers without manual
per-arch tables.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_size(mesh: Mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _divisible(dim: int, mesh: Mesh, axes) -> bool:
    return dim % axis_size(mesh, axes) == 0


# Tensor-parallel placement per weight name (Megatron-style):
#   "row"    — shard the contraction (input) dim over "model"; the matmul
#              produces partial sums -> one all-reduce, activations stay
#              replicated over "model" (attention runs fully local).
#   "column" — shard the output dim over "model"; downstream op consumes
#              the sharded feature dim locally (FFN up / lm head).
_ROW_PARALLEL = {"wq", "wk", "wv", "wo", "w_down", "w_out"}
_COLUMN_PARALLEL = {"w_gate", "w_up", "lm_head", "w_in", "w_gate_branch"}
_VOCAB_SHARDED = {"embed", "pos_embed"}
_REPLICATED = {"router", "conv_kernel", "log_lambda", "a_log", "dt_bias",
               "d_skip", "norm_weight"}


def param_pspec(name: str, leaf: Any, mesh: Mesh, *, zero3: bool,
                stacked: bool) -> P:
    """Choose a spec for one parameter leaf (by its dict key name)."""
    shape = leaf.shape
    ndim = len(shape)
    spec: list = [None] * ndim
    start = 1 if (stacked and ndim >= 2) else 0
    dims = list(range(start, ndim))
    if not dims or ndim - start < 2 or name in _REPLICATED:
        return P(*spec)

    model_dim = None
    is_moe_expert = (name in ("w_gate", "w_up", "w_down")
                     and ndim - start == 3)
    is_gate_heads = name in ("w_a", "w_x") and ndim - start == 3
    if (is_moe_expert or is_gate_heads) and _divisible(
            shape[start], mesh, "model"):
        model_dim = start             # expert / gate-head parallelism
    elif name in _ROW_PARALLEL:
        model_dim = ndim - 2                       # contraction dim
    elif name in _COLUMN_PARALLEL:
        model_dim = ndim - 1                       # output dim
    elif name in _VOCAB_SHARDED:
        model_dim = start                          # vocab / position dim
    if model_dim is not None and _divisible(shape[model_dim], mesh,
                                            "model"):
        spec[model_dim] = "model"
    elif model_dim is not None:
        # fall back to any divisible dim (e.g. hubert vocab=504)
        for d in reversed(dims):
            if _divisible(shape[d], mesh, "model") and shape[d] >= 128:
                spec[d] = "model"
                break

    if zero3:
        ba = batch_axes(mesh)
        if ba:
            for d in dims:
                if spec[d] is None and shape[d] >= 256 and \
                        _divisible(shape[d], mesh, ba):
                    spec[d] = ba if len(ba) > 1 else ba[0]
                    break
    return P(*spec)


def params_shardings(abs_params: Any, cfg: ModelConfig, mesh: Mesh) -> Any:
    if not cfg.tp_weights:
        rep = NamedSharding(mesh, P())
        return jax.tree.map(lambda _: rep, abs_params)

    def choose(path, leaf):
        stacked = any(getattr(p, "key", None) == "blocks" for p in path)
        name = ""
        for p in reversed(path):
            key = getattr(p, "key", None)
            if isinstance(key, str):
                name = key
                break
        return NamedSharding(
            mesh, param_pspec(name, leaf, mesh, zero3=cfg.zero3,
                              stacked=stacked))

    return jax.tree_util.tree_map_with_path(choose, abs_params)


def opt_state_shardings(abs_opt: Any, abs_params_shardings: Any,
                        mesh: Mesh) -> Any:
    """mu/nu shard like params; step replicated."""
    from repro.training.optimizer import OptState
    rep = NamedSharding(mesh, P())
    return OptState(step=rep, mu=abs_params_shardings,
                    nu=abs_params_shardings)


def data_pspec(shape: ShapeConfig, mesh: Mesh, ndim: int,
               seq_dim: int = 1, full: bool = True) -> P:
    """Spec for a batched input [B, N, ...].

    Preference order: batch over ALL axes (pod x data x model — the FSDP
    regime, which keeps tensor-parallel partial-sum all-reduces tiny),
    else batch over (pod, data), else sequence over all axes (batch=1
    long-context)."""
    ba = batch_axes(mesh)
    all_axes = ba + ("model",)
    spec: list = [None] * ndim
    if not ba:
        return P(*spec)
    if full and shape.global_batch % axis_size(mesh, all_axes) == 0:
        spec[0] = all_axes
    elif shape.global_batch % axis_size(mesh, ba) == 0:
        spec[0] = ba if len(ba) > 1 else ba[0]
    elif ndim > seq_dim and shape.seq_len % axis_size(mesh, all_axes) == 0:
        spec[seq_dim] = all_axes
    return P(*spec)


def activation_pspec(shape: ShapeConfig, mesh: Mesh, ndim: int) -> P:
    return data_pspec(shape, mesh, ndim)


def cache_pspec(shape: ShapeConfig, mesh: Mesh, ndim: int) -> P:
    """Cache leaf [Lk, B, N, ...]: B over batch axes, N over model."""
    ba = batch_axes(mesh)
    spec: list = [None] * ndim
    if ba and shape.global_batch % axis_size(mesh, ba) == 0:
        spec[1] = ba if len(ba) > 1 else ba[0]
        if shape.seq_len % axis_size(mesh, "model") == 0:
            spec[2] = "model"
    elif shape.seq_len % axis_size(mesh, ba + ("model",)) == 0:
        # batch=1 long-context: sequence over everything
        spec[2] = ba + ("model",)
    return P(*spec)


def batch_shardings(abs_batch: Dict[str, Any], shape: ShapeConfig,
                    mesh: Mesh, cfg: ModelConfig = None) -> Dict[str, Any]:
    # MoE archs keep the model axis free for expert parallelism / TP.
    full = cfg is None or cfg.moe is None
    return {k: NamedSharding(mesh, data_pspec(shape, mesh, v.ndim,
                                              full=full))
            for k, v in abs_batch.items()}


def cache_shardings(abs_cache: Any, shape: ShapeConfig, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda leaf: NamedSharding(mesh, cache_pspec(shape, mesh,
                                                     leaf.ndim)),
        abs_cache)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())

"""Sharding hints usable from model code without carrying a mesh around.

``shard_hint(x, "batch", None, ...)`` applies a with_sharding_constraint
when tracing under a mesh whose axis names are known; outside any mesh
(CPU smoke tests) it is a no-op.

Dim tokens:
  None     — replicated on this dim
  "keep"   — UNCONSTRAINED (GSPMD chooses)
  "batch"  — the activation batch axes of the current lowering; set by
             the launcher via ``batch_axes_ctx`` (e.g. ("data","model")
             for fully-sharded train batches, ("data",) for MoE / decode);
             defaults to whichever of ("pod","data") exist in the mesh.
  "model" / "data" / "pod" / tuples — those axes if present.

Every resolved axis set is divisibility-checked against the dim size and
dropped (-> replicated) when it does not divide — so the same model code
lowers for every (arch x shape x mesh) combination.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

_STATE = threading.local()


@contextlib.contextmanager
def batch_axes_ctx(axes: Optional[Tuple[str, ...]]):
    """Set the activation batch axes for hints inside this lowering."""
    prev = getattr(_STATE, "batch_axes", None)
    _STATE.batch_axes = axes
    try:
        yield
    finally:
        _STATE.batch_axes = prev


def _current_mesh():
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is not None and not getattr(mesh, "empty", True):
            return mesh
    except Exception:  # pragma: no cover - older jax
        pass
    try:  # `with mesh:` context (physical mesh)
        from jax._src import mesh as mesh_src
        pm = mesh_src.thread_resources.env.physical_mesh
        if pm is not None and not pm.empty:
            return pm
    except Exception:  # pragma: no cover
        pass
    return None


def _resolve(dim, names):
    if dim == "keep":
        return P.UNCONSTRAINED
    if dim is None:
        return None
    if dim == "batch":
        ctx = getattr(_STATE, "batch_axes", None)
        if ctx is not None:
            present = tuple(a for a in ctx if a in names)
            return present if present else None
        ba = tuple(a for a in ("pod", "data") if a in names)
        return ba if ba else None
    if isinstance(dim, str):
        return dim if dim in names else None
    if isinstance(dim, tuple):
        present = tuple(a for a in dim if a in names)
        return present if present else None
    return None


def shard_hint(x: jax.Array, *dims) -> jax.Array:
    """Constrain x's sharding; no-op outside a named mesh."""
    mesh = _current_mesh()
    if mesh is None:
        return x
    names = tuple(mesh.axis_names)
    sizes = dict(zip(names, (mesh.shape[a] for a in names)))
    if len(dims) != x.ndim:
        dims = tuple(dims) + (None,) * (x.ndim - len(dims))
    spec = []
    used: set = set()
    for i, d in enumerate(dims):
        r = _resolve(d, names)
        if r is not None and r is not P.UNCONSTRAINED:
            axes = tuple(a for a in ((r,) if isinstance(r, str) else r)
                         if a not in used)   # each axis at most once
            if not axes:
                r = None
            else:
                total = int(np.prod([sizes[a] for a in axes]))
                if x.shape[i] % total != 0:
                    r = None  # indivisible -> replicate
                else:
                    used.update(axes)
                    r = axes if len(axes) > 1 else axes[0]
        spec.append(r)
    return jax.lax.with_sharding_constraint(x, P(*spec))

"""internlm2-1.8b [dense] — llama-style GQA model.

[arXiv:2403.17297] InternLM2. 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92544.
"""
from repro.configs.base import ATTN_FULL, ModelConfig, SPAConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b",
    arch_type="dense",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92_544,
    layer_pattern=(ATTN_FULL,),
    act="silu",
    tie_embeddings=True,
    spa=SPAConfig(identifier="singular", rank=128),
    source="arXiv:2403.17297",
    param_dtype="bfloat16",
    remat=True,
    microbatch=1,
)

"""dream-7b — the paper's second evaluation model (Dream-v0-Instruct-7B).

[arXiv:2508.15487] Dream 7B: qwen2.5-architecture masked-diffusion LM with
GQA. 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=151936.
SPA hyperparameters from the paper: r=32 (GQA value dim d=512), rho_p=30%
at l_p=14, rho_1=5%, rho_L=25% (Appendix C Table 6).
"""
from repro.configs.base import ATTN_FULL, ModelConfig, SPAConfig

CONFIG = ModelConfig(
    name="dream-7b",
    arch_type="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=151_936,
    layer_pattern=(ATTN_FULL,),
    act="silu",
    tie_embeddings=False,
    spa=SPAConfig(identifier="singular", rank=32, schedule="adaptive",
                  rho_peak=0.30, rho_first=0.05, rho_last=0.25,
                  layer_peak=14),
    source="arXiv:2508.15487",
    param_dtype="bfloat16",
    remat=True,
    microbatch=1,
)

"""internvl2-76b [vlm] — InternViT + InternLM2/Llama3-70B backbone.

[arXiv:2404.16821] InternVL 1.5/2. Language backbone: 80L d_model=8192 64H
(GQA kv=8) d_ff=28672 vocab=128256. The InternViT vision encoder +
MLP projector are stubbed per the carve-out; input_specs() supplies
pre-projected patch embeddings interleaved with text embeddings.
"""
from repro.configs.base import ATTN_FULL, ModelConfig, SPAConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    arch_type="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128_256,
    layer_pattern=(ATTN_FULL,),
    act="silu",
    tie_embeddings=False,
    frontend="vision",
    frontend_tokens=1024,   # stub image-patch tokens prepended
    spa=SPAConfig(identifier="singular", rank=128),
    source="arXiv:2404.16821",
    zero3=True,
    param_dtype="bfloat16",
    cache_dtype="int8",
    remat=True,
    microbatch=1,
)

"""gemma2-2b [dense] — local+global alternating attention, logit softcaps.

[arXiv:2408.00118] Gemma 2 technical report. 26L d_model=2304 8H (GQA kv=4)
d_ff=9216 vocab=256000, sliding window 4096 on local layers, attention
softcap 50.0, final logit softcap 30.0.
"""
from repro.configs.base import (ATTN_GLOBAL, ATTN_LOCAL, ModelConfig,
                                SPAConfig)

CONFIG = ModelConfig(
    name="gemma2-2b",
    arch_type="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256_000,
    layer_pattern=(ATTN_LOCAL, ATTN_GLOBAL),
    window=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    act="gelu",
    tie_embeddings=True,
    spa=SPAConfig(identifier="singular", rank=128),
    source="arXiv:2408.00118",
    post_norms=True,
    embed_scale=True,
    param_dtype="bfloat16",
    remat=True,
    microbatch=1,
)

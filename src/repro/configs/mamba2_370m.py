"""mamba2-370m [ssm] — SSD (state-space duality), attention-free.

[arXiv:2405.21060] Mamba-2. 48L d_model=1024, d_ff=0 (no separate FFN;
the SSD block includes the gated expansion), vocab=50280, ssm_state=128.

SPA-Cache applicability: the SSD mixer is a sequence scan — a changed
token perturbs all later chunk states, so sparse row recompute is unsound.
This arch runs WITHOUT the sparse-update technique (identifier="none",
full linear-cost recompute per refinement step). See DESIGN.md
§Arch-applicability.
"""
from repro.configs.base import SSD, ModelConfig, SPAConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    arch_type="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=32,           # = d_inner / ssm head_dim = 2048/64
    n_kv_heads=32,
    head_dim=64,
    d_ff=0,
    vocab_size=50_280,
    layer_pattern=(SSD,),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                  chunk_size=256),
    act="silu",
    tie_embeddings=True,
    spa=SPAConfig(identifier="none"),
    source="arXiv:2405.21060",
    tp_weights=False,   # 370M replicates; §Perf: 2.3x decode step win
    param_dtype="bfloat16",
    remat=True,
    microbatch=1,
)

"""qwen3-moe-235b-a22b [moe] — 128 experts, top-8 routing.

[hf:Qwen/Qwen3-30B-A3B family scaled per assignment] 94L d_model=4096 64H
(GQA kv=4) per-expert d_ff=1536 vocab=151936, MoE 128e top-8.
"""
from repro.configs.base import ATTN_FULL, ModelConfig, MoEConfig, SPAConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    arch_type="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151_936,
    layer_pattern=(ATTN_FULL,),
    moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=1536,
                  capacity_factor=1.25, router_aux_weight=0.001),
    act="silu",
    tie_embeddings=False,
    spa=SPAConfig(identifier="singular", rank=128),
    source="hf:Qwen/Qwen3-30B-A3B",
    zero3=True,
    param_dtype="bfloat16",
    cache_dtype="int8",
    remat=True,
    microbatch=8,
)

"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention.

[arXiv:2401.04088] Mixtral of Experts (8x22B variant per assignment).
56L d_model=6144 48H (GQA kv=8) expert d_ff=16384 vocab=32768, SWA.
"""
from repro.configs.base import ATTN_SWA, ModelConfig, MoEConfig, SPAConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    arch_type="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32_768,
    layer_pattern=(ATTN_SWA,),
    window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=16384,
                  capacity_factor=1.25, router_aux_weight=0.01),
    act="silu",
    tie_embeddings=False,
    spa=SPAConfig(identifier="singular", rank=128),
    source="arXiv:2401.04088",
    zero3=True,
    param_dtype="bfloat16",
    cache_dtype="int8",
    remat=True,
    microbatch=8,
)

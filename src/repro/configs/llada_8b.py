"""llada-8b — the paper's primary evaluation model (LLaDA-8B-Instruct).

[arXiv:2502.09992] LLaDA: llama-architecture masked-diffusion LM.
32L d_model=4096 32H (MHA) d_ff=12288 vocab=126464.
SPA hyperparameters from the paper: r=128, rho_p=25% at l_p=24,
rho_1=3%, rho_L=13% (Appendix C Table 6).
"""
from repro.configs.base import ATTN_FULL, ModelConfig, SPAConfig

CONFIG = ModelConfig(
    name="llada-8b",
    arch_type="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=12288,
    vocab_size=126_464,
    layer_pattern=(ATTN_FULL,),
    act="silu",
    tie_embeddings=False,
    spa=SPAConfig(identifier="singular", rank=128, schedule="adaptive",
                  rho_peak=0.25, rho_first=0.03, rho_last=0.13,
                  layer_peak=24),
    source="arXiv:2502.09992",
    param_dtype="bfloat16",
    remat=True,
    microbatch=1,
)

"""h2o-danube-3-4b [dense] — llama+mistral mix with sliding-window attention.

[arXiv:2401.16818] H2O-Danube series. 24L d_model=3840 32H (GQA kv=8)
d_ff=10240 vocab=32000, SWA.
"""
from repro.configs.base import ATTN_SWA, ModelConfig, SPAConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    arch_type="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    vocab_size=32_000,
    layer_pattern=(ATTN_SWA,),
    window=4096,
    act="silu",
    tie_embeddings=False,
    spa=SPAConfig(identifier="singular", rank=128),
    source="arXiv:2401.16818",
    param_dtype="bfloat16",
    remat=True,
    microbatch=1,
)

"""Configuration dataclasses for the SPA-Cache framework.

Every architecture in the assigned pool is expressed as a ``ModelConfig``;
the paper's technique is configured via ``SPAConfig`` and the canonical
input shapes via ``ShapeConfig``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

# Layer kinds understood by the transformer assembler.
ATTN_FULL = "attn"          # full bidirectional GQA attention
ATTN_SWA = "swa"            # sliding-window attention
ATTN_LOCAL = "local"        # gemma2-style local (sliding window) layer
ATTN_GLOBAL = "global"      # gemma2-style global (full) layer
RGLRU = "rglru"             # RecurrentGemma gated linear recurrence block
SSD = "ssd"                 # Mamba2 state-space duality mixer

ATTENTION_KINDS = (ATTN_FULL, ATTN_SWA, ATTN_LOCAL, ATTN_GLOBAL)
RECURRENT_KINDS = (RGLRU, SSD)


@dataclasses.dataclass(frozen=True)
class SPAConfig:
    """Configuration of the paper's caching technique (Algorithm 1).

    identifier:
      none      — vanilla decoding, no cache (paper's BASELINE row)
      value     — full d-dim Value-state proxy (dLLM-Cache, Liu et al. 2025b)
      singular  — the paper's rank-r singular proxy (Sec. 3.3)
      query/key/attn_in/attn_out — Table-1 ablation identifiers
      window    — dKV-Cache style locality heuristic (Ma et al. 2025)
    schedule:
      uniform   — fixed rho across layers (prior work)
      adaptive  — piecewise-Gaussian rho(l) of Eq. (5)
    """

    identifier: str = "singular"
    rank: int = 128
    schedule: str = "adaptive"
    rho_peak: float = 0.25          # rho_p
    rho_first: float = 0.03         # rho_1
    rho_last: float = 0.13          # rho_L
    layer_peak: Optional[int] = None  # l_p (1-indexed); None -> ceil(0.6 * L)
    n_buckets: int = 6              # contiguous-layer quantization for lax.scan
    refresh_interval: int = 0       # full refresh every k steps (0 = never)
    locality_window: int = 64       # for identifier == "window"
    incremental_ident: bool = False  # beyond-paper: recompute proxies only
                                     # for rows whose inputs changed

    def resolved_layer_peak(self, n_layers: int) -> int:
        if self.layer_peak is not None:
            return self.layer_peak
        return max(1, math.ceil(0.6 * n_layers))


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    n_shared_experts: int = 0
    d_ff_shared: int = 0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 SSD mixer parameters."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma RG-LRU block parameters."""

    d_rnn: Optional[int] = None      # None -> d_model
    conv_width: int = 4
    n_heads: int = 0                 # block-diagonal gate heads; 0 -> dense gates


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    layer_pattern: Tuple[str, ...] = (ATTN_FULL,)
    window: int = 4096              # sliding window for swa/local layers
    logit_softcap: float = 0.0      # gemma2 final-logit softcap
    attn_softcap: float = 0.0       # gemma2 attention-logit softcap
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    spa: SPAConfig = dataclasses.field(default_factory=SPAConfig)
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    act: str = "silu"               # silu (gated) | gelu (gated) | gelu_plain
    tie_embeddings: bool = True
    is_encoder_only: bool = False   # hubert: no decode step
    frontend: Optional[str] = None  # None | "audio" | "vision"
    frontend_tokens: int = 0        # number of stub modality tokens prepended
    mask_token_id: int = 0          # DLM [MASK]; resolved at init to vocab-1
    source: str = ""                # citation for the config
    post_norms: bool = False        # gemma-style post-attn/post-ffn norms
    embed_scale: bool = False       # gemma-style sqrt(d) embedding scale
    max_position: int = 0           # >0: learned abs positions (encoder-only)
    zero3: bool = False             # shard params over data axis too
    tp_weights: bool = True         # False: replicate all weights (small
                                    # models; kills TP collectives)
    accum_dtype: str = "float32"    # grad-accumulation/AR dtype
    accum_unroll: bool = False      # python-loop microbatches (lets XLA
                                    # CSE ZeRO-3 weight gathers across them)
    # -- numerics / execution --
    param_dtype: str = "float32"
    cache_dtype: str = "float32"    # "int8" enables quantized caches
    remat: bool = False
    microbatch: int = 0             # grad-accum microbatches (0 = off)
    scan_layers: bool = True        # scan over layer stacks when homogeneous

    def kind_of_layer(self, l: int) -> str:
        return self.layer_pattern[l % len(self.layer_pattern)]

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        return tuple(self.kind_of_layer(l) for l in range(self.n_layers))

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def kind_index(self, l: int) -> int:
        """Index of layer ``l`` within the stack of its own kind."""
        kind = self.kind_of_layer(l)
        return sum(1 for j in range(l) if self.kind_of_layer(j) == kind)

    def n_layers_of_kind(self, kind: str) -> int:
        return sum(1 for k in self.layer_kinds if k == kind)

    @property
    def mask_id(self) -> int:
        return self.vocab_size - 1 if self.mask_token_id == 0 else self.mask_token_id

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, L = self.d_model, self.n_layers
        total = self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * d
        for l in range(L):
            kind = self.kind_of_layer(l)
            if kind in ATTENTION_KINDS:
                total += d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
                total += self._ffn_params()
            elif kind == RGLRU:
                dr = (self.rglru.d_rnn or d) if self.rglru else d
                total += 2 * d * dr + dr * d + 3 * dr  # in/out proj + gates
                total += self._ffn_params()
            elif kind == SSD:
                ssm = self.ssm or SSMConfig()
                di = ssm.d_inner(d)
                nh = ssm.n_heads(d)
                total += d * (2 * di + 2 * ssm.d_state + nh) + di * d
                if self.d_ff > 0:
                    total += self._ffn_params()
            total += 2 * d  # norms
        return total

    def _ffn_params(self) -> int:
        d = self.d_model
        if self.moe is not None:
            m = self.moe
            per = 3 * d * m.d_ff_expert
            total = m.n_experts * per + d * m.n_experts  # experts + router
            if m.n_shared_experts:
                total += m.n_shared_experts * 3 * d * m.d_ff_shared
            return total
        if self.d_ff == 0:
            return 0
        mult = 3 if self.act in ("silu", "gelu") else 2
        return mult * d * self.d_ff

    def active_param_count(self) -> int:
        """Active params per token (= dense count for non-MoE)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        m = self.moe
        dense = self.param_count()
        moe_layers = sum(
            1 for l in range(self.n_layers)
            if self.kind_of_layer(l) in ATTENTION_KINDS
        )
        all_experts = moe_layers * m.n_experts * 3 * d * m.d_ff_expert
        active_experts = moe_layers * m.top_k * 3 * d * m.d_ff_expert
        return dense - all_experts + active_experts


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A small same-family variant of ``cfg`` for CPU smoke tests."""
    small = dict(
        n_layers=2,
        d_model=min(cfg.d_model, 128),
        n_heads=min(cfg.n_heads, 4),
        n_kv_heads=min(cfg.n_kv_heads, 2),
        head_dim=32,
        d_ff=min(cfg.d_ff, 256) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        window=min(cfg.window, 64),
        microbatch=0,
        remat=False,
        param_dtype="float32",
        cache_dtype="float32",
        frontend_tokens=min(cfg.frontend_tokens, 16),
    )
    if cfg.moe is not None:
        small["moe"] = dataclasses.replace(
            cfg.moe,
            n_experts=min(cfg.moe.n_experts, 4),
            top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=min(cfg.moe.d_ff_expert, 128),
            d_ff_shared=min(cfg.moe.d_ff_shared, 128),
        )
    if cfg.ssm is not None:
        small["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=16, head_dim=16, chunk_size=16)
    if cfg.rglru is not None:
        small["rglru"] = dataclasses.replace(
            cfg.rglru, d_rnn=None, n_heads=min(cfg.rglru.n_heads or 4, 4))
    if cfg.spa is not None:
        small["spa"] = dataclasses.replace(cfg.spa, rank=16)
    small.update(overrides)
    # Keep pattern but clip peak layer.
    out = dataclasses.replace(cfg, **small)
    if out.spa.layer_peak is not None and out.spa.layer_peak > out.n_layers:
        out = dataclasses.replace(
            out, spa=dataclasses.replace(out.spa, layer_peak=None))
    return out

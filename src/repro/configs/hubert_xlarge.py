"""hubert-xlarge [audio] — encoder-only transformer (wav2vec2 arch).

[arXiv:2106.07447] HuBERT. 48L d_model=1280 16H (MHA kv=16) d_ff=5120
vocab=504 (cluster codebook). The conv/mel frontend is stubbed per the
carve-out; input_specs() provides precomputed frame embeddings.
Encoder-only: no decode step (decode shapes skipped, see DESIGN.md).
"""
from repro.configs.base import ATTN_FULL, ModelConfig, SPAConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    arch_type="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    layer_pattern=(ATTN_FULL,),
    act="gelu_plain",
    tie_embeddings=False,
    is_encoder_only=True,
    frontend="audio",
    spa=SPAConfig(identifier="singular", rank=64),
    source="arXiv:2106.07447",
    max_position=32_768,
    param_dtype="bfloat16",
    remat=True,
    microbatch=1,
)

"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, pattern 1:2.

[arXiv:2402.19427] Griffin/RecurrentGemma. 38L d_model=4096 16H (MQA kv=1)
d_ff=12288 vocab=256000. Pattern: two RG-LRU blocks followed by one local
(sliding-window) attention block.
"""
from repro.configs.base import (ATTN_LOCAL, RGLRU, ModelConfig, RGLRUConfig,
                                SPAConfig)

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    arch_type="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256_000,
    layer_pattern=(RGLRU, RGLRU, ATTN_LOCAL),
    window=2048,
    rglru=RGLRUConfig(d_rnn=4096, conv_width=4, n_heads=16),
    act="gelu",
    tie_embeddings=True,
    spa=SPAConfig(identifier="singular", rank=128),
    source="arXiv:2402.19427",
    post_norms=True,
    embed_scale=True,
    param_dtype="bfloat16",
    remat=True,
    microbatch=1,
)

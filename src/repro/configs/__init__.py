"""Architecture and shape registry."""
from repro.configs import (deepseek_67b, dream_7b, gemma2_2b, h2o_danube3_4b,
                           hubert_xlarge, internlm2_1_8b, internvl2_76b,
                           llada_8b, mamba2_370m, mixtral_8x22b,
                           qwen3_moe_235b_a22b, recurrentgemma_9b)
from repro.configs.base import (DECODE_32K, LONG_500K, PREFILL_32K, SHAPES,
                                TRAIN_4K, ModelConfig, MoEConfig, RGLRUConfig,
                                ShapeConfig, SPAConfig, SSMConfig, reduced)

ARCHS = {
    c.name: c
    for c in (
        gemma2_2b.CONFIG,
        deepseek_67b.CONFIG,
        recurrentgemma_9b.CONFIG,
        hubert_xlarge.CONFIG,
        internlm2_1_8b.CONFIG,
        internvl2_76b.CONFIG,
        qwen3_moe_235b_a22b.CONFIG,
        mamba2_370m.CONFIG,
        mixtral_8x22b.CONFIG,
        h2o_danube3_4b.CONFIG,
        llada_8b.CONFIG,
        dream_7b.CONFIG,
    )
}

ASSIGNED = [
    "gemma2-2b", "deepseek-67b", "recurrentgemma-9b", "hubert-xlarge",
    "internlm2-1.8b", "internvl2-76b", "qwen3-moe-235b-a22b", "mamba2-370m",
    "mixtral-8x22b", "h2o-danube-3-4b",
]

# Archs with sub-quadratic sequence mixing (eligible for long_500k).
SUBQUADRATIC = {"recurrentgemma-9b", "mamba2-370m", "mixtral-8x22b",
                "h2o-danube-3-4b"}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """Whether (arch, shape) is a valid combination (see DESIGN.md)."""
    if shape.kind == "decode" and cfg.is_encoder_only:
        return False  # encoder-only: no decode step
    if shape.name == "long_500k" and cfg.name not in SUBQUADRATIC:
        return False  # needs sub-quadratic attention
    return True


__all__ = [
    "ARCHS", "ASSIGNED", "SHAPES", "SUBQUADRATIC",
    "ModelConfig", "MoEConfig", "RGLRUConfig", "SSMConfig", "SPAConfig",
    "ShapeConfig", "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
    "get_arch", "get_shape", "supports_shape", "reduced",
]

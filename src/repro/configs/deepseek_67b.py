"""deepseek-67b [dense] — llama-architecture dense model.

[arXiv:2401.02954] DeepSeek LLM. 95L d_model=8192 64H (GQA kv=8)
d_ff=22016 vocab=102400.
"""
from repro.configs.base import ATTN_FULL, ModelConfig, SPAConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    arch_type="dense",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=102_400,
    layer_pattern=(ATTN_FULL,),
    act="silu",
    tie_embeddings=False,
    spa=SPAConfig(identifier="singular", rank=128),
    source="arXiv:2401.02954",
    zero3=True,
    param_dtype="bfloat16",
    cache_dtype="int8",   # H/KV caches are TB-scale at 32k x 128 otherwise
    remat=True,
    microbatch=1,
)

"""Pallas kernel: Mamba-2 SSD chunked scan (one head).

The state-space-duality schedule from arXiv:2405.21060 §6 mapped onto
TPU: grid (n_chunks,) is sequential, the inter-chunk state S [hd, ds]
lives in VMEM scratch, and each step runs the dual quadratic form on the
MXU:

  y_intra = ((C B^T) ∘ exp(la_i - la_j) ∘ 1[j<=i] ∘ dt_j) X
  y_inter = (C S^T) ∘ exp(la_i)
  S'      = exp(la_end) S + X^T (exp(la_end - la_j) dt_j ∘ B)

Inputs are per-head; the ops wrapper vmaps over heads/batch. la is the
in-chunk cumulative sum of dt * a (precomputed, elementwise).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, la_ref, b_ref, c_ref, o_ref, s_scr, *,
                cs: int):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    x = x_ref[...].astype(jnp.float32)          # [cs, hd]
    dt = dt_ref[...].astype(jnp.float32)        # [cs]
    la = la_ref[...].astype(jnp.float32)        # [cs]
    b = b_ref[...].astype(jnp.float32)          # [cs, ds]
    c = c_ref[...].astype(jnp.float32)          # [cs, ds]

    g = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [cs,cs]
    ii = jax.lax.broadcasted_iota(jnp.int32, (cs, cs), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (cs, cs), 1)
    decay = jnp.exp(la[:, None] - la[None, :])
    m = jnp.where(jj <= ii, g * decay * dt[None, :], 0.0)
    y_intra = jax.lax.dot_general(m, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    s = s_scr[...]                              # [hd, ds]
    y_inter = jax.lax.dot_general(c, s, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_inter = y_inter * jnp.exp(la)[:, None]    # [cs, hd]

    o_ref[...] = (y_intra + y_inter).astype(o_ref.dtype)

    la_end = la[cs - 1]
    w = jnp.exp(la_end - la) * dt               # [cs]
    s_new = jnp.exp(la_end) * s + jax.lax.dot_general(
        x, b * w[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)     # [hd, ds]
    s_scr[...] = s_new


def ssd_chunk_scan(x: jax.Array, dt: jax.Array, la: jax.Array,
                   b: jax.Array, c: jax.Array, *, chunk: int = 128,
                   interpret: bool = False) -> jax.Array:
    """Single-head SSD scan. x: [T, hd]; dt, la: [T] (la = in-chunk
    cumulative sum of dt * a — resets every ``chunk``); b, c: [T, ds].
    Returns y: [T, hd]."""
    t, hd = x.shape
    ds = b.shape[1]
    cs = min(chunk, t)
    assert t % cs == 0, (t, cs)
    nc = t // cs

    return pl.pallas_call(
        functools.partial(_ssd_kernel, cs=cs),
        grid=(nc,),
        in_specs=[
            pl.BlockSpec((cs, hd), lambda j: (j, 0)),
            pl.BlockSpec((cs,), lambda j: (j,)),
            pl.BlockSpec((cs,), lambda j: (j,)),
            pl.BlockSpec((cs, ds), lambda j: (j, 0)),
            pl.BlockSpec((cs, ds), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((cs, hd), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((t, hd), x.dtype),
        scratch_shapes=[pltpu.VMEM((hd, ds), jnp.float32)],
        interpret=interpret,
    )(x, dt, la, b, c)

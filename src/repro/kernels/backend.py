"""KernelBackend — pluggable kernel dispatch for the serve hot path.

The per-step hot loop of ``core.spa_layer.spa_attn_block`` has four
kernel-shaped stages: Phase-1 identification (projection + drift
scoring), the Phase-1 epilogue (gather + rms_norm of the selected
rows), Phase-2 gathered-query attention, and the Phase-2/3 cache
commits (row scatters).  A :class:`KernelBackend` owns all four, so the
whole layer step runs either through pure-XLA ops or through the Pallas
TPU kernel suite — selected per ``DecodeSession``/``spa_forward`` call
and threaded through ``CacheStrategy`` (a frozen-dataclass field), so
jitted steps close over the backend statically exactly like strategies
and schedulers: switching backend retraces once, switching request does
not.

  ``XlaBackend``    — the current jnp ops (the oracle; default).
  ``PallasBackend`` — TPU kernels (``kernels/*``); interpret mode on
                      CPU.  Decodes byte-identically to ``XlaBackend``
                      for every registered strategy and scheduler
                      (tests/test_backend_parity.py) because the
                      kernels mirror the XLA numerics op-for-op.

Dispatch rules (DESIGN.md §4.5): top-k/stratified SELECTION always
stays in XLA (tiny, latency-bound, and ``jax.lax.top_k`` is already
optimal on TPU); the Pallas identification path engages only when the
strategy's projection is a plain matrix (``projection_matrix``) or the
identity, and only when the strategy keeps the base cosine ``score`` —
anything else falls back to the strategy's own ops, so custom
strategies stay correct on either backend.
"""
from __future__ import annotations

import dataclasses
from typing import Any, ClassVar, Dict, Optional

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class KernelBackend:
    """Protocol base: the four hot-path stages of one SPA layer step."""

    name: ClassVar[str] = "abstract"

    def identifier_scores(self, strategy, bp: Params, proxy_mat,
                          x: jax.Array, p_cached: jax.Array):
        """Phase 1: project x and score drift. Returns (scores, p_now)."""
        raise NotImplementedError

    def score_drift(self, strategy, p_now: jax.Array,
                    p_cached: jax.Array) -> jax.Array:
        """Score-only drift (incremental rescore, attn_out momentum)."""
        raise NotImplementedError

    def gather_norm(self, h: jax.Array, idx: jax.Array,
                    weight: jax.Array, eps: float):
        """Phase-1 epilogue: returns (rows [B,k,d], rms-normed rows)."""
        raise NotImplementedError

    def attention(self, q, k, v, *, k_scale=None, v_scale=None,
                  q_positions=None, window: int = 0, soft_cap: float = 0.0,
                  banded: bool = False, q_span: int = 0) -> jax.Array:
        """Phase 2: (gathered-)query flash attention vs the KV cache."""
        raise NotImplementedError

    def scatter_multi(self, buffers: Dict[str, jax.Array], idx: jax.Array,
                      rows: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
        """Phase 2/3 commit: scatter row payloads into cache buffers."""
        raise NotImplementedError

    # -- shared fallback helpers ------------------------------------

    @staticmethod
    def _base_score(strategy) -> bool:
        """Whether the strategy keeps the protocol's cosine ``score``."""
        from repro.core.strategy import CacheStrategy
        return type(strategy).score is CacheStrategy.score


@dataclasses.dataclass(frozen=True)
class XlaBackend(KernelBackend):
    """Pure-jnp ops (the oracle): exactly the pre-backend serve path."""

    name: ClassVar[str] = "xla"

    def identifier_scores(self, strategy, bp, proxy_mat, x, p_cached):
        p_now = strategy.project(x, bp, proxy_mat)
        return strategy.score(p_now, p_cached), p_now

    def score_drift(self, strategy, p_now, p_cached):
        return strategy.score(p_now, p_cached)

    def gather_norm(self, h, idx, weight, eps):
        from repro.core import selection
        from repro.models import common
        rows = selection.gather_rows(h, idx)
        return rows, common.rms_norm(rows, weight, eps)

    def attention(self, q, k, v, *, k_scale=None, v_scale=None,
                  q_positions=None, window=0, soft_cap=0.0, banded=False,
                  q_span=0):
        from repro.models.attention import flash_attention
        return flash_attention(q, k, v, k_scale=k_scale, v_scale=v_scale,
                               q_positions=q_positions, window=window,
                               soft_cap=soft_cap, banded=banded,
                               q_span=q_span)

    def scatter_multi(self, buffers, idx, rows):
        from repro.core import selection
        return {name: selection.scatter_rows(buffers[name], idx, r)
                for name, r in rows.items()}


@dataclasses.dataclass(frozen=True)
class PallasBackend(KernelBackend):
    """The Pallas TPU kernel suite on the hot path.

    ``interpret=None`` resolves per process: real Mosaic lowering on a
    TPU backend, interpret mode elsewhere (CPU CI validates the exact
    TPU program logic).  ``block_q``/``block_k`` mirror the XLA flash
    defaults so the online-softmax block structure — and therefore the
    f32 accumulation order — is identical across backends.
    """

    interpret: Optional[bool] = None
    block_q: int = 512
    block_k: int = 512

    name: ClassVar[str] = "pallas"

    def _interp(self) -> bool:
        if self.interpret is not None:
            return self.interpret
        return jax.default_backend() != "tpu"

    def identifier_scores(self, strategy, bp, proxy_mat, x, p_cached):
        from repro.kernels import proxy_score as ps
        if not self._base_score(strategy):
            return XLA_BACKEND.identifier_scores(strategy, bp, proxy_mat,
                                                 x, p_cached)
        mat = strategy.projection_matrix(bp, proxy_mat)
        if mat is not None:
            return ps.proxy_score(x, mat, p_cached,
                                  interpret=self._interp())
        p_now = strategy.project(x, bp, proxy_mat)
        if p_now is x:      # identity projection (attn_in): score-only
            return ps.cosine_drift(x, p_cached,
                                   interpret=self._interp()), p_now
        # inexpressible projection: strategy's own ops (stays correct)
        return strategy.score(p_now, p_cached), p_now

    def score_drift(self, strategy, p_now, p_cached):
        from repro.kernels import proxy_score as ps
        if not self._base_score(strategy):
            return strategy.score(p_now, p_cached)
        return ps.cosine_drift(p_now, p_cached, interpret=self._interp())

    def gather_norm(self, h, idx, weight, eps):
        from repro.kernels import proxy_score as ps
        return ps.gather_norm(h, idx, weight, eps,
                              interpret=self._interp())

    def attention(self, q, k, v, *, k_scale=None, v_scale=None,
                  q_positions=None, window=0, soft_cap=0.0, banded=False,
                  q_span=0):
        from repro.kernels import sparse_attention as sa
        b, sq = q.shape[:2]
        if q_positions is None:     # contiguous canvas: span = q block
            q_positions = jnp.broadcast_to(jnp.arange(sq)[None], (b, sq))
            q_span = min(self.block_q, sq)
        return sa.sparse_attention(
            q, k, v, q_positions, k_scale=k_scale, v_scale=v_scale,
            window=window, soft_cap=soft_cap, banded=banded,
            q_span=q_span, block_q=self.block_q, block_k=self.block_k,
            interpret=self._interp())

    def scatter_multi(self, buffers, idx, rows):
        from repro.kernels import scatter_update as sc
        names = sorted(rows)        # deterministic kernel operand order
        outs = sc.scatter_update_multi(
            [buffers[n] for n in names], idx, [rows[n] for n in names],
            interpret=self._interp())
        return dict(zip(names, outs))


XLA_BACKEND = XlaBackend()
PALLAS_BACKEND = PallasBackend()

REGISTRY: Dict[str, KernelBackend] = {
    "xla": XLA_BACKEND,
    "pallas": PALLAS_BACKEND,
}


def resolve_backend(backend) -> KernelBackend:
    """Accept a KernelBackend instance or a registry name."""
    if isinstance(backend, str):
        try:
            return REGISTRY[backend]
        except KeyError:
            raise ValueError(f"unknown kernel backend {backend!r}; "
                             f"registered: {sorted(REGISTRY)}") from None
    return backend

"""KernelBackend — pluggable kernel dispatch for the serve hot path.

The per-step hot loop of ``core.spa_layer.spa_attn_block`` has four
kernel-shaped stages: Phase-1 identification (projection + drift
scoring), the Phase-1 epilogue (gather + rms_norm of the selected
rows), Phase-2 gathered-query attention, and the Phase-2/3 cache
commits (row scatters).  A :class:`KernelBackend` owns all four, so the
whole layer step runs either through pure-XLA ops or through the Pallas
TPU kernel suite — selected per ``DecodeSession``/``spa_forward`` call
and threaded through ``CacheStrategy`` (a frozen-dataclass field), so
jitted steps close over the backend statically exactly like strategies
and schedulers: switching backend retraces once, switching request does
not.

  ``XlaBackend``    — the current jnp ops (the oracle; default).
  ``PallasBackend`` — TPU kernels (``kernels/*``); interpret mode on
                      CPU.  Decodes byte-identically to ``XlaBackend``
                      for every registered strategy and scheduler
                      (tests/test_backend_parity.py) because the
                      kernels mirror the XLA numerics op-for-op.

Dispatch rules (DESIGN.md §4.5): top-k/stratified SELECTION always
stays in XLA (tiny, latency-bound, and ``jax.lax.top_k`` is already
optimal on TPU); the Pallas identification path engages only when the
strategy's projection is a plain matrix (``projection_matrix``) or the
identity, and only when the strategy keeps the base cosine ``score`` —
anything else falls back to the strategy's own ops, so custom
strategies stay correct on either backend.
"""
from __future__ import annotations

import dataclasses
from typing import Any, ClassVar, Dict, Optional

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class KernelBackend:
    """Protocol base: the four hot-path stages of one SPA layer step."""

    name: ClassVar[str] = "abstract"

    def identifier_scores(self, strategy, bp: Params, proxy_mat,
                          x: jax.Array, p_cached: jax.Array,
                          page_table: Optional[jax.Array] = None):
        """Phase 1: project x and score drift. Returns (scores, p_now).

        With ``page_table`` ([B, n_log] int32), ``p_cached`` is a pooled
        page arena [P, page, r] instead of a dense [B, N, r] buffer
        (DESIGN.md §5): scoring reads the cached identifiers through
        page-table indirection."""
        raise NotImplementedError

    def score_drift(self, strategy, p_now: jax.Array,
                    p_cached: jax.Array,
                    page_table: Optional[jax.Array] = None) -> jax.Array:
        """Score-only drift (incremental rescore, attn_out momentum).
        ``page_table`` as in :meth:`identifier_scores`."""
        raise NotImplementedError

    def gather_norm(self, h: jax.Array, idx: jax.Array,
                    weight: jax.Array, eps: float):
        """Phase-1 epilogue: returns (rows [B,k,d], rms-normed rows)."""
        raise NotImplementedError

    def attention(self, q, k, v, *, k_scale=None, v_scale=None,
                  q_positions=None, window: int = 0, soft_cap: float = 0.0,
                  banded: bool = False, q_span: int = 0,
                  kv_len=None) -> jax.Array:
        """Phase 2: (gathered-)query flash attention vs the KV cache.
        ``kv_len`` [B]: per-row valid canvas length (paged serving)."""
        raise NotImplementedError

    def scatter_multi(self, buffers: Dict[str, jax.Array], idx: jax.Array,
                      rows: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
        """Phase 2/3 commit: scatter row payloads into cache buffers."""
        raise NotImplementedError

    # -- paged cache pool stages (DESIGN.md §5) ---------------------

    def gather_pages(self, arena: jax.Array,
                     page_table: jax.Array) -> jax.Array:
        """arena [L, P, page, ...] + page table [B, n_log] -> dense view
        [L, B, n_log*page, ...]."""
        raise NotImplementedError

    def scatter_pages(self, arena: jax.Array, page_table: jax.Array,
                      dense: jax.Array) -> jax.Array:
        """Write a dense view back through the page table (writes to the
        reserved zero page are dropped)."""
        raise NotImplementedError

    def scatter_rows_paged(self, arena: jax.Array, page_table: jax.Array,
                           idx: jax.Array, rows: jax.Array) -> jax.Array:
        """Commit row payloads [B, k, ...] at logical canvas rows idx
        [B, k] into ONE layer's pooled arena [P, page, ...] through the
        page table (zero-page / out-of-range rows dropped)."""
        raise NotImplementedError

    # -- shared fallback helpers ------------------------------------

    @staticmethod
    def _base_score(strategy) -> bool:
        """Whether the strategy keeps the protocol's cosine ``score``."""
        from repro.core.strategy import CacheStrategy
        return type(strategy).score is CacheStrategy.score


@dataclasses.dataclass(frozen=True)
class XlaBackend(KernelBackend):
    """Pure-jnp ops (the oracle): exactly the pre-backend serve path."""

    name: ClassVar[str] = "xla"

    def identifier_scores(self, strategy, bp, proxy_mat, x, p_cached,
                          page_table=None):
        if page_table is not None:
            p_cached = self.gather_pages(p_cached[None], page_table)[0]
        p_now = strategy.project(x, bp, proxy_mat)
        return strategy.score(p_now, p_cached), p_now

    def score_drift(self, strategy, p_now, p_cached, page_table=None):
        if page_table is not None:
            p_cached = self.gather_pages(p_cached[None], page_table)[0]
        return strategy.score(p_now, p_cached)

    def gather_norm(self, h, idx, weight, eps):
        from repro.core import selection
        from repro.models import common
        rows = selection.gather_rows(h, idx)
        return rows, common.rms_norm(rows, weight, eps)

    def attention(self, q, k, v, *, k_scale=None, v_scale=None,
                  q_positions=None, window=0, soft_cap=0.0, banded=False,
                  q_span=0, kv_len=None):
        from repro.models.attention import flash_attention
        return flash_attention(q, k, v, k_scale=k_scale, v_scale=v_scale,
                               q_positions=q_positions, window=window,
                               soft_cap=soft_cap, banded=banded,
                               q_span=q_span, kv_len=kv_len)

    def scatter_multi(self, buffers, idx, rows):
        from repro.core import selection
        return {name: selection.scatter_rows(buffers[name], idx, r)
                for name, r in rows.items()}

    def gather_pages(self, arena, page_table):
        shape = arena.shape
        l, page = shape[0], shape[2]
        b, n_log = page_table.shape
        out = jnp.take(arena, page_table, axis=1)   # [L, B, n_log, page, .]
        return out.reshape((l, b, n_log * page) + shape[3:])

    def scatter_pages(self, arena, page_table, dense):
        shape = arena.shape
        l, p, page = shape[0], shape[1], shape[2]
        b, n_log = page_table.shape
        dense = dense.reshape((l, b, n_log, page) + shape[3:])
        # zero-page writes route out of bounds and drop (page 0 is the
        # pool's reserved all-zero page, shared by every short row's tail)
        pt_w = jnp.where(page_table > 0, page_table, p).astype(jnp.int32)
        return arena.at[:, pt_w].set(dense.astype(arena.dtype),
                                     mode="drop")

    def scatter_rows_paged(self, arena, page_table, idx, rows):
        shape = arena.shape
        p, page = shape[0], shape[1]
        b, n_log = page_table.shape
        idx = idx.astype(jnp.int32)
        lpage = idx // page
        pid = jnp.take_along_axis(
            page_table.astype(jnp.int32),
            jnp.clip(lpage, 0, n_log - 1), axis=1)
        phys = pid * page + idx % page
        # drop: sentinel / out-of-range logical rows and zero-page rows
        ok = jnp.logical_and(jnp.logical_and(idx >= 0, lpage < n_log),
                             pid > 0)
        phys = jnp.where(ok, phys, p * page)
        flat = arena.reshape((p * page,) + shape[2:])
        out = flat.at[phys.reshape(-1)].set(
            rows.reshape((-1,) + flat.shape[1:]).astype(arena.dtype),
            mode="drop")
        return out.reshape(shape)


@dataclasses.dataclass(frozen=True)
class PallasBackend(KernelBackend):
    """The Pallas TPU kernel suite on the hot path.

    ``interpret=None`` resolves per process: real Mosaic lowering on a
    TPU backend, interpret mode elsewhere (CPU CI validates the exact
    TPU program logic).  ``block_q``/``block_k`` mirror the XLA flash
    defaults so the online-softmax block structure — and therefore the
    f32 accumulation order — is identical across backends.
    """

    interpret: Optional[bool] = None
    block_q: int = 512
    block_k: int = 512

    name: ClassVar[str] = "pallas"

    def _interp(self) -> bool:
        if self.interpret is not None:
            return self.interpret
        return jax.default_backend() != "tpu"

    def identifier_scores(self, strategy, bp, proxy_mat, x, p_cached,
                          page_table=None):
        from repro.kernels import proxy_score as ps
        if not self._base_score(strategy):
            return XLA_BACKEND.identifier_scores(strategy, bp, proxy_mat,
                                                 x, p_cached,
                                                 page_table=page_table)
        mat = strategy.projection_matrix(bp, proxy_mat)
        if page_table is not None:
            if mat is not None:
                return ps.proxy_score_paged(x, mat, p_cached, page_table,
                                            interpret=self._interp())
            p_now = strategy.project(x, bp, proxy_mat)
            if p_now is x:  # identity projection: paged score-only
                return ps.cosine_drift_paged(
                    x, p_cached, page_table,
                    interpret=self._interp()), p_now
            p_dense = self.gather_pages(p_cached[None], page_table)[0]
            return strategy.score(p_now, p_dense), p_now
        if mat is not None:
            return ps.proxy_score(x, mat, p_cached,
                                  interpret=self._interp())
        p_now = strategy.project(x, bp, proxy_mat)
        if p_now is x:      # identity projection (attn_in): score-only
            return ps.cosine_drift(x, p_cached,
                                   interpret=self._interp()), p_now
        # inexpressible projection: strategy's own ops (stays correct)
        return strategy.score(p_now, p_cached), p_now

    def score_drift(self, strategy, p_now, p_cached, page_table=None):
        from repro.kernels import proxy_score as ps
        if not self._base_score(strategy):
            if page_table is not None:
                p_cached = self.gather_pages(p_cached[None],
                                             page_table)[0]
            return strategy.score(p_now, p_cached)
        if page_table is not None:
            return ps.cosine_drift_paged(p_now, p_cached, page_table,
                                         interpret=self._interp())
        return ps.cosine_drift(p_now, p_cached, interpret=self._interp())

    def gather_norm(self, h, idx, weight, eps):
        from repro.kernels import proxy_score as ps
        return ps.gather_norm(h, idx, weight, eps,
                              interpret=self._interp())

    def attention(self, q, k, v, *, k_scale=None, v_scale=None,
                  q_positions=None, window=0, soft_cap=0.0, banded=False,
                  q_span=0, kv_len=None):
        from repro.kernels import sparse_attention as sa
        b, sq = q.shape[:2]
        if q_positions is None:     # contiguous canvas: span = q block
            q_positions = jnp.broadcast_to(jnp.arange(sq)[None], (b, sq))
            q_span = min(self.block_q, sq)
        return sa.sparse_attention(
            q, k, v, q_positions, k_scale=k_scale, v_scale=v_scale,
            window=window, soft_cap=soft_cap, banded=banded,
            q_span=q_span, block_q=self.block_q, block_k=self.block_k,
            kv_len=kv_len, interpret=self._interp())

    def scatter_multi(self, buffers, idx, rows):
        from repro.kernels import scatter_update as sc
        names = sorted(rows)        # deterministic kernel operand order
        outs = sc.scatter_update_multi(
            [buffers[n] for n in names], idx, [rows[n] for n in names],
            interpret=self._interp())
        return dict(zip(names, outs))

    def gather_pages(self, arena, page_table):
        from repro.kernels import scatter_update as sc
        return sc.gather_pages(arena, page_table,
                               interpret=self._interp())

    def scatter_pages(self, arena, page_table, dense):
        from repro.kernels import scatter_update as sc
        return sc.scatter_pages(arena, page_table, dense,
                                interpret=self._interp())

    def scatter_rows_paged(self, arena, page_table, idx, rows):
        from repro.kernels import scatter_update as sc
        return sc.scatter_rows_paged(arena, page_table, idx, rows,
                                     interpret=self._interp())


XLA_BACKEND = XlaBackend()
PALLAS_BACKEND = PallasBackend()

REGISTRY: Dict[str, KernelBackend] = {
    "xla": XLA_BACKEND,
    "pallas": PALLAS_BACKEND,
}


def resolve_backend(backend) -> KernelBackend:
    """Accept a KernelBackend instance or a registry name."""
    if isinstance(backend, str):
        try:
            return REGISTRY[backend]
        except KeyError:
            raise ValueError(f"unknown kernel backend {backend!r}; "
                             f"registered: {sorted(REGISTRY)}") from None
    return backend

"""Pallas kernel: fused singular-proxy projection + drift scoring.

The paper's identification hot spot (Fig. 4): p = x @ W_r followed by a
rowwise cosine similarity against the cached identifiers. On GPU these are
two kernels with an HBM round-trip for p; on TPU we fuse them — x streams
HBM -> VMEM once per block, the projection runs on the MXU (r is padded to
a multiple of 128 by construction), and the similarity reduction runs on
the VPU while the block is still resident.

Grid: (N / block_n,). VMEM per step: block_n*d (x) + d*r (W_r) +
2*block_n*r (p_now, p_cached) floats — block_n chosen so this fits ~8 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _proxy_score_kernel(x_ref, w_ref, pc_ref, scores_ref, pnow_ref, *,
                        eps: float):
    x = x_ref[...].astype(jnp.float32)           # [bn, d]
    w = w_ref[...].astype(jnp.float32)           # [d, r]
    pc = pc_ref[...].astype(jnp.float32)         # [bn, r]
    p = jax.lax.dot_general(x, w, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    num = jnp.sum(p * pc, axis=-1)
    den = jnp.sqrt(jnp.sum(p * p, axis=-1) * jnp.sum(pc * pc, axis=-1))
    scores_ref[...] = num / jnp.maximum(den, eps)
    pnow_ref[...] = p.astype(pnow_ref.dtype)


def proxy_score_block_n(d: int, r: int, vmem_budget: int = 8 * 2 ** 20
                        ) -> int:
    per_row = (d + 2 * r) * 4
    bn = max(8, min(1024, (vmem_budget - d * r * 4) // max(per_row, 1)))
    # round down to a multiple of 8 (sublane)
    return max(8, (bn // 8) * 8)


def proxy_score(x: jax.Array, proxy_mat: jax.Array, p_cached: jax.Array,
                *, eps: float = 1e-8, block_n: int = 0,
                interpret: bool = False):
    """x: [N, d]; proxy_mat: [d, r]; p_cached: [N, r].
    Returns (scores [N] f32, p_now [N, r] in x.dtype)."""
    n, d = x.shape
    r = proxy_mat.shape[1]
    bn = block_n or proxy_score_block_n(d, r)
    bn = min(bn, n)
    pad = (-n) % bn
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        p_cached = jnp.pad(p_cached, ((0, pad), (0, 0)))
    n_p = x.shape[0]

    scores, p_now = pl.pallas_call(
        functools.partial(_proxy_score_kernel, eps=eps),
        grid=(n_p // bn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((d, r), lambda i: (0, 0)),
            pl.BlockSpec((bn, r), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn, r), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_p,), jnp.float32),
            jax.ShapeDtypeStruct((n_p, r), x.dtype),
        ],
        interpret=interpret,
    )(x, proxy_mat, p_cached)
    return scores[:n], p_now[:n]

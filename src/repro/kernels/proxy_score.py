"""Pallas kernels for SPA-Cache Phase 1 (identification) hot spots.

``proxy_score``: the paper's identification kernel (Fig. 4): p = x @ W_r
followed by a rowwise cosine similarity against the cached identifiers.
On GPU these are two kernels with an HBM round-trip for p; on TPU we fuse
them — x streams HBM -> VMEM once per block, the projection runs on the
MXU (r is padded to a multiple of 128 by construction), and the
similarity reduction runs on the VPU while the block is still resident.
The batch dimension is a real grid axis (serve batches never round-trip
through a vmap-of-interpret shim).

``cosine_drift``: the projection-free variant (attn_in identifier, the
incremental-identifier full-N rescore): same single pass over the rows,
no matmul.

``gather_norm``: Phase-1 epilogue — the k SELECTED rows are gathered
from the full residual stream and rms-normed in one pass, emitting both
the raw rows (for the residual add) and the normed rows (for QKV): one
HBM read of k rows instead of a gather plus a second norm pass.

Numerics are matched to the XLA serve path bit-for-bit: the projection
accumulates in f32, rounds through the storage dtype, and the cosine is
computed on the ROUNDED p (exactly what ``strategy.project`` followed by
``strategy.score`` produces), so ``PallasBackend`` decodes byte-identically
to ``XlaBackend`` (tests/test_backend_parity.py).

Grids: proxy_score/cosine_drift (B, N / block_n) — VMEM per step:
block_n*d (x) + d*r (W_r) + 2*block_n*r floats, block_n chosen to fit
~8 MB.  gather_norm (B, k / block_g) with the row indices in SMEM and the
full stream in ANY memory; each row moves HBM->VMEM once (the per-row
dynamic-slice load lowers to a DMA, like scatter_update's stores).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _cosine(p: jax.Array, pc: jax.Array, eps: float) -> jax.Array:
    num = jnp.sum(p * pc, axis=-1)
    den = jnp.sqrt(jnp.sum(p * p, axis=-1) * jnp.sum(pc * pc, axis=-1))
    return num / jnp.maximum(den, eps)


def _proxy_score_kernel(x_ref, w_ref, pc_ref, scores_ref, pnow_ref, *,
                        eps: float):
    x = x_ref[0].astype(jnp.float32)             # [bn, d]
    w = w_ref[...].astype(jnp.float32)           # [d, r]
    pc = pc_ref[0].astype(jnp.float32)           # [bn, r]
    p = jax.lax.dot_general(x, w, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # round p through the storage dtype BEFORE scoring — the XLA path
    # scores on the bf16 projection it commits, and byte-parity of the
    # selections requires scoring the same values.
    p_store = p.astype(pnow_ref.dtype)
    scores_ref[0] = _cosine(p_store.astype(jnp.float32), pc, eps)
    pnow_ref[0] = p_store


def _cosine_drift_kernel(x_ref, pc_ref, scores_ref, *, eps: float):
    x = x_ref[0].astype(jnp.float32)
    pc = pc_ref[0].astype(jnp.float32)
    scores_ref[0] = _cosine(x, pc, eps)


def proxy_score_block_n(d: int, r: int, vmem_budget: int = 8 * 2 ** 20
                        ) -> int:
    per_row = (d + 2 * r) * 4
    bn = max(8, min(1024, (vmem_budget - d * r * 4) // max(per_row, 1)))
    # round down to a multiple of 8 (sublane)
    return max(8, (bn // 8) * 8)


def _batched(*arrays):
    """Add a size-1 batch axis to 2D inputs (legacy unbatched callers)."""
    return tuple(a if a is None or a.ndim == 3 else a[None]
                 for a in arrays)


def proxy_score(x: jax.Array, proxy_mat: jax.Array, p_cached: jax.Array,
                *, eps: float = 1e-8, block_n: int = 0,
                interpret: bool = False):
    """x: [B, N, d] (or [N, d]); proxy_mat: [d, r]; p_cached: [B, N, r].
    Returns (scores [B, N] f32, p_now [B, N, r] in x.dtype)."""
    unbatched = x.ndim == 2
    x, p_cached = _batched(x, p_cached)
    b, n, d = x.shape
    r = proxy_mat.shape[1]
    bn = block_n or proxy_score_block_n(d, r)
    bn = min(bn, n)
    pad = (-n) % bn
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        p_cached = jnp.pad(p_cached, ((0, 0), (0, pad), (0, 0)))
    n_p = x.shape[1]

    scores, p_now = pl.pallas_call(
        functools.partial(_proxy_score_kernel, eps=eps),
        grid=(b, n_p // bn),
        in_specs=[
            pl.BlockSpec((1, bn, d), lambda bb, i: (bb, i, 0)),
            pl.BlockSpec((d, r), lambda bb, i: (0, 0)),
            pl.BlockSpec((1, bn, r), lambda bb, i: (bb, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bn), lambda bb, i: (bb, i)),
            pl.BlockSpec((1, bn, r), lambda bb, i: (bb, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n_p), jnp.float32),
            jax.ShapeDtypeStruct((b, n_p, r), x.dtype),
        ],
        interpret=interpret,
    )(x, proxy_mat, p_cached)
    scores, p_now = scores[:, :n], p_now[:, :n]
    return (scores[0], p_now[0]) if unbatched else (scores, p_now)


def cosine_drift(x: jax.Array, p_cached: jax.Array, *, eps: float = 1e-8,
                 block_n: int = 0, interpret: bool = False) -> jax.Array:
    """Projection-free drift: cosine(x, p_cached) per row.
    x, p_cached: [B, N, r] (or [N, r]).  Returns [B, N] f32."""
    unbatched = x.ndim == 2
    x, p_cached = _batched(x, p_cached)
    b, n, r = x.shape
    bn = block_n or proxy_score_block_n(r, r)
    bn = min(bn, n)
    pad = (-n) % bn
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        p_cached = jnp.pad(p_cached, ((0, 0), (0, pad), (0, 0)))
    n_p = x.shape[1]

    scores = pl.pallas_call(
        functools.partial(_cosine_drift_kernel, eps=eps),
        grid=(b, n_p // bn),
        in_specs=[
            pl.BlockSpec((1, bn, r), lambda bb, i: (bb, i, 0)),
            pl.BlockSpec((1, bn, r), lambda bb, i: (bb, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda bb, i: (bb, i)),
        out_shape=jax.ShapeDtypeStruct((b, n_p), jnp.float32),
        interpret=interpret,
    )(x, p_cached)
    scores = scores[:, :n]
    return scores[0] if unbatched else scores


# ---------------------------------------------------------------------------
# Paged variants (DESIGN.md §5): the cached identifier vectors live in a
# pooled page arena [P, page, r] addressed through a per-row page table
# rather than a dense [B, N, r] buffer.  The fused projection+scoring
# pass is unchanged — cached pages are pulled VMEM-resident one
# contiguous DMA at a time (page ids prefetched through SMEM) while the
# projection block is still live, so paging adds indirection but no
# extra HBM round-trip.  Numerics are identical to gathering the pages
# dense and running ``proxy_score``/``cosine_drift`` (pages are exact
# copies), which is exactly what the XLA oracle backend does.
# ---------------------------------------------------------------------------


def _proxy_score_paged_kernel(pt_ref, x_ref, w_ref, a_ref, scores_ref,
                              pnow_ref, *, eps: float, ppb: int,
                              page: int):
    x = x_ref[0].astype(jnp.float32)             # [ppb*page, d]
    w = w_ref[...].astype(jnp.float32)           # [d, r]
    p = jax.lax.dot_general(x, w, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    p_store = p.astype(pnow_ref.dtype)
    pnow_ref[0] = p_store
    pf = p_store.astype(jnp.float32)
    for t in range(ppb):                         # unrolled: ppb is small
        pid = pt_ref[0, t]
        pc = a_ref[pl.dslice(pid, 1), :, :][0].astype(jnp.float32)
        scores_ref[0, t * page:(t + 1) * page] = _cosine(
            pf[t * page:(t + 1) * page], pc, eps)


def _pages_per_block(n_log: int, page: int, d: int, r: int) -> int:
    ppb = max(1, proxy_score_block_n(d, r) // page)
    ppb = min(ppb, n_log)
    while n_log % ppb:
        ppb -= 1
    return ppb


def proxy_score_paged(x: jax.Array, proxy_mat: jax.Array,
                      arena: jax.Array, pt: jax.Array, *,
                      eps: float = 1e-8, interpret: bool = False):
    """Fused Phase-1 identification against a PAGED identifier cache.

    x: [B, N, d]; proxy_mat: [d, r]; arena: [P, page, r] pooled pages;
    pt: [B, n_log] page table (N == n_log * page).  Returns
    (scores [B, N] f32, p_now [B, N, r] in x.dtype) — byte-identical to
    gathering the pages dense and calling :func:`proxy_score`."""
    b, n, d = x.shape
    page, r = arena.shape[1], arena.shape[2]
    n_log = pt.shape[1]
    assert n == n_log * page, (n, n_log, page)
    ppb = _pages_per_block(n_log, page, d, r)
    bn = ppb * page

    scores, p_now = pl.pallas_call(
        functools.partial(_proxy_score_paged_kernel, eps=eps, ppb=ppb,
                          page=page),
        grid=(b, n_log // ppb),
        in_specs=[
            pl.BlockSpec((1, ppb), lambda bb, i: (bb, i),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, bn, d), lambda bb, i: (bb, i, 0)),
            pl.BlockSpec((d, r), lambda bb, i: (0, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=[
            pl.BlockSpec((1, bn), lambda bb, i: (bb, i)),
            pl.BlockSpec((1, bn, r), lambda bb, i: (bb, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n), jnp.float32),
            jax.ShapeDtypeStruct((b, n, r), x.dtype),
        ],
        interpret=interpret,
    )(pt.astype(jnp.int32), x, proxy_mat, arena)
    return scores, p_now


def _cosine_drift_paged_kernel(pt_ref, x_ref, a_ref, scores_ref, *,
                               eps: float, ppb: int, page: int):
    xf = x_ref[0].astype(jnp.float32)            # [ppb*page, r]
    for t in range(ppb):
        pid = pt_ref[0, t]
        pc = a_ref[pl.dslice(pid, 1), :, :][0].astype(jnp.float32)
        scores_ref[0, t * page:(t + 1) * page] = _cosine(
            xf[t * page:(t + 1) * page], pc, eps)


def cosine_drift_paged(x: jax.Array, arena: jax.Array, pt: jax.Array, *,
                       eps: float = 1e-8,
                       interpret: bool = False) -> jax.Array:
    """Projection-free paged drift: cosine(x[b, n], page(n)) per row.
    x: [B, N, r]; arena: [P, page, r]; pt: [B, n_log].  Returns [B, N]
    f32 — byte-identical to the dense gather + :func:`cosine_drift`."""
    b, n, r = x.shape
    page = arena.shape[1]
    n_log = pt.shape[1]
    assert n == n_log * page, (n, n_log, page)
    ppb = _pages_per_block(n_log, page, r, r)
    bn = ppb * page

    scores = pl.pallas_call(
        functools.partial(_cosine_drift_paged_kernel, eps=eps, ppb=ppb,
                          page=page),
        grid=(b, n_log // ppb),
        in_specs=[
            pl.BlockSpec((1, ppb), lambda bb, i: (bb, i),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, bn, r), lambda bb, i: (bb, i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda bb, i: (bb, i)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=interpret,
    )(pt.astype(jnp.int32), x, arena)
    return scores


def _gather_norm_kernel(idx_ref, w_ref, h_ref, rows_ref, normed_ref, *,
                        eps: float, gb: int):
    bb = pl.program_id(0)
    w = w_ref[...].astype(jnp.float32)            # [d]

    def body(j, carry):
        ri = idx_ref[0, j]
        row = h_ref[pl.dslice(bb, 1), pl.dslice(ri, 1), :]     # [1, 1, d]
        rows_ref[0, pl.dslice(j, 1), :] = row[0]
        rf = row[0, 0].astype(jnp.float32)
        var = jnp.mean(rf * rf)
        normed = (rf * jax.lax.rsqrt(var + eps)) * (1.0 + w)
        normed_ref[0, pl.dslice(j, 1), :] = normed[None].astype(
            normed_ref.dtype)
        return carry

    jax.lax.fori_loop(0, gb, body, 0)


def gather_norm(h: jax.Array, idx: jax.Array, weight: jax.Array,
                eps: float = 1e-6, *, block_g: int = 128,
                interpret: bool = False):
    """Fused gathered-row rms_norm (Phase-1 epilogue).

    h: [B, N, d]; idx: [B, k] (out-of-range clamps like a "clip"-mode
    gather); weight: [d] rms_norm scale.  Returns (rows [B, k, d] raw,
    normed [B, k, d]) — one pass over the k selected rows.
    """
    b, n, d = h.shape
    k = idx.shape[1]
    idx = jnp.clip(idx.astype(jnp.int32), 0, n - 1)
    gb = min(block_g, k)
    pad = (-k) % gb
    if pad:
        idx = jnp.pad(idx, ((0, 0), (0, pad)))   # clamped dupes, sliced off
    kp = idx.shape[1]

    rows, normed = pl.pallas_call(
        functools.partial(_gather_norm_kernel, eps=eps, gb=gb),
        grid=(b, kp // gb),
        in_specs=[
            pl.BlockSpec((1, gb), lambda bb, i: (bb, i),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((d,), lambda bb, i: (0,)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=[
            pl.BlockSpec((1, gb, d), lambda bb, i: (bb, i, 0)),
            pl.BlockSpec((1, gb, d), lambda bb, i: (bb, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, kp, d), h.dtype),
            jax.ShapeDtypeStruct((b, kp, d), h.dtype),
        ],
        interpret=interpret,
    )(idx, weight, h)
    return rows[:, :k], normed[:, :k]

"""Pallas kernel: chunked gated linear recurrence (RG-LRU core).

h_t = a_t * h_{t-1} + b_t, elementwise over the channel dim. The grid is
(channel_blocks, seq_chunks) with the chunk dim minor (sequential on
TPU); the carry h lives in VMEM scratch, so the recurrence streams the
sequence through VMEM once — the memory-bound optimum. Within a chunk
the scan is an unrolled VPU loop over rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, o_ref, h_scr, *, chunk: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[...].astype(jnp.float32)     # [chunk, bd]
    b = b_ref[...].astype(jnp.float32)

    def body(t, h):
        h = a[t] * h + b[t]
        o_ref[pl.dslice(t, 1), :] = h[None].astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, body, h_scr[...])
    h_scr[...] = h


def rglru_scan(a: jax.Array, b: jax.Array, *, chunk: int = 128,
               block_d: int = 512, interpret: bool = False) -> jax.Array:
    """a, b: [N, d] -> h: [N, d] with h_t = a_t * h_{t-1} + b_t."""
    n, d = a.shape
    chunk = min(chunk, n)
    bd = min(block_d, d)
    pad_n = (-n) % chunk
    pad_d = (-d) % bd
    if pad_n or pad_d:
        a = jnp.pad(a, ((0, pad_n), (0, pad_d)))
        b = jnp.pad(b, ((0, pad_n), (0, pad_d)))
    np_, dp = a.shape

    out = pl.pallas_call(
        functools.partial(_rglru_kernel, chunk=chunk),
        grid=(dp // bd, np_ // chunk),
        in_specs=[
            pl.BlockSpec((chunk, bd), lambda di, j: (j, di)),
            pl.BlockSpec((chunk, bd), lambda di, j: (j, di)),
        ],
        out_specs=pl.BlockSpec((chunk, bd), lambda di, j: (j, di)),
        out_shape=jax.ShapeDtypeStruct((np_, dp), a.dtype),
        scratch_shapes=[pltpu.VMEM((bd,), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return out[:n, :d]

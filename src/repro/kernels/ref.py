"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def proxy_score_ref(x: jax.Array, proxy_mat: jax.Array,
                    p_cached: jax.Array, eps: float = 1e-8):
    """x: [N, d]; proxy_mat: [d, r]; p_cached: [N, r].
    Returns (scores [N], p_now [N, r]) — scores = cosine(p_now, p_cached),
    scored on p_now AFTER rounding through x.dtype (the value the serve
    path commits and scores — the kernel matches this bit-for-bit).
    """
    p_now = (x.astype(jnp.float32)
             @ proxy_mat.astype(jnp.float32)).astype(x.dtype)
    pf = p_now.astype(jnp.float32)
    pc = p_cached.astype(jnp.float32)
    num = jnp.sum(pf * pc, axis=-1)
    den = jnp.sqrt(jnp.sum(pf * pf, axis=-1) * jnp.sum(pc * pc, axis=-1))
    scores = num / jnp.maximum(den, eps)
    return scores, p_now


def sparse_attention_ref(q, k, v, q_pos, *, k_scale=None, v_scale=None,
                         window=0, soft_cap=0.0):
    """q: [k, H, hd]; k/v: [N, KVH, hd]; q_pos: [k] (original positions).
    GQA + bidirectional window + softcap. Returns [k, H, hd]."""
    nq, h, hd = q.shape
    n, kvh, _ = k.shape
    g = h // kvh
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if k_scale is not None:      # [N, KVH] per-row dequant scales
        kf = kf * k_scale.astype(jnp.float32)[..., None]
    if v_scale is not None:
        vf = vf * v_scale.astype(jnp.float32)[..., None]
    qr = q.reshape(nq, kvh, g, hd).astype(jnp.float32)
    scores = jnp.einsum("qhgd,khd->qhgk", qr, kf) / (hd ** 0.5)
    if soft_cap > 0:
        scores = soft_cap * jnp.tanh(scores / soft_cap)
    if window > 0:
        dist = jnp.abs(q_pos[:, None] - jnp.arange(n)[None, :])
        mask = (dist <= window)[:, None, None, :]
        scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("qhgk,khd->qhgd", p, vf)
    return out.reshape(nq, h, hd).astype(q.dtype)


def scatter_update_ref(cache: jax.Array, idx: jax.Array,
                       rows: jax.Array) -> jax.Array:
    """cache: [N, d]; idx: [k]; rows: [k, d] -> updated cache."""
    return cache.at[idx].set(rows.astype(cache.dtype))


def rglru_scan_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t over axis 0. a, b: [N, d] -> h [N, d]."""
    def step(h, inp):
        a_t, b_t = inp
        h = a_t * h + b_t
        return h, h

    h0 = jnp.zeros((a.shape[1],), jnp.float32)
    _, hs = jax.lax.scan(step, h0, (a.astype(jnp.float32),
                                    b.astype(jnp.float32)))
    return hs.astype(a.dtype)


def ssd_chunk_ref(x, dt, a_scalar_steps, b, c):
    """Sequential single-head SSD oracle. x: [T, hd]; dt: [T];
    a_scalar_steps: [T] = dt_t * a (log-decay per step); b, c: [T, ds]."""
    t, hd = x.shape

    def step(s, inp):
        xi, dti, lai, bi, ci = inp
        s = jnp.exp(lai) * s + dti * jnp.outer(xi, bi)
        y = s @ ci
        return s, y

    s0 = jnp.zeros((hd, b.shape[1]), jnp.float32)
    _, ys = jax.lax.scan(
        step, s0, (x.astype(jnp.float32), dt.astype(jnp.float32),
                   a_scalar_steps.astype(jnp.float32),
                   b.astype(jnp.float32), c.astype(jnp.float32)))
    return ys.astype(x.dtype)

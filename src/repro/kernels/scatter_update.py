"""Pallas kernel: scatter k refreshed rows into a cache buffer in place.

The Upd module of Algorithm 1 (K/V/H cache writes). The cache is aliased
input->output (no copy); the grid walks index blocks, row indices live in
SMEM, row payloads stream through VMEM, and each row is written with a
dynamic-slice store.

NOTE on hardware: the per-row store to the full-cache ref lowers to a
VMEM->HBM DMA per row on TPU; a production variant would batch rows into
contiguous runs (sorted indices make runs common) and issue strided
async copies. Correctness is validated in interpret mode against
ref.scatter_update_ref; the batching optimization only changes DMA
granularity.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scatter_kernel(idx_ref, rows_ref, cache_ref, o_ref, *, bk: int,
                    n: int):
    del cache_ref  # aliased with o_ref; only written

    def body(i, carry):
        row_idx = idx_ref[i]

        @pl.when(row_idx < n)
        def _():
            o_ref[pl.dslice(row_idx, 1), :] = (
                rows_ref[pl.dslice(i, 1), :].astype(o_ref.dtype))

        return carry

    jax.lax.fori_loop(0, bk, body, 0)


def scatter_update(cache: jax.Array, idx: jax.Array, rows: jax.Array,
                   *, block_k: int = 128,
                   interpret: bool = False) -> jax.Array:
    """cache: [N, d]; idx: [k] int32; rows: [k, d]. Returns updated cache.

    The cache buffer is donated (input_output_aliases) — in-place on TPU.
    """
    n, d = cache.shape
    k = idx.shape[0]
    bk = min(block_k, k)
    pad = (-k) % bk
    if pad:
        idx = jnp.pad(idx, (0, pad), constant_values=n + 1)  # masked out
        rows = jnp.pad(rows, ((0, pad), (0, 0)))
    kp = idx.shape[0]

    return pl.pallas_call(
        functools.partial(_scatter_kernel, bk=bk, n=n),
        grid=(kp // bk,),
        in_specs=[
            pl.BlockSpec((bk,), lambda i: (i,), memory_space=pltpu.SMEM),
            pl.BlockSpec((bk, d), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        out_shape=jax.ShapeDtypeStruct((n, d), cache.dtype),
        input_output_aliases={2: 0},
        interpret=interpret,
    )(idx.astype(jnp.int32), rows, cache)

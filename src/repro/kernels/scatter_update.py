"""Pallas kernel: scatter k refreshed rows into cache buffers in place.

The Upd module of Algorithm 1 (K/V/H^c/proxy cache writes).  All buffers
are aliased input->output (no copy); the grid walks (batch, index-block)
steps, row indices live in SMEM, row payloads stream through VMEM, and
rows are written with dynamic-slice stores into the full cache refs.

``scatter_update_multi`` commits an arbitrary set of cache buffers (K,
V, H, proxy, int8 scales — any mix of dtypes/row widths) for a whole
[B, N, ·] cache slice in ONE aliased call, so a layer's Phase-2 commit
(k+v+scales) and Phase-3 commit (h+scale+proxy) each cost a single
kernel launch instead of one scatter per buffer.

DMA granularity: selection indices arrive SORTED (top-k positions are
sorted before the gather), so runs of consecutive indices are common.
The kernel walks ``run``-sized chunks and, when a chunk is exactly
contiguous (idx[i+t] == idx[i]+t for every t), issues ONE ``run``-row
dynamic-slice store per buffer — a batched VMEM->HBM DMA — falling back
to per-row stores otherwise.  Correctness is validated in interpret
mode against ref.scatter_update_ref; the batching only changes DMA
granularity.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scatter_multi_kernel(idx_ref, *refs, n_bufs: int, bk: int, run: int,
                          n: int):
    rows_refs = refs[:n_bufs]
    o_refs = refs[2 * n_bufs:]          # cache_refs aliased; only written
    bb = pl.program_id(0)

    def store(row_idx, src_off, length):
        for o_ref, r_ref in zip(o_refs, rows_refs):
            o_ref[pl.dslice(bb, 1), pl.dslice(row_idx, length), :] = (
                r_ref[0, pl.dslice(src_off, length), :].astype(
                    o_ref.dtype)[None])

    def chunk(c, carry):
        i0 = c * run
        first = idx_ref[0, i0]
        last = idx_ref[0, i0 + run - 1]

        # Endpoint spread alone is NOT sufficient (an unsorted chunk like
        # [5, 20, 7, 9, 2, 3, 4, 12] has last - first == run - 1): every
        # element must sit exactly at first + t for the batched DMA store
        # to land rows where they belong.
        def elem_ok(t, ok):
            return jnp.logical_and(ok, idx_ref[0, i0 + t] == first + t)

        contig = jax.lax.fori_loop(
            1, run, elem_ok,
            jnp.logical_and(first >= 0, last < n))

        @pl.when(contig)
        def _batched():
            store(first, i0, run)

        @pl.when(jnp.logical_not(contig))
        def _rowwise():
            def one(t, cc):
                ri = idx_ref[0, i0 + t]

                @pl.when(jnp.logical_and(ri >= 0, ri < n))
                def _():
                    store(ri, i0 + t, 1)

                return cc

            jax.lax.fori_loop(0, run, one, 0)

        return carry

    jax.lax.fori_loop(0, bk // run, chunk, 0)


def _flat(a: jax.Array) -> jax.Array:
    """[B, N, *f] -> [B, N, prod(f)] (row payload as one minor axis)."""
    b, n = a.shape[:2]
    return a.reshape(b, n, -1) if a.ndim != 3 else a


def scatter_update_multi(caches: Sequence[jax.Array], idx: jax.Array,
                         rows: Sequence[jax.Array], *, block_k: int = 128,
                         run: int = 8, interpret: bool = False
                         ) -> Tuple[jax.Array, ...]:
    """caches[i]: [B, N, ...]; idx: [B, k] int32 (any order; entries
    outside [0, N) are dropped; SORTED indices batch into contiguous DMA
    stores); rows[i]: [B, k, ...] payloads.  Returns the updated caches
    (all buffers committed in one aliased call)."""
    shapes = [c.shape for c in caches]
    caches = [_flat(c) for c in caches]
    rows = [_flat(r) for r in rows]
    b, n = caches[0].shape[:2]
    k = idx.shape[1]
    bk = min(block_k, k)
    pad = (-k) % bk
    if pad:
        idx = jnp.pad(idx, ((0, 0), (0, pad)), constant_values=n)
        rows = [jnp.pad(r, ((0, 0), (0, pad), (0, 0))) for r in rows]
    kp = idx.shape[1]
    run = max(1, min(run, bk))
    while bk % run:
        run -= 1
    m = len(caches)

    outs = pl.pallas_call(
        functools.partial(_scatter_multi_kernel, n_bufs=m, bk=bk,
                          run=run, n=n),
        grid=(b, kp // bk),
        in_specs=(
            [pl.BlockSpec((1, bk), lambda bb, i: (bb, i),
                          memory_space=pltpu.SMEM)]
            + [pl.BlockSpec((1, bk, r.shape[-1]),
                            lambda bb, i: (bb, i, 0)) for r in rows]
            + [pl.BlockSpec(memory_space=pl.ANY)] * m),
        out_specs=[pl.BlockSpec(memory_space=pl.ANY)] * m,
        out_shape=[jax.ShapeDtypeStruct(c.shape, c.dtype) for c in caches],
        input_output_aliases={1 + m + j: j for j in range(m)},
        interpret=interpret,
    )(idx.astype(jnp.int32), *rows, *caches)
    return tuple(o.reshape(s) for o, s in zip(outs, shapes))


# ---------------------------------------------------------------------------
# Paged variants (DESIGN.md §5): cache rows live in a pooled arena of
# fixed-size pages; logical canvas row n of batch row b resolves to
# physical row  pt[b, n // page] * page + n % page.  Page ids ride in
# SMEM (scalar prefetch), page payloads move as ONE contiguous DMA per
# page (pages are contiguous in the arena by construction), and physical
# page 0 is the pool's reserved zero page — never written, so logical
# pages past a request's ``kv_len`` can all alias it.
# ---------------------------------------------------------------------------


def _gather_pages_kernel(pt_ref, a_ref, o_ref):
    ll, bb, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    del bb  # pt block is already the (bb, :) row
    pid = pt_ref[0, j]
    o_ref[0, 0] = a_ref[pl.dslice(ll, 1), pl.dslice(pid, 1), :, :][0, 0]


def gather_pages(arena: jax.Array, pt: jax.Array, *,
                 interpret: bool = False) -> jax.Array:
    """arena: [L, P, page, ...feat]; pt: [B, n_log] int32 page table.
    Returns the dense view [L, B, n_log*page, ...feat] — one contiguous
    VMEM<-HBM DMA per (layer, batch row, logical page)."""
    shape = arena.shape
    l, p, page = shape[0], shape[1], shape[2]
    arena3 = arena.reshape(l, p, page, -1)
    f = arena3.shape[-1]
    b, n_log = pt.shape
    out = pl.pallas_call(
        _gather_pages_kernel,
        grid=(l, b, n_log),
        in_specs=[
            pl.BlockSpec((1, n_log), lambda ll, bb, j: (bb, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((1, 1, page, f),
                               lambda ll, bb, j: (ll, bb, j, 0)),
        out_shape=jax.ShapeDtypeStruct((l, b, n_log * page, f),
                                       arena.dtype),
        interpret=interpret,
    )(pt.astype(jnp.int32), arena3)
    return out.reshape((l, b, n_log * page) + shape[3:])


def _scatter_pages_kernel(pt_ref, d_ref, a_ref, o_ref):
    del a_ref                                # aliased input; only written
    ll, j = pl.program_id(0), pl.program_id(2)
    pid = pt_ref[0, j]

    @pl.when(pid > 0)                        # page 0 = reserved zero page
    def _():
        o_ref[pl.dslice(ll, 1), pl.dslice(pid, 1), :, :] = (
            d_ref[...].astype(o_ref.dtype))


def scatter_pages(arena: jax.Array, pt: jax.Array, dense: jax.Array, *,
                  interpret: bool = False) -> jax.Array:
    """Inverse of :func:`gather_pages`: write the dense view back through
    the page table (arena aliased input->output; writes to the zero page
    are dropped, so tail pages of short rows stay zero)."""
    shape = arena.shape
    l, p, page = shape[0], shape[1], shape[2]
    arena3 = arena.reshape(l, p, page, -1)
    f = arena3.shape[-1]
    b, n_log = pt.shape
    dense3 = dense.reshape(l, b, n_log * page, f)
    out = pl.pallas_call(
        _scatter_pages_kernel,
        grid=(l, b, n_log),
        in_specs=[
            pl.BlockSpec((1, n_log), lambda ll, bb, j: (bb, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, page, f), lambda ll, bb, j: (ll, bb, j, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        out_shape=jax.ShapeDtypeStruct(arena3.shape, arena.dtype),
        input_output_aliases={2: 0},
        interpret=interpret,
    )(pt.astype(jnp.int32), dense3, arena3)
    return out.reshape(shape)


def _scatter_rows_paged_kernel(idx_ref, pt_ref, r_ref, a_ref, o_ref, *,
                               bk: int, run: int, page: int, n_log: int):
    del a_ref

    def store(pid, off, src_off, length):
        o_ref[pl.dslice(pid, 1), pl.dslice(off, length), :] = (
            r_ref[0, pl.dslice(src_off, length), :].astype(
                o_ref.dtype)[None])

    def chunk(c, carry):
        i0 = c * run
        first = idx_ref[0, i0]
        fpage = first // page
        foff = first % page

        # One batched DMA iff the chunk is exactly consecutive AND stays
        # inside one physical page (runs never span pages — the arena is
        # only contiguous within a page).
        def elem_ok(t, ok):
            return jnp.logical_and(ok, idx_ref[0, i0 + t] == first + t)

        contig = jax.lax.fori_loop(
            1, run, elem_ok,
            jnp.logical_and(jnp.logical_and(first >= 0, fpage < n_log),
                            foff + run <= page))
        fpid = pt_ref[0, jnp.minimum(fpage, n_log - 1)]
        contig = jnp.logical_and(contig, fpid > 0)

        @pl.when(contig)
        def _batched():
            store(fpid, foff, i0, run)

        @pl.when(jnp.logical_not(contig))
        def _rowwise():
            def one(t, cc):
                ri = idx_ref[0, i0 + t]
                rpage = ri // page
                ok = jnp.logical_and(ri >= 0, rpage < n_log)
                pid = pt_ref[0, jnp.minimum(rpage, n_log - 1)]

                @pl.when(jnp.logical_and(ok, pid > 0))
                def _():
                    store(pid, ri % page, i0 + t, 1)

                return cc

            jax.lax.fori_loop(0, run, one, 0)

        return carry

    jax.lax.fori_loop(0, bk // run, chunk, 0)


def scatter_rows_paged(arena: jax.Array, pt: jax.Array, idx: jax.Array,
                       rows: jax.Array, *, block_k: int = 128,
                       run: int = 8, interpret: bool = False) -> jax.Array:
    """Row-granular paged commit: arena is ONE layer's pooled buffer
    [P, page, ...feat] SHARED by all batch rows (each row's page-table
    row maps into disjoint pages); idx [B, k] logical canvas rows
    (sorted common; out-of-range/zero-page rows dropped); rows
    [B, k, ...feat].  Returns the updated arena (aliased
    input->output)."""
    shape = arena.shape
    p, page = shape[0], shape[1]
    arena3 = arena.reshape(p, page, -1)
    f = arena3.shape[-1]
    b, k = idx.shape
    n_log = pt.shape[1]
    rows3 = rows.reshape(b, k, -1)
    bk = min(block_k, k)
    pad = (-k) % bk
    if pad:
        idx = jnp.pad(idx, ((0, 0), (0, pad)),
                      constant_values=n_log * page)
        rows3 = jnp.pad(rows3, ((0, 0), (0, pad), (0, 0)))
    kp = idx.shape[1]
    run = max(1, min(run, bk, page))
    while bk % run:
        run -= 1

    out = pl.pallas_call(
        functools.partial(_scatter_rows_paged_kernel, bk=bk, run=run,
                          page=page, n_log=n_log),
        grid=(b, kp // bk),
        in_specs=[
            pl.BlockSpec((1, bk), lambda bb, i: (bb, i),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, n_log), lambda bb, i: (bb, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, bk, f), lambda bb, i: (bb, i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        out_shape=jax.ShapeDtypeStruct(arena3.shape, arena.dtype),
        input_output_aliases={3: 0},
        interpret=interpret,
    )(idx.astype(jnp.int32), pt.astype(jnp.int32), rows3, arena3)
    return out.reshape(shape)


def scatter_update(cache: jax.Array, idx: jax.Array, rows: jax.Array,
                   *, block_k: int = 128,
                   interpret: bool = False) -> jax.Array:
    """cache: [N, d]; idx: [k] int32; rows: [k, d]. Returns updated cache.

    Single-buffer unbatched form of ``scatter_update_multi`` (the cache
    buffer is aliased input->output — in-place on TPU when the caller's
    buffer is donatable)."""
    (out,) = scatter_update_multi([cache[None]], idx[None], [rows[None]],
                                  block_k=block_k, interpret=interpret)
    return out[0]

"""Pallas kernel: scatter k refreshed rows into cache buffers in place.

The Upd module of Algorithm 1 (K/V/H^c/proxy cache writes).  All buffers
are aliased input->output (no copy); the grid walks (batch, index-block)
steps, row indices live in SMEM, row payloads stream through VMEM, and
rows are written with dynamic-slice stores into the full cache refs.

``scatter_update_multi`` commits an arbitrary set of cache buffers (K,
V, H, proxy, int8 scales — any mix of dtypes/row widths) for a whole
[B, N, ·] cache slice in ONE aliased call, so a layer's Phase-2 commit
(k+v+scales) and Phase-3 commit (h+scale+proxy) each cost a single
kernel launch instead of one scatter per buffer.

DMA granularity: selection indices arrive SORTED (top-k positions are
sorted before the gather), so runs of consecutive indices are common.
The kernel walks ``run``-sized chunks and, when a chunk is exactly
contiguous (idx[i+t] == idx[i]+t for every t), issues ONE ``run``-row
dynamic-slice store per buffer — a batched VMEM->HBM DMA — falling back
to per-row stores otherwise.  Correctness is validated in interpret
mode against ref.scatter_update_ref; the batching only changes DMA
granularity.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scatter_multi_kernel(idx_ref, *refs, n_bufs: int, bk: int, run: int,
                          n: int):
    rows_refs = refs[:n_bufs]
    o_refs = refs[2 * n_bufs:]          # cache_refs aliased; only written
    bb = pl.program_id(0)

    def store(row_idx, src_off, length):
        for o_ref, r_ref in zip(o_refs, rows_refs):
            o_ref[pl.dslice(bb, 1), pl.dslice(row_idx, length), :] = (
                r_ref[0, pl.dslice(src_off, length), :].astype(
                    o_ref.dtype)[None])

    def chunk(c, carry):
        i0 = c * run
        first = idx_ref[0, i0]
        last = idx_ref[0, i0 + run - 1]

        # Endpoint spread alone is NOT sufficient (an unsorted chunk like
        # [5, 20, 7, 9, 2, 3, 4, 12] has last - first == run - 1): every
        # element must sit exactly at first + t for the batched DMA store
        # to land rows where they belong.
        def elem_ok(t, ok):
            return jnp.logical_and(ok, idx_ref[0, i0 + t] == first + t)

        contig = jax.lax.fori_loop(
            1, run, elem_ok,
            jnp.logical_and(first >= 0, last < n))

        @pl.when(contig)
        def _batched():
            store(first, i0, run)

        @pl.when(jnp.logical_not(contig))
        def _rowwise():
            def one(t, cc):
                ri = idx_ref[0, i0 + t]

                @pl.when(jnp.logical_and(ri >= 0, ri < n))
                def _():
                    store(ri, i0 + t, 1)

                return cc

            jax.lax.fori_loop(0, run, one, 0)

        return carry

    jax.lax.fori_loop(0, bk // run, chunk, 0)


def _flat(a: jax.Array) -> jax.Array:
    """[B, N, *f] -> [B, N, prod(f)] (row payload as one minor axis)."""
    b, n = a.shape[:2]
    return a.reshape(b, n, -1) if a.ndim != 3 else a


def scatter_update_multi(caches: Sequence[jax.Array], idx: jax.Array,
                         rows: Sequence[jax.Array], *, block_k: int = 128,
                         run: int = 8, interpret: bool = False
                         ) -> Tuple[jax.Array, ...]:
    """caches[i]: [B, N, ...]; idx: [B, k] int32 (any order; entries
    outside [0, N) are dropped; SORTED indices batch into contiguous DMA
    stores); rows[i]: [B, k, ...] payloads.  Returns the updated caches
    (all buffers committed in one aliased call)."""
    shapes = [c.shape for c in caches]
    caches = [_flat(c) for c in caches]
    rows = [_flat(r) for r in rows]
    b, n = caches[0].shape[:2]
    k = idx.shape[1]
    bk = min(block_k, k)
    pad = (-k) % bk
    if pad:
        idx = jnp.pad(idx, ((0, 0), (0, pad)), constant_values=n)
        rows = [jnp.pad(r, ((0, 0), (0, pad), (0, 0))) for r in rows]
    kp = idx.shape[1]
    run = max(1, min(run, bk))
    while bk % run:
        run -= 1
    m = len(caches)

    outs = pl.pallas_call(
        functools.partial(_scatter_multi_kernel, n_bufs=m, bk=bk,
                          run=run, n=n),
        grid=(b, kp // bk),
        in_specs=(
            [pl.BlockSpec((1, bk), lambda bb, i: (bb, i),
                          memory_space=pltpu.SMEM)]
            + [pl.BlockSpec((1, bk, r.shape[-1]),
                            lambda bb, i: (bb, i, 0)) for r in rows]
            + [pl.BlockSpec(memory_space=pl.ANY)] * m),
        out_specs=[pl.BlockSpec(memory_space=pl.ANY)] * m,
        out_shape=[jax.ShapeDtypeStruct(c.shape, c.dtype) for c in caches],
        input_output_aliases={1 + m + j: j for j in range(m)},
        interpret=interpret,
    )(idx.astype(jnp.int32), *rows, *caches)
    return tuple(o.reshape(s) for o, s in zip(outs, shapes))


def scatter_update(cache: jax.Array, idx: jax.Array, rows: jax.Array,
                   *, block_k: int = 128,
                   interpret: bool = False) -> jax.Array:
    """cache: [N, d]; idx: [k] int32; rows: [k, d]. Returns updated cache.

    Single-buffer unbatched form of ``scatter_update_multi`` (the cache
    buffer is aliased input->output — in-place on TPU when the caller's
    buffer is donatable)."""
    (out,) = scatter_update_multi([cache[None]], idx[None], [rows[None]],
                                  block_k=block_k, interpret=interpret)
    return out[0]

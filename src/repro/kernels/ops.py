"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the
kernel body runs in Python, validating the exact TPU program logic against
the pure-jnp oracles in ref.py. On a TPU backend ``interpret=None``
resolves to False (real Mosaic lowering) — including for the batched
wrappers, which are thin jit shells over the kernels' native batch grid
axes (NOT vmaps of the unbatched forms).

Donation: ``scatter_update`` aliases the cache input to its output
INSIDE the kernel (in-place on TPU when XLA proves the buffer dead), but
the jit wrapper itself does NOT donate — callers routinely keep using
the pre-scatter array (oracle comparisons, retries), and a donated
buffer is deleted on dispatch (reading it afterwards raises).  Use
``scatter_update_donated`` on the serving path when the caller truly
hands the buffer over; tests/test_kernels.py pins both behaviours.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import (proxy_score as _ps, rglru_scan as _rg,
                           scatter_update as _sc, sparse_attention as _sa)


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def proxy_score(x, proxy_mat, p_cached, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _ps.proxy_score(x, proxy_mat, p_cached, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def cosine_drift(x, p_cached, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _ps.cosine_drift(x, p_cached, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def gather_norm(h, idx, weight, eps=1e-6, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _ps.gather_norm(h, idx, weight, eps, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("window", "soft_cap",
                                             "banded", "q_span",
                                             "interpret"))
def sparse_attention(q, k, v, q_pos, k_scale=None, v_scale=None,
                     window=0, soft_cap=0.0, banded=False, q_span=0,
                     interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _sa.sparse_attention(q, k, v, q_pos, k_scale=k_scale,
                                v_scale=v_scale, window=window,
                                soft_cap=soft_cap, banded=banded,
                                q_span=q_span, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def scatter_update(cache, idx, rows, interpret=None):
    """Non-donating form: ``cache`` stays readable after the call."""
    interpret = _default_interpret() if interpret is None else interpret
    return _sc.scatter_update(cache, idx, rows, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",),
                   donate_argnums=(0,))
def scatter_update_donated(cache, idx, rows, interpret=None):
    """Donating form: in-place on TPU; ``cache`` is DELETED on dispatch
    and must not be read afterwards."""
    interpret = _default_interpret() if interpret is None else interpret
    return _sc.scatter_update(cache, idx, rows, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def scatter_update_multi(caches, idx, rows, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _sc.scatter_update_multi(caches, idx, rows,
                                    interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def rglru_scan(a, b, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _rg.rglru_scan(a, b, interpret=interpret)


# Batched forms: same kernels — the batch dimension is a real grid axis,
# and interpret resolves per process like every other wrapper (the old
# shims vmapped the unbatched kernels with interpret hard-coded True,
# silently running the kernel body in Python on TPU).
batched_proxy_score = proxy_score
batched_sparse_attention = sparse_attention

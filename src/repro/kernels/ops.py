"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the
kernel body runs in Python, validating the exact TPU program logic against
the pure-jnp oracles in ref.py. On TPU set interpret=False (default when a
TPU backend is detected).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import (proxy_score as _ps, rglru_scan as _rg,
                           scatter_update as _sc, sparse_attention as _sa)


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def proxy_score(x, proxy_mat, p_cached, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _ps.proxy_score(x, proxy_mat, p_cached, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("window", "soft_cap",
                                             "interpret"))
def sparse_attention(q, k, v, q_pos, k_scale=None, v_scale=None,
                     window=0, soft_cap=0.0, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _sa.sparse_attention(q, k, v, q_pos, k_scale=k_scale,
                                v_scale=v_scale, window=window,
                                soft_cap=soft_cap, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",),
                   donate_argnums=(0,))
def scatter_update(cache, idx, rows, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _sc.scatter_update(cache, idx, rows, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def rglru_scan(a, b, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _rg.rglru_scan(a, b, interpret=interpret)


batched_proxy_score = jax.vmap(
    lambda x, w, pc: _ps.proxy_score(x, w, pc, interpret=True),
    in_axes=(0, None, 0))

batched_sparse_attention = jax.vmap(
    lambda q, k, v, qp: _sa.sparse_attention(q, k, v, qp, interpret=True),
    in_axes=(0, 0, 0, 0))

"""Pallas TPU kernels for SPA-Cache hot spots (validated interpret=True).

  proxy_score      — fused rank-r proxy projection + cosine drift scores
  sparse_attention — gathered-query flash attention vs full KV cache
  scatter_update   — in-place row scatter into cache buffers
  rglru_scan       — chunked gated linear recurrence (RecurrentGemma)
  ssd_chunk        — Mamba-2 SSD chunked scan (state-space duality)

Each has a pure-jnp oracle in ref.py and a jit wrapper in ops.py.
"""

"""Pallas TPU kernels for SPA-Cache hot spots (validated interpret=True).

  proxy_score      — fused rank-r proxy projection + cosine drift scores
                     (batch grid axis; ``cosine_drift`` score-only form;
                     ``gather_norm`` fused gather+rms_norm epilogue)
  sparse_attention — gathered-query flash attention vs full KV cache
                     (batch grid axis; banded stratified path via
                     scalar-prefetched per-q-block kv starts)
  scatter_update   — in-place row scatter into cache buffers
                     (``scatter_update_multi``: K/V/H/proxy/scales in one
                     aliased call, contiguous runs batched into one DMA)
  rglru_scan       — chunked gated linear recurrence (RecurrentGemma)
  ssd_chunk        — Mamba-2 SSD chunked scan (state-space duality)

Each has a pure-jnp oracle in ref.py and a jit wrapper in ops.py.
``backend.py`` packages the serve-path kernels as a ``KernelBackend``
(XlaBackend | PallasBackend) that ``CacheStrategy`` threads through the
decode hot loop (DESIGN.md §4.5).
"""

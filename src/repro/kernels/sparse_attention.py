"""Pallas kernel: gathered-query flash attention vs a full KV cache.

SPA-Cache Phase 2 on TPU: k selected query rows attend to the whole
(partially refreshed) KV cache. Flash-style online softmax with the
running (m, l, acc) state held in VMEM scratch across the sequential
kv-block grid dimension. Supports GQA (kv head = q head // G),
bidirectional sliding windows (query positions are arbitrary gathered
indices), gemma2 attention-logit softcap, int8 KV with per-row dequant
scales, a real batch grid axis, and the stratified long-context banded
path: with ``banded=True`` and a static ``q_span`` bound (guaranteed by
stratified selection — DESIGN.md §4) each q block visits only the
``band_width`` kv blocks covering its window, starting at a per-q-block
offset delivered through TPU scalar prefetch (the same
``banded_starts`` the XLA path uses, so the two paths select identical
kv blocks and stay byte-identical).

Numerics mirror ``models.attention.flash_attention`` op-for-op (scale
applied after the QK dot, masking before the running-max update, f32
state) so the backends decode byte-identically.

Grid: (B, H, nq, nk_or_band) — the kv axis minor (sequential on TPU), so
VMEM scratch carries the softmax state per (batch, head, q-block). VMEM
per step: bq*hd (q) + 2*bk*hd (kv) + bq*bk (scores) + scratch — (512,
512) blocks with hd<=256 stay under ~4 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_step(qpos, q, k, v, ks, vs, o_ref, m_scr, l_scr, acc_scr, *,
               kv_base, j, nj, window: int, soft_cap: float,
               n_valid: int, scale: float, kv_limit=None):
    """One kv-block online-softmax update (shared by both grid flavors).

    ``kv_limit`` (scalar int32) is the batch row's valid canvas length
    (paged serving): kv positions >= kv_limit mask out exactly like the
    global ``n_valid`` pad bound, mirroring the XLA path's per-row
    ``kv_len`` mask op-for-op."""

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    qf = q.astype(jnp.float32)                        # [bq, hd]
    kf = k.astype(jnp.float32) * ks[:, None].astype(jnp.float32)
    vf = v.astype(jnp.float32) * vs[:, None].astype(jnp.float32)

    s = jax.lax.dot_general(qf, kf, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if soft_cap > 0.0:
        s = soft_cap * jnp.tanh(s / soft_cap)

    kv_pos = kv_base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = kv_pos < n_valid
    if kv_limit is not None:
        valid = jnp.logical_and(valid, kv_pos < kv_limit)
    if window > 0:
        valid = jnp.logical_and(valid,
                                jnp.abs(qpos[:, None] - kv_pos) <= window)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(valid, p, 0.0)
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_new))
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1)
    acc = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, vf, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(j == nj - 1)
    def _finalize():
        l_safe = jnp.where(l_scr[...] == 0.0, 1.0, l_scr[...])
        o_ref[0, 0] = (acc_scr[...] / l_safe[:, None]).astype(o_ref.dtype)


def _dense_kernel(qpos_ref, kvl_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                  o_ref, m_scr, l_scr, acc_scr, *, nk: int, bk: int,
                  window: int, soft_cap: float, n_valid: int, scale: float):
    j = pl.program_id(3)
    _attn_step(qpos_ref[0], q_ref[0, 0], k_ref[0, 0], v_ref[0, 0],
               ks_ref[0, 0], vs_ref[0, 0], o_ref, m_scr, l_scr, acc_scr,
               kv_base=j * bk, j=j, nj=nk, window=window,
               soft_cap=soft_cap, n_valid=n_valid, scale=scale,
               kv_limit=kvl_ref[0])


def _banded_kernel(starts_ref, qpos_ref, kvl_ref, q_ref, k_ref, v_ref,
                   ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr, *,
                   n_band: int, bk: int, window: int, soft_cap: float,
                   n_valid: int, scale: float):
    i, j = pl.program_id(2), pl.program_id(3)
    _attn_step(qpos_ref[0], q_ref[0, 0], k_ref[0, 0], v_ref[0, 0],
               ks_ref[0, 0], vs_ref[0, 0], o_ref, m_scr, l_scr, acc_scr,
               kv_base=(starts_ref[i] + j) * bk, j=j, nj=n_band,
               window=window, soft_cap=soft_cap, n_valid=n_valid,
               scale=scale, kv_limit=kvl_ref[0])


def sparse_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     q_pos: jax.Array, *, k_scale=None, v_scale=None,
                     window: int = 0, soft_cap: float = 0.0,
                     banded: bool = False, q_span: int = 0,
                     block_q: int = 512, block_k: int = 512,
                     kv_len=None, interpret: bool = False) -> jax.Array:
    """q: [B, kq, H, hd]; k/v: [B, N, KVH, hd]; q_pos: [B, kq]
    (2D/3D unbatched forms also accepted).  k_scale/v_scale: [B, N, KVH]
    or None.  ``banded`` + ``q_span`` enable the stratified banded path
    (requires window > 0).  ``kv_len``: [B] per-row valid canvas length
    (None = N).  Returns [B, kq, H, hd] in q.dtype."""
    unbatched = q.ndim == 3
    if unbatched:
        q, k, v, q_pos = q[None], k[None], v[None], q_pos[None]
        if k_scale is not None:
            k_scale, v_scale = k_scale[None], v_scale[None]
        if kv_len is not None:
            kv_len = kv_len[None]
    b, kq, h, hd = q.shape
    n, kvh = k.shape[1], k.shape[2]
    assert h % kvh == 0
    g = h // kvh
    scale = 1.0 / (hd ** 0.5)

    bq = min(block_q, kq)
    bk = min(block_k, n)
    pad_q = (-kq) % bq
    pad_k = (-n) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad_q)),
                        constant_values=2 ** 30)
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    if k_scale is None:
        k_scale = jnp.ones((b, k.shape[1], kvh), jnp.float32)
        v_scale = jnp.ones((b, k.shape[1], kvh), jnp.float32)
    elif pad_k:
        k_scale = jnp.pad(k_scale, ((0, 0), (0, pad_k), (0, 0)))
        v_scale = jnp.pad(v_scale, ((0, 0), (0, pad_k), (0, 0)))

    qt = jnp.swapaxes(q, 1, 2)                      # [B, H, kq_p, hd]
    kt = jnp.swapaxes(k, 1, 2)                      # [B, KVH, N_p, hd]
    vt = jnp.swapaxes(v, 1, 2)
    kst = jnp.swapaxes(k_scale, 1, 2).astype(jnp.float32)  # [B, KVH, N_p]
    vst = jnp.swapaxes(v_scale, 1, 2).astype(jnp.float32)
    q_pos = q_pos.astype(jnp.int32)
    kv_len = (jnp.full((b,), n, jnp.int32) if kv_len is None
              else kv_len.astype(jnp.int32))

    kq_p, skv_p = qt.shape[2], kt.shape[2]
    nq = kq_p // bq
    nk = skv_p // bk

    out_shape = jax.ShapeDtypeStruct((b, h, kq_p, hd), q.dtype)
    scratch = [
        pltpu.VMEM((bq,), jnp.float32),
        pltpu.VMEM((bq,), jnp.float32),
        pltpu.VMEM((bq, hd), jnp.float32),
    ]
    use_band = (banded and window > 0 and q_span > 0
                and n > (q_span + 2 * window + 2 * bk))

    if use_band:
        from repro.models.attention import band_width, banded_starts
        n_band = band_width(q_span, window, bk, nk)
        starts = banded_starts(q_pos.reshape(b, nq, bq), window, skv_p,
                               n_band, bk)

        def kvi(bb, hh, i, j, st):
            return (bb, hh // g, st[i] + j)

        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, h, nq, n_band),
            in_specs=[
                pl.BlockSpec((1, bq), lambda bb, hh, i, j, st: (bb, i)),
                pl.BlockSpec((1,), lambda bb, hh, i, j, st: (bb,),
                             memory_space=pltpu.SMEM),
                pl.BlockSpec((1, 1, bq, hd),
                             lambda bb, hh, i, j, st: (bb, hh, i, 0)),
                pl.BlockSpec((1, 1, bk, hd),
                             lambda bb, hh, i, j, st: kvi(bb, hh, i, j, st)
                             + (0,)),
                pl.BlockSpec((1, 1, bk, hd),
                             lambda bb, hh, i, j, st: kvi(bb, hh, i, j, st)
                             + (0,)),
                pl.BlockSpec((1, 1, bk), kvi),
                pl.BlockSpec((1, 1, bk), kvi),
            ],
            out_specs=pl.BlockSpec(
                (1, 1, bq, hd), lambda bb, hh, i, j, st: (bb, hh, i, 0)),
            scratch_shapes=scratch,
        )
        out = pl.pallas_call(
            functools.partial(_banded_kernel, n_band=n_band, bk=bk,
                              window=window, soft_cap=soft_cap, n_valid=n,
                              scale=scale),
            grid_spec=grid_spec,
            out_shape=out_shape,
            interpret=interpret,
        )(starts, q_pos, kv_len, qt, kt, vt, kst, vst)
    else:
        out = pl.pallas_call(
            functools.partial(_dense_kernel, nk=nk, bk=bk, window=window,
                              soft_cap=soft_cap, n_valid=n, scale=scale),
            grid=(b, h, nq, nk),
            in_specs=[
                pl.BlockSpec((1, bq), lambda bb, hh, i, j: (bb, i)),
                pl.BlockSpec((1,), lambda bb, hh, i, j: (bb,),
                             memory_space=pltpu.SMEM),
                pl.BlockSpec((1, 1, bq, hd),
                             lambda bb, hh, i, j: (bb, hh, i, 0)),
                pl.BlockSpec((1, 1, bk, hd),
                             lambda bb, hh, i, j: (bb, hh // g, j, 0)),
                pl.BlockSpec((1, 1, bk, hd),
                             lambda bb, hh, i, j: (bb, hh // g, j, 0)),
                pl.BlockSpec((1, 1, bk),
                             lambda bb, hh, i, j: (bb, hh // g, j)),
                pl.BlockSpec((1, 1, bk),
                             lambda bb, hh, i, j: (bb, hh // g, j)),
            ],
            out_specs=pl.BlockSpec(
                (1, 1, bq, hd), lambda bb, hh, i, j: (bb, hh, i, 0)),
            out_shape=out_shape,
            scratch_shapes=scratch,
            interpret=interpret,
        )(q_pos, kv_len, qt, kt, vt, kst, vst)

    out = jnp.swapaxes(out, 1, 2)[:, :kq]           # [B, kq, H, hd]
    return out[0] if unbatched else out

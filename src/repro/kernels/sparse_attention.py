"""Pallas kernel: gathered-query flash attention vs a full KV cache.

SPA-Cache Phase 2 on TPU: k selected query rows attend to the whole
(partially refreshed) KV cache. Flash-style online softmax with the
running (m, l, acc) state held in VMEM scratch across the sequential
kv-block grid dimension. Supports GQA (kv head = q head // G),
bidirectional sliding windows (query positions are arbitrary gathered
indices), gemma2 attention-logit softcap, and int8 KV with per-row
dequant scales.

Grid: (H, nq, nk) — nk minor (sequential on TPU), so VMEM scratch carries
the softmax state per (head, q-block). VMEM per step: bq*hd (q) +
2*bk*hd (kv) + bq*bk (scores) + scratch — (128, 512) blocks with hd<=256
stay under ~2 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _sparse_attn_kernel(qpos_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                        o_ref, m_scr, l_scr, acc_scr, *,
                        nk: int, bk: int, window: int, soft_cap: float,
                        n_valid: int, scale: float):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale          # [bq, hd]
    k = k_ref[0].astype(jnp.float32)                  # [bk, hd]
    v = v_ref[0].astype(jnp.float32)
    k = k * ks_ref[0][:, None].astype(jnp.float32)
    v = v * vs_ref[0][:, None].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [bq, bk]
    if soft_cap > 0.0:
        s = soft_cap * jnp.tanh(s / soft_cap)

    kv_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = kv_pos < n_valid
    if window > 0:
        qpos = qpos_ref[...][:, None]                 # [bq, 1]
        valid = jnp.logical_and(valid, jnp.abs(qpos - kv_pos) <= window)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(valid, p, 0.0)
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_new))
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1)
    acc = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(j == nk - 1)
    def _finalize():
        l_safe = jnp.where(l_scr[...] == 0.0, 1.0, l_scr[...])
        o_ref[0] = (acc_scr[...] / l_safe[:, None]).astype(o_ref.dtype)


def sparse_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     q_pos: jax.Array, *, k_scale=None, v_scale=None,
                     window: int = 0, soft_cap: float = 0.0,
                     block_q: int = 128, block_k: int = 512,
                     interpret: bool = False) -> jax.Array:
    """q: [kq, H, hd]; k/v: [N, KVH, hd]; q_pos: [kq].
    k_scale/v_scale: [N, KVH] or None. Returns [kq, H, hd]."""
    kq, h, hd = q.shape
    n, kvh, _ = k.shape
    assert h % kvh == 0
    g = h // kvh
    scale = 1.0 / (hd ** 0.5)

    bq = min(block_q, kq)
    bk = min(block_k, n)
    pad_q = (-kq) % bq
    pad_k = (-n) % bk
    if pad_q:
        q = jnp.pad(q, ((0, pad_q), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pad_q), constant_values=2 ** 30)
    if pad_k:
        k = jnp.pad(k, ((0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, pad_k), (0, 0), (0, 0)))
    if k_scale is None:
        k_scale = jnp.ones((k.shape[0], kvh), jnp.float32)
        v_scale = jnp.ones((k.shape[0], kvh), jnp.float32)
    elif pad_k:
        k_scale = jnp.pad(k_scale, ((0, pad_k), (0, 0)))
        v_scale = jnp.pad(v_scale, ((0, pad_k), (0, 0)))

    qt = jnp.swapaxes(q, 0, 1)                      # [H, kq_p, hd]
    kt = jnp.swapaxes(k, 0, 1)                      # [KVH, N_p, hd]
    vt = jnp.swapaxes(v, 0, 1)
    kst = jnp.swapaxes(k_scale, 0, 1).astype(jnp.float32)  # [KVH, N_p]
    vst = jnp.swapaxes(v_scale, 0, 1).astype(jnp.float32)

    nq = qt.shape[1] // bq
    nk = kt.shape[1] // bk

    out = pl.pallas_call(
        functools.partial(_sparse_attn_kernel, nk=nk, bk=bk,
                          window=window, soft_cap=soft_cap, n_valid=n,
                          scale=scale),
        grid=(h, nq, nk),
        in_specs=[
            pl.BlockSpec((bq,), lambda hh, i, j: (i,)),
            pl.BlockSpec((1, bq, hd), lambda hh, i, j: (hh, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda hh, i, j: (hh // g, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda hh, i, j: (hh // g, j, 0)),
            pl.BlockSpec((1, bk), lambda hh, i, j: (hh // g, j)),
            pl.BlockSpec((1, bk), lambda hh, i, j: (hh // g, j)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda hh, i, j: (hh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, qt.shape[1], hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q_pos, qt, kt, vt, kst, vst)
    out = jnp.swapaxes(out, 0, 1)                   # [kq_p, H, hd]
    return out[:kq]

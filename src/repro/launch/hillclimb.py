import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

DOC = """§Perf hillclimbing harness: lower + analyze named VARIANTS of a
(arch x shape) pair on the single-pod mesh, appending records tagged with
the variant name to results/hillclimb.jsonl. Each variant is one
hypothesis from EXPERIMENTS.md §Perf.

  PYTHONPATH=src python -m repro.launch.hillclimb \
      --arch deepseek-67b --shape decode_32k \
      --variant incremental_ident
"""

import argparse
import dataclasses
import json
import sys
import traceback

from repro.configs import get_arch
from repro.configs.base import SPAConfig
from repro.launch.dryrun import run_one


def _spa(cfg, **kw):
    return dataclasses.replace(cfg, spa=dataclasses.replace(cfg.spa, **kw))


VARIANTS = {
    # paper-faithful reference points
    "baseline": lambda c: c,
    "paper_value_proxy": lambda c: _spa(c, identifier="value"),
    "paper_uniform_rho": lambda c: _spa(c, schedule="uniform"),
    # beyond-paper candidates
    "incremental_ident": lambda c: _spa(c, incremental_ident=True),
    "int8_cache": lambda c: dataclasses.replace(c, cache_dtype="int8"),
    "bf16_cache": lambda c: dataclasses.replace(c,
                                                cache_dtype="bfloat16"),
    "buckets_2": lambda c: _spa(c, n_buckets=2),
    "buckets_12": lambda c: _spa(c, n_buckets=12),
    "rank_64": lambda c: _spa(c, rank=64),
    "rank_256": lambda c: _spa(c, rank=256),
    "microbatch_1": lambda c: dataclasses.replace(c, microbatch=1),
    "microbatch_2": lambda c: dataclasses.replace(c, microbatch=2),
    "microbatch_4": lambda c: dataclasses.replace(c, microbatch=4),
    "microbatch_16": lambda c: dataclasses.replace(c, microbatch=16),
    "no_zero3": lambda c: dataclasses.replace(c, zero3=False),
    "zero3": lambda c: dataclasses.replace(c, zero3=True),
    "no_remat": lambda c: dataclasses.replace(c, remat=False),
    "replicated_weights": lambda c: dataclasses.replace(
        c, tp_weights=False),
    "bf16_grad_accum": lambda c: dataclasses.replace(
        c, accum_dtype="bfloat16"),
    "int8_cache_incremental": lambda c: dataclasses.replace(
        _spa(c, incremental_ident=True), cache_dtype="int8"),
    "mb4_unrolled": lambda c: dataclasses.replace(
        c, microbatch=4, accum_unroll=True),
    "mb8_unrolled": lambda c: dataclasses.replace(c, accum_unroll=True),
    "repl_weights_nohint": lambda c: dataclasses.replace(
        c, tp_weights=False),
}


def main(argv=None):
    ap = argparse.ArgumentParser(description=DOC)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True,
                    help="|".join(VARIANTS))
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--out", default="results/hillclimb.jsonl")
    ap.add_argument("--profile-store", default="auto",
                    help="kernel/variant profile store (DESIGN.md §12): "
                         "an (arch, shape, mesh, variant) hit "
                         "short-circuits the re-search and replays the "
                         "persisted record; every fresh 'ok' run is "
                         "written back.  'auto' = the shared "
                         "BENCH_artifacts/kernel_profiles.json; '' = off")
    args = ap.parse_args(argv)

    from repro.serving.profiling import ProfileStore
    store = None
    if args.profile_store:
        path = None if args.profile_store == "auto" else args.profile_store
        store = ProfileStore(path)
        store.load()
    key = dict(kind="hillclimb", arch=args.arch, shape=args.shape,
               mesh=args.mesh, variant=args.variant)

    cached = store.get(**key) if store is not None else None
    if cached is not None and cached.get("status") == "ok":
        rec = {k: v for k, v in cached.items() if k != "key"}
        rec["warm_start"] = True
        print(f"[hillclimb] warm start: {args.variant} on "
              f"{args.arch}/{args.shape} from {store.path}")
    else:
        cfg = VARIANTS[args.variant](get_arch(args.arch))
        try:
            rec = run_one(args.arch, args.shape, args.mesh,
                          cfg_override=cfg, tag=args.variant)
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            rec = {"arch": args.arch, "shape": args.shape,
                   "mesh": args.mesh, "tag": args.variant,
                   "status": "error", "error": repr(e)[:500]}
        if store is not None and rec.get("status") == "ok":
            store.put(rec, **key)
            store.save()
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "a") as f:
        f.write(json.dumps(rec) + "\n")
    return 0 if rec.get("status") == "ok" else 1


if __name__ == "__main__":
    sys.exit(main())

import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

For each combination this produces, WITHOUT allocating any real tensors:
  * compiled.memory_analysis()  — per-device bytes (does it fit 16 GB HBM?)
  * compiled.cost_analysis()    — HLO FLOPs / bytes for the roofline
  * collective-bytes breakdown parsed from the partitioned HLO
and appends a JSON record consumed by benchmarks/roofline.py and
EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-1.8b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out results/dryrun.jsonl
"""

import argparse
import functools
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (ASSIGNED, SHAPES, ModelConfig, ShapeConfig,
                           get_arch, get_shape, supports_shape)
from repro.core import budget
from repro.core.cache import init_model_cache
from repro.core.spa_layer import spa_proxy_specs
from repro.distributed import hints, sharding as shd
from repro.dlm.decoding import DecodeSettings, DecodeState, prefill, serve_step
from repro.launch import hlo_cost, mesh as mesh_lib
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.trainer import train_step


# ---------------------------------------------------------------------------
# Abstract inputs
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this shape."""
    b, n = shape.global_batch, shape.seq_len
    tok = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.int32)
    emb = functools.partial(jax.ShapeDtypeStruct,
                            dtype=jnp.dtype(cfg.param_dtype))
    if cfg.frontend == "audio":
        specs = {"frames": emb((b, n, cfg.d_model))}
        if shape.kind == "train":
            specs["targets"] = tok((b, n))
        return specs
    if cfg.frontend == "vision":
        f = min(cfg.frontend_tokens, n // 2)
        return {"tokens": tok((b, n - f)),
                "patches": emb((b, f, cfg.d_model))}
    return {"tokens": tok((b, n))}


def abstract_params(cfg: ModelConfig):
    from repro.models import transformer
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(
        functools.partial(transformer.init_params, cfg), key)


def abstract_decode_state(cfg: ModelConfig, shape: ShapeConfig):
    b, n = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(
        functools.partial(init_model_cache, cfg, b, n))
    extras = {}
    n_text = n
    if cfg.frontend == "vision":
        f = min(cfg.frontend_tokens, n // 2)
        n_text = n - f
        extras["patches"] = jax.ShapeDtypeStruct(
            (b, f, cfg.d_model), jnp.dtype(cfg.param_dtype))
    return DecodeState(
        tokens=jax.ShapeDtypeStruct((b, n_text), jnp.int32),
        cache=cache,
        step=jax.ShapeDtypeStruct((), jnp.int32),
        committed=jax.ShapeDtypeStruct((b, 8), jnp.int32),
        n_masked=jax.ShapeDtypeStruct((b,), jnp.int32),
        active=jax.ShapeDtypeStruct((b, n_text), jnp.bool_),
        extras=extras,
    )


# ---------------------------------------------------------------------------
# Step builders (function + abstract args + in_shardings)
# ---------------------------------------------------------------------------

def build_train(cfg: ModelConfig, shape: ShapeConfig, mesh):
    opt_cfg = AdamWConfig()
    fn = functools.partial(train_step, cfg=cfg, opt_cfg=opt_cfg)
    abs_p = abstract_params(cfg)
    abs_opt = jax.eval_shape(init_opt_state, abs_p)
    abs_batch = input_specs(cfg, shape)
    abs_rng = jax.ShapeDtypeStruct((2,), jnp.uint32)
    p_sh = shd.params_shardings(abs_p, cfg, mesh)
    in_sh = (p_sh, shd.opt_state_shardings(abs_opt, p_sh, mesh),
             shd.batch_shardings(abs_batch, shape, mesh, cfg),
             shd.replicated(mesh))
    abs_out = jax.eval_shape(fn, abs_p, abs_opt, abs_batch, abs_rng)
    out_sh = (p_sh, shd.opt_state_shardings(abs_out[1], p_sh, mesh),
              jax.tree.map(lambda _: shd.replicated(mesh), abs_out[2]))
    return fn, (abs_p, abs_opt, abs_batch, abs_rng), in_sh, (0, 1), out_sh


def build_prefill(cfg: ModelConfig, shape: ShapeConfig, mesh):
    def fn(params, inputs, proxies):
        return prefill(params, cfg, inputs, proxies)

    abs_p = abstract_params(cfg)
    abs_in = input_specs(cfg, shape)
    abs_prox = spa_proxy_specs(cfg)
    p_sh = shd.params_shardings(abs_p, cfg, mesh)
    prox_sh = (jax.tree.map(
        lambda l: shd.replicated(mesh), abs_prox)
        if abs_prox is not None else None)
    in_sh = (p_sh, shd.batch_shardings(abs_in, shape, mesh, cfg), prox_sh)
    # outputs: (h_final, cache) — shard the cache N-dim over "model" so
    # the stored caches use the whole pod's HBM, not just the data axis.
    abs_out = jax.eval_shape(fn, abs_p, abs_in, abs_prox)
    out_sh = (jax.NamedSharding(mesh, shd.data_pspec(shape, mesh, 3)),
              shd.cache_shardings(abs_out[1], shape, mesh))
    return fn, (abs_p, abs_in, abs_prox), in_sh, (), out_sh


def build_decode(cfg: ModelConfig, shape: ShapeConfig, mesh):
    settings = DecodeSettings(n_candidates=64, parallel_threshold=0.9,
                              max_parallel=8)

    def fn(params, state, proxies):
        return serve_step(params, cfg, state, settings, proxies)

    abs_p = abstract_params(cfg)
    abs_state = abstract_decode_state(cfg, shape)
    abs_prox = spa_proxy_specs(cfg)
    p_sh = shd.params_shardings(abs_p, cfg, mesh)
    state_sh = DecodeState(
        tokens=jax.NamedSharding(mesh, shd.data_pspec(shape, mesh, 2)),
        cache=shd.cache_shardings(abs_state.cache, shape, mesh),
        step=shd.replicated(mesh),
        committed=shd.replicated(mesh),   # tiny ring buffer
        n_masked=shd.replicated(mesh),
        active=jax.NamedSharding(mesh, shd.data_pspec(shape, mesh, 2)),
        extras={k: jax.NamedSharding(mesh,
                                     shd.data_pspec(shape, mesh, v.ndim))
                for k, v in abs_state.extras.items()},
    )
    prox_sh = (jax.tree.map(lambda l: shd.replicated(mesh), abs_prox)
               if abs_prox is not None else None)
    in_sh = (p_sh, state_sh, prox_sh)
    abs_out = jax.eval_shape(
        lambda p, st, pr: fn(p, st, pr), abs_p, abs_state, abs_prox)
    out_sh = (DecodeState(
        tokens=jax.NamedSharding(mesh, shd.data_pspec(shape, mesh, 2)),
        cache=shd.cache_shardings(abs_out[0].cache, shape, mesh),
        step=shd.replicated(mesh),
        committed=shd.replicated(mesh),
        n_masked=shd.replicated(mesh),
        active=jax.NamedSharding(mesh, shd.data_pspec(shape, mesh, 2)),
        extras={k: jax.NamedSharding(mesh,
                                     shd.data_pspec(shape, mesh, v.ndim))
                for k, v in abs_out[0].extras.items()},
    ), jax.tree.map(lambda _: shd.replicated(mesh), abs_out[1]))
    return fn, (abs_p, abs_state, abs_prox), in_sh, (1,), out_sh


BUILDERS = {"train": build_train, "prefill": build_prefill,
            "decode": build_decode}


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}
_SHAPE_RE = re.compile(r"(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64"
                       r"|f64)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(segment: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(segment):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Any]:
    """Per-device bytes moved by each collective kind (result shapes of the
    partitioned per-device module)."""
    out = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if not s.startswith("%") and " = " not in s:
            continue
        for kind in _COLLECTIVES:
            m = re.search(rf"=\s*(\([^)]*\)|\S+)\s+{kind}(-start|-done)?\(",
                          s)
            if m and "-done" not in (m.group(2) or ""):
                out[kind]["count"] += 1
                out[kind]["bytes"] += _shape_bytes(m.group(1))
                break
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------

def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Useful FLOPs for this step: 6*N_active*D (train) / 2*N_active*D."""
    p_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * p_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * p_active * shape.global_batch * shape.seq_len
    # decode: sparse rows per layer (mean k over layers)
    from repro.core.strategy import strategy_from_config
    strat = strategy_from_config(cfg)
    if not strat.uses_cache:
        mean_k = shape.seq_len
    else:
        mean_k = float(np.mean(strat.k_schedule(cfg, shape.seq_len)))
    return 2.0 * p_active * shape.global_batch * mean_k


def analytic_memory_bytes(cfg: ModelConfig, shape: ShapeConfig,
                          mesh) -> float:
    """HBM traffic model per device per step (documented in EXPERIMENTS.md).

    The HLO io-bytes estimate counts every loop-body buffer as HBM traffic,
    but on TPU the flash/SSD block buffers are VMEM-resident; this analytic
    model counts only true HBM streams: parameter reads, activation
    residual traffic, cache traffic, optimizer state, and logits.
    """
    n_batch = shd.axis_size(mesh, shd.batch_axes(mesh))
    n_model = int(mesh.shape["model"])
    n_chips = n_batch * n_model
    p_bytes = cfg.param_count() * 2.0            # bf16
    d, L, V = cfg.d_model, cfg.n_layers, cfg.vocab_size
    b, n = shape.global_batch, shape.seq_len
    act_bytes = 2.0

    if shape.kind == "train":
        nm = max(cfg.microbatch, 1)
        b_loc = max(b // n_batch, 1)
        act = b_loc * n * d * act_bytes * L * 6.0 * 3.0   # fwd+bwd+remat
        weights = 2.0 * p_bytes * nm / (1 if not cfg.zero3 else 1)
        opt = 24.0 * cfg.param_count()                     # f32 m/v/p rw
        logits = 2.0 * b_loc * n * V * 4.0 * 2.0           # chunked, recomp
        return act + weights + opt + logits
    if shape.kind == "prefill":
        b_loc = max(b // n_batch, 1)
        act = b_loc * n * d * act_bytes * L * 6.0
        cache_tok = (2 * cfg.kv_dim + d) * act_bytes + cfg.spa.rank * 2.0
        if cfg.cache_dtype == "int8":
            cache_tok = (2 * cfg.kv_dim + d) * 1.0 + cfg.spa.rank * 2.0
        cache = b * n * cache_tok * L / n_chips
        return act + p_bytes + cache
    # decode: sparse rows + identification + cache traffic
    from repro.core.strategy import strategy_from_config
    strat = strategy_from_config(cfg)
    mean_k = (float(np.mean(strat.k_schedule(cfg, n)))
              if strat.uses_cache else n)
    tok_dev = b * n / n_chips
    ident = tok_dev * d * act_bytes * L * 2.0          # read h + proxy mm
    rows = b * mean_k * d * act_bytes * L * 6.0 / n_chips
    cache_tok = (2 * cfg.kv_dim + d) * \
        (1.0 if cfg.cache_dtype == "int8" else act_bytes)
    cache = b * n * cache_tok * L * 1.5 / n_chips      # read + sparse write
    logits = b * 64 * V * 4.0 / n_batch
    return ident + rows + cache + p_bytes + logits


def roofline_terms(parsed: Dict[str, Any], cfg: ModelConfig,
                   shape: ShapeConfig, mesh) -> Dict[str, float]:
    flops = float(parsed["flops"])
    hlo_io = float(parsed["bytes_accessed"])
    mem_bytes = analytic_memory_bytes(cfg, shape, mesh)
    cbytes = float(parsed["collective_bytes"])
    return {
        "hlo_flops_per_device": flops,
        "hlo_io_bytes_per_device": hlo_io,     # upper bound (loop buffers)
        "hbm_bytes_per_device": mem_bytes,
        "collective_bytes_per_device": cbytes,
        "t_compute_s": flops / mesh_lib.PEAK_FLOPS_BF16,
        "t_memory_s": mem_bytes / mesh_lib.HBM_BANDWIDTH,
        "t_collective_s": cbytes / mesh_lib.ICI_BANDWIDTH,
    }


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------

def run_one(arch: str, shape_name: str, mesh_kind: str,
            verbose: bool = True, cfg_override=None,
            tag: str = "") -> Dict[str, Any]:
    cfg = cfg_override if cfg_override is not None else get_arch(arch)
    shape = get_shape(shape_name)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "kind": shape.kind,
    }
    if tag:
        rec["tag"] = tag
    if not supports_shape(cfg, shape):
        rec["status"] = "skipped"
        rec["reason"] = ("encoder-only: no decode step"
                         if cfg.is_encoder_only and shape.kind == "decode"
                         else "requires sub-quadratic attention")
        return rec

    mesh = mesh_lib.make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = int(np.prod(list(mesh.shape.values())))
    fn, abs_args, in_sh, donate, out_sh = \
        BUILDERS[shape.kind](cfg, shape, mesh)

    # Activation batch axes for sharding hints inside model code.
    ba = shd.batch_axes(mesh)
    full = cfg.moe is None
    if full and shape.global_batch % shd.axis_size(
            mesh, ba + ("model",)) == 0:
        act_batch = ba + ("model",)
    elif shape.global_batch % shd.axis_size(mesh, ba) == 0:
        act_batch = ba
    else:
        act_batch = ()

    t0 = time.time()
    with mesh, hints.batch_axes_ctx(act_batch):
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*abs_args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost_list = compiled.cost_analysis()
    cost = cost_list if isinstance(cost_list, dict) else cost_list[0]
    hlo = compiled.as_text()
    parsed = hlo_cost.analyze_hlo(hlo)

    rec["status"] = "ok"
    rec["n_chips"] = n_chips
    rec["lower_s"] = round(t_lower, 1)
    rec["compile_s"] = round(t_compile, 1)
    rec["memory"] = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
    }
    args_b = rec["memory"]["argument_bytes"] or 0
    temp_b = rec["memory"]["temp_bytes"] or 0
    rec["memory"]["per_device_total_gb"] = round(
        (args_b + temp_b) / 2 ** 30, 3)
    rec["collectives"] = parsed["collectives"]
    rec["xla_cost_analysis"] = {   # loop bodies counted once (cross-check)
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
    }
    rec.update(roofline_terms(parsed, cfg, shape, mesh))
    rec["model_flops_per_device"] = model_flops(cfg, shape) / n_chips
    if rec["hlo_flops_per_device"]:
        rec["useful_flop_ratio"] = round(
            rec["model_flops_per_device"] / rec["hlo_flops_per_device"], 4)
    terms = {k: rec[k] for k in ("t_compute_s", "t_memory_s",
                                 "t_collective_s")}
    rec["bottleneck"] = max(terms, key=terms.get)
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_kind}"
              + (f" x {tag}" if tag else "") + "] "
              f"compile={t_compile:.0f}s "
              f"mem/dev={rec['memory']['per_device_total_gb']}GB "
              f"compute={rec['t_compute_s']:.4f}s "
              f"memory={rec['t_memory_s']:.4f}s "
              f"coll={rec['t_collective_s']:.4f}s "
              f"-> {rec['bottleneck']}", flush=True)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    args = ap.parse_args(argv)

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    combos = []
    if args.all:
        for a in ASSIGNED:
            for s in SHAPES:
                for m in meshes:
                    combos.append((a, s, m))
    else:
        assert args.arch and args.shape
        for m in meshes:
            combos.append((args.arch, args.shape, m))

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("status") in ("ok", "skipped"):
                        done.add((r["arch"], r["shape"], r["mesh"]))
                except json.JSONDecodeError:
                    pass

    failures = 0
    with open(args.out, "a") as f:
        for arch, s, m in combos:
            if (arch, s, m) in done:
                print(f"[{arch} x {s} x {m}] cached, skipping", flush=True)
                continue
            try:
                rec = run_one(arch, s, m)
            except Exception as e:  # noqa: BLE001 — record and continue
                traceback.print_exc()
                rec = {"arch": arch, "shape": s, "mesh": m,
                       "status": "error", "error": repr(e)[:500]}
                failures += 1
            f.write(json.dumps(rec) + "\n")
            f.flush()
    print(f"done; {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())

"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; smoke tests and
benches run on the single real CPU device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2x16x16 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh on the real local device (smoke tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


# TPU v5e hardware model used for the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BANDWIDTH = 819e9           # B/s
ICI_BANDWIDTH = 50e9            # B/s per link
HBM_BYTES = 16 * 1024 ** 3      # capacity

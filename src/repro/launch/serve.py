"""Serving launcher: run the batched SPA-Cache engine on a model
checkpoint (or a freshly initialized reduced model for demo purposes).

The caching policy is selected per run with ``--strategy`` (any
registered CacheStrategy identifier: singular, value, window, attn_out,
none, ...) without touching the model config.

  PYTHONPATH=src python -m repro.launch.serve --arch llada-8b \
      --requests 8 --gen-len 16 --strategy singular

``--serve`` switches from the offline batch loop to the online
front-end (DESIGN.md §8): an asyncio HTTP server on ``--port`` that
streams per-token ndjson events per request, with SLO-aware admission
(``--slo-ttft`` / ``--slo-deadline``, seconds; 0 disables the policy).
``--client HOST:PORT`` instead runs a demo streaming client against a
running server (see also ``examples/serve_stream.py``).
"""
from __future__ import annotations

import argparse
import dataclasses
import sys

import jax
import numpy as np

from repro.configs import get_arch, reduced
from repro.core.strategy import REGISTRY, strategy_from_spec
from repro.dlm.decoding import DecodeSettings
from repro.models import transformer
from repro.serving.engine import ServingEngine
from repro.training import checkpoint


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llada-8b")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--canvas", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--parallel-threshold", type=float, default=0.0)
    ap.add_argument("--strategy", default="",
                    choices=[""] + sorted(REGISTRY),
                    help="cache strategy override (default: cfg.spa)")
    ap.add_argument("--kernel-backend", default="",
                    choices=["", "xla", "pallas"],
                    help="hot-path kernel backend (DESIGN.md §4.5; "
                         "default xla; pallas = TPU kernel suite, "
                         "interpret mode off-TPU)")
    ap.add_argument("--static-batching", action="store_true",
                    help="disable step-granular continuous batching")
    ap.add_argument("--pool-pages", type=int, default=0,
                    help="paged serving (DESIGN.md §5): total pages in "
                         "the device cache pool (page 0 is the reserved "
                         "zero page); 0 = dense per-lane slabs")
    ap.add_argument("--page-size", type=int, default=16,
                    help="canvas rows per cache page (the canvas length "
                         "must be a multiple)")
    ap.add_argument("--prefix-cache", dest="prefix_cache",
                    action="store_true", default=True,
                    help="shared-prefix radix cache (DESIGN.md §6): "
                         "reuse prefill pages across requests with "
                         "matching prompt prefixes + canvas layout "
                         "(paged mode only; default on)")
    ap.add_argument("--no-prefix-cache", dest="prefix_cache",
                    action="store_false")
    ap.add_argument("--host-pages", type=int, default=0,
                    help="hierarchical cache (DESIGN.md §9): host-RAM "
                         "page tier capacity in exact-page units — "
                         "evicted prefix entries demote there instead "
                         "of dying and promote back on a hit; 0 = off "
                         "(needs --pool-pages and the prefix cache)")
    ap.add_argument("--host-dtype", default="auto",
                    choices=["auto", "f32", "int8"],
                    help="cold-tier representation: f32 = every "
                         "promotion byte-identical; int8 = ~2x host "
                         "capacity, promoted prefixes allclose-class; "
                         "auto = int8 only for stability-scored pages")
    ap.add_argument("--serve", action="store_true",
                    help="online mode (DESIGN.md §8): run the asyncio "
                         "streaming front-end instead of the offline "
                         "batch loop")
    ap.add_argument("--port", type=int, default=8411)
    ap.add_argument("--slo-ttft", type=float, default=0.0,
                    help="TTFT target (s) attached to demo/client "
                         "requests; enables the SLO-aware policy")
    ap.add_argument("--slo-deadline", type=float, default=0.0,
                    help="e2e deadline (s) for demo/client requests")
    ap.add_argument("--client", default="",
                    help="HOST:PORT — run a streaming client against a "
                         "running --serve front-end and exit")
    ap.add_argument("--supervise", action="store_true",
                    help="wrap the engine in the fault supervisor "
                         "(DESIGN.md §10): invariant checking, NaN "
                         "quarantine, watchdog, degradation ladder")
    ap.add_argument("--chaos-seed", type=int, default=-1,
                    help="enable deterministic fault injection with "
                         "this seed (DESIGN.md §10); -1 = off")
    ap.add_argument("--chaos-rate", type=float, default=0.02,
                    help="per-probe fire rate for every fault site "
                         "when --chaos-seed is set")
    ap.add_argument("--trace-out", default="",
                    help="write a Chrome-trace/Perfetto JSON of the run "
                         "(request lifecycle spans + engine phase "
                         "breakdown, DESIGN.md §11) to this path")
    ap.add_argument("--metrics", action="store_true",
                    help="sample SPA cache-dynamics every step and "
                         "print the full metrics-registry dump at exit "
                         "(the compact non-zero dump always prints)")
    ap.add_argument("--profile", action="store_true",
                    help="compute-path profiling (DESIGN.md §12): fence "
                         "per-step device time, print the step-time "
                         "decomposition and the top-3 most-retraced "
                         "lane signatures at exit")
    ap.add_argument("--jax-trace-dir", default="",
                    help="with --profile: also wrap the run in "
                         "jax.profiler.trace writing to this directory "
                         "(when the runtime supports it)")
    args = ap.parse_args(argv)

    if args.client:
        return _run_client(args)

    cfg = reduced(get_arch(args.arch))
    if args.ckpt:
        params, meta = checkpoint.load_checkpoint(args.ckpt)
        print(f"loaded checkpoint {args.ckpt} ({meta})")
    else:
        params = transformer.init_params(cfg, jax.random.PRNGKey(0))
        print("no checkpoint given; serving an untrained reduced model")

    if cfg.is_encoder_only:
        print(f"{cfg.name} is encoder-only; no decode serving path")
        return 0

    strategy = None
    if args.strategy:
        strategy = strategy_from_spec(
            dataclasses.replace(cfg.spa, identifier=args.strategy))
    if args.kernel_backend:
        strategy = (strategy or strategy_from_spec(cfg.spa)) \
            .with_backend(args.kernel_backend)

    slo_policy = None
    if args.slo_ttft or args.slo_deadline:
        from repro.serving.slo import SLOPolicy
        slo_policy = SLOPolicy()
    fault_plan = None
    if args.chaos_seed >= 0:
        from repro.serving.faults import FAULT_SITES, FaultPlan
        fault_plan = FaultPlan(
            seed=args.chaos_seed,
            rates={s: args.chaos_rate for s in FAULT_SITES})
        print(f"chaos: seed={args.chaos_seed} "
              f"rate={args.chaos_rate} on all sites")
    telemetry = None
    if args.trace_out or args.metrics or args.profile:
        from repro.serving.telemetry import Telemetry, Tracer
        telemetry = Telemetry(
            tracer=Tracer(enabled=bool(args.trace_out)),
            dynamics_every=1 if args.metrics else 0)
    profiler = None
    if args.profile:
        from repro.serving.profiling import StepProfiler
        profiler = StepProfiler(
            telemetry, jax_trace_dir=args.jax_trace_dir or None)
    engine = ServingEngine(
        cfg, params, max_batch=args.max_batch, canvas_len=args.canvas,
        strategy=strategy, continuous=not args.static_batching,
        pool_pages=args.pool_pages, page_size=args.page_size,
        prefix_cache=args.prefix_cache, host_pages=args.host_pages,
        host_dtype=args.host_dtype, slo_policy=slo_policy,
        fault_plan=fault_plan, supervise=args.supervise,
        telemetry=telemetry, profiler=profiler,
        settings=DecodeSettings(
            parallel_threshold=args.parallel_threshold,
            max_parallel=4 if args.parallel_threshold else 0))
    if args.serve:
        return _serve_online(engine, args)
    import contextlib
    trace_ctx = profiler.jax_trace() if profiler is not None \
        else contextlib.nullcontext()
    with trace_ctx:
        _run_offline(engine, args)
    _summarize(engine, args)
    for req in engine.done[:3]:
        out = "<faulted>" if req.output is None else f"{req.output[:10]}..."
        print(f"  req {req.uid}: out={out}")
    return 0


def _run_offline(engine, args) -> None:
    """The offline batch loop (the pre-``--serve`` demo path)."""
    cfg = engine.cfg
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size - 1,
                            int(rng.integers(6, 18))).astype(np.int32)
               for _ in range(args.requests)]
    if args.prefix_cache and args.requests > 1:
        # half unique prompts, then repeats, staged so the §6/§9
        # machinery actually fires (visible in --metrics/--trace-out):
        # cold prompts run SOLO — publication allocs a whole run's
        # worth of pages on top of the row, so a concurrent cold pass
        # mostly fails to publish; the repeats then churn CONCURRENTLY
        # — admission pressure evicts the LRU entries (demoting them
        # to host RAM under --host-pages); the last repeat runs solo
        # against the drained pool, where its promotion alloc can
        # succeed (mid-churn it would only stall).
        uniq = prompts[: max(1, args.requests // 2)]
        wall = 0.0
        for prompt in uniq:
            engine.submit(prompt, args.gen_len)
            engine.run()
            wall += getattr(engine, "_wall", 0.0)
        repeats = [uniq[(i + 1) % len(uniq)]
                   for i in range(args.requests - len(uniq))]
        churn, late = repeats[:-1], []
        if len(churn) > 1:
            # hold one back and land it mid-churn at high priority on
            # the full pool — the §5 preemption path, live in the trace
            churn, late = churn[:-1], [churn[-1]]

        def on_step(e):
            if late and e.stats.steps >= 2:
                e.submit(late.pop(), args.gen_len, priority=5)

        for prompt in churn:
            engine.submit(prompt, args.gen_len)
        engine.run(on_step=on_step)
        wall += getattr(engine, "_wall", 0.0)
        while late:                  # churn drained before step 2
            engine.submit(late.pop(), args.gen_len, priority=5)
            engine.run()
            wall += getattr(engine, "_wall", 0.0)
        engine.submit(repeats[-1], args.gen_len)
        engine.run()
        engine._wall = getattr(engine, "_wall", 0.0) + wall
    else:
        for prompt in prompts:
            engine.submit(prompt, args.gen_len)
        engine.run()


def _summarize(engine, args) -> None:
    """End-of-run report: a one-line headline, exact latency
    percentiles when anything completed, and the metrics-registry dump
    (DESIGN.md §11) in place of the old ad-hoc per-subsystem prints.
    Renders cleanly when zero requests complete."""
    stats = engine.stats
    wall = getattr(engine, "_wall", 0.0)
    print(f"served {stats.requests_done} requests, "
          f"{stats.tokens_committed} tokens, {stats.steps} steps, "
          f"{stats.swaps} slot swaps, {stats.tps(wall):.1f} tok/s")
    if stats.requests_done:
        _print_latency(stats)
    else:
        print("latency: no requests completed")
    if getattr(args, "profile", False) and engine.profiler is not None:
        _print_profile(engine)
    print("metrics registry " + "-" * 46)
    print(engine.telemetry.registry.format_summary(
        skip_zero=not args.metrics))
    if args.trace_out:
        engine.export_trace(args.trace_out)
        n_ev = len(engine.telemetry.tracer.events)
        print(f"trace: {n_ev} events -> {args.trace_out} "
              f"(load in Perfetto / chrome://tracing)")


def _print_profile(engine) -> None:
    """``--profile`` report: step-time decomposition + the top-3
    most-retraced lane signatures (DESIGN.md §12).  Renders cleanly
    when zero steps were profiled (e.g. zero requests completed)."""
    from repro.core import runtime

    print("step-time decomposition " + "-" * 39)
    print(engine.profiler.format_summary())
    top = runtime.compile_tracker().top_retraced(3)
    if top:
        print("most-retraced lane signatures:")
        for lane, n in top:
            print(f"  {n:4d} traces  {lane or '<unlabeled>'}")
    else:
        print("most-retraced lane signatures: none recorded")


def _print_latency(stats) -> None:
    pct = stats.percentiles()
    print(f"latency: e2e p50={pct['e2e_p50'] * 1e3:.0f}ms "
          f"p95={pct['e2e_p95'] * 1e3:.0f}ms | queue-wait "
          f"p50={pct['wait_p50'] * 1e3:.0f}ms "
          f"p95={pct['wait_p95'] * 1e3:.0f}ms")
    print(f"streaming: TTFT p50={pct['ttft_p50'] * 1e3:.0f}ms "
          f"p95={pct['ttft_p95'] * 1e3:.0f}ms | TPOT "
          f"p50={pct['tpot_p50'] * 1e3:.0f}ms "
          f"p95={pct['tpot_p95'] * 1e3:.0f}ms | SLO "
          f"{stats.slo_met} met / {stats.slo_missed} missed, "
          f"{stats.requests_shed} shed, "
          f"{stats.requests_canceled} canceled")


def _serve_online(engine, args) -> int:
    """``--serve``: run the asyncio streaming front-end until ^C."""
    import asyncio

    from repro.serving.frontend import AsyncFrontend

    async def amain():
        front = AsyncFrontend(engine, port=args.port, max_steps=4096)
        await front.start(serve_http=True)
        print(f"serving on http://{front.host}:{front.port} — "
              f"POST /generate {{prompt, gen_len, slo?}} streams "
              f"ndjson; GET /stats | /metrics (Prometheus) | "
              f"/debug/requests")
        try:
            await asyncio.Event().wait()      # until interrupted
        finally:
            await front.stop()
            _summarize(engine, args)

    try:
        asyncio.run(amain())
    except KeyboardInterrupt:
        pass
    return 0


def _run_client(args) -> int:
    """``--client HOST:PORT``: stream one demo request and print the
    per-event arrivals (see also examples/serve_stream.py)."""
    import asyncio
    import time as _time

    from repro.serving.frontend import fetch_stats, stream_request

    host, _, port = args.client.partition(":")
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, 128, 8).astype(np.int32)
    slo = None
    if args.slo_ttft or args.slo_deadline:
        slo = {"ttft": args.slo_ttft or 1e9,
               "deadline": args.slo_deadline or 1e9}

    async def amain():
        t0 = _time.time()
        n = 0
        async for ev in stream_request(host, int(port), prompt,
                                       args.gen_len, slo=slo):
            dt = _time.time() - t0
            if ev["kind"] == "token":
                n += len(ev["tokens"])
                print(f"  +{dt * 1e3:7.1f}ms step {ev['step']:4d} "
                      f"tokens {ev['tokens']}")
            else:
                print(f"  +{dt * 1e3:7.1f}ms {ev['kind']} "
                      f"({n} tokens streamed)")
        stats = await fetch_stats(host, int(port))
        print(f"server: {stats['requests_done']} done, "
              f"TTFT p50={stats['ttft_p50'] * 1e3:.0f}ms, "
              f"TPOT p50={stats['tpot_p50'] * 1e3:.0f}ms")

    asyncio.run(amain())
    return 0


if __name__ == "__main__":
    sys.exit(main())

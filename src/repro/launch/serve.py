"""Serving launcher: run the batched SPA-Cache engine on a model
checkpoint (or a freshly initialized reduced model for demo purposes).

The caching policy is selected per run with ``--strategy`` (any
registered CacheStrategy identifier: singular, value, window, attn_out,
none, ...) without touching the model config.

  PYTHONPATH=src python -m repro.launch.serve --arch llada-8b \
      --requests 8 --gen-len 16 --strategy singular

``--serve`` switches from the offline batch loop to the online
front-end (DESIGN.md §8): an asyncio HTTP server on ``--port`` that
streams per-token ndjson events per request, with SLO-aware admission
(``--slo-ttft`` / ``--slo-deadline``, seconds; 0 disables the policy).
``--client HOST:PORT`` instead runs a demo streaming client against a
running server (see also ``examples/serve_stream.py``).
"""
from __future__ import annotations

import argparse
import dataclasses
import sys

import jax
import numpy as np

from repro.configs import get_arch, reduced
from repro.core.strategy import REGISTRY, strategy_from_spec
from repro.dlm.decoding import DecodeSettings
from repro.models import transformer
from repro.serving.engine import ServingEngine
from repro.training import checkpoint


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llada-8b")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--canvas", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--parallel-threshold", type=float, default=0.0)
    ap.add_argument("--strategy", default="",
                    choices=[""] + sorted(REGISTRY),
                    help="cache strategy override (default: cfg.spa)")
    ap.add_argument("--kernel-backend", default="",
                    choices=["", "xla", "pallas"],
                    help="hot-path kernel backend (DESIGN.md §4.5; "
                         "default xla; pallas = TPU kernel suite, "
                         "interpret mode off-TPU)")
    ap.add_argument("--static-batching", action="store_true",
                    help="disable step-granular continuous batching")
    ap.add_argument("--pool-pages", type=int, default=0,
                    help="paged serving (DESIGN.md §5): total pages in "
                         "the device cache pool (page 0 is the reserved "
                         "zero page); 0 = dense per-lane slabs")
    ap.add_argument("--page-size", type=int, default=16,
                    help="canvas rows per cache page (the canvas length "
                         "must be a multiple)")
    ap.add_argument("--prefix-cache", dest="prefix_cache",
                    action="store_true", default=True,
                    help="shared-prefix radix cache (DESIGN.md §6): "
                         "reuse prefill pages across requests with "
                         "matching prompt prefixes + canvas layout "
                         "(paged mode only; default on)")
    ap.add_argument("--no-prefix-cache", dest="prefix_cache",
                    action="store_false")
    ap.add_argument("--host-pages", type=int, default=0,
                    help="hierarchical cache (DESIGN.md §9): host-RAM "
                         "page tier capacity in exact-page units — "
                         "evicted prefix entries demote there instead "
                         "of dying and promote back on a hit; 0 = off "
                         "(needs --pool-pages and the prefix cache)")
    ap.add_argument("--host-dtype", default="auto",
                    choices=["auto", "f32", "int8"],
                    help="cold-tier representation: f32 = every "
                         "promotion byte-identical; int8 = ~2x host "
                         "capacity, promoted prefixes allclose-class; "
                         "auto = int8 only for stability-scored pages")
    ap.add_argument("--serve", action="store_true",
                    help="online mode (DESIGN.md §8): run the asyncio "
                         "streaming front-end instead of the offline "
                         "batch loop")
    ap.add_argument("--port", type=int, default=8411)
    ap.add_argument("--slo-ttft", type=float, default=0.0,
                    help="TTFT target (s) attached to demo/client "
                         "requests; enables the SLO-aware policy")
    ap.add_argument("--slo-deadline", type=float, default=0.0,
                    help="e2e deadline (s) for demo/client requests")
    ap.add_argument("--client", default="",
                    help="HOST:PORT — run a streaming client against a "
                         "running --serve front-end and exit")
    ap.add_argument("--supervise", action="store_true",
                    help="wrap the engine in the fault supervisor "
                         "(DESIGN.md §10): invariant checking, NaN "
                         "quarantine, watchdog, degradation ladder")
    ap.add_argument("--chaos-seed", type=int, default=-1,
                    help="enable deterministic fault injection with "
                         "this seed (DESIGN.md §10); -1 = off")
    ap.add_argument("--chaos-rate", type=float, default=0.02,
                    help="per-probe fire rate for every fault site "
                         "when --chaos-seed is set")
    args = ap.parse_args(argv)

    if args.client:
        return _run_client(args)

    cfg = reduced(get_arch(args.arch))
    if args.ckpt:
        params, meta = checkpoint.load_checkpoint(args.ckpt)
        print(f"loaded checkpoint {args.ckpt} ({meta})")
    else:
        params = transformer.init_params(cfg, jax.random.PRNGKey(0))
        print("no checkpoint given; serving an untrained reduced model")

    if cfg.is_encoder_only:
        print(f"{cfg.name} is encoder-only; no decode serving path")
        return 0

    strategy = None
    if args.strategy:
        strategy = strategy_from_spec(
            dataclasses.replace(cfg.spa, identifier=args.strategy))
    if args.kernel_backend:
        strategy = (strategy or strategy_from_spec(cfg.spa)) \
            .with_backend(args.kernel_backend)

    slo_policy = None
    if args.slo_ttft or args.slo_deadline:
        from repro.serving.slo import SLOPolicy
        slo_policy = SLOPolicy()
    fault_plan = None
    if args.chaos_seed >= 0:
        from repro.serving.faults import FAULT_SITES, FaultPlan
        fault_plan = FaultPlan(
            seed=args.chaos_seed,
            rates={s: args.chaos_rate for s in FAULT_SITES})
        print(f"chaos: seed={args.chaos_seed} "
              f"rate={args.chaos_rate} on all sites")
    engine = ServingEngine(
        cfg, params, max_batch=args.max_batch, canvas_len=args.canvas,
        strategy=strategy, continuous=not args.static_batching,
        pool_pages=args.pool_pages, page_size=args.page_size,
        prefix_cache=args.prefix_cache, host_pages=args.host_pages,
        host_dtype=args.host_dtype, slo_policy=slo_policy,
        fault_plan=fault_plan, supervise=args.supervise,
        settings=DecodeSettings(
            parallel_threshold=args.parallel_threshold,
            max_parallel=4 if args.parallel_threshold else 0))
    if args.serve:
        return _serve_online(engine, args)
    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size - 1,
                              int(rng.integers(6, 18))).astype(np.int32)
        engine.submit(prompt, args.gen_len)
    stats = engine.run()
    print(f"served {stats.requests_done} requests, "
          f"{stats.tokens_committed} tokens, {stats.steps} steps, "
          f"{stats.swaps} slot swaps, "
          f"{stats.tps(engine._wall):.1f} tok/s")
    _print_latency(stats)
    if args.pool_pages:
        print(f"pool: {args.pool_pages} pages x {args.page_size} rows, "
              f"peak util {stats.peak_pool_util:.0%}, steady "
              f"{stats.steady_pool_util:.0%}, "
              f"{stats.preemptions} preemptions, "
              f"{stats.admission_stalls} admission stalls")
        if engine.prefix is not None:
            print(f"prefix cache: {stats.prefix_hits} hits "
                  f"({stats.prefix_full_hits} full), "
                  f"{stats.prefix_tokens_saved} prefill tokens saved, "
                  f"{stats.prefix_published} pages published "
                  f"({stats.prefix_publish_skipped} skipped), "
                  f"{stats.prefix_evicted_pages} evicted "
                  f"({stats.prefix_demoted_pages} demoted, "
                  f"{stats.prefix_dropped_pages} dropped)")
        if engine.host_pool is not None:
            print(f"host tier: {args.host_pages} page units "
                  f"({args.host_dtype}), "
                  f"{stats.prefix_promoted_pages} pages promoted in "
                  f"{stats.prefix_promotions} promotions "
                  f"({stats.promotion_stalls} stalls), "
                  f"peak util {stats.peak_host_util:.0%}, "
                  f"{engine.host_pool.used_pages} resident at exit")
    if engine.supervisor is not None or engine.faults is not None:
        print(f"supervisor: {stats.faults_injected} faults injected, "
              f"{stats.requests_faulted} requests faulted, "
              f"{stats.nan_quarantines} NaN quarantines, "
              f"{stats.alloc_faults} alloc faults, "
              f"{stats.host_checksum_failures} checksum failures "
              f"({stats.cold_prefill_fallbacks} cold fallbacks), "
              f"{stats.watchdog_fires} watchdog fires, "
              f"{stats.invariant_checks} invariant checks")
        print(f"ladder: level {stats.degrade_level} at exit, "
              f"{stats.degradations} degradations / "
              f"{stats.restorations} restorations "
              f"{stats.degradation_events}")
    for req in engine.done[:3]:
        out = "<faulted>" if req.output is None else f"{req.output[:10]}..."
        print(f"  req {req.uid}: out={out}")
    return 0


def _print_latency(stats) -> None:
    pct = stats.percentiles()
    print(f"latency: e2e p50={pct['e2e_p50'] * 1e3:.0f}ms "
          f"p95={pct['e2e_p95'] * 1e3:.0f}ms | queue-wait "
          f"p50={pct['wait_p50'] * 1e3:.0f}ms "
          f"p95={pct['wait_p95'] * 1e3:.0f}ms")
    print(f"streaming: TTFT p50={pct['ttft_p50'] * 1e3:.0f}ms "
          f"p95={pct['ttft_p95'] * 1e3:.0f}ms | TPOT "
          f"p50={pct['tpot_p50'] * 1e3:.0f}ms "
          f"p95={pct['tpot_p95'] * 1e3:.0f}ms | SLO "
          f"{stats.slo_met} met / {stats.slo_missed} missed, "
          f"{stats.requests_shed} shed, "
          f"{stats.requests_canceled} canceled")


def _serve_online(engine, args) -> int:
    """``--serve``: run the asyncio streaming front-end until ^C."""
    import asyncio

    from repro.serving.frontend import AsyncFrontend

    async def amain():
        front = AsyncFrontend(engine, port=args.port, max_steps=4096)
        await front.start(serve_http=True)
        print(f"serving on http://{front.host}:{front.port} — "
              f"POST /generate {{prompt, gen_len, slo?}} streams "
              f"ndjson; GET /stats")
        try:
            await asyncio.Event().wait()      # until interrupted
        finally:
            await front.stop()
            _print_latency(engine.stats)

    try:
        asyncio.run(amain())
    except KeyboardInterrupt:
        pass
    return 0


def _run_client(args) -> int:
    """``--client HOST:PORT``: stream one demo request and print the
    per-event arrivals (see also examples/serve_stream.py)."""
    import asyncio
    import time as _time

    from repro.serving.frontend import fetch_stats, stream_request

    host, _, port = args.client.partition(":")
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, 128, 8).astype(np.int32)
    slo = None
    if args.slo_ttft or args.slo_deadline:
        slo = {"ttft": args.slo_ttft or 1e9,
               "deadline": args.slo_deadline or 1e9}

    async def amain():
        t0 = _time.time()
        n = 0
        async for ev in stream_request(host, int(port), prompt,
                                       args.gen_len, slo=slo):
            dt = _time.time() - t0
            if ev["kind"] == "token":
                n += len(ev["tokens"])
                print(f"  +{dt * 1e3:7.1f}ms step {ev['step']:4d} "
                      f"tokens {ev['tokens']}")
            else:
                print(f"  +{dt * 1e3:7.1f}ms {ev['kind']} "
                      f"({n} tokens streamed)")
        stats = await fetch_stats(host, int(port))
        print(f"server: {stats['requests_done']} done, "
              f"TTFT p50={stats['ttft_p50'] * 1e3:.0f}ms, "
              f"TPOT p50={stats['tpot_p50'] * 1e3:.0f}ms")

    asyncio.run(amain())
    return 0


if __name__ == "__main__":
    sys.exit(main())

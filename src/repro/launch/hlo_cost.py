"""Trip-count-aware cost model over optimized (partitioned) HLO text.

``compiled.cost_analysis()`` counts each while-loop body ONCE, which
undercounts scanned layer stacks by ~L x. This module re-derives FLOPs,
memory traffic, and collective bytes from ``compiled.as_text()`` with
loop-trip multipliers:

  * parse the module into computations (instruction name -> result shape,
    including computation parameters from the header);
  * find every `while`, recover its trip count from the condition's
    `compare(iter, constant)` (jax scans lower to this form);
  * propagate multipliers through the call graph (while bodies, fusions,
    calls, reduces, conditionals);
  * FLOPs: 2 * prod(output dims) * prod(lhs contracting dims) per `dot`;
  * bytes: per instruction, operand + output buffer sizes for
    traffic-relevant top-level ops — an HLO-cost-analysis-style estimate
    consistent across configurations;
  * collectives: result-shape bytes per op kind, multiplied by trips.

Everything is derived from the compiled artifact itself, as required by
the roofline deliverable; the analytic model (benchmarks/roofline.py)
cross-checks.
"""
from __future__ import annotations

import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "s2": 1, "u2": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f4e2m1fn": 1, "f8e8m0fnu": 1,
    "f8e4m3": 1, "f8e3m4": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "f8e4m3b11fnuz": 1,
}
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\](?:\{[^}]*\})?")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_BYTES_OPS = frozenset((
    "fusion", "copy", "scatter", "gather", "sort", "reduce", "transpose",
    "dynamic-slice", "dynamic-update-slice", "concatenate", "pad",
    "broadcast", "reshape", "convert", "select", "add", "multiply",
    "subtract", "divide", "exponential", "tanh", "rsqrt", "iota", "slice",
    "bitcast", "custom-call", "compare", "maximum", "minimum", "negate",
    "abs", "log", "power", "clamp", "and", "or", "xor",
))
_CALLERS = frozenset((
    "fusion", "call", "map", "reduce", "sort", "scatter", "reduce-window",
    "select-and-scatter", "custom-call", "conditional", "all-reduce",
    "reduce-scatter",
))


def _shape_list(segment: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(segment):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(segment: str) -> int:
    total = 0
    for dt, dims in _shape_list(segment):
        total += _DTYPE_BYTES[dt] * int(math.prod(dims) if dims else 1)
    return total


def _balanced_prefix(s: str) -> str:
    """Return the balanced (...) prefix of s (s must start with '(')."""
    depth = 0
    for i, ch in enumerate(s):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return s[: i + 1]
    return s


class Computation:
    def __init__(self, name: str, header: str):
        self.name = name
        self.instructions: List[str] = []
        # name -> result shape segment (params from the header)
        self.defs: Dict[str, str] = {}
        for m in re.finditer(r"([\w.\-]+)\s*:\s*(\([^()]*\)|[a-z0-9]+"
                             r"\[[0-9,]*\](?:\{[^}]*\})?)", header):
            self.defs[m.group(1)] = m.group(2)

    def add(self, instr: str):
        self.instructions.append(instr)
        m = re.match(r"(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*", instr)
        if m:
            self.defs[m.group(1)] = _result_segment(instr)


def _result_segment(instr: str) -> str:
    if " = " not in instr:
        return ""
    rhs = instr.split(" = ", 1)[1]
    if rhs.startswith("("):
        return _balanced_prefix(rhs)
    m = re.match(r"\s*(\S+)\s", rhs)
    return m.group(1) if m else ""


def _opcode(instr: str) -> str:
    if " = " not in instr:
        return ""
    rhs = instr.split(" = ", 1)[1]
    if rhs.startswith("("):
        rhs = rhs[len(_balanced_prefix(rhs)):]
    m = re.match(r"\s*(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?\s+)?"
                 r"([\w\-]+)\(", rhs)
    return m.group(1) if m else ""


def _operand_names(instr: str) -> List[str]:
    """Names of the operands of the top-level op in this instruction."""
    op = _opcode(instr)
    if not op:
        return []
    idx = instr.find(op + "(")
    if idx < 0:
        return []
    args = _balanced_prefix(instr[idx + len(op):])
    return re.findall(r"%([\w.\-]+)", args)


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, Computation] = {}
        self.entry: Optional[str] = None
        cur: Optional[Computation] = None
        for raw in text.splitlines():
            s = raw.strip()
            if s.endswith("{") and "->" in s:
                m = re.match(r"(ENTRY\s+)?%?([\w.\-]+)\s*\(", s)
                if m:
                    cur = Computation(m.group(2), s)
                    self.computations[cur.name] = cur
                    if m.group(1):
                        self.entry = cur.name
                    continue
            if s == "}":
                cur = None
                continue
            if cur is not None and "=" in s:
                cur.add(s)
        if self.entry is None and self.computations:
            for name in self.computations:
                if "main" in name:
                    self.entry = name
                    break
            else:
                self.entry = max(
                    self.computations,
                    key=lambda k: len(self.computations[k].instructions))

    # -- shape resolution ---------------------------------------------------

    def operand_shapes(self, comp: Computation, instr: str) -> List[str]:
        segs = []
        for name in _operand_names(instr):
            seg = comp.defs.get(name)
            if seg is None:
                for c in self.computations.values():
                    if name in c.defs:
                        seg = c.defs[name]
                        break
            if seg:
                segs.append(seg)
        return segs

    # -- structure ------------------------------------------------------------

    def called_computations(self, instr: str) -> List[str]:
        names = []
        for key in ("body=", "calls=", "to_apply=", "condition=",
                    "true_computation=", "false_computation=",
                    "branch_computations={"):
            for m in re.finditer(re.escape(key) + r"\{?%?([\w.\-]+)", instr):
                names.append(m.group(1))
        return [n for n in names if n in self.computations]

    def while_trip_count(self, cond_name: str) -> int:
        comp = self.computations.get(cond_name)
        if comp is None:
            return 1
        const_vals: Dict[str, int] = {}
        for ln in comp.instructions:
            m = re.match(r"(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*\S+\s+"
                         r"constant\((\d+)\)", ln)
            if m:
                const_vals[m.group(1)] = int(m.group(2))
        for ln in comp.instructions:
            if "compare(" not in ln:
                continue
            args = _operand_names(ln)
            for a in args:
                if a in const_vals:
                    return max(const_vals[a], 1)
        # Compare may be wrapped in a fusion: jax while-conditions are tiny
        # (iter < trip_count), so the max integer constant IS the bound.
        if const_vals:
            return max(max(const_vals.values()), 1)
        return 1

    # -- cost walk -------------------------------------------------------------

    def analyze(self) -> Dict[str, float]:
        flops = 0.0
        bytes_accessed = 0.0
        coll = {k: {"count": 0.0, "bytes": 0.0} for k in _COLLECTIVES}
        stack = set()

        def walk(comp_name: str, mult: float, top_level: bool):
            nonlocal flops, bytes_accessed
            if comp_name in stack:
                return
            comp = self.computations.get(comp_name)
            if comp is None:
                return
            stack.add(comp_name)
            for instr in comp.instructions:
                op = _opcode(instr)
                if op == "while":
                    mb = re.search(r"body=%?([\w.\-]+)", instr)
                    mc = re.search(r"condition=%?([\w.\-]+)", instr)
                    trips = self.while_trip_count(mc.group(1)) if mc else 1
                    if mb:
                        walk(mb.group(1), mult * trips, True)
                    continue
                if op in _CALLERS:
                    # fusions: count dots inside, not the scalar to_apply
                    for sub in self.called_computations(instr):
                        if op in ("fusion", "call", "conditional"):
                            walk(sub, mult, False)
                if op == "dot":
                    flops += mult * self._dot_flops(comp, instr)
                    if top_level:
                        bytes_accessed += mult * self._io_bytes(comp, instr)
                elif op == "convolution":
                    flops += mult * self._conv_flops(comp, instr)
                    if top_level:
                        bytes_accessed += mult * self._io_bytes(comp, instr)
                elif top_level and op in _BYTES_OPS:
                    bytes_accessed += mult * self._io_bytes(comp, instr)
                kind = op[:-6] if op.endswith("-start") else op
                if kind in _COLLECTIVES:
                    coll[kind]["count"] += mult
                    coll[kind]["bytes"] += mult * _shape_bytes(
                        _result_segment(instr))
            stack.discard(comp_name)

        if self.entry:
            walk(self.entry, 1.0, True)
        return {
            "flops": flops,
            "bytes_accessed": bytes_accessed,
            "collectives": coll,
            "collective_bytes": sum(v["bytes"] for v in coll.values()),
        }

    def _io_bytes(self, comp: Computation, instr: str) -> float:
        total = _shape_bytes(_result_segment(instr))
        for seg in self.operand_shapes(comp, instr):
            total += _shape_bytes(seg)
        return float(total)

    def _dot_flops(self, comp: Computation, instr: str) -> float:
        out = _shape_list(_result_segment(instr))
        if not out:
            return 0.0
        out_elems = math.prod(out[0][1]) if out[0][1] else 1
        contract = 1
        mlhs = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr)
        operands = self.operand_shapes(comp, instr)
        if mlhs and operands:
            lhs = _shape_list(operands[0])
            if lhs:
                dims = lhs[0][1]
                for d in mlhs.group(1).split(","):
                    if d and int(d) < len(dims):
                        contract *= dims[int(d)]
        return 2.0 * out_elems * contract

    def _conv_flops(self, comp: Computation, instr: str) -> float:
        out = _shape_list(_result_segment(instr))
        if not out:
            return 0.0
        out_elems = math.prod(out[0][1]) if out[0][1] else 1
        operands = self.operand_shapes(comp, instr)
        if len(operands) >= 2:
            k = _shape_list(operands[1])
            if k and k[0][1]:
                kernel = math.prod(k[0][1])
                out_ch = out[0][1][-1] if out[0][1] else 1
                return 2.0 * out_elems * max(kernel // max(out_ch, 1), 1)
        return 2.0 * out_elems


def analyze_hlo(text: str) -> Dict[str, float]:
    return HloModule(text).analyze()

"""pjit training launcher.

On this CPU container it runs a reduced model on the degenerate 1x1 host
mesh by default (--mesh host); on a real pod pass --mesh single/multi to
use the production meshes with the same sharding rules the dry-run
validates.

  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
      --steps 20
"""
from __future__ import annotations

import argparse
import functools
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import TRAIN_4K, get_arch, reduced
from repro.configs.base import ShapeConfig
from repro.data.synthetic import token_batches
from repro.distributed import hints, sharding as shd
from repro.launch import mesh as mesh_lib
from repro.models import transformer
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.trainer import train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--mesh", choices=["host", "single", "multi"],
                    default="host")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full-size", action="store_true",
                    help="use the full config (real hardware only)")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if not args.full_size:
        cfg = reduced(cfg)
    if args.mesh == "host":
        mesh = mesh_lib.make_host_mesh()
    else:
        mesh = mesh_lib.make_production_mesh(
            multi_pod=(args.mesh == "multi"))

    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=5,
                          total_steps=args.steps)
    fn = functools.partial(train_step, cfg=cfg, opt_cfg=opt_cfg)

    key = jax.random.PRNGKey(0)
    params = transformer.init_params(cfg, key)
    opt = init_opt_state(params)
    p_sh = shd.params_shardings(params, cfg, mesh)
    in_sh = (p_sh, shd.opt_state_shardings(opt, p_sh, mesh),
             shd.batch_shardings(
                 {"tokens": jax.ShapeDtypeStruct(
                     (args.batch, args.seq), jnp.int32)},
                 shape, mesh, cfg),
             shd.replicated(mesh))

    data = token_batches(cfg, args.batch, args.seq, seed=0)
    with mesh, hints.batch_axes_ctx(shd.batch_axes(mesh)):
        step = jax.jit(fn, in_shardings=in_sh, donate_argnums=(0, 1))
        for i in range(args.steps):
            batch = {k: jnp.asarray(v) for k, v in next(data).items()
                     if k == "tokens"}
            t0 = time.time()
            params, opt, metrics = step(params, opt, batch,
                                        jax.random.fold_in(key, i))
            loss = float(jax.device_get(metrics["loss"]))
            if i % 5 == 0:
                print(f"step {i:4d} loss {loss:.4f} "
                      f"({(time.time()-t0)*1e3:.0f} ms)", flush=True)
    print("done")


if __name__ == "__main__":
    sys.exit(main())

"""Process-wide JAX runtime accounting (DESIGN.md §12).

Three small facilities that every layer above core can share:

  * **Executable tracking** — ``track_executables`` registers a jitted
    callable in a process-wide weak set; ``live_executable_count`` sums
    the per-function executable-cache sizes (``PjitFunction._cache_size``
    — compiled executables live in C++ and are invisible to ``gc``, so
    counting them any other way reads zero).  Coverage is best-effort by
    construction: whoever jits a function registers it, and the decode
    sessions (the dominant executable source — one step fn + loop fns +
    partial prefills per lane) all do.
  * **The ONE executable-cache dropper** — ``drop_executables`` wraps
    ``jax.clear_caches()`` and reports how many live executables it
    cleared.  ``tests/conftest.py`` and ``benchmarks/bench_serving.py``
    used to hand-roll the same call; both now come through here.
  * **Compile/retrace accounting** — :class:`CompileTracker` counts
    every retrace exactly (a Python wrapper around the function handed
    to ``jax.jit`` only executes at trace time, so its invocation count
    IS the trace count — and it is a no-op on traced values, so decode
    outputs are byte-identical with counting on).  Where available,
    ``jax.monitoring`` duration events add backend-compile wall time;
    when the module is absent the trace counters still work alone.

Counting is passive and always-on: it is host-side, fires only at trace
time (never per step), and costs one dict increment per compile — so
unlike the :mod:`repro.serving.profiling` step decomposition it needs
no enable flag.
"""
from __future__ import annotations

import functools
import threading
import weakref
from typing import Any, Callable, Dict, List, Optional, Tuple

__all__ = [
    "track_executables", "live_executable_count", "drop_executables",
    "CompileTracker", "compile_tracker",
]

_LOCK = threading.Lock()
_TRACKED: "weakref.WeakSet" = weakref.WeakSet()


def track_executables(fn: Any) -> Any:
    """Register a jitted callable for live-executable accounting and
    return it unchanged (chainable around ``jax.jit(...)``)."""
    if hasattr(fn, "_cache_size"):
        with _LOCK:
            _TRACKED.add(fn)
    return fn


def live_executable_count() -> int:
    """Total compiled executables across tracked jitted functions."""
    total = 0
    with _LOCK:
        fns = list(_TRACKED)
    for fn in fns:
        try:
            total += int(fn._cache_size())
        except Exception:      # fn mid-teardown: count what we can
            pass
    return total


def drop_executables(note: str = "") -> int:
    """Clear every jitted executable cache (the tests/bench memory
    valve: accumulated lane/prefill executables deterministically crash
    XLA's CPU JIT late in a long run).  Returns the tracked
    live-executable count that was dropped; prints ``note`` when given
    so bench logs show part boundaries."""
    import jax
    n = live_executable_count()
    jax.clear_caches()
    if note:
        print(f"[runtime] {note} (dropped {n} tracked executables)",
              flush=True)
    return n


class CompileTracker:
    """Process-wide retrace/compile accounting.

    ``wrap(fn, name=..., lane=...)`` returns a function whose body runs
    only when JAX traces it — wrap BEFORE ``jax.jit``.  Each execution
    increments the per-name and per-lane trace counters exactly once
    per (re)trace.  A guarded ``jax.monitoring`` listener adds compile
    wall-time totals when the runtime exposes duration events.
    """

    # monitoring event -> short key in the seconds table
    _EVENTS = {
        "/jax/core/compile/backend_compile_duration": "backend_compile",
        "/jax/core/compile/jaxpr_to_mlir_module_duration": "lowering",
        "/jax/core/compile/jaxpr_trace_duration": "tracing",
    }

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.traces: Dict[str, int] = {}        # fn name -> trace count
        self.lane_traces: Dict[str, int] = {}   # lane signature -> count
        self.event_counts: Dict[str, int] = {}
        self.event_seconds: Dict[str, float] = {}
        self._listener_installed = False

    # ---- trace counting ----------------------------------------------

    def wrap(self, fn: Callable, *, name: str,
             lane: str = "") -> Callable:
        """Count (re)traces of ``fn``.  The wrapper body only runs at
        trace time, never per step, and passes arguments through
        untouched — traced values are unaffected."""
        @functools.wraps(fn)
        def counted(*args, **kwargs):
            with self._lock:
                self.traces[name] = self.traces.get(name, 0) + 1
                if lane:
                    self.lane_traces[lane] = \
                        self.lane_traces.get(lane, 0) + 1
            return fn(*args, **kwargs)
        return counted

    def trace_count(self, name: Optional[str] = None) -> int:
        with self._lock:
            if name is not None:
                return self.traces.get(name, 0)
            return sum(self.traces.values())

    def top_retraced(self, k: int = 3) -> List[Tuple[str, int]]:
        """Lane signatures by descending trace count (serve.py
        ``--profile`` summary)."""
        with self._lock:
            items = sorted(self.lane_traces.items(),
                           key=lambda kv: (-kv[1], kv[0]))
        return items[:k]

    # ---- jax.monitoring compile durations ----------------------------

    def install_monitoring(self) -> bool:
        """Attach the compile-duration listener once.  Returns whether
        the runtime supports it; safe to call repeatedly."""
        with self._lock:
            if self._listener_installed:
                return True
            try:
                from jax import monitoring
                register = monitoring.register_event_duration_secs_listener
            except Exception:
                return False
            self._listener_installed = True
        register(self._on_event)
        return True

    def _on_event(self, event: str, duration: float, **kw) -> None:
        key = self._EVENTS.get(event)
        if key is None:
            return
        with self._lock:
            self.event_counts[key] = self.event_counts.get(key, 0) + 1
            self.event_seconds[key] = \
                self.event_seconds.get(key, 0.0) + float(duration)

    # ---- exposition --------------------------------------------------

    def snapshot(self) -> Dict[str, Any]:
        """JSON-safe dump (bench metrics artifact embeds this)."""
        with self._lock:
            return {
                "traces": dict(self.traces),
                "lane_traces": dict(self.lane_traces),
                "event_counts": dict(self.event_counts),
                "event_seconds": {k: round(v, 6) for k, v in
                                  self.event_seconds.items()},
                "live_executables": live_executable_count(),
            }

    def export_metrics(self, registry) -> None:
        """Mirror the counters into a §11 registry (engine collector):
        ``spa_runtime_*`` series on /metrics."""
        with self._lock:
            traces = dict(self.traces)
            events = dict(self.event_counts)
            seconds = dict(self.event_seconds)
        for name, n in sorted(traces.items()):
            registry.counter(
                "spa_runtime_trace_total",
                "function (re)traces by jitted entry point",
                labels={"fn": name}).set(n)
        for key, n in sorted(events.items()):
            registry.counter(
                "spa_runtime_compile_events_total",
                "jax.monitoring compile events by stage",
                labels={"stage": key}).set(n)
        for key, s in sorted(seconds.items()):
            registry.counter(
                "spa_runtime_compile_seconds_total",
                "compile wall time by stage",
                labels={"stage": key}).set(s)
        registry.gauge(
            "spa_runtime_live_executables",
            "compiled executables across tracked jitted functions",
        ).set(live_executable_count())

    def reset(self) -> None:
        """Zero all counters (bench part boundaries, tests)."""
        with self._lock:
            self.traces.clear()
            self.lane_traces.clear()
            self.event_counts.clear()
            self.event_seconds.clear()


_TRACKER = CompileTracker()


def compile_tracker() -> CompileTracker:
    """The process-wide tracker (monitoring listener attached lazily)."""
    _TRACKER.install_monitoring()
    return _TRACKER

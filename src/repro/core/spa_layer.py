"""SPA-Cache transformer block (paper Algorithm 1) + layer orchestration.

Phase 1 — update identification & selection: project current (normed)
inputs to identifier vectors, score cosine drift against the cached
identifiers, select the top-k most-drifted rows (k = rho(l) * N from the
adaptive budget).

Phase 2 — attention with partially cached KV: recompute Q/K/V only for
selected rows, scatter K/V into the cache, attend sparse queries against
the full (partially refreshed) KV cache.

Phase 3 — FFN & output update: run FFN/MoE on the selected rows, scatter
into the output cache H^c; the layer output is the refreshed H^c.

Execution modes:
  * unrolled  — exact per-layer k (small models, hybrids)
  * bucketed  — contiguous layer buckets with shared k compiled as
                ``lax.scan`` segments (full-size models; DESIGN.md §4.4)

The kernel-shaped stages of every phase (identification, gather+norm,
attention, commits) dispatch through ``strategy.backend`` — a
``KernelBackend`` (DESIGN.md §4.5): XLA ops by default, the Pallas TPU
kernel suite with ``PallasBackend`` (selection/top-k always stays XLA).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTENTION_KINDS, ModelConfig
from repro.core import budget, cache as cache_lib, identifiers, selection
from repro.core.cache import CachePolicy
from repro.core.strategy import CacheStrategy, resolve_strategy
from repro.models import common
from repro.models.transformer import (apply_block_dense, apply_ffn_or_moe,
                                      layer_window, qkv_project)

Params = Dict[str, Any]


def _hint_cache_slice(cache_sl: Dict[str, jax.Array], b: int,
                      skip: Tuple[str, ...] = ()) -> Dict[str, jax.Array]:
    """Keep cache buffers sequence-sharded over "model" after scatters
    (GSPMD otherwise materializes replicated copies per layer). For
    batch=1 long-context the sequence spans all axes.  ``skip`` names
    buffers left untouched (paged arenas have no batch/sequence axes)."""
    from repro.distributed.hints import shard_hint
    n_spec = ("pod", "data", "model") if b == 1 else "model"
    b_spec = None if b == 1 else "batch"
    out = {}
    for key, arr in cache_sl.items():
        if key in skip:
            out[key] = arr
            continue
        dims = (b_spec, n_spec) + (None,) * (arr.ndim - 2)
        out[key] = shard_hint(arr, *dims)
    return out


def stratify_blocks_for(n: int, k: int) -> int:
    """Number of strata so that every q block's position span is bounded.

    With per-block top-(k/nb) selection over nb equal blocks, any
    ``block_q`` consecutive selected rows span at most
    ``ceil(block_q / (k/nb)) + 1`` strata, i.e. <= span_bound positions.
    We pick nb so each stratum is ~4096 positions.
    """
    if n <= 8192:
        return 0
    nb = max(1, n // 4096)
    while n % nb:
        nb -= 1
    return nb


def q_span_bound(n: int, k: int, nb: int, block_q: int = 512) -> int:
    if nb <= 1:
        return 0
    per = max(1, k // nb)
    stratum = n // nb
    n_strata_per_block = (block_q + per - 1) // per + 1
    return n_strata_per_block * stratum


def _mask_tail_scores(scores: jax.Array, n: int,
                      kv_len: Optional[jax.Array]) -> jax.Array:
    """Rows past a request's valid canvas length never select: their
    similarity is forced to +inf (LOW = drifted = update, so +inf is
    'never update') — shared by both identifier paths so the paged
    selection semantics cannot drift between them.

    Caveat: ``select_stratified`` (long-context windowed path,
    n > 8192) takes a fixed per-block quota regardless of score, so
    strata wholly past ``kv_len`` still select dead rows — state stays
    correct (zero-page commits drop, attention masks them) but a short
    row's refresh budget dilutes.  Per-row dynamic stratification needs
    dynamic shapes; until then keep paged canvases <= the stratify
    threshold or window-free (DESIGN.md §5)."""
    if kv_len is None:
        return scores
    return jnp.where(jnp.arange(n)[None, :] < kv_len[:, None],
                     scores, jnp.inf)


def _identifier_scores(strategy: CacheStrategy, bp: Params, proxy_mat, x,
                       cache_sl, scores_override, prev_idx=None,
                       page_table=None):
    """Returns (scores, p_now_full_or_None, proxy_now_cache_or_None).

    Projection + drift scoring run on ``strategy.backend`` — the fused
    Pallas identification kernel on ``PallasBackend``, jnp ops on
    ``XlaBackend`` (DESIGN.md §4.5).  With ``page_table`` the cached
    identifiers are a pooled page arena (DESIGN.md §5) and scoring reads
    them through page-table indirection.

    Incremental mode (beyond-paper, DESIGN.md §6): only rows whose
    INPUTS changed (= rows refreshed by the previous layer, or newly
    committed tokens at layer 0) can have drifted proxies, so the rank-r
    projection runs on those k rows instead of all N — identification HBM
    traffic drops from N*d to k*d per layer.  The full-N rescore against
    the cached identifiers is the backend's score-only pass."""
    backend = strategy.backend
    if scores_override is not None:
        return scores_override, None, None
    if (strategy.incremental and prev_idx is not None
            and "proxy_now" in cache_sl):
        rows = selection.gather_rows(x, prev_idx)   # x = scaled h
        p_rows = strategy.project(rows, bp, proxy_mat)
        proxy_now = selection.scatter_rows(cache_sl["proxy_now"],
                                           prev_idx, p_rows)
        scores = backend.score_drift(
            strategy, proxy_now.astype(jnp.float32), cache_sl["proxy"],
            page_table=page_table)
        return scores, None, proxy_now
    scores, p_now = backend.identifier_scores(strategy, bp, proxy_mat, x,
                                              cache_sl["proxy"],
                                              page_table=page_table)
    return scores, p_now, None


def spa_attn_block(cfg: ModelConfig, kind: str, bp: Params,
                   proxy_mat: Optional[jax.Array],
                   cache_sl: Dict[str, jax.Array], h: jax.Array,
                   k_upd: int, policy: CachePolicy,
                   strategy: Optional[CacheStrategy] = None,
                   scores_override: Optional[jax.Array] = None,
                   prev_idx: Optional[jax.Array] = None,
                   page_table: Optional[jax.Array] = None,
                   kv_len: Optional[jax.Array] = None
                   ) -> Tuple[jax.Array, Dict[str, jax.Array], jax.Array,
                              jax.Array]:
    """One SPA-Cache attention block step. h: [B,N,d] current inputs.
    Returns (h_out, new_cache, aux, selected_idx).

    Paged serving (DESIGN.md §5): with ``page_table`` the ``proxy``
    buffer in ``cache_sl`` is a pooled page arena (identification and
    proxy commits go through page-table indirection); ``kv_len`` [B]
    marks each row's valid canvas length — rows past it never select
    (scores forced to +inf) and never attend (masked K/V)."""
    strategy = resolve_strategy(cfg, strategy)
    b, n, d = h.shape
    w = layer_window(cfg, kind)

    if strategy.full_attn_ident:
        x = common.rms_norm(h, bp["norm1"], cfg.norm_eps)
        h_out, cache_sl, aux, idx = _attn_out_identifier_block(
            cfg, kind, bp, cache_sl, h, x, k_upd, policy, strategy,
            page_table=page_table, kv_len=kv_len)
        return h_out, cache_sl, aux, idx

    # ---- Phase 1: identification & selection ----
    # Cosine drift is invariant to per-row scale, so the rms division of
    # the pre-attention norm is mathematically irrelevant for the
    # identifier: score on h * (1 + norm_weight) directly and rms-norm
    # only the k SELECTED rows afterwards. This keeps the full-sequence
    # tensor in bf16 (the gather's cross-shard all-reduce halves) and
    # skips an N*d norm per layer.
    ident_in = h * (1.0 + bp["norm1"]).astype(h.dtype)
    scores, p_now, proxy_now = _identifier_scores(
        strategy, bp, proxy_mat, ident_in, cache_sl, scores_override,
        prev_idx, page_table=page_table)
    scores = _mask_tail_scores(scores, n, kv_len)
    nb = stratify_blocks_for(n, k_upd) if w > 0 else 0
    if nb > 1:
        idx = selection.select_stratified(scores, k_upd, nb)
        span = q_span_bound(n, k_upd, nb)
    else:
        idx = selection.select_topk_drift(scores, k_upd)
        span = 0
    k_eff = idx.shape[1]

    # NOTE §Perf: sharding the selected rows over "model" here was
    # MEASURED WORSE (7x compute): GSPMD lowers a cross-shard gather with
    # sharded output to a one-hot matmul (B*k*N*d FLOPs). Rows stay
    # replicated over "model"; the gather costs one all-reduce per layer.
    # The backend's gather_norm emits BOTH the raw rows (residual) and
    # the rms-normed rows (QKV input) in one pass over the k rows.
    h_rows, x_rows = strategy.backend.gather_norm(h, idx, bp["norm1"],
                                                  cfg.norm_eps)

    # ---- Phase 2: attention with partially cached KV ----
    q, k_new, v_new = qkv_project(bp, x_rows, cfg, idx)
    cache_sl = strategy.commit_kv(cache_sl, idx, k_new, v_new, policy)
    kf, vf, ks, vs = cache_lib.read_kv_for_attention(cache_sl, policy)
    attn = strategy.backend.attention(
        q, kf, vf, k_scale=ks, v_scale=vs, q_positions=idx, window=w,
        soft_cap=cfg.attn_softcap, banded=(w > 0 and span > 0),
        q_span=span, kv_len=kv_len)
    from repro.distributed.hints import shard_hint
    attn_out = shard_hint(attn.reshape(b, k_eff, cfg.q_dim) @ bp["wo"],
                          "batch", "keep", None)
    if cfg.post_norms:
        attn_out = common.rms_norm(attn_out, bp["norm_post_attn"],
                                   cfg.norm_eps)
    h_mid = h_rows + attn_out

    # ---- Phase 3: FFN & output update ----
    y = common.rms_norm(h_mid, bp["norm2"], cfg.norm_eps)
    ffn_out, aux = apply_ffn_or_moe(bp, y, cfg)
    if cfg.post_norms:
        ffn_out = common.rms_norm(ffn_out, bp["norm_post_ffn"],
                                  cfg.norm_eps)
    y_rows = h_mid + ffn_out
    cache_sl = strategy.commit(cache_sl, idx, y_rows, policy,
                               p_now=p_now, proxy_now=proxy_now,
                               page_table=page_table)

    cache_sl = _hint_cache_slice(
        cache_sl, b, skip=(("proxy",) if page_table is not None else ()))
    h_out = cache_lib.read_h_full(cache_sl, policy, h.dtype)
    # sequence-parallel residual stream between layers (decode): the
    # identification / gathers / FFN are row-local; only attention and
    # top-k cross shards.
    from repro.distributed.hints import shard_hint
    n_spec = ("pod", "data", "model") if b == 1 else "model"
    h_out = shard_hint(h_out, None if b == 1 else "batch", n_spec, None)
    return h_out, cache_sl, aux, idx


def _attn_out_identifier_block(cfg, kind, bp, cache_sl, h, x, k_upd,
                               policy, strategy, page_table=None,
                               kv_len=None):
    """Table-1 'attn output' identifier: full attention is computed for ALL
    rows against the (stale) cached KV purely for identification; only the
    FFN runs sparsely. Matches the paper's cost profile (slower than the
    value proxy, still much faster than vanilla)."""
    b, n, d = h.shape
    w = layer_window(cfg, kind)
    positions = jnp.broadcast_to(jnp.arange(n)[None], (b, n))
    q_all, k_all, v_all = qkv_project(bp, x, cfg, positions)
    kf, vf, ks, vs = cache_lib.read_kv_for_attention(cache_sl, policy)
    attn_all = strategy.backend.attention(
        q_all, kf, vf, k_scale=ks, v_scale=vs, window=w,
        soft_cap=cfg.attn_softcap, banded=(w > 0), kv_len=kv_len)
    attn_all = attn_all.reshape(b, n, cfg.q_dim) @ bp["wo"]
    if cfg.post_norms:
        attn_all = common.rms_norm(attn_all, bp["norm_post_attn"],
                                   cfg.norm_eps)
    scores = strategy.backend.score_drift(strategy, attn_all,
                                          cache_sl["proxy"],
                                          page_table=page_table)
    scores = _mask_tail_scores(scores, n, kv_len)
    idx = selection.select_topk_drift(scores, k_upd)

    cache_sl = strategy.commit_kv(
        cache_sl, idx, selection.gather_rows(k_all, idx),
        selection.gather_rows(v_all, idx), policy)
    h_mid = selection.gather_rows(h, idx) + selection.gather_rows(
        attn_all, idx)
    y = common.rms_norm(h_mid, bp["norm2"], cfg.norm_eps)
    ffn_out, aux = apply_ffn_or_moe(bp, y, cfg)
    if cfg.post_norms:
        ffn_out = common.rms_norm(ffn_out, bp["norm_post_ffn"],
                                  cfg.norm_eps)
    y_rows = h_mid + ffn_out
    cache_sl = strategy.commit(cache_sl, idx, y_rows, policy,
                               attn_all=attn_all, page_table=page_table)
    cache_sl = _hint_cache_slice(
        cache_sl, b, skip=(("proxy",) if page_table is not None else ()))
    h_out = cache_lib.read_h_full(cache_sl, policy, h.dtype)
    return h_out, cache_sl, aux, idx


# ---------------------------------------------------------------------------
# Whole-model serve forward
# ---------------------------------------------------------------------------

def _homogeneous_attention(cfg: ModelConfig) -> bool:
    kinds = set(cfg.layer_pattern)
    return len(kinds) == 1 and next(iter(kinds)) in ATTENTION_KINDS


def spa_forward(params: Params, cfg: ModelConfig,
                cache: Dict[str, Dict[str, jax.Array]], h: jax.Array,
                spa_proxies: Optional[Dict[str, jax.Array]] = None,
                scores_override: Optional[jax.Array] = None,
                changed_idx: Optional[jax.Array] = None,
                strategy: Optional[CacheStrategy] = None,
                backend=None,
                page_table: Optional[jax.Array] = None,
                kv_len: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Dict, jax.Array]:
    """Run all blocks with the given CacheStrategy on attention layers.

    cache: {kind: {name: [Lk, B, N, ...]}} (from ``init_model_cache`` or
    prefill). changed_idx [B, c]: positions whose INPUT rows changed since
    the previous step (newly committed tokens) — used by the incremental
    identifier. strategy defaults to ``cfg.spa`` resolved through the
    registry; ``backend`` (a KernelBackend or "xla"/"pallas") overrides
    the strategy's kernel backend for this call. Returns (h_final,
    new_cache, aux).

    Paged serving (DESIGN.md §5): ``page_table`` [B, n_log] marks the
    ``proxy`` buffers in ``cache`` as pooled page arenas
    ([Lk, P, page, r]); ``kv_len`` [B] is each row's valid canvas length
    (selection + attention mask the tail).
    """
    strategy = resolve_strategy(cfg, strategy)
    if backend is not None:
        strategy = strategy.with_backend(backend)
    policy = CachePolicy.from_config(cfg)
    b, n = h.shape[0], h.shape[1]
    ks = strategy.k_schedule(cfg, n)
    k_max = max(ks)
    uses_proxy_mat = strategy.uses_proxy_mat
    aux_total = jnp.zeros((), jnp.float32)

    incremental = strategy.incremental and scores_override is None

    def pad_idx(idx):
        """Pad/clip an index set to [B, k_max] with sentinel n."""
        if idx is None:
            return jnp.full((b, k_max), n, jnp.int32)
        idx = idx.astype(jnp.int32)
        idx = jnp.where(idx < 0, n, idx)       # -1 ring slots -> sentinel
        if idx.shape[1] >= k_max:
            return idx[:, :k_max]
        return jnp.pad(idx, ((0, 0), (0, k_max - idx.shape[1])),
                       constant_values=n)

    prev = pad_idx(changed_idx) if incremental else None

    if (_homogeneous_attention(cfg) and cfg.scan_layers
            and cfg.n_layers >= 8 and scores_override is None):
        # The cache rides in the scan CARRY (updated with
        # dynamic_update_slice per layer) rather than as xs/ys — while-loop
        # carries update in place under XLA buffer donation, so the
        # multi-GB cache stacks exist ONCE instead of as input + output +
        # copy (3x) buffers.
        kind = cfg.layer_pattern[0]
        segments = budget.bucketize(ks, strategy.n_buckets)
        new_slices: List = []
        for (a, b_end, kseg) in segments:
            bp_sl = jax.tree.map(lambda t: t[a:b_end],
                                 params["blocks"][kind])
            cache_seg = jax.tree.map(lambda t: t[a:b_end], cache[kind])
            prox = (spa_proxies[kind][a:b_end]
                    if uses_proxy_mat and spa_proxies else None)

            def body(carry, xs, _kseg=kseg):
                if incremental:
                    h_c, aux_c, cache_c, prev_c = carry
                else:
                    h_c, aux_c, cache_c = carry
                    prev_c = None
                if prox is not None:
                    bp_l, l_idx, pm = xs
                else:
                    bp_l, l_idx = xs
                    pm = None
                csl = jax.tree.map(
                    lambda t: jax.lax.dynamic_index_in_dim(
                        t, l_idx, 0, keepdims=False), cache_c)
                h_c, csl_new, aux, idx = spa_attn_block(
                    cfg, kind, bp_l, pm, csl, h_c, _kseg, policy,
                    strategy, prev_idx=prev_c, page_table=page_table,
                    kv_len=kv_len)
                cache_c = jax.tree.map(
                    lambda t, sl: jax.lax.dynamic_update_index_in_dim(
                        t, sl.astype(t.dtype), l_idx, 0),
                    cache_c, csl_new)
                if incremental:
                    return (h_c, aux_c + aux, cache_c,
                            pad_idx(idx)), None
                return (h_c, aux_c + aux, cache_c), None

            seg_len = b_end - a
            layer_ids = jnp.arange(seg_len, dtype=jnp.int32)
            xs = (bp_sl, layer_ids, prox) if prox is not None \
                else (bp_sl, layer_ids)
            init = (h, aux_total, cache_seg, prev) if incremental \
                else (h, aux_total, cache_seg)
            carry, _ = jax.lax.scan(body, init, xs)
            if incremental:
                h, aux_total, cache_seg, prev = carry
            else:
                h, aux_total, cache_seg = carry
            new_slices.append(cache_seg)
        new_cache = {kind: jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0), *new_slices)}
        return h, new_cache, aux_total

    # Unrolled path: exact per-layer k; hybrid / SSM blocks recompute fully.
    per_kind_new: Dict[str, List] = {}
    for l in range(cfg.n_layers):
        kind = cfg.kind_of_layer(l)
        ki = cfg.kind_index(l)
        bp = jax.tree.map(lambda t: t[ki], params["blocks"][kind])
        if kind in ATTENTION_KINDS and strategy.uses_cache:
            csl = jax.tree.map(lambda t: t[ki], cache[kind])
            prox = (spa_proxies[kind][ki]
                    if uses_proxy_mat and spa_proxies else None)
            h, csl_new, aux, idx = spa_attn_block(
                cfg, kind, bp, prox, csl, h, ks[l], policy, strategy,
                scores_override=scores_override, prev_idx=prev,
                page_table=page_table, kv_len=kv_len)
            if incremental:
                prev = pad_idx(idx)
            per_kind_new.setdefault(kind, []).append(csl_new)
            aux_total = aux_total + aux
        else:
            h, aux, _ = apply_block_dense(cfg, kind, bp, h, kv_len=kv_len)
            aux_total = aux_total + aux
            # recurrent blocks recompute everything: downstream inputs all
            # changed -> fall back to full identification next layer
            if incremental and kind not in ATTENTION_KINDS:
                prev = None   # full identification next attention layer
            if kind in cache:  # identifier "none": keep cache untouched
                per_kind_new.setdefault(kind, []).append(
                    jax.tree.map(lambda t: t[ki], cache[kind]))
    new_cache = {
        kind: jax.tree.map(lambda *xs: jnp.stack(xs), *slices)
        for kind, slices in per_kind_new.items()
    }
    return h, new_cache, aux_total


def build_spa_proxies(params: Params, cfg: ModelConfig,
                      strategy: Optional[CacheStrategy] = None
                      ) -> Optional[Dict[str, jax.Array]]:
    """Offline proxy stacks {kind: [Lk,d,r]} for the resolved strategy
    (SVD of value projections for SPACache; None for every other)."""
    return resolve_strategy(cfg, strategy).build_proxies(params, cfg)


def spa_proxy_specs(cfg: ModelConfig,
                    strategy: Optional[CacheStrategy] = None
                    ) -> Optional[Dict[str, Any]]:
    """ShapeDtypeStructs of the proxy stacks (for the dry-run)."""
    return resolve_strategy(cfg, strategy).proxy_specs(cfg)

"""SPA-Cache state pytrees + int8 cache quantization.

Per attention layer the cache holds (Algorithm 1):
  k, v   — the partially-updated KV cache          [B, N, KVH, HD]
  h      — the block OUTPUT states H^c             [B, N, d]
  proxy  — identifier vectors at the last refresh  [B, N, r]

Layers are stacked per layer-kind ([L_kind, ...] leading axis) so the
serve path can ``lax.scan`` over them. Recurrent kinds (rglru / ssd) are
fully recomputed each step (DESIGN.md §Arch-applicability) and carry no
cache.

int8 mode (``cache_dtype="int8"``): symmetric per-row quantization with a
float16 scale. At 32k tokens x batch 128, bf16 H-caches for a 67B model
are ~TB-scale — int8 halves them; this is a beyond-paper serving feature
(see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ATTENTION_KINDS, ModelConfig


def quantize_rows(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric int8 over the last axis. Returns (q [.., d] i8, scale).

    The rowwise math stays in x's dtype (values <= 127 are exactly
    representable in bf16) — upcasting the whole block to f32 doubles the
    live-buffer footprint on the serve path for no precision gain."""
    amax = jnp.max(jnp.abs(x), axis=-1).astype(jnp.float32)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    inv = (1.0 / scale).astype(x.dtype)
    q = jnp.clip(jnp.round((x * inv[..., None]).astype(jnp.float32)),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float16)


def dequantize_rows(q: jax.Array, scale: jax.Array,
                    dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32)
            * scale.astype(jnp.float32)[..., None]).astype(dtype)


def quantize_rows_np(x: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Host-side (numpy) twin of :func:`quantize_rows` for the cold
    host-RAM page tier (DESIGN.md §9): symmetric int8 over the last
    axis with a float16 per-row scale.  Per-row reconstruction error is
    bounded by ``scale/2`` per element (= ``max|row| / 254``) plus the
    f16 cast of the scale itself (relative 2^-11, absolute 2^-24 for
    subnormal scales), which ``tests/test_hier.py`` asserts
    property-style."""
    xf = np.asarray(x).astype(np.float32)
    amax = np.max(np.abs(xf), axis=-1)
    scale = np.maximum(amax / 127.0, 1e-8).astype(np.float32)
    q = np.clip(np.round(xf / scale[..., None]), -127, 127).astype(np.int8)
    return q, scale.astype(np.float16)


def dequantize_rows_np(q: np.ndarray, scale: np.ndarray,
                       dtype=np.float32) -> np.ndarray:
    return (q.astype(np.float32)
            * np.asarray(scale).astype(np.float32)[..., None]).astype(dtype)


@dataclasses.dataclass(frozen=True)
class CachePolicy:
    quantized: bool
    compute_dtype: jnp.dtype

    @classmethod
    def from_config(cls, cfg: ModelConfig) -> "CachePolicy":
        return cls(quantized=(cfg.cache_dtype == "int8"),
                   compute_dtype=jnp.dtype(cfg.param_dtype))


def proxy_dim(cfg: ModelConfig, strategy=None) -> int:
    """Identifier-vector width r for the (resolved) strategy."""
    from repro.core.strategy import resolve_strategy
    return resolve_strategy(cfg, strategy).proxy_dim(cfg)


def init_attn_layer_cache(cfg: ModelConfig, batch: int, n: int,
                          policy: CachePolicy,
                          strategy=None) -> Dict[str, jax.Array]:
    """Zeros cache for ONE attention layer (no leading L axis)."""
    from repro.core.strategy import resolve_strategy
    strategy = resolve_strategy(cfg, strategy)
    kvh, hd, d = cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    r = strategy.proxy_dim(cfg)
    cd = policy.compute_dtype
    out: Dict[str, jax.Array] = {}
    if policy.quantized:
        out["k"] = jnp.zeros((batch, n, kvh, hd), jnp.int8)
        out["v"] = jnp.zeros((batch, n, kvh, hd), jnp.int8)
        out["h"] = jnp.zeros((batch, n, d), jnp.int8)
        out["k_scale"] = jnp.zeros((batch, n, kvh), jnp.float16)
        out["v_scale"] = jnp.zeros((batch, n, kvh), jnp.float16)
        out["h_scale"] = jnp.zeros((batch, n), jnp.float16)
    else:
        out["k"] = jnp.zeros((batch, n, kvh, hd), cd)
        out["v"] = jnp.zeros((batch, n, kvh, hd), cd)
        out["h"] = jnp.zeros((batch, n, d), cd)
    if r:
        out["proxy"] = jnp.zeros((batch, n, r), cd)
        if strategy.incremental:
            out["proxy_now"] = jnp.zeros((batch, n, r), cd)
    return out


def init_model_cache(cfg: ModelConfig, batch: int, n: int, strategy=None
                     ) -> Dict[str, Dict[str, jax.Array]]:
    """Stacked caches per attention kind: {kind: {name: [Lk, B, N, ...]}}."""
    policy = CachePolicy.from_config(cfg)
    out: Dict[str, Dict[str, jax.Array]] = {}
    for kind in sorted(set(cfg.layer_kinds)):
        if kind not in ATTENTION_KINDS:
            continue
        lk = cfg.n_layers_of_kind(kind)
        one = init_attn_layer_cache(cfg, batch, n, policy, strategy)
        out[kind] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (lk,) + a.shape).copy(), one)
    return out


# ---------------------------------------------------------------------------
# Paged layout (DESIGN.md §5): cache rows live in ONE pooled device
# arena of fixed-size pages per buffer; a per-request page table maps
# logical canvas pages to physical pages.  Physical page 0 is the
# reserved ZERO page — it is never written, and every logical page past
# a request's ``kv_len`` aliases it, so short rows cost only the pages
# they actually cover instead of a full canvas_len slab.
# ---------------------------------------------------------------------------

class PagedCache(NamedTuple):
    """Paged cache state: pooled arenas + the batch page table.

    arenas:     {kind: {name: [Lk, P, page, ...feat]}}
    page_table: [B, n_log] int32 physical page per logical canvas page
    """
    arenas: Dict[str, Dict[str, jax.Array]]
    page_table: jax.Array


# Buffers that stay PAGED through the per-layer hot loop (identification
# reads + row commits go through page-table indirection); every other
# buffer is materialized as a dense per-step view (attention reads the
# whole K/V anyway in a bidirectional DLM step).
PAGED_IN_STEP = ("proxy",)


def n_logical_pages(canvas_len: int, page_size: int) -> int:
    if canvas_len % page_size:
        raise ValueError(
            f"canvas_len {canvas_len} must be a multiple of page_size "
            f"{page_size}")
    return canvas_len // page_size


def init_paged_arenas(cfg: ModelConfig, n_pages: int, page_size: int,
                      strategy=None) -> Dict[str, Dict[str, jax.Array]]:
    """Zeroed pooled arenas {kind: {name: [Lk, n_pages, page, ...]}}.

    Same buffer set as :func:`init_model_cache` with (batch, n) replaced
    by (physical pages, page rows); page 0 is the zero page."""
    policy = CachePolicy.from_config(cfg)
    out: Dict[str, Dict[str, jax.Array]] = {}
    for kind in sorted(set(cfg.layer_kinds)):
        if kind not in ATTENTION_KINDS:
            continue
        lk = cfg.n_layers_of_kind(kind)
        one = init_attn_layer_cache(cfg, n_pages, page_size, policy,
                                    strategy)
        out[kind] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (lk,) + a.shape).copy(),
            one)
    return out


def copy_arena_pages(arenas: Dict[str, Dict[str, jax.Array]],
                     src: "list[int]", dst: "list[int]"
                     ) -> Dict[str, Dict[str, jax.Array]]:
    """Copy whole physical pages ``src[i] -> dst[i]`` in every buffer of
    every arena (the copy-on-write and prefix-publication primitive,
    DESIGN.md §6).  Pure gather+scatter on the page axis; the caller
    patches page tables separately.

    The index lists are padded up to a power-of-two bucket with
    ``0 -> 0`` entries (re-writing the reserved zero page with its own
    zeros is a no-op), so every copy of a similar size shares one
    compiled executable instead of recompiling per page count."""
    if not src:
        return arenas
    assert len(src) == len(dst)
    bucket = 1
    while bucket < len(src):
        bucket *= 2
    pad = bucket - len(src)
    s = jnp.asarray(list(src) + [0] * pad, jnp.int32)
    d = jnp.asarray(list(dst) + [0] * pad, jnp.int32)
    return jax.tree.map(lambda a: a.at[:, d].set(a[:, s]), arenas)


def _page_bucket(n: int) -> int:
    bucket = 1
    while bucket < n:
        bucket *= 2
    return bucket


def read_arena_pages(arenas: Dict[str, Dict[str, jax.Array]],
                     pages: "list[int]") -> Dict[str, Dict[str, jax.Array]]:
    """Gather whole physical pages out of every buffer of every arena:
    returns blocks ``{kind: {name: [Lk, n, page, ...]}}`` with
    ``n == len(pages)`` (the tier demotion read, DESIGN.md §9).

    Like :func:`copy_arena_pages` the index list is padded to a
    power-of-two bucket with zero-page entries so similar-sized reads
    share one executable; the pad rows are sliced off before returning,
    so callers see exactly the pages they asked for."""
    if not pages:
        return {}
    n = len(pages)
    pad = _page_bucket(n) - n
    idx = jnp.asarray(list(pages) + [0] * pad, jnp.int32)
    return jax.tree.map(lambda a: a[:, idx][:, :n], arenas)


def write_arena_pages(arenas: Dict[str, Dict[str, jax.Array]],
                      pages: "list[int]", blocks
                      ) -> Dict[str, Dict[str, jax.Array]]:
    """Scatter page blocks (``{kind: {name: [Lk, n, page, ...]}}``, the
    layout :func:`read_arena_pages` returns) into physical pages of
    every arena buffer — the tier promotion write (DESIGN.md §9).

    The index list pads to a power-of-two bucket with zero-page entries
    whose block rows are zeros: re-writing the reserved zero page with
    zeros is a value-level no-op, so every similar-sized promotion
    shares one executable."""
    if not pages:
        return arenas
    n = len(pages)
    pad = _page_bucket(n) - n
    idx = jnp.asarray(list(pages) + [0] * pad, jnp.int32)

    def wr(a, b):
        b = jnp.asarray(b).astype(a.dtype)
        assert b.shape[1] == n, (b.shape, n)
        if pad:
            b = jnp.concatenate(
                [b, jnp.zeros((b.shape[0], pad) + b.shape[2:], a.dtype)],
                axis=1)
        return a.at[:, idx].set(b)

    return jax.tree.map(wr, arenas, blocks)


def paged_step_view(pc: PagedCache,
                    backend=None) -> Dict[str, Dict[str, jax.Array]]:
    """Per-step compute view of a paged cache: every buffer except the
    ``PAGED_IN_STEP`` set is gathered dense through the page table (one
    contiguous DMA per page on ``PallasBackend``); the identifier pages
    stay in arena form and are consumed in-layer via the paged
    identification/commit kernels."""
    if backend is None:
        from repro.kernels.backend import XLA_BACKEND as backend
    view: Dict[str, Dict[str, jax.Array]] = {}
    for kind, bufs in pc.arenas.items():
        view[kind] = {
            name: (arena if name in PAGED_IN_STEP
                   else backend.gather_pages(arena, pc.page_table))
            for name, arena in bufs.items()}
    return view


def paged_step_commit(pc: PagedCache,
                      view: Dict[str, Dict[str, jax.Array]],
                      backend=None) -> PagedCache:
    """Write a stepped compute view back into the arenas (zero-page
    writes drop, so short rows' tails stay zero)."""
    if backend is None:
        from repro.kernels.backend import XLA_BACKEND as backend
    arenas: Dict[str, Dict[str, jax.Array]] = {}
    for kind, bufs in pc.arenas.items():
        arenas[kind] = {
            name: (view[kind][name] if name in PAGED_IN_STEP
                   else backend.scatter_pages(arena, pc.page_table,
                                              view[kind][name]))
            for name, arena in bufs.items()}
    return PagedCache(arenas, pc.page_table)


def paged_from_dense(arenas: Dict[str, Dict[str, jax.Array]],
                     page_table: jax.Array,
                     dense: Dict[str, Dict[str, jax.Array]],
                     backend=None) -> Dict[str, Dict[str, jax.Array]]:
    """Scatter a dense cache (prefill/refresh output, [Lk, B, N, ...])
    into the arenas through the page table — EVERY buffer, including the
    identifier pages.  ``page_table`` may cover a sub-batch (row swap)."""
    if backend is None:
        from repro.kernels.backend import XLA_BACKEND as backend
    out: Dict[str, Dict[str, jax.Array]] = {}
    for kind, bufs in arenas.items():
        out[kind] = {
            name: backend.scatter_pages(arena, page_table,
                                        dense[kind][name])
            for name, arena in bufs.items()}
    return out


def repage(arenas: Dict[str, Dict[str, jax.Array]],
           page_table: jax.Array,
           dense: Dict[str, Dict[str, jax.Array]],
           backend=None,
           full_table: Optional[jax.Array] = None) -> PagedCache:
    """Scatter a freshly built dense cache into the arenas and wrap the
    result as a :class:`PagedCache` — the ONE repage protocol shared by
    attach, host refresh, the compiled-loop refresh branch and row
    swaps (``page_table`` may cover a sub-batch; ``full_table`` is the
    whole-batch table to carry in that case)."""
    return PagedCache(
        paged_from_dense(arenas, page_table, dense, backend),
        page_table if full_table is None else full_table)


def scatter_buffers(cache: Dict[str, jax.Array], idx: jax.Array,
                    upd: Dict[str, jax.Array],
                    backend=None) -> Dict[str, jax.Array]:
    """Scatter row payloads ``upd`` [B,k,...] into the named cache
    buffers at idx, through the KernelBackend — ONE aliased multi-buffer
    kernel call on ``PallasBackend``, per-buffer XLA scatters otherwise.
    Quantization (if any) happens before this, in XLA, on both backends.
    """
    if backend is None:
        from repro.kernels.backend import XLA_BACKEND as backend
    cache = dict(cache)
    cache.update(backend.scatter_multi(
        {name: cache[name] for name in upd}, idx, upd))
    return cache


def h_row_update(h_rows: jax.Array, policy: CachePolicy
                 ) -> Dict[str, jax.Array]:
    """Row payloads for an H^c commit ({"h"[, "h_scale"]})."""
    if policy.quantized:
        hq, hs = quantize_rows(h_rows)
        return {"h": hq, "h_scale": hs}
    return {"h": h_rows}


def write_kv(cache: Dict[str, jax.Array], idx: jax.Array,
             k_rows: jax.Array, v_rows: jax.Array,
             policy: CachePolicy, backend=None) -> Dict[str, jax.Array]:
    """Scatter new K/V rows ([B,k,KVH,HD]) into the layer cache at idx."""
    if policy.quantized:
        kq, ks = quantize_rows(k_rows)
        vq, vs = quantize_rows(v_rows)
        upd = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
    else:
        upd = {"k": k_rows, "v": v_rows}
    return scatter_buffers(cache, idx, upd, backend)


def write_h(cache: Dict[str, jax.Array], idx: jax.Array, h_rows: jax.Array,
            policy: CachePolicy, backend=None) -> Dict[str, jax.Array]:
    return scatter_buffers(cache, idx, h_row_update(h_rows, policy),
                           backend)


def read_kv_for_attention(cache: Dict[str, jax.Array],
                          policy: CachePolicy):
    """Returns (k, v, k_scale, v_scale) for flash_attention."""
    if policy.quantized:
        return (cache["k"], cache["v"], cache["k_scale"], cache["v_scale"])
    return (cache["k"], cache["v"], None, None)


def read_h_full(cache: Dict[str, jax.Array], policy: CachePolicy,
                dtype=None) -> jax.Array:
    dtype = dtype or policy.compute_dtype
    if policy.quantized:
        return dequantize_rows(cache["h"], cache["h_scale"], dtype)
    return cache["h"].astype(dtype)


def read_h_rows(cache: Dict[str, jax.Array], idx: jax.Array,
                policy: CachePolicy, dtype=None) -> jax.Array:
    from repro.core.selection import gather_rows
    dtype = dtype or policy.compute_dtype
    rows = gather_rows(cache["h"], idx)
    if policy.quantized:
        return dequantize_rows(rows, gather_rows(cache["h_scale"], idx),
                               dtype)
    return rows.astype(dtype)


def fill_from_prefill(cfg: ModelConfig, cache_k, cache_v, cache_h,
                      proxies: Optional[jax.Array],
                      policy: CachePolicy,
                      strategy=None) -> Dict[str, jax.Array]:
    """Build one layer's cache dict from full prefill tensors."""
    from repro.core.strategy import resolve_strategy
    strategy = resolve_strategy(cfg, strategy)
    out: Dict[str, jax.Array] = {}
    if policy.quantized:
        out["k"], out["k_scale"] = quantize_rows(cache_k)
        out["v"], out["v_scale"] = quantize_rows(cache_v)
        out["h"], out["h_scale"] = quantize_rows(cache_h)
    else:
        out["k"] = cache_k.astype(policy.compute_dtype)
        out["v"] = cache_v.astype(policy.compute_dtype)
        out["h"] = cache_h.astype(policy.compute_dtype)
    if proxies is not None:
        out["proxy"] = proxies.astype(policy.compute_dtype)
        if strategy.incremental:
            out["proxy_now"] = proxies.astype(policy.compute_dtype)
    return out

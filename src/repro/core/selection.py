"""Top-k update selection and batched gather/scatter (Algorithm 2, Phase 1).

All index sets have STATIC size k (k = ceil(rho(l) * N) is known at trace
time), so gather/scatter lower to static-shaped dynamic-gather/scatter ops.

``select_topk_drift``   — global top-k lowest similarity (the paper).
``select_stratified``   — per-sequence-block top-(k/nb): our long-context
                          variant that guarantees banded sparsity so windowed
                          attention stays O(k * W) (DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Similarity quantum for tie-breaking: cross-program float noise on
# unchanged rows is ~1e-7, real drift is >> 2^-12; quantizing scores makes
# top-k ties index-stable across compilation strategies (scan vs
# unrolled) without affecting genuine selections.
_SCORE_QUANTUM = 4096.0


def _stable(scores: jax.Array) -> jax.Array:
    return jnp.round(scores.astype(jnp.float32) * _SCORE_QUANTUM)


def select_topk_drift(scores: jax.Array, k: int, *,
                      sort_positions: bool = True) -> jax.Array:
    """scores: [B, N] similarity (LOW = drifted = update). Returns [B, k]."""
    n = scores.shape[-1]
    k = min(k, n)
    _, idx = jax.lax.top_k(-_stable(scores), k)
    if sort_positions:
        idx = jnp.sort(idx, axis=-1)
    return idx.astype(jnp.int32)


def select_stratified(scores: jax.Array, k: int, n_blocks: int) -> jax.Array:
    """Per-block top-(k / n_blocks); returns globally sorted [B, k']."""
    b, n = scores.shape
    n_blocks = max(1, min(n_blocks, n))
    while n % n_blocks:
        n_blocks -= 1
    per = max(1, k // n_blocks)
    blocked = _stable(scores).reshape(b, n_blocks, n // n_blocks)
    _, idx = jax.lax.top_k(-blocked, min(per, n // n_blocks))
    offset = (jnp.arange(n_blocks) * (n // n_blocks))[None, :, None]
    idx = (idx + offset).reshape(b, -1)
    return jnp.sort(idx, axis=-1).astype(jnp.int32)


def gather_rows(x: jax.Array, idx: jax.Array) -> jax.Array:
    """x: [B, N, ...]; idx: [B, k] -> [B, k, ...].

    vmap'ed per-sequence gather: the batch dim stays a gather BATCH dim so
    GSPMD keeps batch sharding instead of all-gathering across data.
    Out-of-range (sentinel) indices clamp to the last row."""
    return jax.vmap(
        lambda xi, ii: jnp.take(xi, ii, axis=0, mode="clip"))(x, idx)


def scatter_rows(x: jax.Array, idx: jax.Array, rows: jax.Array) -> jax.Array:
    """Write rows [B, k, ...] into x [B, N, ...] at idx [B, k].

    Out-of-range indices (sentinel N padding) are dropped."""
    return jax.vmap(lambda xi, ii, ri: xi.at[ii].set(ri, mode="drop"))(
        x, idx, rows.astype(x.dtype))


def scatter_mask(idx: jax.Array, n: int) -> jax.Array:
    """Boolean [B, N] mask with True at selected indices."""
    return jax.vmap(
        lambda ii: jnp.zeros((n,), bool).at[ii].set(True))(idx)

"""First-class caching strategies (the ``CacheStrategy`` protocol).

The paper's core claim is that update identification (§3.2/3.3) and
budget allocation (§3.4) are *pluggable policies* over a shared DLM
cache.  This module makes that literal: every policy is a frozen
dataclass implementing one protocol, and any decode surface
(``DecodeSession``, ``decode``, ``decode_semi_ar``, ``ServingEngine``)
accepts a strategy at call time — ``ModelConfig.spa`` is only the
*default* spec, resolved through :func:`strategy_from_spec`.

Concrete strategies (DESIGN.md §2):

  ``SPACache``        — the paper: rank-r singular proxy (§3.3) +
                        piecewise-Gaussian adaptive budget (Eq. 5).
  ``ValueProxyCache`` — dLLM-Cache (Liu et al. 2025): full value-state
                        proxy, uniform budget; ``projection`` selects the
                        Table-1 ablation variants (value/query/key/attn_in).
  ``WindowCache``     — dKV-Cache (Ma et al. 2025): locality heuristic,
                        rows near recently committed tokens refresh.
  ``AttnOutCache``    — Table-1 'attn output' identifier: full attention
                        for identification, sparse FFN.
  ``NoCache``         — vanilla full recomputation (baseline rows).

A strategy owns:
  * the identifier projection  (``project`` / ``prefill_proxy``)
  * the drift scoring          (``score`` / ``pre_scores``)
  * the per-layer budget       (``k_schedule`` / ``k_for``)
  * cache layout + lifecycle   (``proxy_dim`` / ``init_cache`` /
                                ``commit_kv`` / ``commit``)
  * offline artefacts          (``build_proxies`` / ``proxy_specs``)

Strategies are hashable (frozen dataclasses) so jitted step functions
close over them statically — switching strategy retraces, switching
request does not.  The same applies to the ``backend`` field (a
:class:`repro.kernels.backend.KernelBackend`): it selects whether the
hot-path stages (identification, gather+norm, attention, commits) run
through XLA ops or the Pallas TPU kernel suite, per call, without
touching the serializable spec.
"""
from __future__ import annotations

import dataclasses
from typing import Any, ClassVar, Dict, List, Optional, Type

import jax
import jax.numpy as jnp

from repro.configs.base import ATTENTION_KINDS, ModelConfig, SPAConfig
from repro.kernels.backend import XLA_BACKEND, KernelBackend

Params = Dict[str, Any]

# Registry of strategy classes, keyed by the SPAConfig identifier string
# they correspond to (the serializable spec format).
REGISTRY: Dict[str, Type["CacheStrategy"]] = {}


def register(*idents: str):
    def deco(cls):
        for ident in idents:
            REGISTRY[ident] = cls
        return cls

    return deco


@dataclasses.dataclass(frozen=True)
class CacheStrategy:
    """Protocol base.  Subclasses override the class-vars and methods.

    ``refresh_interval`` — full cache rebuild every R steps (0 = never);
    the *session* owns the loop, this is just the strategy's default.
    ``n_buckets`` — lax.scan budget quantization (DESIGN.md §4.4).
    ``backend`` — KernelBackend running the hot-path stages (DESIGN.md
    §4.5); not part of the serializable spec (``from_spec`` yields the
    XLA default — use :meth:`with_backend` to select kernels).
    """

    refresh_interval: int = 0
    n_buckets: int = 6
    backend: KernelBackend = XLA_BACKEND

    name: ClassVar[str] = "abstract"
    uses_cache: ClassVar[bool] = True     # False only for NoCache
    uses_proxy_mat: ClassVar[bool] = False   # True only for SPACache
    full_attn_ident: ClassVar[bool] = False  # True only for AttnOutCache
    incremental: ClassVar[bool] = False      # proxy recompute on changed rows

    # ---- spec bridge (ModelConfig.spa stays the serializable format) ----

    @property
    def spec(self) -> SPAConfig:
        raise NotImplementedError

    def with_backend(self, backend) -> "CacheStrategy":
        """Same strategy, hot path on the given KernelBackend (or
        registry name "xla"/"pallas")."""
        from repro.kernels.backend import resolve_backend
        return dataclasses.replace(self, backend=resolve_backend(backend))

    def prefix_key(self) -> Any:
        """Hashable identity of this strategy's PREFILL states, used as
        part of the shared-prefix index root key (DESIGN.md §6): two
        strategies with the same key produce byte-identical prefill
        caches (same buffers, same identifier projection), so their
        requests may share published pages.  Prefill never runs through
        the hot-path kernels, so the ``backend`` is deliberately NOT
        part of the key — an xla lane and a pallas lane share entries."""
        return self.spec

    # ---- budget ----

    def k_schedule(self, cfg: ModelConfig, seq_len: int) -> List[int]:
        """Static per-layer update counts k(l)."""
        from repro.core import budget
        return budget.k_schedule(self.spec, cfg.n_layers, seq_len)

    def k_for(self, cfg: ModelConfig, layer: int, seq_len: int) -> int:
        return self.k_schedule(cfg, seq_len)[layer]

    # ---- identification ----

    def project(self, h: jax.Array, bp: Params,
                proxy_mat: Optional[jax.Array] = None) -> jax.Array:
        """Project (scaled) input states to identifier vectors p."""
        raise NotImplementedError(f"{self.name} has no projection")

    def projection_matrix(self, bp: Params,
                          proxy_mat: Optional[jax.Array] = None
                          ) -> Optional[jax.Array]:
        """The [d, r] matrix M with ``project(h) == h @ M``, when the
        projection is a plain matmul — lets ``PallasBackend`` run the
        fused projection+scoring kernel.  None means "not expressible";
        the backend then falls back to ``project``/``score``."""
        return None

    def score(self, p_now: jax.Array, p_cached: jax.Array) -> jax.Array:
        """Similarity per row [B, N]; LOW = drifted = update."""
        from repro.core.identifiers import drift_scores
        return drift_scores(p_now, p_cached)

    def pre_scores(self, n: int, committed: jax.Array
                   ) -> Optional[jax.Array]:
        """Scores computed *before* the layer stack from decode-loop state
        (committed-token ring).  None for projection-based strategies."""
        return None

    def prefill_proxy(self, bp: Params, proxy_mat, h_in, x, attn_out,
                      h_out) -> Optional[jax.Array]:
        """Identifier vectors collected during prefill.

        Projection identifiers score on h * (1 + norm_weight) WITHOUT the
        rms division (cosine drift is row-scale invariant), matching the
        serve path bit-for-bit so unchanged rows tie at cosine == 1.0."""
        scaled = h_in * (1.0 + bp["norm1"]).astype(h_in.dtype)
        return self.project(scaled, bp, proxy_mat)

    # ---- cache layout + lifecycle ----

    def proxy_dim(self, cfg: ModelConfig) -> int:
        return 0

    def init_cache(self, cfg: ModelConfig, batch: int, n: int,
                   policy=None) -> Dict[str, Dict[str, jax.Array]]:
        """Zeroed stacked caches {kind: {name: [Lk, B, N, ...]}}."""
        from repro.core import cache as cache_lib
        return cache_lib.init_model_cache(cfg, batch, n, strategy=self)

    def commit_kv(self, cache_sl: Dict[str, jax.Array], idx: jax.Array,
                  k_rows: jax.Array, v_rows: jax.Array, policy
                  ) -> Dict[str, jax.Array]:
        """Scatter refreshed K/V rows into the layer cache at idx (one
        aliased multi-buffer kernel call on the Pallas backend)."""
        from repro.core import cache as cache_lib
        return cache_lib.write_kv(cache_sl, idx, k_rows, v_rows, policy,
                                  backend=self.backend)

    def commit(self, cache_sl: Dict[str, jax.Array], idx: jax.Array,
               h_rows: jax.Array, policy, *,
               p_now: Optional[jax.Array] = None,
               proxy_now: Optional[jax.Array] = None,
               attn_all: Optional[jax.Array] = None,
               page_table: Optional[jax.Array] = None
               ) -> Dict[str, jax.Array]:
        """Scatter refreshed block outputs + identifier vectors at idx.

        H rows (+ int8 scale) and the proxy rows commit in ONE
        multi-buffer scatter (aliased kernel call on PallasBackend).
        With ``page_table`` (DESIGN.md §5) the ``proxy`` buffer is a
        pooled page arena: its rows commit through page-table
        indirection (``backend.scatter_rows_paged``) while the dense
        per-step views (h + scales) keep the fused scatter."""
        from repro.core import cache as cache_lib
        from repro.core import selection
        upd = cache_lib.h_row_update(h_rows, policy)
        proxy_rows = None
        if proxy_now is not None:   # incremental path keeps both buffers
            proxy_rows = selection.gather_rows(proxy_now, idx)
        elif p_now is not None and "proxy" in cache_sl:
            proxy_rows = selection.gather_rows(p_now, idx)
        if proxy_rows is not None:
            if page_table is not None:
                cache_sl = dict(cache_sl)
                cache_sl["proxy"] = self.backend.scatter_rows_paged(
                    cache_sl["proxy"], page_table, idx, proxy_rows)
            else:
                upd["proxy"] = proxy_rows
        cache_sl = cache_lib.scatter_buffers(cache_sl, idx, upd,
                                             backend=self.backend)
        if proxy_now is not None:
            cache_sl["proxy_now"] = proxy_now.astype(
                cache_sl["proxy_now"].dtype)
        elif p_now is not None and "proxy_now" in cache_sl:
            cache_sl["proxy_now"] = p_now.astype(
                cache_sl["proxy_now"].dtype)
        return cache_sl

    def refresh_cache(self, params: Params, cfg: ModelConfig,
                      tokens: jax.Array,
                      extras: Optional[Dict[str, jax.Array]] = None,
                      spa_proxies=None,
                      kv_len: Optional[jax.Array] = None
                      ) -> Dict[str, Dict[str, jax.Array]]:
        """Full cache rebuild from the current canvas (periodic refresh).

        Pure jax — shared verbatim by the host loop
        (``DecodeSession.refresh``) and the device-resident loop
        (``run_compiled``'s ``lax.cond`` branch), so the two paths
        cannot drift.  Strategies may override to refresh cheaper than
        a full prefill (e.g. keep offline artefacts, rebuild only KV).
        ``kv_len`` [B] masks each row's canvas tail during the rebuild
        (paged serving), so a short row's cache matches a prefill on a
        kv_len-long canvas.
        """
        if not self.uses_cache:
            return {}
        from repro.dlm import decoding
        inputs = dict(extras) if extras else {}
        inputs["tokens"] = tokens
        _, cache = decoding.prefill(params, cfg, inputs, spa_proxies,
                                    self, kv_len=kv_len)
        return cache

    # ---- offline artefacts ----

    def build_proxies(self, params: Params, cfg: ModelConfig
                      ) -> Optional[Dict[str, jax.Array]]:
        return None

    def proxy_specs(self, cfg: ModelConfig) -> Optional[Dict[str, Any]]:
        return None


@register("singular")
@dataclasses.dataclass(frozen=True)
class SPACache(CacheStrategy):
    """The paper: rank-r singular proxy + adaptive budget (Alg. 1)."""

    rank: int = 128
    schedule: str = "adaptive"
    rho_peak: float = 0.25
    rho_first: float = 0.03
    rho_last: float = 0.13
    layer_peak: Optional[int] = None
    incremental_ident: bool = False   # beyond-paper (DESIGN.md §6)

    name: ClassVar[str] = "spa"
    uses_proxy_mat: ClassVar[bool] = True

    @property
    def incremental(self) -> bool:  # type: ignore[override]
        return self.incremental_ident

    @property
    def spec(self) -> SPAConfig:
        return SPAConfig(
            identifier="singular", rank=self.rank, schedule=self.schedule,
            rho_peak=self.rho_peak, rho_first=self.rho_first,
            rho_last=self.rho_last, layer_peak=self.layer_peak,
            n_buckets=self.n_buckets,
            refresh_interval=self.refresh_interval,
            incremental_ident=self.incremental_ident)

    @classmethod
    def from_spec(cls, spa: SPAConfig) -> "SPACache":
        return cls(rank=spa.rank, schedule=spa.schedule,
                   rho_peak=spa.rho_peak, rho_first=spa.rho_first,
                   rho_last=spa.rho_last, layer_peak=spa.layer_peak,
                   n_buckets=spa.n_buckets,
                   refresh_interval=spa.refresh_interval,
                   incremental_ident=spa.incremental_ident)

    def proxy_dim(self, cfg: ModelConfig) -> int:
        return self.rank

    def project(self, h, bp, proxy_mat=None):
        assert proxy_mat is not None, "SPACache needs offline proxies"
        return h @ proxy_mat

    def projection_matrix(self, bp, proxy_mat=None):
        assert proxy_mat is not None, "SPACache needs offline proxies"
        return proxy_mat

    def build_proxies(self, params, cfg):
        """Offline SVD of value projections -> {kind: [Lk, d, r]}."""
        from repro.core.svd_proxy import build_proxy_stack
        out = {}
        for kind in sorted(set(cfg.layer_kinds)):
            if kind not in ATTENTION_KINDS:
                continue
            wv = params["blocks"][kind]["wv"]          # [Lk, d, kv_dim]
            out[kind] = jnp.asarray(build_proxy_stack(wv, self.rank))
        return out

    def proxy_specs(self, cfg):
        out = {}
        for kind in sorted(set(cfg.layer_kinds)):
            if kind not in ATTENTION_KINDS:
                continue
            lk = cfg.n_layers_of_kind(kind)
            out[kind] = jax.ShapeDtypeStruct(
                (lk, cfg.d_model, self.rank), jnp.dtype(cfg.param_dtype))
        return out


@dataclasses.dataclass(frozen=True)
class _RhoBudgetStrategy(CacheStrategy):
    """Shared budget fields for the baseline strategies.

    ``rho_first``/``rho_last``/``layer_peak`` only matter with
    ``schedule="adaptive"``; None means flat at ``rho``."""

    schedule: str = "uniform"
    rho: float = 0.25
    rho_first: Optional[float] = None
    rho_last: Optional[float] = None
    layer_peak: Optional[int] = None

    def _spec_budget(self) -> Dict[str, Any]:
        return dict(
            schedule=self.schedule, rho_peak=self.rho,
            rho_first=self.rho if self.rho_first is None else self.rho_first,
            rho_last=self.rho if self.rho_last is None else self.rho_last,
            layer_peak=self.layer_peak, n_buckets=self.n_buckets,
            refresh_interval=self.refresh_interval)

    @staticmethod
    def _budget_from_spec(spa: SPAConfig) -> Dict[str, Any]:
        def ramp(r):                 # flat-at-rho normalizes to None
            return None if r == spa.rho_peak else r
        return dict(schedule=spa.schedule, rho=spa.rho_peak,
                    rho_first=ramp(spa.rho_first),
                    rho_last=ramp(spa.rho_last), layer_peak=spa.layer_peak,
                    n_buckets=spa.n_buckets,
                    refresh_interval=spa.refresh_interval)


@register("value", "query", "key", "attn_in")
@dataclasses.dataclass(frozen=True)
class ValueProxyCache(_RhoBudgetStrategy):
    """dLLM-Cache (value) and the Table-1 projection ablations."""

    projection: str = "value"        # value | query | key | attn_in
    incremental_ident: bool = False  # changed-rows-only projection

    name: ClassVar[str] = "value_proxy"

    @property
    def incremental(self) -> bool:  # type: ignore[override]
        return self.incremental_ident

    @property
    def spec(self) -> SPAConfig:
        return SPAConfig(identifier=self.projection,
                         incremental_ident=self.incremental_ident,
                         **self._spec_budget())

    @classmethod
    def from_spec(cls, spa: SPAConfig) -> "ValueProxyCache":
        return cls(projection=spa.identifier,
                   incremental_ident=spa.incremental_ident,
                   **cls._budget_from_spec(spa))

    def proxy_dim(self, cfg: ModelConfig) -> int:
        return {"value": cfg.kv_dim, "key": cfg.kv_dim,
                "query": cfg.q_dim, "attn_in": cfg.d_model}[self.projection]

    def project(self, h, bp, proxy_mat=None):
        if self.projection == "value":
            return h @ bp["wv"]
        if self.projection == "query":
            return h @ bp["wq"]
        if self.projection == "key":
            return h @ bp["wk"]
        return h                      # attn_in: raw inputs

    def projection_matrix(self, bp, proxy_mat=None):
        w = {"value": "wv", "query": "wq", "key": "wk"}.get(self.projection)
        return bp[w] if w else None   # attn_in: identity (score-only)


@register("window")
@dataclasses.dataclass(frozen=True)
class WindowCache(_RhoBudgetStrategy):
    """dKV-Cache-style locality heuristic: rows within ``locality_window``
    of a recently committed token refresh; no projection, no proxy cache."""

    locality_window: int = 64

    name: ClassVar[str] = "window"

    @property
    def spec(self) -> SPAConfig:
        return SPAConfig(identifier="window",
                         locality_window=self.locality_window,
                         **self._spec_budget())

    @classmethod
    def from_spec(cls, spa: SPAConfig) -> "WindowCache":
        return cls(locality_window=spa.locality_window,
                   **cls._budget_from_spec(spa))

    def pre_scores(self, n: int, committed: jax.Array):
        from repro.core.identifiers import locality_scores
        return locality_scores(n, committed, self.locality_window)

    def prefill_proxy(self, bp, proxy_mat, h_in, x, attn_out, h_out):
        return None


@register("attn_out")
@dataclasses.dataclass(frozen=True)
class AttnOutCache(_RhoBudgetStrategy):
    """Table-1 'attn output' identifier: full attention against the stale
    cached KV for ALL rows (identification only), sparse FFN after.
    Suffers the Appendix-B anisotropy masking (fig5_anisotropy)."""

    name: ClassVar[str] = "attn_out"
    full_attn_ident: ClassVar[bool] = True

    @property
    def spec(self) -> SPAConfig:
        return SPAConfig(identifier="attn_out", **self._spec_budget())

    @classmethod
    def from_spec(cls, spa: SPAConfig) -> "AttnOutCache":
        return cls(**cls._budget_from_spec(spa))

    def proxy_dim(self, cfg: ModelConfig) -> int:
        return cfg.d_model

    def prefill_proxy(self, bp, proxy_mat, h_in, x, attn_out, h_out):
        return attn_out

    def commit(self, cache_sl, idx, h_rows, policy, *, p_now=None,
               proxy_now=None, attn_all=None, page_table=None):
        from repro.core import cache as cache_lib
        cache_sl = cache_lib.write_h(cache_sl, idx, h_rows, policy,
                                     backend=self.backend)
        # momentum signal: proxy = latest full attention output (paged:
        # a whole-view page write; zero-page tails drop)
        if page_table is not None:
            cache_sl["proxy"] = self.backend.scatter_pages(
                cache_sl["proxy"][None], page_table,
                attn_all.astype(cache_sl["proxy"].dtype)[None])[0]
        else:
            cache_sl["proxy"] = attn_all.astype(cache_sl["proxy"].dtype)
        return cache_sl


@register("none")
@dataclasses.dataclass(frozen=True)
class NoCache(CacheStrategy):
    """Vanilla full recomputation every refinement step (baseline)."""

    name: ClassVar[str] = "none"
    uses_cache: ClassVar[bool] = False

    @property
    def spec(self) -> SPAConfig:
        return SPAConfig(identifier="none")

    @classmethod
    def from_spec(cls, spa: SPAConfig) -> "NoCache":
        return cls()

    def k_schedule(self, cfg: ModelConfig, seq_len: int) -> List[int]:
        return [seq_len] * cfg.n_layers

    def prefill_proxy(self, bp, proxy_mat, h_in, x, attn_out, h_out):
        return None

    def init_cache(self, cfg, batch, n, policy=None):
        return {}


# ---------------------------------------------------------------------------
# Resolution
# ---------------------------------------------------------------------------

def strategy_from_spec(spa: SPAConfig) -> CacheStrategy:
    """Build the strategy described by a (serializable) ``SPAConfig``."""
    cls = REGISTRY.get(spa.identifier)
    if cls is None:
        raise ValueError(
            f"unknown identifier {spa.identifier!r}; registered: "
            f"{sorted(REGISTRY)}")
    return cls.from_spec(spa)


def strategy_from_config(cfg: ModelConfig) -> CacheStrategy:
    return strategy_from_spec(cfg.spa)


def resolve_strategy(cfg: ModelConfig,
                     strategy: Optional[CacheStrategy] = None
                     ) -> CacheStrategy:
    """Call-time strategy wins; ``cfg.spa`` is only the default spec."""
    return strategy if strategy is not None else strategy_from_spec(cfg.spa)

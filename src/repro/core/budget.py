"""Adaptive budget allocation (paper §3.4, Eq. 5) + scan-friendly bucketing.

``rho_schedule`` is the exact piecewise-Gaussian of Eq. (5); layers are
1-indexed in the paper's notation.

``bucketize`` is our TPU/XLA adaptation (DESIGN.md §4.4): ``lax.scan`` over
layer stacks needs a single static top-k size, so contiguous layers are
grouped into at most ``n_buckets`` segments; each segment runs with the max
k inside it. This never under-allocates (k_bucket >= k_exact per layer) and
over-allocates at most one quantization step.
"""
from __future__ import annotations

import math
from typing import List, Sequence, Tuple

import numpy as np

from repro.configs.base import SPAConfig


def rho_schedule(spa: SPAConfig, n_layers: int) -> np.ndarray:
    """Per-layer update ratio rho(l) for l = 1..L (returned 0-indexed)."""
    L = n_layers
    if spa.schedule == "uniform" or L == 1:
        return np.full(L, spa.rho_peak, dtype=np.float64)
    lp = min(spa.resolved_layer_peak(L), L)
    rho_p = spa.rho_peak
    rho_1 = min(spa.rho_first, rho_p)
    rho_L = min(spa.rho_last, rho_p)
    out = np.empty(L, dtype=np.float64)
    for l in range(1, L + 1):
        if l <= lp:
            denom = max(lp - 1, 1)
            out[l - 1] = rho_p * math.exp(
                math.log(max(rho_1, 1e-9) / rho_p)
                * ((l - lp) / denom) ** 2)
        else:
            denom = max(L - lp, 1)
            out[l - 1] = rho_p * math.exp(
                math.log(max(rho_L, 1e-9) / rho_p)
                * ((l - lp) / denom) ** 2)
    return out


def k_schedule(spa: SPAConfig, n_layers: int, seq_len: int,
               multiple: int = 16) -> List[int]:
    """Static per-layer update counts k(l) = ceil(rho(l) * N), >= 1.

    Rounded UP to a multiple of 16 (when seq_len permits) so the selected
    rows shard evenly over the "model" axis (row-parallel sparse
    pipeline, EXPERIMENTS.md §Perf) — a tiny over-provision, never
    under-budget."""
    rhos = rho_schedule(spa, n_layers)
    ks = [max(1, int(math.ceil(r * seq_len))) for r in rhos]
    if seq_len >= multiple:
        ks = [min(seq_len, ((k + multiple - 1) // multiple) * multiple)
              for k in ks]
    return ks


def average_rho(spa: SPAConfig, n_layers: int) -> float:
    return float(np.mean(rho_schedule(spa, n_layers)))


def bucketize(ks: Sequence[int], n_buckets: int
              ) -> List[Tuple[int, int, int]]:
    """Split layers into <= n_buckets contiguous segments.

    Returns [(start, stop, k_seg)] with k_seg = max(ks[start:stop]).
    Segment boundaries are chosen greedily at the largest relative jumps of
    the (unimodal) k-curve, which minimizes over-provisioning in practice.
    """
    L = len(ks)
    n_buckets = max(1, min(n_buckets, L))
    if n_buckets == 1:
        return [(0, L, max(ks))]
    # Rank interior boundaries by |log k[i] - log k[i-1]|.
    jumps = [(abs(math.log(ks[i]) - math.log(ks[i - 1])), i)
             for i in range(1, L)]
    jumps.sort(reverse=True)
    cuts = sorted({i for _, i in jumps[: n_buckets - 1]})
    bounds = [0] + cuts + [L]
    return [(a, b, max(ks[a:b])) for a, b in zip(bounds[:-1], bounds[1:])]


def over_provision_ratio(ks: Sequence[int],
                         segments: Sequence[Tuple[int, int, int]]) -> float:
    """sum(bucketized k) / sum(exact k) — 1.0 means no waste."""
    exact = sum(ks)
    bucketed = sum(kseg * (b - a) for a, b, kseg in segments)
    return bucketed / max(exact, 1)

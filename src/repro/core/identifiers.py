"""Update identifiers (paper §3.2, Table 1; §3.3 singular proxy).

Given the layer's CURRENT input states H [B,N,d] and the cached identifier
vectors from the last time each row was refreshed, produce a similarity
score per row (LOW similarity = drifted = update).

identifier types:
  value     — p = h @ W_v                (dLLM-Cache; Theorems 3.1/3.2)
  singular  — p = h @ (U_r S_r)          (the paper's proxy; Theorem 3.4)
  query/key — p = h @ W_q / W_k          (Table-1 ablations)
  attn_in   — p = h                      (Table-1 ablation)
  attn_out  — stale attention-output momentum (Table-1 ablation; suffers
              the Appendix-B anisotropy masking — see docstring below)
  window    — dKV-Cache-style locality heuristic: rows near recently
              committed tokens score low (i.e. get updated); no projection.
  none      — no cache (vanilla); selection layer never invoked.

``attn_out`` note: the paper does not specify how the attention output is
obtained before computing the layer; we use the drift between the two most
recent CACHED attention outputs as a momentum signal (zero extra FLOPs).
Its failure mode — anisotropy-collapsed similarities — is reproduced in
benchmarks/fig5_anisotropy.py either way.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.svd_proxy import cosine_similarity


def proxy_project(h: jax.Array, identifier: str, *,
                  w_value: Optional[jax.Array] = None,
                  w_query: Optional[jax.Array] = None,
                  w_key: Optional[jax.Array] = None,
                  proxy_mat: Optional[jax.Array] = None) -> jax.Array:
    """Project input states to identifier vectors p. h: [B,N,d] -> [B,N,r].

    Deprecated shim: projection dispatch now lives on
    ``core.strategy.CacheStrategy.project``; this resolves the identifier
    string through the strategy registry for old callers."""
    from repro.core.strategy import REGISTRY
    cls = REGISTRY.get(identifier)
    if cls is None or identifier in ("none", "window", "attn_out"):
        raise ValueError(f"identifier {identifier!r} has no projection")
    strat = (cls() if identifier == "singular"
             else cls(projection=identifier))
    return strat.project(h, {"wv": w_value, "wq": w_query, "wk": w_key},
                         proxy_mat)


def drift_scores(p_now: jax.Array, p_cached: jax.Array) -> jax.Array:
    """Similarity scores [B, N]; low = drifted."""
    return cosine_similarity(p_now, p_cached)


def locality_scores(n: int, committed_pos: jax.Array,
                    window: int) -> jax.Array:
    """dKV-Cache heuristic. committed_pos: [B, C] recently committed token
    positions (-1 = unused slot). Rows within ``window`` of any committed
    position get score 0 (update); others 1 (keep). Ties broken by distance.
    """
    b, c = committed_pos.shape
    pos = jnp.arange(n)[None, None, :]                      # [1,1,N]
    cp = committed_pos[:, :, None]                          # [B,C,1]
    dist = jnp.where(cp >= 0, jnp.abs(pos - cp), n + 1)
    min_dist = jnp.min(dist, axis=1)                        # [B,N]
    return jnp.clip(min_dist.astype(jnp.float32) / max(window, 1), 0.0, 1.0)

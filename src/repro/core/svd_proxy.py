"""Singular proxy construction (paper §3.3, Theorem 3.4).

The paper writes v = W h with W in R^{d x d} (row-acting). Our weights act
by right-multiplication, v = h @ W_v with W_v in R^{d_in x d_out}, i.e.
W_paper = W_v^T. The paper keeps the top-r RIGHT singular vectors of
W_paper, which are the top-r LEFT singular vectors of W_v:

    W_v = U S V^T  =>  f_proxy(h) = S_r (U_r^T h) = h @ (U_r * S_r)

so the proxy matrix is ``proxy = U[:, :r] * S[:r]`` of shape [d_in, r].

Theorem 3.4 bound: |S_cos(v1,v2) - S_cos(p1,p2)| <= 2 (s_{r+1}/s_r)^2 for
inputs in span of the retained subspace; ``spectral_bound`` reports it.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def build_proxy(w_v: np.ndarray, rank: int) -> Tuple[np.ndarray, float]:
    """SVD-truncated proxy matrix for one layer.

    w_v: [d_in, d_out] value projection. Returns (proxy [d_in, r], bound).
    """
    w = np.asarray(w_v, dtype=np.float32)
    u, s, _ = np.linalg.svd(w, full_matrices=False)
    r = min(rank, s.shape[0])
    proxy = u[:, :r] * s[None, :r]
    bound = spectral_bound(s, r)
    return proxy.astype(w_v.dtype), bound


def spectral_bound(singular_values: np.ndarray, r: int) -> float:
    """2 * (s_{r+1} / s_r)^2 from Theorem 3.4 (0 if fully retained)."""
    s = np.asarray(singular_values, dtype=np.float64)
    if r >= s.shape[0] or s[r - 1] <= 0:
        return 0.0
    return float(2.0 * (s[r] / s[r - 1]) ** 2)


def build_proxy_stack(w_v_stack: jax.Array, rank: int) -> np.ndarray:
    """Proxies for stacked per-layer value weights [L, d_in, d_out]."""
    ws = np.asarray(jax.device_get(w_v_stack), dtype=np.float32)
    out = np.stack([build_proxy(w, rank)[0] for w in ws])
    return out


def cosine_similarity(a: jax.Array, b: jax.Array,
                      eps: float = 1e-8) -> jax.Array:
    """Rowwise cosine similarity over the last axis (f32)."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    num = jnp.sum(a * b, axis=-1)
    den = jnp.sqrt(jnp.sum(a * a, axis=-1) * jnp.sum(b * b, axis=-1))
    return num / jnp.maximum(den, eps)

"""Pure-JAX AdamW with gradient clipping and LR schedules."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * decay


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(cfg: AdamWConfig, params, grads, state: OptState
                 ) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    # production guard: skip the update entirely on nonfinite grads
    # (overflow in a bad microbatch) instead of poisoning the moments
    ok = jnp.isfinite(gnorm)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else 1.0
    clip = jnp.where(ok, clip, 0.0)
    grads = jax.tree.map(
        lambda g: jnp.nan_to_num(g.astype(jnp.float32)) * clip, grads)

    step = state.step + 1
    lr = lr_at(cfg, state.step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                      state.mu, grads)
    nu = jax.tree.map(lambda n, g: cfg.b2 * n + (1 - cfg.b2) * g * g,
                      state.nu, grads)

    def upd(p, m, n):
        mh = m / b1c
        nh = n / b2c
        delta = mh / (jnp.sqrt(nh) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (standard practice)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, OptState(step, mu, nu), {
        "grad_norm": gnorm, "lr": lr,
        "nonfinite_grads": (~ok).astype(jnp.float32)}

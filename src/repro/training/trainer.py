"""Training loop: masked-diffusion objective + AdamW, grad accumulation,
pjit-ready train_step."""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dlm.loss import diffusion_loss, encoder_loss
from repro.training.optimizer import (AdamWConfig, OptState, adamw_update,
                                      init_opt_state)


def loss_fn_for(cfg: ModelConfig) -> Callable:
    return encoder_loss if cfg.is_encoder_only else diffusion_loss


def train_step(params, opt_state: OptState, batch: Dict[str, jax.Array],
               rng: jax.Array, *, cfg: ModelConfig, opt_cfg: AdamWConfig
               ) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    """One optimizer step, with optional microbatch gradient accumulation."""
    loss_fn = loss_fn_for(cfg)
    nm = max(cfg.microbatch, 1)

    def grads_of(mb, mb_rng):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, cfg, mb, mb_rng)
        return grads, metrics

    if nm == 1:
        grads, metrics = grads_of(batch, rng)
    else:
        def slice_mb(i):
            # Interleaved split so every microbatch spans all data shards
            # (row j of microbatch i = global row j*nm + i).
            return jax.tree.map(
                lambda x: x.reshape((x.shape[0] // nm, nm) + x.shape[1:])
                           [:, i], batch)

        acc_dt = jnp.dtype(cfg.accum_dtype)

        if cfg.accum_unroll:
            grads = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt),
                                 params)
            ms = []
            for i in range(nm):
                g, m = grads_of(slice_mb(i), jax.random.fold_in(rng, i))
                grads = jax.tree.map(
                    lambda a, b: a + b.astype(acc_dt), grads, g)
                ms.append(m)
            metrics = jax.tree.map(lambda *xs: jnp.mean(jnp.stack(xs)),
                                   *ms)
        else:
            def body(carry, i):
                acc = carry
                g, m = grads_of(slice_mb(i), jax.random.fold_in(rng, i))
                acc = jax.tree.map(lambda a, b: a + b.astype(acc_dt),
                                   acc, g)
                return acc, m

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt),
                                params)
            grads, ms = jax.lax.scan(body, zero, jnp.arange(nm))
            metrics = jax.tree.map(lambda x: jnp.mean(x, axis=0), ms)
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) / nm, grads)

    new_params, new_opt, opt_metrics = adamw_update(
        opt_cfg, params, grads, opt_state)
    metrics.update(opt_metrics)
    return new_params, new_opt, metrics


@dataclasses.dataclass
class Trainer:
    cfg: ModelConfig
    opt_cfg: AdamWConfig
    params: Any = None
    opt_state: Optional[OptState] = None

    def init(self, key: jax.Array):
        from repro.models import transformer
        self.params = transformer.init_params(self.cfg, key)
        self.opt_state = init_opt_state(self.params)
        return self

    def compiled_step(self):
        return jax.jit(functools.partial(
            train_step, cfg=self.cfg, opt_cfg=self.opt_cfg))

    def fit(self, data_iter, n_steps: int, rng: jax.Array,
            log_every: int = 10, log_fn=print) -> Dict[str, list]:
        step_fn = self.compiled_step()
        history = {"loss": [], "step_time": []}
        for step in range(n_steps):
            batch = next(data_iter)
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = step_fn(
                self.params, self.opt_state, batch,
                jax.random.fold_in(rng, step))
            loss = float(jax.device_get(metrics["loss"]))
            dt = time.perf_counter() - t0
            history["loss"].append(loss)
            history["step_time"].append(dt)
            if log_every and step % log_every == 0:
                log_fn(f"step {step:5d} loss {loss:.4f} "
                       f"lr {float(metrics['lr']):.2e} "
                       f"gnorm {float(metrics['grad_norm']):.3f} "
                       f"({dt*1e3:.0f} ms)")
        return history

"""Flat-npz pytree checkpointing (no external deps)."""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix="") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}#{i}/"))
    else:
        out[prefix.rstrip("/")] = np.asarray(jax.device_get(tree))
    return out


def _unflatten(flat: Dict[str, np.ndarray]):
    root: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = jnp.asarray(val)

    def fix(node):
        if isinstance(node, dict) and node and all(
                k.startswith("#") for k in node):
            return [fix(node[f"#{i}"]) for i in range(len(node))]
        if isinstance(node, dict):
            return {k: fix(v) for k, v in node.items()}
        return node

    return fix(root)


def save_checkpoint(path: str, tree, metadata: Dict[str, Any] | None = None
                    ) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    # bf16 is not npz-native; view as uint16 with a dtype tag.
    tagged = {}
    dtypes = {}
    for k, v in flat.items():
        if v.dtype == jnp.bfloat16:
            tagged[k] = v.view(np.uint16)
            dtypes[k] = "bfloat16"
        else:
            tagged[k] = v
            dtypes[k] = str(v.dtype)
    np.savez(path, __dtypes__=json.dumps(dtypes),
             __meta__=json.dumps(metadata or {}), **tagged)


def load_checkpoint(path: str) -> Tuple[Any, Dict[str, Any]]:
    with np.load(path, allow_pickle=False) as z:
        dtypes = json.loads(str(z["__dtypes__"]))
        meta = json.loads(str(z["__meta__"]))
        flat = {}
        for k in z.files:
            if k.startswith("__"):
                continue
            v = z[k]
            if dtypes.get(k) == "bfloat16":
                v = v.view(jnp.bfloat16)
            flat[k] = v
    return _unflatten(flat), meta

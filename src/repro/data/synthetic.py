"""Synthetic + text data pipelines for training and serving.

The synthetic stream generates structured sequences (Zipf-distributed
n-gram chains) so that the masked-diffusion loss is genuinely learnable
(the model must exploit bidirectional context), rather than pure noise.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


class SyntheticTokens:
    """Markov-chain token stream with Zipf unigram prior."""

    def __init__(self, vocab_size: int, seed: int = 0, order: int = 2,
                 branch: int = 4):
        self.vocab = max(vocab_size - 1, 2)  # reserve mask id
        self.rng = np.random.default_rng(seed)
        self.order = order
        self.branch = branch
        ranks = np.arange(1, self.vocab + 1, dtype=np.float64)
        self.unigram = (1.0 / ranks) / np.sum(1.0 / ranks)

    def _next(self, context: np.ndarray) -> np.ndarray:
        # Deterministic successor set per context hash + random pick.
        h = (context @ (np.arange(self.order) * 2654435761 + 1)) \
            % (2 ** 31)
        choices = (h[:, None] * (np.arange(self.branch) + 1)) % self.vocab
        pick = self.rng.integers(0, self.branch, size=h.shape[0])
        return choices[np.arange(h.shape[0]), pick].astype(np.int32)

    def batch(self, batch_size: int, seq_len: int) -> np.ndarray:
        out = np.zeros((batch_size, seq_len), np.int32)
        out[:, : self.order] = self.rng.choice(
            self.vocab, size=(batch_size, self.order), p=self.unigram)
        for t in range(self.order, seq_len):
            out[:, t] = self._next(out[:, t - self.order: t])
        return out


def token_batches(cfg: ModelConfig, batch_size: int, seq_len: int,
                  seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    gen = SyntheticTokens(cfg.vocab_size, seed)
    rng = np.random.default_rng(seed + 1)
    while True:
        batch: Dict[str, np.ndarray] = {}
        if cfg.frontend == "audio":
            frames = rng.standard_normal(
                (batch_size, seq_len, cfg.d_model)).astype(np.float32) * 0.02
            batch["frames"] = frames
            batch["targets"] = gen.batch(batch_size, seq_len)
        elif cfg.frontend == "vision":
            f = min(cfg.frontend_tokens, max(seq_len // 4, 1))
            text_len = seq_len - f
            batch["tokens"] = gen.batch(batch_size, text_len)
            batch["patches"] = rng.standard_normal(
                (batch_size, f, cfg.d_model)).astype(np.float32) * 0.02
        else:
            batch["tokens"] = gen.batch(batch_size, seq_len)
        yield batch


class ByteTokenizer:
    """Trivial byte-level tokenizer for the text examples."""

    def __init__(self, vocab_size: int):
        assert vocab_size >= 258
        self.vocab_size = vocab_size
        self.bos, self.eos = 256, 257

    def encode(self, text: str, seq_len: Optional[int] = None) -> np.ndarray:
        ids = [self.bos] + list(text.encode("utf-8"))[: (seq_len or 1 << 30)
                                                      - 2] + [self.eos]
        if seq_len:
            ids = ids[:seq_len] + [self.eos] * max(0, seq_len - len(ids))
        return np.asarray(ids, np.int32)

    def decode(self, ids) -> str:
        body = bytes(int(i) for i in ids if int(i) < 256)
        return body.decode("utf-8", errors="replace")


def text_batches(cfg: ModelConfig, corpus: str, batch_size: int,
                 seq_len: int, seed: int = 0
                 ) -> Iterator[Dict[str, np.ndarray]]:
    tok = ByteTokenizer(cfg.vocab_size)
    data = tok.encode(corpus)
    rng = np.random.default_rng(seed)
    while True:
        starts = rng.integers(0, max(len(data) - seq_len, 1),
                              size=batch_size)
        rows = np.stack([
            np.resize(data[s: s + seq_len], seq_len) for s in starts])
        yield {"tokens": rows.astype(np.int32)}

"""Dense feed-forward blocks (gated and plain)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common


def init_ffn_params(key, d_model: int, d_ff: int, act: str, dtype):
    ks = common.split_keys(key, 3)
    if act in ("silu", "gelu"):  # gated (LLaMA / Gemma style)
        return {
            "w_gate": common.dense_init(ks[0], (d_model, d_ff), dtype),
            "w_up": common.dense_init(ks[1], (d_model, d_ff), dtype),
            "w_down": common.dense_init(ks[2], (d_ff, d_model), dtype),
        }
    # plain two-matrix MLP (hubert / classic transformer)
    return {
        "w_up": common.dense_init(ks[0], (d_model, d_ff), dtype),
        "b_up": jnp.zeros((d_ff,), dtype),
        "w_down": common.dense_init(ks[1], (d_ff, d_model), dtype),
        "b_down": jnp.zeros((d_model,), dtype),
    }


def apply_ffn(params, x: jax.Array, act: str) -> jax.Array:
    """x: [..., d_model] -> [..., d_model]. The row-parallel w_down
    all-reduce is pinned at the bf16 dot output (see qkv_project)."""
    from repro.distributed.hints import shard_hint

    def pin(y):
        return shard_hint(y, *(["batch"] + ["keep"] * (y.ndim - 2)
                               + [None]))

    fn = common.act_fn(act)
    if "w_gate" in params:
        gate = fn(x @ params["w_gate"])
        up = x @ params["w_up"]
        return pin((gate * up) @ params["w_down"])
    h = fn(x @ params["w_up"] + params["b_up"])
    return pin(h @ params["w_down"] + params["b_down"])

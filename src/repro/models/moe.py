"""Mixture-of-Experts FFN with capacity-based gather/scatter dispatch.

Dispatch is index-based (NOT the Mesh-TF one-hot einsum, whose
[T, E, C] x [T, d] contraction costs ~top_k*cf times the expert FLOPs
themselves at 4k tokens): each expert slot (e, c) records the token index
that fills it; expert inputs are a gather, outputs a scatter-add weighted
by the gate. FLOPs therefore scale with ACTIVE expert capacity only.

Sharding: expert weights shard the E dim over "model" when divisible
(expert parallelism — the all-to-all emerges from the slot gather /
scatter under GSPMD); otherwise they fall back to per-expert tensor
parallelism over d_ff.

Router: softmax top-k with Switch-style load-balance auxiliary loss.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models import common


def init_moe_params(key, d_model: int, moe: MoEConfig, act: str, dtype):
    ks = common.split_keys(key, 5)
    e, dff = moe.n_experts, moe.d_ff_expert
    params = {
        "router": common.dense_init(ks[0], (d_model, e), dtype),
        "w_gate": common.dense_init(ks[1], (e, d_model, dff), dtype),
        "w_up": common.dense_init(ks[2], (e, d_model, dff), dtype),
        "w_down": common.dense_init(ks[3], (e, dff, d_model), dtype),
    }
    if moe.n_shared_experts:
        from repro.models import ffn
        params["shared"] = ffn.init_ffn_params(
            ks[4], d_model, moe.n_shared_experts * moe.d_ff_shared, act,
            dtype)
    return params


def _capacity(n_tokens: int, moe: MoEConfig) -> int:
    cap = int(moe.top_k * n_tokens * moe.capacity_factor / moe.n_experts)
    return max(4, ((cap + 3) // 4) * 4)


def _route_one(probs: jax.Array, k: int, e: int, cap: int):
    """Per-sequence routing. probs: [T, E].

    Returns (slots [E, cap] token index or T (sentinel),
             slot_gates [E, cap] f32)."""
    t = probs.shape[0]
    gate_vals, gate_idx = jax.lax.top_k(probs, k)          # [T, k]
    gate_vals = gate_vals / jnp.clip(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    flat_expert = gate_idx.reshape(-1)                     # [T*k]
    flat_gate = gate_vals.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(t), k)
    onehot = jax.nn.one_hot(flat_expert, e, dtype=jnp.int32)  # [T*k, E]
    pos = jnp.cumsum(onehot, axis=0) - onehot
    slot = jnp.sum(onehot * pos, axis=-1)                  # [T*k]
    ok = slot < cap
    slot = jnp.minimum(slot, cap - 1)

    slots = jnp.full((e, cap), t, jnp.int32)               # sentinel = T
    slots = slots.at[flat_expert, slot].set(
        jnp.where(ok, flat_token, t))
    slot_gates = jnp.zeros((e, cap), jnp.float32)
    slot_gates = slot_gates.at[flat_expert, slot].add(
        jnp.where(ok, flat_gate, 0.0))
    return slots, slot_gates, gate_idx


def apply_moe(params, x: jax.Array, moe: MoEConfig, act: str
              ) -> Tuple[jax.Array, jax.Array]:
    """x: [B, T, d] -> (out [B, T, d], aux_loss scalar)."""
    b, t, d = x.shape
    e, k = moe.n_experts, moe.top_k
    cap = _capacity(t, moe)
    fn = common.act_fn(act)

    logits = (x.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                # [B,T,E]

    slots, slot_gates, gate_idx = jax.vmap(
        lambda p: _route_one(p, k, e, cap))(probs)         # [B,E,cap]

    # Load-balance aux loss (Switch): E * mean_e f_e * p_e.
    me = jnp.mean(probs, axis=1)                           # [B,E]
    ce = jnp.mean(jax.nn.one_hot(gate_idx[..., 0], e), axis=1)
    aux = jnp.mean(jnp.sum(me * ce, axis=-1)) * e

    # Gather expert inputs (sentinel row T reads zeros).
    from repro.distributed.hints import shard_hint
    x_pad = jnp.concatenate(
        [x, jnp.zeros((b, 1, d), x.dtype)], axis=1)        # [B,T+1,d]
    expert_in = jax.vmap(lambda xp, sl: xp[sl])(x_pad, slots)  # [B,E,C,d]
    # expert parallelism: E over "model" (dropped when E % model != 0)
    expert_in = shard_hint(expert_in, "batch", "model", None, None)

    gate = fn(jnp.einsum("becd,edf->becf", expert_in, params["w_gate"]))
    up = jnp.einsum("becd,edf->becf", expert_in, params["w_up"])
    expert_out = jnp.einsum("becf,efd->becd", gate * up, params["w_down"])
    expert_out = shard_hint(expert_out, "batch", "model", None, None)
    expert_out = expert_out * slot_gates[..., None].astype(expert_out.dtype)

    # Scatter-add back to token positions.
    def combine(eo, sl):
        out = jnp.zeros((t + 1, d), eo.dtype)
        return out.at[sl.reshape(-1)].add(
            eo.reshape(-1, d))[:t]

    out = jax.vmap(combine)(expert_out, slots)

    if "shared" in params:
        from repro.models import ffn
        out = out + ffn.apply_ffn(params["shared"], x, act)
    return out.astype(x.dtype), aux.astype(jnp.float32)

"""RG-LRU recurrent block (Griffin / RecurrentGemma).

The block is: input proj -> short temporal conv -> gated linear recurrence
  r_t = sigmoid(W_a x_t + b_a);  i_t = sigmoid(W_x x_t + b_x)
  a_t = exp(-c * softplus(Lambda) * r_t)
  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
in parallel with a GeLU gate branch, merged by elementwise product and an
output projection.

DLM adaptation: masked-diffusion denoising needs bidirectional context, so
the recurrence runs in both directions and the two half-width states are
concatenated (standard bidirectional-SSM construction). Documented in
DESIGN.md §Hardware-adaptation. The recurrence itself is a log-depth
``associative_scan`` (TPU-friendly; the Pallas ``rglru_scan`` kernel is the
chunked VMEM-resident version).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common

_C = 8.0  # Griffin's gate sharpness constant


def _gate_heads(cfg: ModelConfig, dr: int) -> int:
    nb = cfg.rglru.n_heads if (cfg.rglru and cfg.rglru.n_heads) else 1
    while dr % nb:
        nb -= 1
    return max(nb, 1)


def init_rglru_params(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    dr = (cfg.rglru.d_rnn or d) if cfg.rglru else d
    conv_w = cfg.rglru.conv_width if cfg.rglru else 4
    nb = _gate_heads(cfg, dr)
    c = dr // nb
    ks = common.split_keys(key, 7)
    return {
        "w_in": common.dense_init(ks[0], (d, dr), dtype),
        "w_gate_branch": common.dense_init(ks[1], (d, dr), dtype),
        "conv_kernel": common.dense_init(ks[2], (conv_w, dr), dtype,
                                         scale=0.1),
        # Griffin uses BLOCK-DIAGONAL gate matrices (n_heads blocks) —
        # faithful to the paper and model-axis shardable (head dim).
        "w_a": common.dense_init(ks[3], (nb, c, c), dtype),
        "b_a": jnp.zeros((dr,), dtype),
        "w_x": common.dense_init(ks[4], (nb, c, c), dtype),
        "b_x": jnp.zeros((dr,), dtype),
        "log_lambda": jnp.full((dr,), -1.0, dtype),  # softplus -> decay
        "w_out": common.dense_init(ks[5], (dr, d), dtype),
    }


def _temporal_conv(x: jax.Array, kernel: jax.Array) -> jax.Array:
    """Depthwise causal conv along T. x: [B,T,dr], kernel: [W,dr]."""
    w = kernel.shape[0]
    pads = jnp.pad(x, ((0, 0), (w - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(w):
        out = out + pads[:, i:i + x.shape[1]] * kernel[w - 1 - i]
    return out


def linear_recurrence(a: jax.Array, b: jax.Array,
                      chunk: int = 256) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t along axis 1.

    Chunked: log-depth associative scan WITHIN each chunk, sequential
    ``lax.scan`` ACROSS chunks carrying the boundary state. Keeps both the
    HLO size and the live memory O(chunk) instead of O(T log T) — at 500k
    tokens the monolithic associative scan materializes ~19 full-sequence
    intermediates. (The Pallas ``rglru_scan`` kernel is the VMEM-resident
    version of the same schedule.)
    """

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a2 * a1, a2 * b1 + b2

    bsz, t, d = a.shape
    c = min(chunk, t)
    pad = (-t) % c
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
    nc = a.shape[1] // c
    ar = jnp.moveaxis(a.reshape(bsz, nc, c, d), 1, 0)   # [nc,B,c,d]
    br = jnp.moveaxis(b.reshape(bsz, nc, c, d), 1, 0)

    out_dtype = a.dtype

    def step(h_prev, inp):
        a_c, b_c = inp
        a_cum, b_cum = jax.lax.associative_scan(
            combine, (a_c.astype(jnp.float32), b_c.astype(jnp.float32)),
            axis=1)
        h = a_cum * h_prev[:, None, :] + b_cum          # [B,c,d] f32
        return h[:, -1, :], h.astype(out_dtype)

    h0 = jnp.zeros((bsz, d), jnp.float32)
    _, hs = jax.lax.scan(step, h0, (ar, br))
    h = jnp.moveaxis(hs, 0, 1).reshape(bsz, nc * c, d)
    return h[:, :t]


def _block_gate(xf: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Block-diagonal gate: xf [B,T,dr], w [nb,c,c] -> [B,T,dr]."""
    bsz, t, dr = xf.shape
    nb, c, _ = w.shape
    xh = xf.reshape(bsz, t, nb, c)
    from repro.distributed.hints import shard_hint
    xh = shard_hint(xh, "batch", None, "model", None)
    out = jnp.einsum("btnc,nck->btnk", xh, w.astype(jnp.float32))
    return jax.nn.sigmoid(out.reshape(bsz, t, dr)
                          + b.astype(jnp.float32))


def rglru_core(params, x: jax.Array, *, reverse: bool = False) -> jax.Array:
    """The gated linear recurrence on pre-activations x: [B,T,dr]."""
    if reverse:
        x = jnp.flip(x, axis=1)
    xf = x.astype(jnp.float32)
    r = _block_gate(xf, params["w_a"], params["b_a"])
    i = _block_gate(xf, params["w_x"], params["b_x"])
    decay = jax.nn.softplus(params["log_lambda"].astype(jnp.float32))
    log_a = -_C * decay * r                       # [B,T,dr] (<= 0)
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12)) * (i * xf)
    # stream the recurrence in the model dtype (f32 carry inside chunks)
    h = linear_recurrence(a.astype(x.dtype), gated_in.astype(x.dtype))
    if reverse:
        h = jnp.flip(h, axis=1)
    return h.astype(x.dtype)


def apply_rglru(params, x: jax.Array, cfg: ModelConfig,
                bidirectional: bool = True) -> jax.Array:
    """Full RG-LRU block. x: [B,T,d] -> [B,T,d]."""
    pre = x @ params["w_in"]
    pre = _temporal_conv(pre, params["conv_kernel"])
    h = rglru_core(params, pre)
    if bidirectional:
        h = 0.5 * (h + rglru_core(params, pre, reverse=True))
    gate = jax.nn.gelu(x @ params["w_gate_branch"])
    return (gate * h) @ params["w_out"]

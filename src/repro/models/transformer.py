"""Generic DLM transformer assembled from a ModelConfig.

Every assigned backbone (dense / MoE / SSM / hybrid / audio / VLM) is
instantiated as a masked-diffusion denoiser: bidirectional sequence mixing,
iterative-unmasking decoding (exactly how LLaDA reuses the Llama
architecture). Parameters are stored STACKED per layer-kind
([L_kind, ...] leading axis) so full-size models compile as a handful of
``lax.scan`` loops (period-scan for hybrid patterns, DESIGN.md §4.4).
"""
from __future__ import annotations

import functools
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTENTION_KINDS, ATTN_GLOBAL, ATTN_LOCAL,
                                ATTN_SWA, RGLRU, SSD, ModelConfig)
from repro.models import common, ffn, moe, rglru, ssd
from repro.models.attention import flash_attention

Params = Dict[str, Any]


def layer_window(cfg: ModelConfig, kind: str) -> int:
    return cfg.window if kind in (ATTN_SWA, ATTN_LOCAL) else 0


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_block_params(cfg: ModelConfig, kind: str, key: jax.Array) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    ks = common.split_keys(key, 8)
    p: Params = {"norm1": jnp.zeros((d,), dtype)}
    if kind in ATTENTION_KINDS:
        p["wq"] = common.dense_init(ks[0], (d, cfg.q_dim), dtype)
        p["wk"] = common.dense_init(ks[1], (d, cfg.kv_dim), dtype)
        p["wv"] = common.dense_init(ks[2], (d, cfg.kv_dim), dtype)
        p["wo"] = common.dense_init(ks[3], (cfg.q_dim, d), dtype)
        p["norm2"] = jnp.zeros((d,), dtype)
        if cfg.moe is not None:
            p["moe"] = moe.init_moe_params(ks[4], d, cfg.moe, cfg.act, dtype)
        elif cfg.d_ff > 0:
            p["ffn"] = ffn.init_ffn_params(ks[4], d, cfg.d_ff, cfg.act,
                                           dtype)
        if cfg.post_norms:
            p["norm_post_attn"] = jnp.zeros((d,), dtype)
            p["norm_post_ffn"] = jnp.zeros((d,), dtype)
    elif kind == RGLRU:
        p["mixer"] = rglru.init_rglru_params(ks[0], cfg, dtype)
        p["norm2"] = jnp.zeros((d,), dtype)
        p["ffn"] = ffn.init_ffn_params(ks[1], d, cfg.d_ff, cfg.act, dtype)
        if cfg.post_norms:
            p["norm_post_attn"] = jnp.zeros((d,), dtype)
            p["norm_post_ffn"] = jnp.zeros((d,), dtype)
    elif kind == SSD:
        p["mixer"] = ssd.init_ssd_params(ks[0], cfg, dtype)
        if cfg.d_ff > 0:
            p["norm2"] = jnp.zeros((d,), dtype)
            p["ffn"] = ffn.init_ffn_params(ks[1], d, cfg.d_ff, cfg.act,
                                           dtype)
    else:
        raise ValueError(kind)
    return p


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    keys = common.split_keys(key, 8)
    params: Params = {
        "embed": common.embed_init(keys[0], (cfg.vocab_size, cfg.d_model),
                                   dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = common.dense_init(
            keys[1], (cfg.d_model, cfg.vocab_size), dtype)
    if cfg.max_position:
        params["pos_embed"] = common.embed_init(
            keys[2], (cfg.max_position, cfg.d_model), dtype)
    blocks: Dict[str, Params] = {}
    for kind in sorted(set(cfg.layer_kinds)):
        lk = cfg.n_layers_of_kind(kind)
        # stable per-kind fold (builtin hash() is randomized per process
        # by PYTHONHASHSEED — same-seed init must be reproducible)
        kind_keys = jax.random.split(
            jax.random.fold_in(keys[3],
                               zlib.crc32(kind.encode()) % (2 ** 31)), lk)
        blocks[kind] = jax.vmap(
            functools.partial(init_block_params, cfg, kind))(kind_keys)
    params["blocks"] = blocks
    return params


# ---------------------------------------------------------------------------
# Embedding / input handling (incl. audio / VLM stub frontends)
# ---------------------------------------------------------------------------

def embed_inputs(params: Params, cfg: ModelConfig,
                 inputs: Dict[str, jax.Array]) -> jax.Array:
    """inputs: {"tokens": [B,T]} | {"frames": [B,T,d]} |
    {"tokens": [B,T_text], "patches": [B,F,d]} -> h0 [B,N,d]."""
    if cfg.frontend == "audio":
        h = inputs["frames"].astype(jnp.dtype(cfg.param_dtype))
    elif cfg.frontend == "vision":
        text = jnp.take(params["embed"], inputs["tokens"], axis=0)
        patches = inputs["patches"].astype(text.dtype)
        h = jnp.concatenate([patches, text], axis=1)
    else:
        h = jnp.take(params["embed"], inputs["tokens"], axis=0)
    if cfg.embed_scale:
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    if cfg.max_position:
        n = h.shape[1]
        h = h + params["pos_embed"][:n][None]
    return h


# ---------------------------------------------------------------------------
# Block application (dense path)
# ---------------------------------------------------------------------------

def qkv_project(bp: Params, x: jax.Array, cfg: ModelConfig,
                positions: jax.Array):
    """x: [B,S,d] (already normed) -> q [B,S,H,hd], k/v [B,S,KVH,hd].

    The row-parallel partial-sum all-reduce is pinned HERE, at the bf16
    dot output — otherwise XLA fuses the f32 rope/norm converts first and
    the AR moves 2x the bytes."""
    from repro.distributed.hints import shard_hint
    b, s, _ = x.shape

    def proj(w):
        return shard_hint(x @ w, "batch", "keep", None)

    q = proj(bp["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = proj(bp["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = proj(bp["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    if not cfg.max_position:  # rope unless learned-absolute (encoder-only)
        q = common.apply_rope(q, positions, cfg.rope_theta)
        k = common.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def apply_ffn_or_moe(bp: Params, x: jax.Array, cfg: ModelConfig
                     ) -> Tuple[jax.Array, jax.Array]:
    if "moe" in bp:
        return moe.apply_moe(bp["moe"], x, cfg.moe, cfg.act)
    if "ffn" in bp:
        return ffn.apply_ffn(bp["ffn"], x, cfg.act), jnp.zeros(
            (), jnp.float32)
    return jnp.zeros_like(x), jnp.zeros((), jnp.float32)


def apply_block_dense(cfg: ModelConfig, kind: str, bp: Params,
                      h: jax.Array, *, collect_cache: bool = False,
                      proxy_mat: Optional[jax.Array] = None,
                      strategy=None,
                      kv_len: Optional[jax.Array] = None
                      ) -> Tuple[jax.Array, jax.Array,
                                 Optional[Dict[str, jax.Array]]]:
    """One transformer block over the full sequence.

    Returns (h_out, aux_loss, cache_entries or None). cache_entries has
    raw (unquantized) k/v/h/proxy tensors built per the CacheStrategy
    (``strategy.prefill_proxy``, computed in-block so prefill never
    materializes raw layer inputs across layers); the caller quantizes
    via ``cache.fill_from_prefill``.

    ``kv_len`` ([B] int32, paged serving): per-row valid canvas length —
    attention masks kv positions >= kv_len[b] so a short row computes
    exactly as on a kv_len-long canvas.  Recurrent kinds (rglru/ssd) are
    causal, so positions beyond kv_len cannot influence valid rows and
    need no masking.
    """
    b, n, _ = h.shape
    aux = jnp.zeros((), jnp.float32)
    entries: Optional[Dict[str, jax.Array]] = None

    if kind in ATTENTION_KINDS:
        x = common.rms_norm(h, bp["norm1"], cfg.norm_eps)
        positions = jnp.broadcast_to(jnp.arange(n)[None], (b, n))
        q, k, v = qkv_project(bp, x, cfg, positions)
        w = layer_window(cfg, kind)
        attn = flash_attention(q, k, v, window=w,
                               soft_cap=cfg.attn_softcap,
                               banded=(w > 0), kv_len=kv_len)
        from repro.distributed.hints import shard_hint
        attn_out = shard_hint(attn.reshape(b, n, cfg.q_dim) @ bp["wo"],
                              "batch", "keep", None)
        if cfg.post_norms:
            attn_out = common.rms_norm(attn_out, bp["norm_post_attn"],
                                       cfg.norm_eps)
        h_mid = h + attn_out
        y = common.rms_norm(h_mid, bp["norm2"], cfg.norm_eps)
        ffn_out, aux = apply_ffn_or_moe(bp, y, cfg)
        if cfg.post_norms:
            ffn_out = common.rms_norm(ffn_out, bp["norm_post_ffn"],
                                      cfg.norm_eps)
        h_out = h_mid + ffn_out
        if collect_cache:
            from repro.core.strategy import resolve_strategy
            strat = resolve_strategy(cfg, strategy)
            entries = {"k": k, "v": v, "h": h_out}
            prox = strat.prefill_proxy(bp, proxy_mat, h, x, attn_out,
                                       h_out)
            if prox is not None:
                entries["proxy"] = prox
    elif kind == RGLRU:
        x = common.rms_norm(h, bp["norm1"], cfg.norm_eps)
        mix = rglru.apply_rglru(bp["mixer"], x, cfg)
        if cfg.post_norms:
            mix = common.rms_norm(mix, bp["norm_post_attn"], cfg.norm_eps)
        h_mid = h + mix
        y = common.rms_norm(h_mid, bp["norm2"], cfg.norm_eps)
        ffn_out = ffn.apply_ffn(bp["ffn"], y, cfg.act)
        if cfg.post_norms:
            ffn_out = common.rms_norm(ffn_out, bp["norm_post_ffn"],
                                      cfg.norm_eps)
        h_out = h_mid + ffn_out
    elif kind == SSD:
        x = common.rms_norm(h, bp["norm1"], cfg.norm_eps)
        h_out = h + ssd.apply_ssd(bp["mixer"], x, cfg)
        if cfg.d_ff > 0:
            y = common.rms_norm(h_out, bp["norm2"], cfg.norm_eps)
            h_out = h_out + ffn.apply_ffn(bp["ffn"], y, cfg.act)
    else:
        raise ValueError(kind)
    return h_out, aux, entries


# ---------------------------------------------------------------------------
# Layer iteration plan (period scan for hybrid patterns)
# ---------------------------------------------------------------------------

def period_plan(cfg: ModelConfig) -> Tuple[Tuple[str, ...], int, List[int]]:
    """Returns (period_kinds, n_full_periods, remainder_layer_indices)."""
    period = cfg.layer_pattern
    plen = len(period)
    n_full = cfg.n_layers // plen
    remainder = list(range(n_full * plen, cfg.n_layers))
    return period, n_full, remainder


def _slice_kind_stacks(cfg: ModelConfig, blocks: Params, n_full: int):
    """Reshape each kind's stack [Lk, ...] -> [n_full, per_period, ...]
    over the layers covered by full periods."""
    period = cfg.layer_pattern
    per_kind_count = {k: period.count(k) for k in set(period)}
    out = {}
    for kind, cnt in per_kind_count.items():
        used = n_full * cnt
        out[kind] = jax.tree.map(
            lambda a: a[:used].reshape((n_full, cnt) + a.shape[1:]),
            blocks[kind])
    return out


def forward_hidden(params: Params, cfg: ModelConfig, h: jax.Array,
                   *, collect_cache: bool = False, spa_proxies=None,
                   strategy=None, kv_len: Optional[jax.Array] = None
                   ) -> Tuple[jax.Array, jax.Array, Optional[Dict]]:
    """Run all blocks. Returns (h, total_aux, caches).

    caches (when collect_cache): {kind: {"k": [Lk,B,N,KVH,HD], ...}} with
    raw tensors in layer order within each kind. spa_proxies
    ({kind: [Lk, d, r]}) are needed only when collecting with the
    singular identifier.  kv_len ([B] or None) is the per-row valid
    canvas length, threaded to every attention block (paged serving).
    """
    period, n_full, remainder = period_plan(cfg)
    blocks = params["blocks"]
    aux_total = jnp.zeros((), jnp.float32)
    caches: Dict[str, List] = {k: [] for k in set(period)
                               if k in ATTENTION_KINDS}

    use_scan = cfg.scan_layers and n_full >= 2

    def _prox_slice(kind, idx_in_kind):
        if spa_proxies is None or kind not in (spa_proxies or {}):
            return None
        return spa_proxies[kind][idx_in_kind]

    if use_scan:
        stacks = _slice_kind_stacks(cfg, blocks, n_full)
        if spa_proxies is not None and collect_cache:
            per_kind_count = {k: period.count(k) for k in set(period)}
            prox_stacks = {
                k: spa_proxies[k][: n_full * c].reshape(
                    (n_full, c) + spa_proxies[k].shape[1:])
                for k, c in per_kind_count.items() if k in spa_proxies}
            stacks = (stacks, prox_stacks)
        else:
            stacks = (stacks, None)

        def body(carry, xs):
            period_slice, prox_slice = xs
            h_c, aux_c = carry
            used = {k: 0 for k in period_slice}
            ys: Dict[str, List] = {}
            for kind in period:
                bp = jax.tree.map(lambda a: a[used[kind]],
                                  period_slice[kind])
                pm = (prox_slice[kind][used[kind]]
                      if prox_slice and kind in prox_slice else None)
                used[kind] += 1
                h_c, aux, entries = apply_block_dense(
                    cfg, kind, bp, h_c, collect_cache=collect_cache,
                    proxy_mat=pm, strategy=strategy, kv_len=kv_len)
                aux_c = aux_c + aux
                if collect_cache and entries is not None:
                    ys.setdefault(kind, []).append(entries)
            ys_out = {k: jax.tree.map(lambda *xs: jnp.stack(xs), *v)
                      for k, v in ys.items()} if collect_cache else None
            return (h_c, aux_c), ys_out

        if cfg.remat and not collect_cache:
            body = jax.checkpoint(body, prevent_cse=False)
        (h, aux_total), scan_ys = jax.lax.scan(body, (h, aux_total),
                                               stacks)
        if collect_cache and scan_ys:
            for kind, entries in scan_ys.items():
                # [n_full, per_period, ...] -> list of [B, N, ...] slices
                merged = jax.tree.map(
                    lambda a: a.reshape((-1,) + a.shape[2:]), entries)
                lk = jax.tree.leaves(merged)[0].shape[0]
                caches[kind].extend(
                    jax.tree.map(lambda a, i=i: a[i], merged)
                    for i in range(lk))
    else:
        for l in range(n_full * len(period)):
            kind = cfg.kind_of_layer(l)
            bp = jax.tree.map(lambda a: a[cfg.kind_index(l)], blocks[kind])
            pm = _prox_slice(kind, cfg.kind_index(l))
            if cfg.remat and not collect_cache:
                blk = jax.checkpoint(
                    functools.partial(apply_block_dense,
                                      collect_cache=False, kv_len=kv_len),
                    static_argnums=(0, 1), prevent_cse=False)
                h, aux, entries = blk(cfg, kind, bp, h)
            else:
                h, aux, entries = apply_block_dense(
                    cfg, kind, bp, h, collect_cache=collect_cache,
                    proxy_mat=pm, strategy=strategy, kv_len=kv_len)
            aux_total = aux_total + aux
            if collect_cache and entries is not None:
                caches[kind].append(entries)

    for l in remainder:
        kind = cfg.kind_of_layer(l)
        bp = jax.tree.map(lambda a: a[cfg.kind_index(l)], blocks[kind])
        h, aux, entries = apply_block_dense(
            cfg, kind, bp, h, collect_cache=collect_cache,
            proxy_mat=_prox_slice(kind, cfg.kind_index(l)),
            strategy=strategy, kv_len=kv_len)
        aux_total = aux_total + aux
        if collect_cache and entries is not None and kind in caches:
            caches[kind].append(entries)

    cache_out = None
    if collect_cache:
        cache_out = {
            kind: jax.tree.map(lambda *xs: jnp.stack(xs), *entries_list)
            for kind, entries_list in caches.items() if entries_list
        }
    return h, aux_total, cache_out


def logits_from_hidden(params: Params, cfg: ModelConfig,
                       h: jax.Array) -> jax.Array:
    h = common.rms_norm(h, params["final_norm"], cfg.norm_eps)
    table = (params["embed"].T if cfg.tie_embeddings
             else params["lm_head"])
    logits = (h @ table).astype(jnp.float32)
    if cfg.logit_softcap > 0:
        logits = common.softcap(logits, cfg.logit_softcap)
    return logits


def forward_logits(params: Params, cfg: ModelConfig,
                   inputs: Dict[str, jax.Array]
                   ) -> Tuple[jax.Array, jax.Array]:
    h = embed_inputs(params, cfg, inputs)
    h, aux, _ = forward_hidden(params, cfg, h)
    return logits_from_hidden(params, cfg, h), aux

"""Shared model primitives: norms, RoPE, activations, inits, softcaps."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    """Gemma-2 style logit soft-capping: cap * tanh(x / cap)."""
    if cap <= 0.0:
        return x
    return cap * jnp.tanh(x / cap)


def act_fn(name: str):
    if name in ("silu", "swish"):
        return jax.nn.silu
    if name in ("gelu", "gelu_plain"):
        return jax.nn.gelu
    raise ValueError(f"unknown activation {name!r}")


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (np.arange(half, dtype=np.float32) * 2 / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    freqs = jnp.asarray(rope_frequencies(head_dim, theta))          # [half]
    angles = positions[..., None].astype(jnp.float32) * freqs       # [..., S, half]
    cos = jnp.cos(angles)[..., None, :]                             # [..., S, 1, half]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:2 * half]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    rot = jnp.concatenate([out1, out2], axis=-1)
    if head_dim % 2:  # odd head_dim (h2o-danube head_dim=120 is even; safety)
        rot = jnp.concatenate([rot, x[..., 2 * half:]], axis=-1)
    return rot.astype(x.dtype)


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def dense_init(key: jax.Array, shape, dtype, scale: float | None = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    if scale is None:
        scale = 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key: jax.Array, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def split_keys(key: jax.Array, n: int):
    return list(jax.random.split(key, n))

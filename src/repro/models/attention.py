"""Chunked (flash-style) bidirectional attention in pure JAX.

This is the XLA-path implementation used by every architecture; the Pallas
kernel in ``repro.kernels.sparse_attention`` is the TPU-native version of
the same math (same oracle).

Access patterns:
  * dense          — all queries vs all keys (train / prefill, full attn)
  * banded         — contiguous queries vs a sliding window, with static
                     block skipping so FLOPs are O(N * W), not O(N^2)
  * gathered       — k selected query rows (SPA-Cache Phase 2) vs the full
                     KV cache, optionally window-masked
  * gathered+band  — stratified-selected queries vs a window; the per-block
                     KV range starts at a DYNAMIC offset derived from the
                     block's min position, bounded by a static ``q_span``
                     (guaranteed by stratified selection — DESIGN.md §4)

All paths share one online-softmax inner loop and support GQA,
bidirectional windows, gemma2 attention-logit softcapping, and int8 KV
caches (per-row scales are applied blockwise). Accumulation is f32.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _pad_axis(x: jax.Array, axis: int, multiple: int, value=0.0):
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _deq(xb: jax.Array, scale_b: Optional[jax.Array]) -> jax.Array:
    x = xb.astype(jnp.float32)
    if scale_b is not None:
        x = x * scale_b.astype(jnp.float32)[..., None]
    return x


def _attend_one_block(q, kb, vb, kb_scale, vb_scale, qpos, kbpos, kv_valid,
                      window, soft_cap, scale, carry, kv_len=None):
    """One online-softmax step.

    q:    [B, bq, KVH, G, D] (f32);  kb, vb: [B, bk, KVH, D]
    kb_scale/vb_scale: [B, bk, KVH] or None (int8 dequant scales)
    qpos: [B, bq]; kbpos: [bk]; kv_valid: [bk] bool
    kv_len: [B] int32 or None — per-row valid canvas length; kv positions
      >= kv_len[b] are masked out exactly like pad positions, so a row
      whose canvas occupies only kv_len positions attends identically to
      one computed on a kv_len-long canvas (masked positions contribute
      exact zeros to p and pv).
    carry: (m [B,bq,KVH,G], l [B,bq,KVH,G], acc [B,bq,KVH,G,D])
    """
    m_prev, l_prev, acc_prev = carry
    kf = _deq(kb, kb_scale)
    vf = _deq(vb, vb_scale)
    scores = jnp.einsum("bqhgd,bkhd->bqhgk", q, kf) * scale
    if soft_cap > 0.0:
        scores = soft_cap * jnp.tanh(scores / soft_cap)
    mask = kv_valid[None, None, :]                       # [1,1,bk]
    if kv_len is not None:
        mask = jnp.logical_and(mask,
                               kbpos[None, None, :] < kv_len[:, None, None])
    if window > 0:
        dist = jnp.abs(qpos[:, :, None] - kbpos[None, None, :])
        mask = jnp.logical_and(mask, dist <= window)     # [B,bq,bk]
    else:
        mask = jnp.broadcast_to(mask, (qpos.shape[0], qpos.shape[1],
                                       kbpos.shape[0]))
    mask5 = mask[:, :, None, None, :]                    # [B,bq,1,1,bk]
    scores = jnp.where(mask5, scores, NEG_INF)

    m_blk = jnp.max(scores, axis=-1)                     # [B,bq,KVH,G]
    m_new = jnp.maximum(m_prev, m_blk)
    p = jnp.exp(scores - m_new[..., None])
    p = jnp.where(mask5, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, alpha)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1)
    pv = jnp.einsum("bqhgk,bkhd->bqhgd", p, vf)
    acc_new = alpha[..., None] * acc_prev + pv
    return (m_new, l_new, acc_new)


def _finalize(carry):
    _, l, acc = carry
    l_safe = jnp.where(l == 0.0, 1.0, l)
    return acc / l_safe[..., None]


def band_width(q_span: int, window: int, block_k: int, n_kb: int) -> int:
    """Number of kv blocks a banded q block must visit (static)."""
    return min((q_span + 2 * window) // block_k + 2, n_kb)


def banded_starts(qpos_r: jax.Array, window: int, skv_p: int,
                  n_band: int, block_k: int) -> jax.Array:
    """First kv-block index per q block for the banded path.

    qpos_r: [B, n_qb, bq] padded query positions (pad value >= 2**30).
    Shared by the XLA banded scan below and the Pallas banded kernel
    (``kernels.sparse_attention``) so the start formula cannot drift —
    the start is per q BLOCK (min over the whole [B, bq] tile), which
    both paths consume identically.  Returns [n_qb] int32.
    """
    # Pads (>= 2**30) must NOT win the min: mapping them low would anchor
    # a partially-padded q block at kv block 0, masking out its real rows'
    # windows entirely (l = 0 -> zero output). An all-pad block clips to
    # the last valid start; its rows are discarded anyway.
    pmin = jnp.min(qpos_r, axis=(0, 2))
    start = jnp.clip(pmin - window, 0, skv_p - n_band * block_k)
    return (start // block_k).astype(jnp.int32)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
    q_positions: Optional[jax.Array] = None,
    window: int = 0,
    soft_cap: float = 0.0,
    block_q: int = 512,
    block_k: int = 512,
    banded: bool = False,
    q_span: int = 0,
    kv_len: Optional[jax.Array] = None,
) -> jax.Array:
    """Bidirectional chunked attention.

    q: [B, Sq, H, D]; k, v: [B, Skv, KVH, D] (any dtype; int8 with scales).
    q_positions: [B, Sq] original positions of (possibly gathered) queries;
      default arange. KV positions are always 0..Skv-1 (the full canvas).
    window: 0 = full; >0 = |q_pos - kv_pos| <= window.
    kv_len: [B] per-row valid canvas length (paged serving: rows shorter
      than the canvas mask out their tail exactly like pad); None = Skv.
    banded: static/dynamic block skipping (needs window > 0).
    q_span: static bound on (max-min) position span inside any q block;
      0 means "contiguous canvas" (span = block_q). Required for gathered
      banded queries (use stratified selection to guarantee the bound).
    Returns [B, Sq, H, D] in q.dtype.
    """
    from repro.distributed.hints import shard_hint
    b, sq, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    assert h % kvh == 0, (h, kvh)
    g = h // kvh
    scale = 1.0 / (d ** 0.5)
    out_dtype = q.dtype

    # Attention is model-axis-local in the baseline scheme: materialize the
    # row-parallel projection all-reduces HERE, once, instead of letting
    # GSPMD sink partial-sum reductions into the kv-block loop.
    # and gather a (sequence-sharded) KV cache ONCE per layer, not once
    # per kv block inside the scan.
    q = shard_hint(q, "batch", "keep", None, None)
    k = shard_hint(k, "batch", None, None, None)
    v = shard_hint(v, "batch", None, None, None)
    if k_scale is not None:
        k_scale = shard_hint(k_scale, "batch", None, None)
        v_scale = shard_hint(v_scale, "batch", None, None)

    contiguous = q_positions is None
    if q_positions is None:
        q_positions = jnp.broadcast_to(jnp.arange(sq)[None, :], (b, sq))
    q_positions = q_positions.astype(jnp.int32)
    if kv_len is not None:
        kv_len = kv_len.astype(jnp.int32)

    bq = min(block_q, sq)
    bk = min(block_k, skv)
    q = _pad_axis(q, 1, bq)
    q_positions = _pad_axis(q_positions, 1, bq, value=2**30)
    k = _pad_axis(k, 1, bk)
    v = _pad_axis(v, 1, bk)
    if k_scale is not None:
        k_scale = _pad_axis(k_scale, 1, bk)
        v_scale = _pad_axis(v_scale, 1, bk)
    sq_p, skv_p = q.shape[1], k.shape[1]
    n_qb, n_kb = sq_p // bq, skv_p // bk

    qr = q.reshape(b, n_qb, bq, kvh, g, d).astype(jnp.float32)
    qpos_r = q_positions.reshape(b, n_qb, bq)
    kr = k.reshape(b, n_kb, bk, kvh, d)
    vr = v.reshape(b, n_kb, bk, kvh, d)
    ks_r = (k_scale.reshape(b, n_kb, bk, kvh)
            if k_scale is not None else None)
    vs_r = (v_scale.reshape(b, n_kb, bk, kvh)
            if v_scale is not None else None)
    kv_valid_full = (jnp.arange(skv_p) < skv).reshape(n_kb, bk)
    kpos_full = jnp.arange(skv_p, dtype=jnp.int32).reshape(n_kb, bk)

    def init_carry():
        return (
            jnp.full((b, bq, kvh, g), NEG_INF, jnp.float32),
            jnp.zeros((b, bq, kvh, g), jnp.float32),
            jnp.zeros((b, bq, kvh, g, d), jnp.float32),
        )

    span = bq if contiguous else q_span
    use_band = (banded and window > 0 and span > 0
                and skv > (span + 2 * window + 2 * bk))

    if use_band:
        n_band = band_width(span, window, bk, n_kb)
        starts = banded_starts(qpos_r, window, skv_p, n_band, bk)

        def q_block_fn(q_i, qpos_i, start):
            def kv_step(carry, off):
                kb_idx = start + off
                kb = jax.lax.dynamic_index_in_dim(kr, kb_idx, 1, False)
                vb = jax.lax.dynamic_index_in_dim(vr, kb_idx, 1, False)
                kbs = (jax.lax.dynamic_index_in_dim(ks_r, kb_idx, 1, False)
                       if ks_r is not None else None)
                vbs = (jax.lax.dynamic_index_in_dim(vs_r, kb_idx, 1, False)
                       if vs_r is not None else None)
                kv_val = jax.lax.dynamic_index_in_dim(
                    kv_valid_full, kb_idx, 0, False)
                kpos = jax.lax.dynamic_index_in_dim(
                    kpos_full, kb_idx, 0, False)
                carry = _attend_one_block(
                    q_i, kb, vb, kbs, vbs, qpos_i, kpos, kv_val, window,
                    soft_cap, scale, carry, kv_len=kv_len)
                return carry, None

            carry, _ = jax.lax.scan(kv_step, init_carry(),
                                    jnp.arange(n_band))
            return _finalize(carry)
    else:
        starts = jnp.zeros((n_qb,), jnp.int32)

        def q_block_fn(q_i, qpos_i, start):
            del start

            def kv_step(carry, idx):
                kb, vb, kv_val, kpos = (
                    kr[:, idx], vr[:, idx], kv_valid_full[idx],
                    kpos_full[idx])
                kbs = ks_r[:, idx] if ks_r is not None else None
                vbs = vs_r[:, idx] if vs_r is not None else None
                carry = _attend_one_block(
                    q_i, kb, vb, kbs, vbs, qpos_i, kpos, kv_val, window,
                    soft_cap, scale, carry, kv_len=kv_len)
                return carry, None

            carry, _ = jax.lax.scan(kv_step, init_carry(),
                                    jnp.arange(n_kb))
            return _finalize(carry)

    # Recompute each q-block in the backward pass (flash-attention memory
    # profile): only block inputs are saved, not per-kv-step residuals.
    q_block_ck = jax.checkpoint(q_block_fn, prevent_cse=False)

    def scan_qb(_, i):
        q_i = jax.lax.dynamic_index_in_dim(qr, i, 1, False)
        qpos_i = jax.lax.dynamic_index_in_dim(qpos_r, i, 1, False)
        start_i = jax.lax.dynamic_index_in_dim(starts, i, 0, False)
        return None, q_block_ck(q_i, qpos_i, start_i)

    _, outs = jax.lax.scan(scan_qb, None, jnp.arange(n_qb))
    out = jnp.moveaxis(outs, 0, 1)  # [B, n_qb, bq, KVH, G, D]
    out = out.reshape(b, sq_p, h, d)[:, :sq]
    return out.astype(out_dtype)


def reference_attention(q, k, v, *, k_scale=None, v_scale=None,
                        q_positions=None, window=0,
                        soft_cap=0.0, kv_len=None) -> jax.Array:
    """O(Sq*Skv) dense oracle for tests."""
    b, sq, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    if q_positions is None:
        q_positions = jnp.broadcast_to(jnp.arange(sq)[None, :], (b, sq))
    kf = _deq(k, k_scale)
    vf = _deq(v, v_scale)
    qr = q.reshape(b, sq, kvh, g, d).astype(jnp.float32)
    scores = jnp.einsum("bqhgd,bkhd->bqhgk", qr, kf) / (d ** 0.5)
    if soft_cap > 0.0:
        scores = soft_cap * jnp.tanh(scores / soft_cap)
    if kv_len is not None:
        mask = (jnp.arange(skv)[None, :] < kv_len[:, None]
                )[:, None, None, None, :]
        scores = jnp.where(mask, scores, NEG_INF)
    if window > 0:
        dist = jnp.abs(q_positions[:, :, None] - jnp.arange(skv)[None, None])
        mask = (dist <= window)[:, :, None, None, :]
        scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", p, vf)
    if kv_len is not None:
        # a fully-released row (kv_len == 0) has no valid keys: match
        # flash_attention's l == 0 guard (exact zeros, not softmax of a
        # uniform -inf row)
        out = jnp.where((kv_len > 0)[:, None, None, None, None], out, 0.0)
    return out.reshape(b, sq, h, d).astype(q.dtype)

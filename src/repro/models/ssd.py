"""Mamba-2 SSD (state-space duality) mixer, chunked algorithm.

Implements the blocked SSD recurrence from arXiv:2405.21060 §6: the
sequence is split into chunks; within a chunk the dual quadratic
(attention-like) form runs on the MXU; across chunks a small state
[H, hd, d_state] is carried — linear in T, constant memory.

DLM adaptation: the scan is causal, so for masked-diffusion denoising the
block runs both directions and averages (bidirectional-SSM construction);
see DESIGN.md. SPA-Cache sparse row updates are UNSOUND for this mixer
(global sequential dependency) — mamba2 runs with identifier="none".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.models import common


def init_ssd_params(key, cfg: ModelConfig, dtype):
    ssm = cfg.ssm or SSMConfig()
    d = cfg.d_model
    di = ssm.d_inner(d)
    nh = ssm.n_heads(d)
    ds = ssm.d_state
    conv_dim = di + 2 * ds
    ks = common.split_keys(key, 6)
    return {
        "w_in": common.dense_init(
            ks[0], (d, 2 * di + 2 * ds + nh), dtype),
        "conv_kernel": common.dense_init(ks[1], (ssm.d_conv, conv_dim),
                                         dtype, scale=0.1),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(dtype),
        "dt_bias": jnp.full((nh,), -3.0, dtype),   # softplus(-3) ~ 0.049
        "d_skip": jnp.ones((nh,), dtype),
        "norm_weight": jnp.zeros((di,), dtype),
        "w_out": common.dense_init(ks[2], (di, d), dtype),
    }


def _depthwise_conv(x: jax.Array, kernel: jax.Array) -> jax.Array:
    w = kernel.shape[0]
    pads = jnp.pad(x, ((0, 0), (w - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(w):
        out = out + pads[:, i:i + x.shape[1]] * kernel[w - 1 - i]
    return out


def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, bmat: jax.Array,
             cmat: jax.Array, chunk: int) -> jax.Array:
    """Chunked SSD core.

    x:    [B, T, H, hd]   (SSM inputs per head)
    dt:   [B, T, H]       (positive step sizes)
    a:    [H]             (negative decay rates)
    bmat: [B, T, ds]      (input projections, ngroups=1)
    cmat: [B, T, ds]      (output projections)
    Returns y: [B, T, H, hd].
    """
    b, t, h, hd = x.shape
    ds = bmat.shape[-1]
    assert t % chunk == 0, (t, chunk)
    ncs = t // chunk

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    la_steps = dtf * a[None, None, :]                     # [B,T,H], <= 0
    xr = xf.reshape(b, ncs, chunk, h, hd)
    dtr = dtf.reshape(b, ncs, chunk, h)
    lar = la_steps.reshape(b, ncs, chunk, h)
    br = bmat.astype(jnp.float32).reshape(b, ncs, chunk, ds)
    cr = cmat.astype(jnp.float32).reshape(b, ncs, chunk, ds)

    la = jnp.cumsum(lar, axis=2)                          # [B,L,cs,H]
    la_end = la[:, :, -1, :]                              # [B,L,H]

    # --- intra-chunk (quadratic, masked) ---
    g = jnp.einsum("blis,bljs->blij", cr, br)             # [B,L,cs,cs]
    decay = jnp.exp(la[:, :, :, None, :] - la[:, :, None, :, :])  # [B,L,i,j,H]
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    m = g[..., None] * jnp.where(mask[None, None, :, :, None], decay, 0.0)
    m = m * dtr[:, :, None, :, :]                         # weight by dt_j
    y_intra = jnp.einsum("blijh,bljhd->blihd", m, xr)

    # --- chunk states ---
    # S_c = sum_j exp(la_end - la_j) dt_j B_j (x) x_j  -> [B,L,H,hd,ds]
    w = jnp.exp(la_end[:, :, None, :] - la) * dtr          # [B,L,cs,H]
    s_chunk = jnp.einsum("bljh,bljhd,bljs->blhds", w, xr, br)

    # --- inter-chunk recurrence over L ---
    a_tot = jnp.exp(la_end)                               # [B,L,H]

    def step(s_prev, inp):
        a_c, s_c = inp
        s_new = a_c[:, :, None, None] * s_prev + s_c
        return s_new, s_prev

    s0 = jnp.zeros((b, h, hd, ds), jnp.float32)
    _, s_before = jax.lax.scan(
        step, s0, (jnp.moveaxis(a_tot, 1, 0), jnp.moveaxis(s_chunk, 1, 0)))
    s_before = jnp.moveaxis(s_before, 0, 1)               # [B,L,H,hd,ds]

    y_inter = jnp.einsum("blis,blhds->blihd", cr, s_before)
    y_inter = y_inter * jnp.exp(la)[..., None]            # decay to pos i

    y = (y_intra + y_inter).reshape(b, t, h, hd)
    return y.astype(x.dtype)


def ssd_scan_ref(x, dt, a, bmat, cmat) -> jax.Array:
    """O(T^2)-free sequential oracle (lax.scan per step) for tests."""
    b, t, h, hd = x.shape
    ds = bmat.shape[-1]

    def step(s, inp):
        xi, dti, bi, ci = inp       # [B,H,hd], [B,H], [B,ds], [B,ds]
        a_t = jnp.exp(dti * a[None, :])                    # [B,H]
        s = s * a_t[:, :, None, None] + jnp.einsum(
            "bh,bhd,bs->bhds", dti, xi, bi)
        y = jnp.einsum("bs,bhds->bhd", ci, s)
        return s, y

    s0 = jnp.zeros((b, h, hd, ds), jnp.float32)
    _, ys = jax.lax.scan(
        step, s0,
        (jnp.moveaxis(x.astype(jnp.float32), 1, 0),
         jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
         jnp.moveaxis(bmat.astype(jnp.float32), 1, 0),
         jnp.moveaxis(cmat.astype(jnp.float32), 1, 0)))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)


def _ssd_one_direction(params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    ssm = cfg.ssm or SSMConfig()
    d = cfg.d_model
    di = ssm.d_inner(d)
    nh = ssm.n_heads(d)
    ds = ssm.d_state
    b, t, _ = x.shape

    proj = x @ params["w_in"]
    z, xbc, dt_raw = jnp.split(proj, [di, 2 * di + 2 * ds], axis=-1)
    xbc = _depthwise_conv(xbc, params["conv_kernel"])
    xbc = jax.nn.silu(xbc)
    x_ssm, bmat, cmat = jnp.split(xbc, [di, di + ds], axis=-1)
    x_ssm = x_ssm.reshape(b, t, nh, ssm.head_dim)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32)
        + params["dt_bias"].astype(jnp.float32))           # [B,T,H]
    a = -jnp.exp(params["a_log"].astype(jnp.float32))      # [H]

    chunk = min(ssm.chunk_size, t)
    pad = (-t) % chunk
    if pad:
        x_ssm = jnp.pad(x_ssm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat_p = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat_p = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    else:
        bmat_p, cmat_p = bmat, cmat

    y = ssd_scan(x_ssm, dt, a, bmat_p, cmat_p, chunk)[:, :t]
    y = y + params["d_skip"].astype(y.dtype)[None, None, :, None] \
        * x_ssm[:, :t]
    y = y.reshape(b, t, di)
    y = y * jax.nn.silu(z)
    y = common.rms_norm(y, params["norm_weight"], cfg.norm_eps)
    return y @ params["w_out"]


def apply_ssd(params, x: jax.Array, cfg: ModelConfig,
              bidirectional: bool = True) -> jax.Array:
    """Full Mamba-2 block. x: [B,T,d] -> [B,T,d]."""
    y = _ssd_one_direction(params, x, cfg)
    if bidirectional:
        y_rev = _ssd_one_direction(params, jnp.flip(x, axis=1), cfg)
        y = 0.5 * (y + jnp.flip(y_rev, axis=1))
    return y

"""Masked-diffusion forward process (LLaDA / MDLM style)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def sample_masking(key: jax.Array, tokens: jax.Array, mask_id: int,
                   min_t: float = 0.05, max_t: float = 1.0
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Sample per-example mask ratio t ~ U(min_t, max_t), mask each token
    i.i.d. with probability t.

    Returns (noisy_tokens, mask [B,T] bool, t [B]).
    """
    b, n = tokens.shape
    k_t, k_m = jax.random.split(key)
    t = jax.random.uniform(k_t, (b,), minval=min_t, maxval=max_t)
    mask = jax.random.uniform(k_m, (b, n)) < t[:, None]
    noisy = jnp.where(mask, mask_id, tokens)
    return noisy, mask, t


def mask_canvas(prompt: jax.Array, gen_len: int, mask_id: int) -> jax.Array:
    """Decoding canvas: prompt followed by gen_len [MASK] slots."""
    b = prompt.shape[0]
    canvas = jnp.full((b, prompt.shape[1] + gen_len), mask_id,
                      prompt.dtype)
    return canvas.at[:, : prompt.shape[1]].set(prompt)

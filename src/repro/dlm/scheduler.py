"""First-class unmask schedulers (the ``UnmaskScheduler`` protocol).

SPA-Cache makes *caching* policy pluggable (``core.strategy``); this
module does the same for the *commit* policy — which masked positions
unmask at each refinement step.  The decoding schedules the paper
benchmarks against (greedy confidence, Fast-dLLM parallel thresholds,
semi-AR blocks §2.2, dKV-Cache-style order heuristics) are all
instances of one protocol instead of flags scattered over
``DecodeSettings`` and host-side loops.

A scheduler is a frozen (hashable) dataclass, so jitted step functions
close over it statically — exactly like ``CacheStrategy``: switching
scheduler retraces once, switching request does not.  Every decode
surface (``DecodeSession``, ``decode``, ``decode_semi_ar``,
``ServingEngine``) accepts ``scheduler=`` at call time; the legacy
``DecodeSettings.parallel_threshold``/``max_parallel`` knobs remain as
a spec bridge resolved by :func:`resolve_scheduler`.

The protocol is ONE method::

    commit, pred = scheduler.select_commits(view)

where ``view`` (a :class:`CommitView`) exposes this step's candidate
set — logits, confidences, greedy predictions, candidate positions,
open flags, the full open/active masks, and (for stochastic
schedulers) a per-step rng.  ``commit`` is a [B, C] bool mask over
candidates and ``pred`` the [B, C] token ids to write where committed.
``serve_step`` intersects ``commit`` with the open-candidate flags, so
schedulers never have to re-guard closed slots.

Schedulers run entirely on device (no host syncs, no data-dependent
Python), which is what makes ``DecodeSession.run_compiled()`` — the
whole decode loop as one ``jax.lax.while_loop`` — possible.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Dict, NamedTuple, Optional, Tuple, Type

import jax
import jax.numpy as jnp

# Registry of scheduler classes keyed by their serializable name.
SCHEDULERS: Dict[str, Type["UnmaskScheduler"]] = {}


def register(name: str):
    def deco(cls):
        SCHEDULERS[name] = cls
        return cls

    return deco


class CommitView(NamedTuple):
    """Everything a scheduler may look at when picking commits.

    All arrays are per refinement step; C = ``settings.n_candidates``.
    ``conf`` is already ``-inf`` at closed candidates, so plain
    ``argmax(conf)`` is safe.
    """

    logits: jax.Array            # [B, C, V] ([MASK] already -inf)
    conf: jax.Array              # [B, C] max prob, -inf at closed cands
    pred: jax.Array              # [B, C] greedy token ids
    cand_idx: jax.Array          # [B, C] canvas positions of candidates
    cand_open: jax.Array         # [B, C] candidate is masked AND active
    open_mask: jax.Array         # [B, N] full canvas open mask
    active: jax.Array            # [B, N] full active-position mask
    rng: Optional[jax.Array]     # per-step key (uses_rng schedulers only)


def _argmax_commit(conf: jax.Array) -> jax.Array:
    """One-hot bool mask of the per-row argmax candidate."""
    return jax.nn.one_hot(jnp.argmax(conf, axis=-1), conf.shape[-1],
                          dtype=bool)


def _commit_with_parallel(score: jax.Array, par: Optional[jax.Array],
                          max_parallel: int) -> jax.Array:
    """Fast-dLLM parallel commit: the argmax-``score`` candidate plus
    every candidate in ``par`` (optionally capped at the ``max_parallel``
    highest-scoring) — op-for-op the pre-scheduler ``serve_step``
    branch, so the settings bridge is byte-identical."""
    commit = _argmax_commit(score)
    if par is not None:
        if max_parallel > 0:
            b = score.shape[0]
            _, topp = jax.lax.top_k(score, min(max_parallel,
                                               score.shape[-1]))
            in_top = jnp.zeros_like(par).at[
                jnp.arange(b)[:, None], topp].set(True)
            par = jnp.logical_and(par, in_top)
        commit = jnp.logical_or(commit, par)
    return commit


@dataclasses.dataclass(frozen=True)
class UnmaskScheduler:
    """Protocol base: frozen, hashable, device-only commit policy."""

    name: ClassVar[str] = "abstract"
    uses_rng: ClassVar[bool] = False   # True -> DecodeState carries an rng

    def select_commits(self, view: CommitView
                       ) -> Tuple[jax.Array, jax.Array]:
        """Return (commit [B, C] bool, pred [B, C] token ids)."""
        raise NotImplementedError


@register("confidence")
@dataclasses.dataclass(frozen=True)
class ConfidenceScheduler(UnmaskScheduler):
    """Greedy argmax-confidence: exactly one commit per row per step
    (the repo's historical default)."""

    name: ClassVar[str] = "confidence"

    def select_commits(self, view):
        return _argmax_commit(view.conf), view.pred


@register("parallel")
@dataclasses.dataclass(frozen=True)
class ParallelThresholdScheduler(UnmaskScheduler):
    """Fast-dLLM-style parallel commit (absorbs the legacy
    ``DecodeSettings.parallel_threshold``/``max_parallel`` knobs)."""

    threshold: float = 0.05
    max_parallel: int = 0            # 0 = uncapped

    name: ClassVar[str] = "parallel"

    def select_commits(self, view):
        par = (view.conf > self.threshold) if self.threshold > 0.0 \
            else None
        return _commit_with_parallel(view.conf, par,
                                     self.max_parallel), view.pred


@register("entropy")
@dataclasses.dataclass(frozen=True)
class EntropyScheduler(UnmaskScheduler):
    """Commit the minimum-entropy candidate (full-distribution
    uncertainty instead of top-1 confidence); ``threshold`` > 0
    additionally commits every candidate whose entropy (in nats) is
    below it, capped at ``max_parallel``."""

    threshold: float = 0.0
    max_parallel: int = 0

    name: ClassVar[str] = "entropy"

    def select_commits(self, view):
        probs = jax.nn.softmax(view.logits, axis=-1)
        ent = -jnp.sum(probs * jnp.log(jnp.clip(probs, 1e-30)), axis=-1)
        # negate: the shared parallel helper expects HIGH = commit
        neg_ent = jnp.where(view.cand_open, -ent, -jnp.inf)
        par = (neg_ent > -self.threshold) if self.threshold > 0.0 \
            else None
        return _commit_with_parallel(neg_ent, par,
                                     self.max_parallel), view.pred


@register("temperature")
@dataclasses.dataclass(frozen=True)
class TemperatureSampler(UnmaskScheduler):
    """Stochastic commit: the position is sampled ∝ softmax(conf/T) over
    open candidates (Gumbel-max) and the token is sampled from
    softmax(logits/T) — rng threaded through ``DecodeState.rng``."""

    temperature: float = 1.0

    name: ClassVar[str] = "temperature"
    uses_rng: ClassVar[bool] = True

    def select_commits(self, view):
        k_pos, k_tok = jax.random.split(view.rng)
        t = max(self.temperature, 1e-6)
        g_tok = jax.random.gumbel(k_tok, view.logits.shape,
                                  jnp.float32)
        pred = jnp.argmax(view.logits.astype(jnp.float32) / t + g_tok,
                          axis=-1).astype(view.pred.dtype)
        g_pos = jax.random.gumbel(k_pos, view.conf.shape, jnp.float32)
        score = jnp.where(view.cand_open, view.conf / t + g_pos,
                          -jnp.inf)
        return _argmax_commit(score), pred


@register("random_order")
@dataclasses.dataclass(frozen=True)
class RandomOrderScheduler(UnmaskScheduler):
    """Uniformly random unmask order with greedy tokens — the
    order-heuristic ablation (dKV-Cache family contrasts decode order
    against confidence order)."""

    name: ClassVar[str] = "random_order"
    uses_rng: ClassVar[bool] = True

    def select_commits(self, view):
        score = jnp.where(view.cand_open,
                          jax.random.uniform(view.rng, view.conf.shape),
                          -jnp.inf)
        return _argmax_commit(score), view.pred


@register("block")
@dataclasses.dataclass(frozen=True)
class BlockScheduler(UnmaskScheduler):
    """Semi-AR blocks expressed as DATA instead of a host loop: commits
    are restricted to the current ``block_len``-wide window of the
    generation span, and the window advances automatically once its
    slots drain (the leftmost open position defines the current block).
    Inside the window, commits follow confidence with an optional
    Fast-dLLM parallel threshold — the §2.2 restrictive schedule the
    paper contrasts with SPA-Cache's arbitrary-order updates, now
    runnable inside ``run_compiled``'s single ``lax.while_loop``."""

    block_len: int = 8
    threshold: float = 0.0
    max_parallel: int = 0

    name: ClassVar[str] = "block"

    def select_commits(self, view):
        b, n = view.active.shape
        pos = jnp.arange(n, dtype=jnp.int32)[None, :]
        # generation span start = first active position per row
        gen_start = jnp.min(jnp.where(view.active, pos, n),
                            axis=-1).astype(jnp.int32)      # [B]
        first_open = jnp.min(jnp.where(view.open_mask, pos, n),
                             axis=-1).astype(jnp.int32)     # [B]
        blk = jnp.maximum(first_open - gen_start, 0) // self.block_len
        win_lo = gen_start + blk * self.block_len
        win_hi = win_lo + self.block_len
        in_win = jnp.logical_and(view.cand_idx >= win_lo[:, None],
                                 view.cand_idx < win_hi[:, None])
        conf = jnp.where(in_win, view.conf, -jnp.inf)
        par = (conf > self.threshold) if self.threshold > 0.0 else None
        return _commit_with_parallel(conf, par,
                                     self.max_parallel), view.pred


# ---------------------------------------------------------------------------
# Resolution
# ---------------------------------------------------------------------------

def scheduler_from_name(name: str, **kw) -> UnmaskScheduler:
    cls = SCHEDULERS.get(name)
    if cls is None:
        raise ValueError(f"unknown scheduler {name!r}; registered: "
                         f"{sorted(SCHEDULERS)}")
    return cls(**kw)


def resolve_scheduler(settings=None,
                      scheduler: Optional[UnmaskScheduler] = None
                      ) -> UnmaskScheduler:
    """Call-time scheduler wins; else the legacy ``DecodeSettings``
    parallel knobs map onto their scheduler equivalents (byte-identical
    commits), else greedy confidence."""
    if scheduler is not None:
        return scheduler
    if settings is not None and settings.parallel_threshold > 0.0:
        return ParallelThresholdScheduler(
            threshold=settings.parallel_threshold,
            max_parallel=settings.max_parallel)
    return ConfidenceScheduler()

"""DLM iterative-unmasking decoding primitives with pluggable caching.

  prefill    — full forward over the canvas that populates all layer caches
               (K, V, H^c, identifier vectors) per the CacheStrategy.
  serve_step — ONE diffusion refinement step: sparse layer updates driven
               by the strategy, candidate-limited logit evaluation, and
               the commit decision delegated to an ``UnmaskScheduler``
               (greedy confidence / Fast-dLLM parallel / entropy /
               stochastic / random-order / semi-AR blocks).

The step LOOP (prefill + jitted step + periodic refresh) lives in
``repro.dlm.session.DecodeSession``; ``decode`` and ``decode_semi_ar``
below are thin compatibility wrappers over it.

All caching policy dispatch goes through ``core.strategy.CacheStrategy``
(DESIGN.md §2) and all commit policy through
``dlm.scheduler.UnmaskScheduler`` (DESIGN.md §2.5) — this module never
inspects identifier strings or branches on schedule flags itself.

Candidate-limited logits: computing lm-head logits over the full 32k/500k
canvas each step would dominate all other costs, so logits are evaluated
only at ``n_candidates`` masked positions per step (a serving design
choice documented in DESIGN.md §3).

Active-position masks: ``DecodeState.active`` [B, N_text] bool marks the
canvas positions a session is allowed to commit. Slots outside a
request's prompt+gen span (serving) or outside the current semi-AR block
stay ``active=False`` — token ids are never overloaded as sentinels
(token 0 is a legal vocab id).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTENTION_KINDS, ModelConfig
from repro.core import cache as cache_lib
from repro.core import selection, spa_layer
from repro.core.cache import CachePolicy
from repro.core.strategy import CacheStrategy, resolve_strategy
from repro.dlm.scheduler import (CommitView, UnmaskScheduler,
                                 resolve_scheduler)
from repro.models import transformer

Params = Dict[str, Any]


class DecodeState(NamedTuple):
    tokens: jax.Array            # [B, N_text] canvas (mask_id at open slots)
    cache: Any                   # {kind: {name: [Lk,B,N,...]}}
    step: jax.Array              # scalar int32
    committed: jax.Array         # [B, C] recently committed positions (-1 pad)
    n_masked: jax.Array          # [B] remaining masked counts
    active: Optional[jax.Array] = None   # [B, N_text] bool commit mask
    # None (NOT a dict literal: NamedTuple defaults are shared across
    # every instance, so a mutable {} leaks writes between sessions);
    # DecodeSession normalizes to a fresh dict at construction.
    extras: Optional[Dict[str, jax.Array]] = None  # modality stubs (VLM)
    rng: Optional[jax.Array] = None      # stochastic-scheduler key chain
    # [B] valid canvas length per row (paged serving, DESIGN.md §5):
    # attention/selection mask positions >= kv_len[b].  None = full N.
    kv_len: Optional[jax.Array] = None


@dataclasses.dataclass(frozen=True)
class DecodeSettings:
    """Per-request decode knobs (hashable: used as an engine lane key).

    ``refresh_interval`` — periodic full cache rebuilds, single-sourced
    in ``DecodeSession``:  R > 0 rebuilds every R steps, 0 falls back to
    the strategy's own default (``CacheStrategy.refresh_interval``), and
    -1 explicitly DISABLES refresh even when the strategy has one.

    ``parallel_threshold``/``max_parallel`` are the legacy spec form of
    the commit policy; ``dlm.scheduler.resolve_scheduler`` maps them to
    a ``ParallelThresholdScheduler`` (byte-identical commits).  Prefer
    passing ``scheduler=`` to the decode surfaces directly.
    """
    n_candidates: int = 64
    parallel_threshold: float = 0.0   # 0 = commit exactly 1 token / step
    max_parallel: int = 0             # cap on tokens committed per step
    refresh_interval: int = 0         # rebuild cache every R steps
    commit_ring: int = 8              # size of "recently committed" buffer


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------

def prefill(params: Params, cfg: ModelConfig, inputs: Dict[str, jax.Array],
            spa_proxies=None, strategy: Optional[CacheStrategy] = None,
            kv_len: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, Any]:
    """Full forward building the strategy's caches. Returns (h_final, cache).

    ``kv_len`` [B] masks each row's canvas tail in attention (paged
    serving) so a short row prefills exactly as on its own canvas."""
    strategy = resolve_strategy(cfg, strategy)
    policy = CachePolicy.from_config(cfg)
    h = transformer.embed_inputs(params, cfg, inputs)
    h, _, raw = transformer.forward_hidden(
        params, cfg, h, collect_cache=True, spa_proxies=spa_proxies,
        strategy=strategy, kv_len=kv_len)
    cache = {}
    for kind, entries in (raw or {}).items():
        out: Dict[str, jax.Array] = {}
        if policy.quantized:
            out["k"], out["k_scale"] = cache_lib.quantize_rows(entries["k"])
            out["v"], out["v_scale"] = cache_lib.quantize_rows(entries["v"])
            out["h"], out["h_scale"] = cache_lib.quantize_rows(entries["h"])
        else:
            cd = policy.compute_dtype
            out["k"] = entries["k"].astype(cd)
            out["v"] = entries["v"].astype(cd)
            out["h"] = entries["h"].astype(cd)
        if "proxy" in entries:
            out["proxy"] = entries["proxy"].astype(policy.compute_dtype)
            if strategy.incremental:
                out["proxy_now"] = out["proxy"]
        cache[kind] = out
    return h, cache


def partial_prefill_supported(cfg: ModelConfig) -> bool:
    """Whether ``prefill_partial`` can reproduce cold-prefill numerics
    for this architecture: every layer must be a cache-carrying
    attention kind (a recurrent block's suffix states depend on prefix
    states that carry no cache) and window-free (the cold prefill's
    banded kv scan visits a different kv-block range than the gathered
    path, so low bits could differ).  Architectures outside this set
    still get FULL prefix hits (no forward at all) — only partial hits
    degrade to misses."""
    from repro.models.transformer import layer_window
    kinds = set(cfg.layer_kinds)
    return (kinds <= set(ATTENTION_KINDS)
            and all(layer_window(cfg, k) == 0 for k in kinds))


def prefill_partial(params: Params, cfg: ModelConfig,
                    inputs: Dict[str, jax.Array],
                    kv_view: Dict[str, Dict[str, jax.Array]],
                    suffix_start: int,
                    kv_len: Optional[jax.Array] = None,
                    spa_proxies=None,
                    strategy: Optional[CacheStrategy] = None
                    ) -> Dict[str, Dict[str, jax.Array]]:
    """Prefill ONLY canvas positions >= ``suffix_start``, reading the
    already-cached K/V for [0, suffix_start) from ``kv_view``
    ({kind: {"k"/"v": [Lk, B, N, ...]}}, a dense gather of the shared
    prefix pages — DESIGN.md §6).

    Exactness: every per-row op of the cold prefill (embedding, norms,
    QKV, FFN) is row-local, and the flash-attention kv scan visits the
    same kv blocks in the same order whether the query set is the full
    canvas or a slice — so given exact prefix K/V (same prompt, same
    row span) the suffix states match the cold prefill's suffix rows up
    to XLA op-scheduling float error (~1e-6: the cold path compiles a
    layer scan, this path an unrolled loop, and fusion grouping
    differs; asserted per strategy in ``tests/test_prefix.py``).  This
    wobble only ever reaches decode through PARTIAL prefix hits, whose
    matched pages already carry the (much larger) cross-suffix
    staleness the strategy's drift identification manages — exact
    rematches are FULL hits, a pure page copy with no forward at all,
    and those are byte-identical end-to-end (DESIGN.md §6).

    Returns the same {kind: {name: [Lk, B, N, ...]}} layout as
    :func:`prefill`, with zeros at positions < suffix_start — callers
    scatter it through a write page table whose prefix entries alias
    the zero page, so the zeros never land anywhere.

    Requires :func:`partial_prefill_supported` and a non-quantized
    cache (int8 prefix pages dequantize, breaking bit-exactness).
    """
    from repro.models.attention import flash_attention
    from repro.distributed.hints import shard_hint
    strategy = resolve_strategy(cfg, strategy)
    policy = CachePolicy.from_config(cfg)
    assert partial_prefill_supported(cfg), cfg.layer_kinds
    assert not policy.quantized, "partial prefill needs a float cache"
    assert strategy.uses_cache
    from repro.models import common

    h_full = transformer.embed_inputs(params, cfg, inputs)
    b, n = h_full.shape[0], h_full.shape[1]
    s0 = int(suffix_start)
    assert 0 < s0 < n, (s0, n)
    h = h_full[:, s0:]
    positions = jnp.broadcast_to(jnp.arange(s0, n, dtype=jnp.int32)[None],
                                 (b, n - s0))
    cd = policy.compute_dtype
    per_kind: Dict[str, Dict[str, list]] = {}
    for l in range(cfg.n_layers):
        kind = cfg.kind_of_layer(l)
        ki = cfg.kind_index(l)
        bp = jax.tree.map(lambda t: t[ki], params["blocks"][kind])
        proxy_mat = (spa_proxies[kind][ki]
                     if strategy.uses_proxy_mat and spa_proxies else None)
        x = common.rms_norm(h, bp["norm1"], cfg.norm_eps)
        q, k_new, v_new = transformer.qkv_project(bp, x, cfg, positions)
        k_all = kv_view[kind]["k"][ki].astype(cd).at[:, s0:].set(
            k_new.astype(cd))
        v_all = kv_view[kind]["v"][ki].astype(cd).at[:, s0:].set(
            v_new.astype(cd))
        attn = flash_attention(q, k_all, v_all, q_positions=positions,
                               soft_cap=cfg.attn_softcap, kv_len=kv_len)
        attn_out = shard_hint(
            attn.reshape(b, n - s0, cfg.q_dim) @ bp["wo"],
            "batch", "keep", None)
        if cfg.post_norms:
            attn_out = common.rms_norm(attn_out, bp["norm_post_attn"],
                                       cfg.norm_eps)
        h_mid = h + attn_out
        y = common.rms_norm(h_mid, bp["norm2"], cfg.norm_eps)
        ffn_out, _ = transformer.apply_ffn_or_moe(bp, y, cfg)
        if cfg.post_norms:
            ffn_out = common.rms_norm(ffn_out, bp["norm_post_ffn"],
                                      cfg.norm_eps)
        h_out = h_mid + ffn_out
        entries = {"k": k_new, "v": v_new, "h": h_out}
        prox = strategy.prefill_proxy(bp, proxy_mat, h, x, attn_out, h_out)
        if prox is not None:
            entries["proxy"] = prox
        slot = per_kind.setdefault(kind, {})
        for name, val in entries.items():
            slot.setdefault(name, []).append(val)
        h = h_out

    cache: Dict[str, Dict[str, jax.Array]] = {}
    for kind, bufs in per_kind.items():
        out: Dict[str, jax.Array] = {}
        for name, vals in bufs.items():
            stacked = jnp.stack(vals).astype(cd)        # [Lk, B, S, ...]
            full = jnp.zeros(stacked.shape[:2] + (n,) + stacked.shape[3:],
                             cd)
            out[name] = full.at[:, :, s0:].set(stacked)
        if "proxy" in out and strategy.incremental:
            out["proxy_now"] = out["proxy"]
        cache[kind] = out
    return cache


# ---------------------------------------------------------------------------
# Serve step
# ---------------------------------------------------------------------------

def _candidate_positions(tokens: jax.Array, mask_id: int, n_cand: int,
                         active: Optional[jax.Array] = None) -> jax.Array:
    """First n_cand open (masked AND active) positions per row."""
    b, n = tokens.shape
    is_masked = tokens == mask_id
    if active is not None:
        is_masked = jnp.logical_and(is_masked, active)
    score = jnp.where(is_masked, -jnp.arange(n)[None, :].astype(jnp.float32),
                      -jnp.inf)
    _, idx = jax.lax.top_k(score, min(n_cand, n))
    return jnp.sort(idx, axis=-1).astype(jnp.int32), is_masked


def serve_step(params: Params, cfg: ModelConfig, state: DecodeState,
               settings: DecodeSettings, spa_proxies=None,
               strategy: Optional[CacheStrategy] = None,
               scheduler: Optional[UnmaskScheduler] = None
               ) -> Tuple[DecodeState, Dict[str, jax.Array]]:
    """One diffusion refinement step under the resolved CacheStrategy;
    the commit decision is the resolved ``UnmaskScheduler``'s.  Fully
    device-resident (no host syncs), so ``DecodeSession.run_compiled``
    can run it inside a single ``lax.while_loop``."""
    strategy = resolve_strategy(cfg, strategy)
    scheduler = resolve_scheduler(settings, scheduler)
    tokens, cache = state.tokens, state.cache
    b = tokens.shape[0]
    mask_id = cfg.mask_id

    inputs = dict(state.extras) if state.extras else {}
    inputs["tokens"] = tokens
    h = transformer.embed_inputs(params, cfg, inputs)
    n = h.shape[1]                     # full canvas (incl. patch tokens)
    offset = n - tokens.shape[1]       # VLM: text starts after patches
    # sequence-parallel residual stream (sets the layer-scan carry
    # sharding; see spa_layer h_out hint). Measured best for SSM archs
    # too (EXPERIMENTS.md §Perf: mamba2 with replicated weights +
    # sequence sharding is 2.3x over the TP baseline and fits HBM,
    # whereas dropping the sharding trades 44 GB of replicated scan
    # buffers for zero collectives).
    from repro.distributed.hints import shard_hint
    n_spec = ("pod", "data", "model") if b == 1 else "model"
    h = shard_hint(h, None if b == 1 else "batch", n_spec, None)

    scores_override = strategy.pre_scores(n, state.committed + offset)

    # Paged cache (DESIGN.md §5): the persistent state is a pooled page
    # arena + page table.  Per step, every buffer except the identifier
    # pages is gathered into the dense compute view through the page
    # table (the identifier pages are consumed in-layer by the paged
    # identification/commit kernels), and the stepped view scatters back
    # at the end — all through strategy.backend, so XLA stays the
    # byte-identical oracle for the Pallas paged kernels.
    paged = isinstance(cache, cache_lib.PagedCache)
    view = (cache_lib.paged_step_view(cache, backend=strategy.backend)
            if paged else cache)
    page_table = cache.page_table if paged else None

    if not strategy.uses_cache or not view:
        h, _, _ = transformer.forward_hidden(params, cfg, h,
                                             kv_len=state.kv_len)
        new_cache = cache
    else:
        h, new_view, _ = spa_layer.spa_forward(
            params, cfg, view, h, spa_proxies=spa_proxies,
            scores_override=scores_override,
            changed_idx=state.committed, strategy=strategy,
            page_table=page_table, kv_len=state.kv_len)
        new_cache = (cache_lib.paged_step_commit(
            cache, new_view, backend=strategy.backend)
            if paged else new_view)

    # Candidate-limited logit evaluation + commit.
    cand_idx, is_masked = _candidate_positions(
        tokens, mask_id, settings.n_candidates, state.active)
    h_cand = selection.gather_rows(h, cand_idx + offset)
    logits = transformer.logits_from_hidden(params, cfg, h_cand)
    # the model must never commit the [MASK] token itself
    logits = logits.at[..., mask_id].set(-jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    conf = jnp.max(probs, axis=-1)                   # [B, n_cand]
    pred = jnp.argmax(probs, axis=-1).astype(tokens.dtype)

    cand_is_masked = selection.gather_rows(
        is_masked[..., None], cand_idx)[..., 0]
    conf = jnp.where(cand_is_masked, conf, -jnp.inf)

    # Commit decision is the scheduler's (dlm/scheduler.py).  The rng
    # chain lives in DecodeState so stochastic schedules replay exactly
    # in both the host loop and run_compiled's while_loop.
    rng_next, step_rng = state.rng, None
    if scheduler.uses_rng:
        assert state.rng is not None, \
            f"scheduler {scheduler.name!r} needs an rng: pass rng= to " \
            "DecodeSession.prefill()/attach()"
        rng_next, step_rng = jax.random.split(state.rng)
    active = state.active if state.active is not None \
        else jnp.ones_like(tokens, bool)
    view = CommitView(
        logits=logits, conf=conf, pred=pred, cand_idx=cand_idx,
        cand_open=cand_is_masked, open_mask=is_masked, active=active,
        rng=step_rng)
    commit, pred = scheduler.select_commits(view)
    commit = jnp.logical_and(commit, cand_is_masked)

    new_vals = jnp.where(commit, pred, selection.gather_rows(
        tokens[..., None], cand_idx)[..., 0])
    new_tokens = selection.scatter_rows(
        tokens[..., None], cand_idx, new_vals[..., None])[..., 0]

    committed_pos = jnp.where(commit, cand_idx, -1)
    ring = settings.commit_ring
    _, order = jax.lax.top_k(committed_pos.astype(jnp.float32),
                             min(ring, committed_pos.shape[-1]))
    committed = jnp.take_along_axis(committed_pos, order, axis=-1)
    if committed.shape[-1] < ring:
        committed = jnp.pad(committed, ((0, 0),
                                        (0, ring - committed.shape[-1])),
                            constant_values=-1)

    n_committed = jnp.sum(commit, axis=-1)
    new_state = DecodeState(
        tokens=new_tokens, cache=new_cache, step=state.step + 1,
        committed=committed,
        n_masked=state.n_masked - n_committed,
        active=state.active, extras=state.extras, rng=rng_next,
        kv_len=state.kv_len)
    info = {"n_committed": n_committed,
            "mean_conf": jnp.mean(jnp.where(jnp.isfinite(conf), conf, 0.0)),
            # per-row finiteness of this step's hidden states, consumed
            # by the supervisor's NaN/Inf canvas guard (DESIGN.md §10).
            # Only meaningful for rows with a live request: released /
            # inactive rows legitimately go non-finite under fully
            # masked attention.
            "row_finite": jnp.all(jnp.isfinite(h), axis=(1, 2))}
    return new_state, info


# ---------------------------------------------------------------------------
# Compatibility wrappers over DecodeSession
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ModelConfig, params: Params, prompt: jax.Array,
                      gen_len: int, spa_proxies=None,
                      use_cache: bool = True,
                      strategy: Optional[CacheStrategy] = None,
                      settings: Optional[DecodeSettings] = None
                      ) -> DecodeState:
    """Deprecated: use ``DecodeSession.prefill``; kept for old callers."""
    from repro.dlm.session import DecodeSession
    sess = DecodeSession(params, cfg, strategy=strategy, settings=settings,
                         spa_proxies=spa_proxies)
    return sess.prefill(prompt, gen_len, use_cache=use_cache)


def decode(params: Params, cfg: ModelConfig, prompt: jax.Array,
           gen_len: int, settings: Optional[DecodeSettings] = None,
           spa_proxies=None, max_steps: Optional[int] = None,
           strategy: Optional[CacheStrategy] = None,
           scheduler: Optional[UnmaskScheduler] = None,
           rng: Optional[jax.Array] = None
           ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Run the unmasking loop until every slot is committed.

    Deprecated signature-compatible wrapper over ``DecodeSession``."""
    from repro.dlm.session import DecodeSession
    sess = DecodeSession(params, cfg, strategy=strategy, settings=settings,
                         spa_proxies=spa_proxies, scheduler=scheduler)
    sess.prefill(prompt, gen_len, rng=rng)
    return sess.run(max_steps)


def decode_semi_ar(params: Params, cfg: ModelConfig, prompt: jax.Array,
                   gen_len: int, block_len: int = 8,
                   settings: Optional[DecodeSettings] = None,
                   spa_proxies=None,
                   strategy: Optional[CacheStrategy] = None,
                   scheduler: Optional[UnmaskScheduler] = None,
                   rng: Optional[jax.Array] = None):
    """Block-wise semi-AR decoding (Wu et al. 2025: Fast-dLLM; Ma et al.
    2025 family): the canvas is unmasked block-by-block left-to-right;
    within the active block tokens commit per the scheduler (confidence
    by default, optionally in parallel). Positions outside the active
    block are excluded through the session's active-position mask — the
    restrictive trade-off the paper contrasts with SPA-Cache's
    arbitrary-order updates (§2.2).  ``BlockScheduler`` expresses the
    same schedule as data inside the step (no host loop, compatible
    with ``run_compiled``); this wrapper keeps the host ``run_blocks``
    path, which additionally refreshes caches at block boundaries.

    Deprecated signature-compatible wrapper over
    ``DecodeSession.run_blocks``."""
    from repro.dlm.session import DecodeSession
    sess = DecodeSession(params, cfg, strategy=strategy, settings=settings,
                         spa_proxies=spa_proxies, scheduler=scheduler)
    sess.prefill(prompt, gen_len, rng=rng)
    return sess.run_blocks(block_len)

"""DLM iterative-unmasking decoding with SPA-Cache.

  prefill    — full forward over the canvas that populates all layer caches
               (K, V, H^c, identifier vectors).
  serve_step — ONE diffusion refinement step: SPA sparse layer updates,
               candidate-limited logit evaluation, confidence-based commit
               of >= 1 token (parallel decoding commits every candidate
               above the confidence threshold — Fast-dLLM style).
  decode     — the step loop (jitted per-step), plus baseline strategies:
               vanilla (no cache), dllm_cache (value proxy, uniform rho,
               optional refresh), dkv_window (locality heuristic).

Candidate-limited logits: computing lm-head logits over the full 32k/500k
canvas each step would dominate all other costs, so logits are evaluated
only at ``n_candidates`` masked positions per step (a serving design
choice documented in DESIGN.md).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTENTION_KINDS, ModelConfig
from repro.core import cache as cache_lib
from repro.core import identifiers, selection, spa_layer
from repro.core.cache import CachePolicy
from repro.models import common, transformer

Params = Dict[str, Any]


class DecodeState(NamedTuple):
    tokens: jax.Array            # [B, N_text] canvas (mask_id at open slots)
    cache: Any                   # {kind: {name: [Lk,B,N,...]}}
    step: jax.Array              # scalar int32
    committed: jax.Array         # [B, C] recently committed positions (-1 pad)
    n_masked: jax.Array          # [B] remaining masked counts
    extras: Dict[str, jax.Array] = {}   # modality stubs (VLM patches)


@dataclasses.dataclass(frozen=True)
class DecodeSettings:
    n_candidates: int = 64
    parallel_threshold: float = 0.0   # 0 = commit exactly 1 token / step
    max_parallel: int = 0             # cap on tokens committed per step
    refresh_interval: int = 0         # rebuild cache every R steps
    commit_ring: int = 8              # size of "recently committed" buffer


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------

def prefill(params: Params, cfg: ModelConfig, inputs: Dict[str, jax.Array],
            spa_proxies=None) -> Tuple[jax.Array, Any]:
    """Full forward building the SPA caches. Returns (h_final, cache)."""
    policy = CachePolicy.from_config(cfg)
    h = transformer.embed_inputs(params, cfg, inputs)
    h, _, raw = transformer.forward_hidden(
        params, cfg, h, collect_cache=True, spa_proxies=spa_proxies)
    cache = {}
    for kind, entries in (raw or {}).items():
        out: Dict[str, jax.Array] = {}
        if policy.quantized:
            out["k"], out["k_scale"] = cache_lib.quantize_rows(entries["k"])
            out["v"], out["v_scale"] = cache_lib.quantize_rows(entries["v"])
            out["h"], out["h_scale"] = cache_lib.quantize_rows(entries["h"])
        else:
            cd = policy.compute_dtype
            out["k"] = entries["k"].astype(cd)
            out["v"] = entries["v"].astype(cd)
            out["h"] = entries["h"].astype(cd)
        if "proxy" in entries:
            out["proxy"] = entries["proxy"].astype(policy.compute_dtype)
            if cfg.spa.incremental_ident:
                out["proxy_now"] = out["proxy"]
        cache[kind] = out
    return h, cache


# ---------------------------------------------------------------------------
# Serve step
# ---------------------------------------------------------------------------

def _candidate_positions(tokens: jax.Array, mask_id: int,
                         n_cand: int) -> jax.Array:
    """First n_cand masked positions per row (static shape)."""
    b, n = tokens.shape
    is_masked = tokens == mask_id
    score = jnp.where(is_masked, -jnp.arange(n)[None, :].astype(jnp.float32),
                      -jnp.inf)
    _, idx = jax.lax.top_k(score, min(n_cand, n))
    return jnp.sort(idx, axis=-1).astype(jnp.int32), is_masked


def serve_step(params: Params, cfg: ModelConfig, state: DecodeState,
               settings: DecodeSettings, spa_proxies=None
               ) -> Tuple[DecodeState, Dict[str, jax.Array]]:
    """One SPA-Cache diffusion refinement step."""
    tokens, cache = state.tokens, state.cache
    b = tokens.shape[0]
    mask_id = cfg.mask_id

    inputs = dict(state.extras)
    inputs["tokens"] = tokens
    h = transformer.embed_inputs(params, cfg, inputs)
    n = h.shape[1]                     # full canvas (incl. patch tokens)
    offset = n - tokens.shape[1]       # VLM: text starts after patches
    # sequence-parallel residual stream (sets the layer-scan carry
    # sharding; see spa_layer h_out hint). Measured best for SSM archs
    # too (EXPERIMENTS.md §Perf: mamba2 with replicated weights +
    # sequence sharding is 2.3x over the TP baseline and fits HBM,
    # whereas dropping the sharding trades 44 GB of replicated scan
    # buffers for zero collectives).
    from repro.distributed.hints import shard_hint
    n_spec = ("pod", "data", "model") if b == 1 else "model"
    h = shard_hint(h, None if b == 1 else "batch", n_spec, None)

    scores_override = None
    if cfg.spa.identifier == "window":
        scores_override = identifiers.locality_scores(
            n, state.committed + offset, cfg.spa.locality_window)

    if cfg.spa.identifier == "none" or not cache:
        h, _, _ = transformer.forward_hidden(params, cfg, h)
        new_cache = cache
    else:
        h, new_cache, _ = spa_layer.spa_forward(
            params, cfg, cache, h, spa_proxies=spa_proxies,
            scores_override=scores_override,
            changed_idx=state.committed)

    # Candidate-limited logit evaluation + commit.
    cand_idx, is_masked = _candidate_positions(
        tokens, mask_id, settings.n_candidates)
    h_cand = selection.gather_rows(h, cand_idx + offset)
    logits = transformer.logits_from_hidden(params, cfg, h_cand)
    # the model must never commit the [MASK] token itself
    logits = logits.at[..., mask_id].set(-jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    conf = jnp.max(probs, axis=-1)                   # [B, n_cand]
    pred = jnp.argmax(probs, axis=-1).astype(tokens.dtype)

    cand_is_masked = selection.gather_rows(
        is_masked[..., None], cand_idx)[..., 0]
    conf = jnp.where(cand_is_masked, conf, -jnp.inf)

    best = jnp.argmax(conf, axis=-1)                 # [B]
    commit = jax.nn.one_hot(best, conf.shape[-1], dtype=bool)
    if settings.parallel_threshold > 0.0:
        par = conf > settings.parallel_threshold
        if settings.max_parallel > 0:
            _, topp = jax.lax.top_k(conf, min(settings.max_parallel,
                                              conf.shape[-1]))
            in_top = jnp.zeros_like(par).at[
                jnp.arange(b)[:, None], topp].set(True)
            par = jnp.logical_and(par, in_top)
        commit = jnp.logical_or(commit, par)
    commit = jnp.logical_and(commit, cand_is_masked)

    new_vals = jnp.where(commit, pred, selection.gather_rows(
        tokens[..., None], cand_idx)[..., 0])
    new_tokens = selection.scatter_rows(
        tokens[..., None], cand_idx, new_vals[..., None])[..., 0]

    committed_pos = jnp.where(commit, cand_idx, -1)
    ring = settings.commit_ring
    _, order = jax.lax.top_k(committed_pos.astype(jnp.float32),
                             min(ring, committed_pos.shape[-1]))
    committed = jnp.take_along_axis(committed_pos, order, axis=-1)
    if committed.shape[-1] < ring:
        committed = jnp.pad(committed, ((0, 0),
                                        (0, ring - committed.shape[-1])),
                            constant_values=-1)

    n_committed = jnp.sum(commit, axis=-1)
    new_state = DecodeState(
        tokens=new_tokens, cache=new_cache, step=state.step + 1,
        committed=committed,
        n_masked=state.n_masked - n_committed)
    info = {"n_committed": n_committed,
            "mean_conf": jnp.mean(jnp.where(jnp.isfinite(conf), conf, 0.0))}
    return new_state, info


# ---------------------------------------------------------------------------
# Decode loop (host-side loop; step is jitted once)
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ModelConfig, params: Params, prompt: jax.Array,
                      gen_len: int, spa_proxies=None,
                      use_cache: bool = True) -> DecodeState:
    from repro.dlm.noise import mask_canvas
    if spa_proxies is None and cfg.spa.identifier == "singular":
        spa_proxies = spa_layer.build_spa_proxies(params, cfg)
    canvas = mask_canvas(prompt, gen_len, cfg.mask_id)
    b, n = canvas.shape
    if use_cache and cfg.spa.identifier != "none":
        _, cache = prefill(params, cfg, {"tokens": canvas}, spa_proxies)
    else:
        cache = {}
    return DecodeState(
        tokens=canvas, cache=cache, step=jnp.zeros((), jnp.int32),
        committed=jnp.full((b, 8), -1, jnp.int32),
        n_masked=jnp.full((b,), gen_len, jnp.int32), extras={})


def decode(params: Params, cfg: ModelConfig, prompt: jax.Array,
           gen_len: int, settings: Optional[DecodeSettings] = None,
           spa_proxies=None, max_steps: Optional[int] = None
           ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Run the unmasking loop until every slot is committed."""
    settings = settings or DecodeSettings()
    if spa_proxies is None and cfg.spa.identifier == "singular":
        spa_proxies = spa_layer.build_spa_proxies(params, cfg)
    state = init_decode_state(cfg, params, prompt, gen_len, spa_proxies,
                              use_cache=cfg.spa.identifier != "none")
    step_fn = jax.jit(functools.partial(
        serve_step, params, cfg, settings=settings,
        spa_proxies=spa_proxies))
    max_steps = max_steps or gen_len + 4
    total_steps = 0
    for _ in range(max_steps):
        if cfg.spa.refresh_interval and total_steps and \
                total_steps % cfg.spa.refresh_interval == 0:
            _, cache = prefill(params, cfg, {"tokens": state.tokens},
                               spa_proxies)
            state = state._replace(cache=cache)
        state, info = step_fn(state)
        total_steps += 1
        if int(jax.device_get(jnp.max(state.n_masked))) <= 0:
            break
    return state.tokens, {"steps": total_steps}


# ---------------------------------------------------------------------------
# Semi-autoregressive block decoding (Fast-dLLM / block-diffusion baseline)
# ---------------------------------------------------------------------------

def decode_semi_ar(params: Params, cfg: ModelConfig, prompt: jax.Array,
                   gen_len: int, block_len: int = 8,
                   settings: Optional[DecodeSettings] = None,
                   spa_proxies=None):
    """Block-wise semi-AR decoding (Wu et al. 2025: Fast-dLLM; Ma et al.
    2025 family): the canvas is unmasked block-by-block left-to-right;
    within the active block tokens commit by confidence (optionally in
    parallel). Positions outside the active block are masked out of the
    candidate set, which is the restrictive trade-off the paper contrasts
    with SPA-Cache's arbitrary-order updates (§2.2).

    Composable with the SPA cache: each block decode runs serve_step with
    candidates restricted via the committed-ring locality of the block.
    """
    settings = settings or DecodeSettings()
    if spa_proxies is None and cfg.spa.identifier == "singular":
        spa_proxies = spa_layer.build_spa_proxies(params, cfg)
    from repro.dlm.noise import mask_canvas
    p_len = prompt.shape[1]
    canvas = mask_canvas(prompt, gen_len, cfg.mask_id)
    b = canvas.shape[0]
    total_steps = 0
    for block_start in range(p_len, p_len + gen_len, block_len):
        block_end = min(block_start + block_len, p_len + gen_len)
        # freeze positions outside the active block with a temp token,
        # restore after the block finishes
        frozen = canvas[:, block_end:]
        work = canvas.at[:, block_end:].set(0)
        use_cache = cfg.spa.identifier != "none"
        if use_cache:
            _, cache = prefill(params, cfg, {"tokens": work}, spa_proxies)
        else:
            cache = {}
        state = DecodeState(
            tokens=work, cache=cache, step=jnp.zeros((), jnp.int32),
            committed=jnp.full((b, 8), -1, jnp.int32),
            n_masked=jnp.full((b,), block_end - block_start, jnp.int32),
            extras={})
        step_fn = jax.jit(functools.partial(
            serve_step, params, cfg, settings=settings,
            spa_proxies=spa_proxies))
        for _ in range(2 * block_len):
            state, _ = step_fn(state)
            total_steps += 1
            if int(jax.device_get(jnp.max(state.n_masked))) <= 0:
                break
        canvas = state.tokens.at[:, block_end:].set(frozen)
    return canvas, {"steps": total_steps}

"""Masked-diffusion training objective (LLaDA style) with chunked CE.

The lm-head logits over a 256k vocab at 4k x 256 tokens are ~TB-scale in
f32, so the cross-entropy is computed in sequence chunks inside a
``lax.scan`` — only [B, chunk, V] is ever materialized (the backward pass
recomputes per chunk under remat).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common, transformer


def _chunk_size(cfg: ModelConfig, n: int) -> int:
    # Keep chunk * V bounded (~16M elements) so [B, chunk, V] f32 stays
    # well under HBM even at B_local ~ 16.
    target = max(64, int(2 ** 24 // max(cfg.vocab_size, 1)))
    c = min(n, target)
    while n % c:
        c -= 1
    return max(c, 1)


def chunked_token_nll(params, cfg: ModelConfig, h: jax.Array,
                      targets: jax.Array) -> jax.Array:
    """-log p(target) per token from final hidden states, chunked over N.

    h: [B, N, d]; targets: [B, N] -> nll [B, N] (f32).
    """
    b, n, d = h.shape
    c = _chunk_size(cfg, n)
    nc = n // c
    h_n = common.rms_norm(h, params["final_norm"], cfg.norm_eps)
    table = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])

    hc = h_n.reshape(b, nc, c, d)
    tc = targets.reshape(b, nc, c)

    @jax.checkpoint
    def _chunk_nll(h_i, t_i):
        logits = (h_i @ table).astype(jnp.float32)
        if cfg.logit_softcap > 0:
            logits = common.softcap(logits, cfg.logit_softcap)
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.take_along_axis(logp, t_i[..., None], axis=-1)[..., 0]

    def body(_, xs):
        h_i, t_i = xs                       # [B,c,d], [B,c]
        return None, _chunk_nll(h_i, t_i)

    _, nll = jax.lax.scan(
        body, None, (jnp.moveaxis(hc, 1, 0), jnp.moveaxis(tc, 1, 0)))
    return jnp.moveaxis(nll, 0, 1).reshape(b, n)


def diffusion_loss(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
                   rng: jax.Array) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """batch: {"tokens": [B,T]} (plus modality stubs). Returns
    (loss, metrics). LLaDA ELBO: mean_b [(1/t_b) sum_masked nll / T]."""
    from repro.dlm.noise import sample_masking
    tokens = batch["tokens"]
    b, n = tokens.shape
    noisy, mask, t = sample_masking(rng, tokens, cfg.mask_id)
    inputs = dict(batch)
    inputs["tokens"] = noisy

    h = transformer.embed_inputs(params, cfg, inputs)
    h, aux, _ = transformer.forward_hidden(params, cfg, h)
    if cfg.frontend == "vision":
        f = batch["patches"].shape[1]
        h = h[:, f:]
    nll = chunked_token_nll(params, cfg, h, tokens)
    per_tok = nll * mask.astype(jnp.float32)
    per_ex = jnp.sum(per_tok, axis=-1) / (jnp.maximum(t, 1e-3) * n)
    ce = jnp.mean(per_ex)

    total = ce + (cfg.moe.router_aux_weight * aux if cfg.moe else 0.0)
    metrics = {"loss": total, "ce": ce, "aux": aux,
               "mask_frac": jnp.mean(mask.astype(jnp.float32))}
    return total, metrics


def encoder_loss(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
                 rng: jax.Array) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """HuBERT-style masked-frame cluster prediction for encoder-only."""
    frames = batch["frames"]
    targets = batch["targets"]          # [B,T] cluster ids
    b, n, _ = frames.shape
    k_m, _ = jax.random.split(rng)
    mask = jax.random.uniform(k_m, (b, n)) < 0.3
    frames = jnp.where(mask[..., None], 0.0, frames)
    h = transformer.embed_inputs(params, cfg, {"frames": frames})
    h, aux, _ = transformer.forward_hidden(params, cfg, h)
    nll = chunked_token_nll(params, cfg, h, targets)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"loss": loss, "aux": aux,
                  "mask_frac": jnp.mean(mask.astype(jnp.float32))}

"""DecodeSession — the ONE decode loop (DESIGN.md §3).

Every decode surface in the repo (``decode``, ``decode_semi_ar``, the
benchmark timing loops, ``ServingEngine``) used to hand-roll its own
prefill + ``jax.jit(serve_step)`` + refresh loop.  ``DecodeSession``
owns all of it:

  * the canvas (tokens + active-position mask + masked counts),
  * the strategy cache and its lifecycle (prefill / periodic refresh),
  * the jitted step function (compiled once per
    (strategy, settings, scheduler) — the strategy's ``KernelBackend``
    (``backend=`` here, "xla" or "pallas") is part of that key),
  * the commit policy — an ``UnmaskScheduler`` (dlm/scheduler.py);
    legacy ``DecodeSettings.parallel_threshold`` resolves to one,
  * row-granular state surgery for continuous batching
    (``replace_rows`` — swap a finished request's slot for a queued one
    without touching sibling rows).

Refresh has ONE source of truth here: ``settings.refresh_interval`` > 0
wins, 0 falls back to the strategy's own ``refresh_interval`` default
(which ``strategy_from_spec`` lifts from ``cfg.spa.refresh_interval``),
and -1 explicitly disables refresh.

Two run modes with byte-identical outputs (asserted per scheduler in
``tests/test_scheduler.py``):

  * ``run()``        — host loop: one jitted step per iteration, a host
                       sync on ``n_masked`` per step; supports
                       streaming ``events()`` and mid-loop row surgery.
  * ``run_compiled()`` — the WHOLE loop as a single ``jax.lax.while_loop``
                       (periodic refresh folded in via ``lax.cond``):
                       no per-step dispatch, no host syncs until the
                       loop exits.  The serving hot path.

Typical use::

    sess = DecodeSession(params, cfg, strategy=SPACache(rank=16),
                         scheduler=ParallelThresholdScheduler(0.1))
    sess.prefill(prompt, gen_len)
    tokens, info = sess.run_compiled()
    # or streaming (host loop):
    for event in sess.events():
        print(event.step, event.n_committed)
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import cache as cache_lib
from repro.core import runtime
from repro.core.cache import PagedCache
from repro.core.strategy import CacheStrategy, resolve_strategy
from repro.dlm import decoding
from repro.dlm.decoding import DecodeSettings, DecodeState
from repro.dlm.scheduler import UnmaskScheduler, resolve_scheduler

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class SharedPrefix:
    """One batch row's shared-prefix attachment (DESIGN.md §6).

    ``pages``: physical pages (from the prefix index) mapped read-only
    at the row's logical pages [0, len(pages)); ``reserve``: the row's
    own private pages of the same count.  The session runs its prefill
    reads (and the partial prefill of the unmatched suffix) against
    ``pages``, then copies them into ``reserve`` and patches the page
    table immediately before its first cache write — commits never
    mutate another reader's view (copy-on-write, tests/test_prefix.py).
    """
    row: int
    pages: Tuple[int, ...]
    reserve: Tuple[int, ...]

    def __post_init__(self):
        assert len(self.pages) == len(self.reserve)


@dataclasses.dataclass(frozen=True)
class StepEvent:
    """One refinement step's outcome, for the streaming iterator."""
    step: int
    n_committed: np.ndarray      # [B] tokens committed this step
    committed: np.ndarray        # [B, ring] positions (-1 pad)
    done: bool
    refreshed: bool              # a full cache rebuild preceded this step
    # token VALUES at the committed ring positions (-1 at ring pads):
    # what a streaming consumer actually wants to print.  NOTE the ring
    # caps at ``settings.commit_ring`` positions per step — wide
    # parallel commits overflow it, so exact per-token streams should
    # diff ``tokens`` against the previous step instead (the serving
    # front-end does; DESIGN.md §8).
    committed_tokens: Optional[np.ndarray] = None
    tokens: Optional[np.ndarray] = None   # [B, N] full canvas snapshot


class DecodeSession:
    """Owns canvas, cache, jitted step, refresh and commit policy."""

    def __init__(self, params: Params, cfg: ModelConfig, *,
                 strategy: Optional[CacheStrategy] = None,
                 settings: Optional[DecodeSettings] = None,
                 scheduler: Optional[UnmaskScheduler] = None,
                 spa_proxies=None, backend=None,
                 profiler=None, label: str = ""):
        self.params = params
        self.cfg = cfg
        self.strategy = resolve_strategy(cfg, strategy)
        if backend is not None:
            # hot-path kernel dispatch (KernelBackend or "xla"/"pallas");
            # rides on the strategy so the jitted step/loop close over it
            # statically, exactly like the strategy and scheduler.
            self.strategy = self.strategy.with_backend(backend)
        self.settings = settings or DecodeSettings()
        self.scheduler = resolve_scheduler(self.settings, scheduler)
        # ONE source of truth for periodic refresh (see module docstring):
        # settings > 0 wins, 0 falls back to the strategy, -1 disables.
        ri = self.settings.refresh_interval
        self.refresh_interval = (0 if ri < 0
                                 else ri or self.strategy.refresh_interval)
        if spa_proxies is None:
            spa_proxies = self.strategy.build_proxies(params, cfg)
        self.spa_proxies = spa_proxies
        # step-time decomposition (DESIGN.md §12): a StepProfiler from
        # serving/profiling.py, or None (default — exact unprofiled
        # path).  ``label`` names this session's device track / lane
        # signature in traces and retrace accounting.
        self.profiler = profiler
        self.label = label or (
            f"{getattr(self.strategy, 'name', 'strategy')}"
            f"/{getattr(self.strategy.backend, 'name', 'backend')}")
        self._tracker = runtime.compile_tracker()
        self._step_fn = runtime.track_executables(jax.jit(
            self._tracker.wrap(
                functools.partial(
                    decoding.serve_step, params, cfg,
                    settings=self.settings, spa_proxies=spa_proxies,
                    strategy=self.strategy, scheduler=self.scheduler),
                name="serve_step", lane=self.label)))
        self._loop_fns: Dict[bool, Any] = {}   # run_compiled, by can_refresh
        self._partial_fns: Dict[int, Any] = {}  # prefill_partial, by s0
        # shared-prefix rows awaiting copy-on-write (DESIGN.md §6):
        # {batch row: SharedPrefix}; resolved before the first write
        self._shared_pending: Dict[int, SharedPrefix] = {}
        # called with the resolved specs right after a COW copy (the
        # engine releases its read holds on the shared pages here)
        self.cow_callback = None
        self.state: Optional[DecodeState] = None
        self.steps_taken = 0
        self.refresh_count = 0
        self._last_step_refreshed = False
        self._gen_span: Optional[Tuple[int, int]] = None  # semi-AR bounds
        # one host transfer of the canvas per step, shared by every
        # consumer (harvest, streaming diff, events()) — keyed on the
        # state object, which is replaced by each step/row surgery
        self._host_tokens: Optional[np.ndarray] = None
        self._host_tokens_for: Optional[DecodeState] = None
        # one-shot NaN fault payload armed by the engine's injector,
        # applied inside the next step() AFTER auto-refresh (§10)
        self._poison_pages: Optional[List[int]] = None
        # cache-dynamics telemetry (DESIGN.md §11): previous-step host
        # snapshots of the proxy identifier buffers + the previous
        # changed-row sets, diffed by cache_dynamics().  Host-side only
        # — never threaded into the jitted step.
        self._dyn_prev: Optional[Dict[str, np.ndarray]] = None
        self._dyn_prev_sel: Optional[Dict[str, List[set]]] = None

    # ------------------------------------------------------------------
    # State construction
    # ------------------------------------------------------------------

    def prefill(self, prompt: jax.Array, gen_len: int, *,
                use_cache: bool = True,
                extras: Optional[Dict[str, jax.Array]] = None,
                rng: Optional[jax.Array] = None,
                kv_len: Optional[jax.Array] = None,
                arenas=None,
                page_table: Optional[jax.Array] = None) -> DecodeState:
        """Build the canvas (prompt + gen_len [MASK] slots) and run the
        full prefill forward that populates the strategy's caches."""
        from repro.dlm.noise import mask_canvas
        canvas = mask_canvas(prompt, gen_len, self.cfg.mask_id)
        b, n = canvas.shape
        p_len = int(prompt.shape[1])
        active = jnp.zeros((b, n), bool).at[:, p_len:].set(True)
        n_masked = jnp.full((b,), gen_len, jnp.int32)
        state = self.attach(canvas, active=active, n_masked=n_masked,
                            extras=extras, use_cache=use_cache, rng=rng,
                            kv_len=kv_len, arenas=arenas,
                            page_table=page_table)
        self._gen_span = (p_len, p_len + gen_len)
        return state

    def attach(self, tokens: jax.Array, *,
               active: Optional[jax.Array] = None,
               n_masked: Optional[jax.Array] = None,
               extras: Optional[Dict[str, jax.Array]] = None,
               use_cache: bool = True,
               rng: Optional[jax.Array] = None,
               kv_len: Optional[jax.Array] = None,
               arenas=None,
               page_table: Optional[jax.Array] = None,
               shared: Optional[Sequence[SharedPrefix]] = None
               ) -> DecodeState:
        """Adopt an externally built canvas (serving engine path).

        Paged mode (DESIGN.md §5): pass pooled ``arenas``
        ({kind: {name: [Lk, P, page, ...]}}) plus a ``page_table``
        [B, n_log] — the prefilled dense cache is scattered into the
        arenas and the session's cache state becomes a
        :class:`~repro.core.cache.PagedCache`.  ``kv_len`` [B] marks each
        row's valid canvas length (shorter rows only own the pages that
        cover them; the tail aliases the zero page).

        ``shared`` (DESIGN.md §6): per-row shared-prefix attachments.
        A shared row's page-table prefix points at read-only pages from
        the prefix index; its prefill forward runs only over the
        unmatched suffix (``decoding.prefill_partial``) — or not at all
        when the whole row span is covered — and the shared pages are
        copied into the row's ``reserve`` pages right before the first
        cache write (copy-on-write)."""
        tokens = jnp.asarray(tokens)
        b = tokens.shape[0]
        if active is None:
            active = jnp.ones_like(tokens, bool)
        if n_masked is None:
            n_masked = jnp.sum(
                jnp.logical_and(tokens == self.cfg.mask_id, active),
                axis=-1).astype(jnp.int32)
        # fresh dict per state — never share or alias the caller's
        # (DecodeState's extras default used to be a shared {} literal).
        extras = dict(extras) if extras else {}
        if kv_len is not None:
            kv_len = jnp.asarray(kv_len, jnp.int32)
        self._shared_pending = {}
        if (shared and use_cache and self.strategy.uses_cache
                and arenas is not None):
            assert page_table is not None, "paged attach needs page_table"
            pt = jnp.asarray(page_table, jnp.int32)
            arenas = self._paged_fill(arenas, tokens, extras, kv_len,
                                      pt, shared)
            cache = cache_lib.PagedCache(arenas, pt)
            self._shared_pending = {s.row: s for s in shared}
        else:
            cache = (self._build_cache(tokens, extras, kv_len)
                     if use_cache else {})
            if arenas is not None and cache:
                assert page_table is not None, \
                    "paged attach needs page_table"
                cache = cache_lib.repage(
                    arenas, jnp.asarray(page_table, jnp.int32),
                    cache, self.strategy.backend)
        ring = self.settings.commit_ring
        self.state = DecodeState(
            tokens=tokens, cache=cache, step=jnp.zeros((), jnp.int32),
            committed=jnp.full((b, ring), -1, jnp.int32),
            n_masked=n_masked, active=active, extras=extras,
            rng=self._as_rng(rng), kv_len=kv_len)
        self.steps_taken = 0
        self.refresh_count = 0
        self._dyn_prev = None          # new canvas: old diffs meaningless
        self._dyn_prev_sel = None
        self._gen_span = None     # run_blocks needs a prefill()'d canvas
        return self.state

    def _as_rng(self, rng) -> Optional[jax.Array]:
        """Normalize the rng argument: ints become keys; stochastic
        schedulers get a default key so replay is seeded by default."""
        if rng is None:
            return (jax.random.PRNGKey(0) if self.scheduler.uses_rng
                    else None)
        if isinstance(rng, (int, np.integer)):
            return jax.random.PRNGKey(int(rng))
        return jnp.asarray(rng)

    def _build_cache(self, tokens, extras, kv_len=None):
        return self.strategy.refresh_cache(self.params, self.cfg, tokens,
                                           extras, self.spa_proxies,
                                           kv_len=kv_len)

    # ------------------------------------------------------------------
    # Shared-prefix attach + copy-on-write (DESIGN.md §6)
    # ------------------------------------------------------------------

    def _partial_fn(self, s0: int):
        """Jitted suffix-only prefill, one executable per suffix start
        (the engine's hit rows repeat the same few prompt layouts, so
        the compile amortizes like the lane step does)."""
        fn = self._partial_fns.get(s0)
        if fn is None:
            def run(inputs, kv_view, kv_len):
                return decoding.prefill_partial(
                    self.params, self.cfg, inputs, kv_view, s0,
                    kv_len=kv_len, spa_proxies=self.spa_proxies,
                    strategy=self.strategy)
            fn = runtime.track_executables(jax.jit(self._tracker.wrap(
                run, name="prefill_partial", lane=self.label)))
            self._partial_fns[s0] = fn
        return fn

    def _paged_fill(self, arenas, tokens, extras, kv_len, read_pt,
                    shared: Sequence[SharedPrefix]):
        """Prefill a (sub-)batch into pooled arenas, honouring shared
        prefixes: rows without a spec get the normal full prefill, rows
        with one run only the unmatched suffix (grouped by suffix
        start, one jitted partial prefill per group), and fully covered
        rows run nothing.  All scatters go through a WRITE page table
        whose shared prefix entries alias the zero page, so the shared
        pages are never written here — ``shared[i].row`` indexes into
        THIS sub-batch."""
        m, n = tokens.shape
        n_log = read_pt.shape[1]
        page = n // n_log
        spec_by_row = {s.row: s for s in shared}
        wt = np.asarray(read_pt).copy()
        for s in spec_by_row.values():
            wt[s.row, :len(s.pages)] = 0
        kv_np = (np.asarray(kv_len) if kv_len is not None
                 else np.full((m,), n, np.int32))
        groups: Dict[int, list] = {}
        for r in range(m):
            s = spec_by_row.get(r)
            s0 = len(s.pages) * page if s else 0
            if s is not None and s0 >= int(kv_np[r]):
                continue                     # full hit: states are there
            groups.setdefault(s0, []).append(r)
        from repro.kernels.backend import XLA_BACKEND
        tokens = jnp.asarray(tokens)
        for s0, rows in sorted(groups.items()):
            idx = jnp.asarray(rows, jnp.int32)
            sub_tokens = tokens[idx]
            sub_extras = {k: jnp.asarray(v)[idx]
                          for k, v in (extras or {}).items()}
            sub_kv = kv_len[idx] if kv_len is not None else None
            sub_wt = jnp.asarray(wt[rows], jnp.int32)
            if s0 == 0:
                fresh = self._build_cache(sub_tokens, sub_extras, sub_kv)
            else:
                sub_rt = jnp.asarray(read_pt)[idx]
                kv_view = {
                    kind: {nm: XLA_BACKEND.gather_pages(bufs[nm], sub_rt)
                           for nm in ("k", "v")}
                    for kind, bufs in arenas.items()}
                inputs = dict(sub_extras)
                inputs["tokens"] = sub_tokens
                fresh = self._partial_fn(s0)(inputs, kv_view, sub_kv)
            arenas = cache_lib.paged_from_dense(arenas, sub_wt, fresh,
                                                self.strategy.backend)
        return arenas

    def copy_cache_pages(self, src: Sequence[int],
                         dst: Sequence[int]) -> None:
        """Copy physical pages src[i] -> dst[i] in this session's paged
        cache (the engine's prefix-publication primitive: snapshot a
        row's prefill-time pages into index-owned pages BEFORE the first
        decode write evolves them)."""
        cache = self.state.cache
        assert isinstance(cache, PagedCache), "copy needs a paged cache"
        arenas = cache_lib.copy_arena_pages(cache.arenas, list(src),
                                            list(dst))
        self.state = self.state._replace(
            cache=PagedCache(arenas, cache.page_table))

    def read_cache_pages(self, pages: Sequence[int]):
        """Gather whole physical pages out of this session's LIVE paged
        arenas (the tier demotion read, DESIGN.md §9).  Mid-lane the
        pool's stored arenas are stale — the current values ride this
        session's step futures — so host-ward copies must come through
        here.  Returns device blocks {kind: {name: [Lk, n, page, ...]}}
        (callers ``np.asarray`` them, which syncs on the in-flight
        step)."""
        cache = self.state.cache
        assert isinstance(cache, PagedCache), "page read needs paging"
        return cache_lib.read_arena_pages(cache.arenas, list(pages))

    def write_cache_pages(self, pages: Sequence[int], blocks) -> None:
        """Scatter whole-page blocks into this session's LIVE paged
        arenas (the tier promotion write, §9).  The write is dispatched
        as an ``.at[].set`` on the step-future arenas, so it lands in
        dataflow order after the in-flight step without a host sync —
        which is what lets promotions overlap decode."""
        cache = self.state.cache
        assert isinstance(cache, PagedCache), "page write needs paging"
        arenas = cache_lib.write_arena_pages(cache.arenas, list(pages),
                                             blocks)
        self.state = self.state._replace(
            cache=PagedCache(arenas, cache.page_table))

    def cache_dynamics(self, max_rows: int = 2048
                       ) -> Optional[Dict[str, Any]]:
        """Host-side SPA cache-dynamics probe (DESIGN.md §11).

        Diffs the current ``proxy`` identifier buffers against the
        snapshot taken on the previous call; the rows whose proxies
        changed are exactly the rows the strategy selected AND committed
        that interval (``commit`` scatters the fresh proxy alongside the
        K/V rows), so the diff recovers — without touching the jitted
        step — per layer:

          * ``changed``: refreshed row count (→ budget utilization
            against ``k_schedule`` in the engine),
          * ``drift``: ``1 - cos(old_row, new_row)`` over the changed
            rows (the drift-score distribution the paper's adaptive
            budget responds to), sampled to ``max_rows`` rows,
          * ``overlap``: Jaccard overlap of this interval's changed-row
            set vs the previous one (selection stability).

        Returns None on the first call after ``attach`` (nothing to
        diff), for cache-less strategies, and when no proxy buffer
        exists.  Purely host-side: ``np.asarray`` reads sync on the
        in-flight step but never feed anything back, so decode outputs
        are byte-identical with sampling on (tests/test_telemetry.py).
        """
        if self.state is None:
            return None
        cache = self.state.cache
        bufs = cache.arenas if isinstance(cache, PagedCache) else cache
        if not isinstance(bufs, dict):
            return None
        cur: Dict[str, np.ndarray] = {}
        for kind, b in bufs.items():
            if isinstance(b, dict) and "proxy" in b:
                cur[kind] = np.asarray(b["proxy"])
        if not cur:
            return None
        prev, prev_sel = self._dyn_prev, self._dyn_prev_sel
        self._dyn_prev = cur
        if prev is None:
            return None
        out: Dict[str, Any] = {
            "refreshed": bool(self._last_step_refreshed), "kinds": {}}
        sel_now: Dict[str, List[set]] = {}
        for kind, now_arr in cur.items():
            p = prev.get(kind)
            if p is None or p.shape != now_arr.shape:
                continue
            n_layers = now_arr.shape[0]
            a = p.reshape(n_layers, -1, p.shape[-1])
            b2 = now_arr.reshape(n_layers, -1, now_arr.shape[-1])
            changed = np.any(a != b2, axis=-1)          # [L, rows]
            layers = []
            sel_now[kind] = []
            for l in range(n_layers):
                idx = np.nonzero(changed[l])[0]
                drift: List[float] = []
                if idx.size:
                    ii = idx[:max_rows]
                    va = a[l, ii].astype(np.float64)
                    vb = b2[l, ii].astype(np.float64)
                    denom = np.maximum(
                        np.linalg.norm(va, axis=-1)
                        * np.linalg.norm(vb, axis=-1), 1e-12)
                    cos = np.clip((va * vb).sum(-1) / denom, -1.0, 1.0)
                    drift = [float(x) for x in 1.0 - cos]
                cur_set = set(int(x) for x in idx)
                overlap = None
                if prev_sel is not None and kind in prev_sel \
                        and l < len(prev_sel[kind]):
                    ps = prev_sel[kind][l]
                    union = ps | cur_set
                    if union:
                        overlap = len(ps & cur_set) / len(union)
                layers.append({"changed": int(idx.size),
                               "rows": int(changed.shape[1]),
                               "drift": drift, "overlap": overlap})
                sel_now[kind].append(cur_set)
            out["kinds"][kind] = layers
        self._dyn_prev_sel = sel_now or prev_sel
        return out

    def poison_cache_pages(self, pages: Sequence[int]) -> None:
        """Overwrite the float buffers of physical ``pages`` with NaN —
        the ``step_nan`` fault payload (DESIGN.md §10).  The poisoned
        K/V entries propagate through the owning row's attention into
        its hidden states on the next step, where the supervisor's
        canvas guard catches them.  Integer buffers (page tables,
        identifier indices) are left intact: the fault models numeric
        bit-rot, not structural corruption."""
        blocks = self.read_cache_pages(pages)
        poisoned = {
            kind: {nm: (jnp.full_like(b, jnp.nan)
                        if jnp.issubdtype(b.dtype, jnp.floating) else b)
                   for nm, b in bufs.items()}
            for kind, bufs in blocks.items()}
        self.write_cache_pages(pages, poisoned)

    def poison_pages_after_refresh(self, pages: Sequence[int]) -> None:
        """Arm a one-shot :meth:`poison_cache_pages` applied inside the
        NEXT ``step()`` after its auto-refresh — so a
        ``refresh_interval=1`` strategy cannot heal the corruption
        before compute sees it (models bit-rot landing on the freshly
        rebuilt arena)."""
        self._poison_pages = list(pages)

    def _cow_if_shared(self) -> None:
        """Copy-on-write barrier: immediately before the first cache
        write (first step, compiled-loop entry, or an explicit refresh),
        copy every pending row's shared pages into its private reserve
        and patch the page table.  After this the shared pages are
        untouched forever — the other readers' (and the index's) view
        never changes."""
        if not self._shared_pending:
            return
        specs = list(self._shared_pending.values())
        self._shared_pending = {}
        cache = self.state.cache
        assert isinstance(cache, PagedCache), "shared rows need paging"
        src = [p for s in specs for p in s.pages]
        dst = [p for s in specs for p in s.reserve]
        arenas = cache_lib.copy_arena_pages(cache.arenas, src, dst)
        pt = cache.page_table
        for s in specs:
            pt = pt.at[s.row, :len(s.reserve)].set(
                jnp.asarray(s.reserve, jnp.int32))
        self.state = self.state._replace(cache=PagedCache(arenas, pt))
        if self.cow_callback is not None:
            self.cow_callback(specs)

    # ------------------------------------------------------------------
    # Stepping
    # ------------------------------------------------------------------

    def refresh(self) -> None:
        """Full cache rebuild from the current canvas.  A session running
        cache-less (``attach(use_cache=False)`` or ``NoCache``) never
        grows one — matching ``run_compiled``, whose carry structure is
        fixed at trace time.  Paged sessions rebuild dense and scatter
        back into their arenas (zero-page tails stay zero)."""
        if (not self.strategy.uses_cache or self.state is None
                or not self.state.cache):
            return
        self._cow_if_shared()     # the rebuild scatters into every page
        cache = self._build_cache(self.state.tokens, self.state.extras,
                                  self.state.kv_len)
        old = self.state.cache
        if isinstance(old, PagedCache):
            cache = cache_lib.repage(old.arenas, old.page_table, cache,
                                     self.strategy.backend)
        self.state = self.state._replace(cache=cache)
        self.refresh_count += 1

    def _maybe_refresh(self) -> bool:
        if (self.refresh_interval and self.steps_taken
                and self.steps_taken % self.refresh_interval == 0):
            before = self.refresh_count
            self.refresh()
            return self.refresh_count > before
        return False

    def step(self) -> Dict[str, jax.Array]:
        """One jitted refinement step (auto-refresh applied first).

        With a profiler attached and this step sampled, consecutive
        ``perf_counter`` fences decompose it into segments that TILE the
        step — ``refresh`` (COW + cache rebuild, synced), ``dispatch``
        (the jitted call returning futures) and ``device_wait`` (the
        sync on the step result) — so segment sums match the total
        (DESIGN.md §12).  The fences only add ``block_until_ready``:
        traced values are untouched, outputs stay byte-identical.
        """
        assert self.state is not None, "call prefill()/attach() first"
        prof = self.profiler
        if prof is not None and prof.should_sample(self.steps_taken):
            t0 = time.perf_counter()
            self._cow_if_shared()
            self._last_step_refreshed = self._maybe_refresh()
            if self._poison_pages:
                pages, self._poison_pages = self._poison_pages, None
                self.poison_cache_pages(pages)
            jax.block_until_ready(self.state)
            t1 = time.perf_counter()
            self.state, info = self._step_fn(self.state)
            t2 = time.perf_counter()
            jax.block_until_ready(self.state)
            t3 = time.perf_counter()
            self.steps_taken += 1
            prof.observe_step(self.label,
                              {"refresh": t1 - t0, "dispatch": t2 - t1,
                               "device_wait": t3 - t2}, t3 - t0)
            return info
        self._cow_if_shared()     # first write: un-share prefix pages
        self._last_step_refreshed = self._maybe_refresh()
        if self._poison_pages:
            pages, self._poison_pages = self._poison_pages, None
            self.poison_cache_pages(pages)
        self.state, info = self._step_fn(self.state)
        self.steps_taken += 1
        return info

    @property
    def done(self) -> bool:
        return int(jax.device_get(jnp.max(self.state.n_masked))) <= 0

    @property
    def tokens(self) -> jax.Array:
        return self.state.tokens

    def host_tokens(self) -> np.ndarray:
        """Host copy of the canvas, fetched AT MOST ONCE per state (the
        serving engine's per-step streaming diff and its harvest both
        read it; without the cache each would pay its own transfer)."""
        assert self.state is not None
        if self._host_tokens_for is not self.state:
            self._host_tokens = np.asarray(self.state.tokens)
            self._host_tokens_for = self.state
        return self._host_tokens

    def run(self, max_steps: Optional[int] = None
            ) -> Tuple[jax.Array, Dict[str, Any]]:
        """Step until every active slot is committed (or max_steps)."""
        assert self.state is not None, "call prefill()/attach() first"
        if max_steps is None:
            max_steps = int(jax.device_get(
                jnp.max(self.state.n_masked))) + 4
        n = 0
        for _ in range(max_steps):
            # check-first, like run_compiled's while_loop cond: an
            # already-finished session runs 0 steps in BOTH modes (and
            # never shifts the refresh cadence with no-commit steps)
            if self.done:
                break
            self.step()
            n += 1
        return self.state.tokens, {"steps": n,
                                   "refreshes": self.refresh_count}

    # ------------------------------------------------------------------
    # Device-resident loop
    # ------------------------------------------------------------------

    def run_compiled(self, max_steps: Optional[int] = None
                     ) -> Tuple[jax.Array, Dict[str, Any]]:
        """The whole decode loop as ONE ``jax.lax.while_loop``.

        Eliminates the per-step Python dispatch and the per-step host
        sync on ``n_masked`` that ``run()`` pays; periodic refresh is
        folded into the loop body via ``lax.cond`` on
        ``step % refresh_interval`` (same schedule as the host loop, so
        outputs are byte-identical — asserted per scheduler in
        ``tests/test_scheduler.py``).  ``max_steps`` is a dynamic
        argument: changing it never retraces.
        """
        assert self.state is not None, "call prefill()/attach() first"
        self._cow_if_shared()     # the loop body writes every page
        if max_steps is None:
            max_steps = int(jax.device_get(
                jnp.max(self.state.n_masked))) + 4
        can_refresh = bool(self.refresh_interval
                           and self.strategy.uses_cache
                           and self.state.cache)
        if can_refresh not in self._loop_fns:
            self._loop_fns[can_refresh] = self._build_loop_fn(can_refresh)
        prof = self.profiler
        t0 = time.perf_counter() if prof is not None else 0.0
        state, n_done, n_ref = self._loop_fns[can_refresh](
            self.state, jnp.asarray(max_steps, jnp.int32))
        self.state = state
        n_done = int(jax.device_get(n_done))
        n_ref = int(jax.device_get(n_ref))
        if prof is not None:
            # whole-loop timing only: inside the while_loop there is no
            # host boundary to fence, so phases are not attributable
            # here (DESIGN.md §12); the device_get above synced the run.
            prof.observe_loop(self.label, n_done,
                              time.perf_counter() - t0)
        self.steps_taken += n_done
        self.refresh_count += n_ref
        return state.tokens, {"steps": n_done,
                              "refreshes": self.refresh_count}

    def _build_loop_fn(self, can_refresh: bool):
        """while_loop(cond=open slots remain, body=maybe-refresh + step).

        The refresh branch reuses ``CacheStrategy.refresh_cache`` — the
        exact function the host loop calls — under a ``lax.cond`` on the
        step counter (``state.step`` == completed steps, so the rebuild
        lands before steps R, 2R, ... exactly like ``_maybe_refresh``).
        """
        step_fn = functools.partial(
            decoding.serve_step, self.params, self.cfg,
            settings=self.settings, spa_proxies=self.spa_proxies,
            strategy=self.strategy, scheduler=self.scheduler)
        interval = self.refresh_interval
        params, cfg = self.params, self.cfg
        strategy, proxies = self.strategy, self.spa_proxies

        def rebuilt(state: DecodeState) -> DecodeState:
            cache = strategy.refresh_cache(params, cfg, state.tokens,
                                           state.extras, proxies,
                                           kv_len=state.kv_len)
            if isinstance(state.cache, PagedCache):
                old = state.cache
                cache = cache_lib.repage(old.arenas, old.page_table,
                                         cache, strategy.backend)
            return state._replace(cache=cache)

        def loop(state0: DecodeState, max_steps: jax.Array):
            def cond(carry):
                state, n_done, _ = carry
                return jnp.logical_and(n_done < max_steps,
                                       jnp.max(state.n_masked) > 0)

            def body(carry):
                state, n_done, n_ref = carry
                if can_refresh:
                    do = jnp.logical_and(state.step > 0,
                                         state.step % interval == 0)
                    state = jax.lax.cond(do, rebuilt, lambda s: s, state)
                    n_ref = n_ref + do.astype(jnp.int32)
                state, _ = step_fn(state)
                return state, n_done + 1, n_ref

            zero = jnp.zeros((), jnp.int32)
            return jax.lax.while_loop(cond, body, (state0, zero, zero))

        return runtime.track_executables(jax.jit(self._tracker.wrap(
            loop, name="decode_loop", lane=self.label)))

    def events(self, max_steps: Optional[int] = None
               ) -> Iterator[StepEvent]:
        """Streaming iterator: yields a StepEvent after every step."""
        assert self.state is not None, "call prefill()/attach() first"
        if max_steps is None:
            max_steps = int(jax.device_get(
                jnp.max(self.state.n_masked))) + 4
        for _ in range(max_steps):
            info = self.step()
            done = self.done
            committed = np.asarray(self.state.committed)
            toks = self.host_tokens()
            ctoks = np.where(committed >= 0,
                             np.take_along_axis(
                                 toks, np.maximum(committed, 0), axis=-1),
                             -1).astype(np.int32)
            yield StepEvent(
                step=self.steps_taken,
                n_committed=np.asarray(info["n_committed"]),
                committed=committed,
                done=done, refreshed=self._last_step_refreshed,
                committed_tokens=ctoks, tokens=toks)
            if done:
                break

    # ------------------------------------------------------------------
    # Active-position control (semi-AR blocks, serving slots)
    # ------------------------------------------------------------------

    def set_active(self, active: jax.Array) -> None:
        """Replace the commit mask; recounts open slots from the canvas."""
        assert self.state is not None
        n_masked = jnp.sum(
            jnp.logical_and(self.state.tokens == self.cfg.mask_id, active),
            axis=-1).astype(jnp.int32)
        self.state = self.state._replace(active=active, n_masked=n_masked)

    def set_active_span(self, start: int, stop: int) -> None:
        b, n = self.state.tokens.shape
        active = jnp.zeros((b, n), bool).at[:, start:stop].set(True)
        self.set_active(active)

    def run_blocks(self, block_len: int,
                   max_steps_per_block: Optional[int] = None
                   ) -> Tuple[jax.Array, Dict[str, Any]]:
        """Semi-AR block schedule: activate ``block_len``-wide windows
        left-to-right over the generation span, refreshing the cache at
        each block boundary (the committed block changes every row's
        context)."""
        assert self._gen_span is not None, "run_blocks needs prefill()"
        start, stop = self._gen_span
        total = 0
        for blk_start in range(start, stop, block_len):
            blk_end = min(blk_start + block_len, stop)
            self.set_active_span(blk_start, blk_end)
            if blk_start > start:
                self.refresh()
            cap = max_steps_per_block or 2 * block_len
            _, info = self.run(max_steps=cap)
            total += info["steps"]
        self.set_active_span(start, stop)
        return self.state.tokens, {"steps": total,
                                   "refreshes": self.refresh_count}

    # ------------------------------------------------------------------
    # Row surgery (continuous batching)
    # ------------------------------------------------------------------

    def replace_rows(self, rows: Sequence[int], row_tokens: np.ndarray,
                     row_active: np.ndarray,
                     row_extras: Optional[Dict[str, np.ndarray]] = None,
                     row_kv_len: Optional[np.ndarray] = None,
                     row_page_table: Optional[np.ndarray] = None,
                     row_committed: Optional[np.ndarray] = None,
                     row_shared: Optional[Sequence[SharedPrefix]] = None
                     ) -> None:
        """Swap canvas rows in-place and re-prefill ONLY those rows.

        The fresh cache is computed with a prefill over just the swapped
        rows (prefill is row-independent, so the per-row results match a
        full static-batch prefill — asserted byte-for-byte by the
        continuous-batching parity test) and spliced into the running
        cache at those batch rows — sibling rows keep their evolved
        partially-updated caches.

        Paged sessions take ``row_page_table`` [n_swap, n_log] (the
        incoming requests' freshly allocated pages; tail entries 0) and
        ``row_kv_len`` [n_swap]: the sub-row prefill scatters into those
        pages, sibling rows' pages are untouched.  ``row_committed``
        restores a preempted request's commit ring (resume); default
        clears it.  ``row_shared`` (DESIGN.md §6) attaches shared
        prefix pages for incoming rows exactly like ``attach(shared=)``
        — specs carry BATCH row ids (members of ``rows``).
        """
        assert self.state is not None
        idx = jnp.asarray(list(rows), jnp.int32)
        row_tokens = jnp.asarray(row_tokens)
        tokens = self.state.tokens.at[idx].set(row_tokens)
        active = self.state.active.at[idx].set(jnp.asarray(row_active))
        extras = dict(self.state.extras)
        for k, v in (row_extras or {}).items():
            extras[k] = extras[k].at[idx].set(jnp.asarray(v))
        sub_extras = {k: v[idx] for k, v in extras.items()}
        n_masked = jnp.sum(
            jnp.logical_and(tokens == self.cfg.mask_id, active),
            axis=-1).astype(jnp.int32)
        if row_committed is not None:
            committed = self.state.committed.at[idx].set(
                jnp.asarray(row_committed, jnp.int32))
        else:
            committed = self.state.committed.at[idx].set(-1)
        kv_len = self.state.kv_len
        sub_kv = None
        if kv_len is not None:
            assert row_kv_len is not None, "paged session needs row_kv_len"
            sub_kv = jnp.asarray(row_kv_len, jnp.int32)
            kv_len = kv_len.at[idx].set(sub_kv)
        cache = self.state.cache
        rows_list = list(rows)
        for r in rows_list:      # replaced rows' pending shares lapse
            self._shared_pending.pop(r, None)
        if self.strategy.uses_cache and cache:
            if isinstance(cache, PagedCache):
                assert row_page_table is not None
                row_pt = jnp.asarray(row_page_table, jnp.int32)
                if row_shared:
                    sub_specs = [dataclasses.replace(
                        s, row=rows_list.index(s.row)) for s in row_shared]
                    arenas = self._paged_fill(
                        cache.arenas, row_tokens, sub_extras, sub_kv,
                        row_pt, sub_specs)
                    for s in row_shared:
                        self._shared_pending[s.row] = s
                else:
                    fresh = self._build_cache(row_tokens, sub_extras,
                                              sub_kv)
                    arenas = cache_lib.paged_from_dense(
                        cache.arenas, row_pt, fresh,
                        self.strategy.backend)
                cache = PagedCache(arenas,
                                   cache.page_table.at[idx].set(row_pt))
            else:
                fresh = self._build_cache(row_tokens, sub_extras, sub_kv)
                cache = jax.tree.map(
                    lambda old, new: old.at[:, idx].set(new), cache, fresh)
        self.state = self.state._replace(
            tokens=tokens, active=active, n_masked=n_masked,
            committed=committed, cache=cache, extras=extras,
            kv_len=kv_len)

    def deactivate_rows(self, rows: Sequence[int]) -> None:
        """Park finished slots with no replacement request."""
        assert self.state is not None
        idx = jnp.asarray(list(rows), jnp.int32)
        # before the first step the attach()-provided buffers may still
        # be host numpy (watchdog recovery can fire that early)
        active = jnp.asarray(self.state.active).at[idx].set(False)
        n_masked = jnp.asarray(self.state.n_masked).at[idx].set(0)
        self.state = self.state._replace(active=active, n_masked=n_masked)

    def release_rows(self, rows: Sequence[int]) -> None:
        """Release finished/preempted slots AND their pages: the rows'
        page-table entries drop to the zero page and kv_len to 0, so the
        physical pages can be handed to the next admitted request without
        this session ever reading them again (a zero-kv_len row is fully
        masked out of attention and selection)."""
        assert self.state is not None
        self.deactivate_rows(rows)
        for r in rows:           # released rows never COW (the engine
            self._shared_pending.pop(r, None)   # releases their holds)
        idx = jnp.asarray(list(rows), jnp.int32)
        kv_len = self.state.kv_len
        if kv_len is not None:
            kv_len = jnp.asarray(kv_len).at[idx].set(0)
        cache = self.state.cache
        if isinstance(cache, PagedCache):
            pt = jnp.asarray(cache.page_table).at[idx].set(0)
            cache = PagedCache(cache.arenas, pt)
        self.state = self.state._replace(cache=cache, kv_len=kv_len)

    def snapshot_rows(self, rows: Sequence[int]) -> Dict[str, np.ndarray]:
        """Host copies of per-row canvas state (preemption snapshot):
        tokens, active mask and the commit ring.  Enough to resume the
        request later via ``replace_rows`` — the cache itself is NOT
        saved (resume re-prefills, which for ring-preserving resumes is
        byte-identical to a periodic refresh at the resume step)."""
        assert self.state is not None
        idx = np.asarray(list(rows))
        return {
            "tokens": np.asarray(self.state.tokens)[idx],
            "active": np.asarray(self.state.active)[idx],
            "committed": np.asarray(self.state.committed)[idx],
        }

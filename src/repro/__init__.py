"""SPA-Cache: Singular Proxies for Adaptive Caching in Diffusion Language
Models — a production-grade JAX reproduction framework."""
__version__ = "1.0.0"

"""Async streaming serving front-end (DESIGN.md §8).

An asyncio layer over :class:`~repro.serving.engine.ServingEngine`
using ONLY stdlib primitives (``asyncio`` streams for HTTP, a
``threading.Thread`` for the engine).  Three layers:

  * **Engine thread** — the blocking decode loop
    (``ServingEngine.run_online``) runs on a dedicated thread.  The
    asyncio side never touches engine state directly: submissions and
    cancels ride the engine's thread-safe mailbox
    (``submit_threadsafe``/``cancel_threadsafe``), which the engine
    drains at its double-buffer overlap point — intake costs the
    serving loop nothing.
  * **Event bridge** — each request carries its own ``sink`` callback
    (attached BEFORE the engine can see the request, so no
    registration race).  The sink fires on the engine thread and
    trampolines every :class:`~repro.serving.engine.RequestEvent` onto
    the event loop with ``loop.call_soon_threadsafe`` into a
    per-request ``asyncio.Queue`` — ``generate()`` is just an async
    iterator over that queue.
  * **HTTP** — a deliberately tiny HTTP/1.1 server
    (``asyncio.start_server``): ``POST /generate`` streams
    newline-delimited JSON events (``Connection: close`` delimits the
    body; no chunked-encoding machinery), ``GET /stats`` returns an
    engine-stats snapshot.  A dropped client connection cancels the
    request — pages and prefix holds are released mid-decode.

In-process use (benchmarks, tests: no sockets)::

    front = AsyncFrontend(engine)
    async with front:                      # starts the engine thread
        async for ev in front.generate(prompt, gen_len=16, slo=slo):
            ...                            # ev.kind: token/done/...

Socket use: ``await front.start(serve_http=True)`` then point
``stream_request()`` (or ``examples/serve_stream.py``) at
``front.port``.
"""
from __future__ import annotations

import asyncio
import dataclasses
import json
import threading
from typing import AsyncIterator, Dict, Optional

import numpy as np

from repro.serving.engine import RequestEvent, ServingEngine
from repro.serving.slo import SLO
from repro.serving.telemetry import Histogram

_TERMINAL = ("done", "shed", "canceled", "aborted")


class AsyncFrontend:
    """Bridges asyncio clients onto a ServingEngine thread."""

    def __init__(self, engine: ServingEngine, *, host: str = "127.0.0.1",
                 port: int = 0, max_steps: int = 256,
                 idle_wait: float = 0.005, max_body: int = 1 << 20):
        self.engine = engine
        self.host = host
        self.port = port              # 0 = ephemeral; set after start()
        self.max_steps = max_steps
        self.idle_wait = idle_wait
        self.max_body = max_body      # request bodies past this → 413
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._server: Optional[asyncio.AbstractServer] = None

    # ------------------------------------------------------------------
    # Lifecycle
    # ------------------------------------------------------------------

    async def start(self, serve_http: bool = False) -> "AsyncFrontend":
        assert self._thread is None, "frontend already started"
        self._loop = asyncio.get_running_loop()
        self._stop.clear()
        self._thread = threading.Thread(
            target=self.engine.run_online,
            kwargs=dict(stop=self._stop, max_steps=self.max_steps,
                        idle_wait=self.idle_wait),
            name="serving-engine", daemon=True)
        self._thread.start()
        if serve_http:
            self._server = await asyncio.start_server(
                self._handle_conn, self.host, self.port)
            self.port = self._server.sockets[0].getsockname()[1]
        return self

    async def stop(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        self._stop.set()
        if self._thread is not None:
            # run_online wakes on its idle mailbox timeout
            await asyncio.get_running_loop().run_in_executor(
                None, self._thread.join)
            self._thread = None

    async def __aenter__(self) -> "AsyncFrontend":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # ------------------------------------------------------------------
    # Streaming generate
    # ------------------------------------------------------------------

    async def generate(self, prompt, gen_len: int, *,
                       priority: int = 0, slo: Optional[SLO] = None,
                       row_len: Optional[int] = None,
                       ) -> AsyncIterator[RequestEvent]:
        """Submit one request and yield its events ("token" batches,
        then exactly one terminal "done"/"shed"/"canceled").  Closing
        the iterator early (client gone) cancels the request on the
        engine."""
        assert self._loop is not None, "call start() first"
        q: asyncio.Queue = asyncio.Queue()
        loop = self._loop

        def sink(ev: RequestEvent) -> None:   # fires on engine thread
            loop.call_soon_threadsafe(q.put_nowait, ev)

        uid = self.engine.submit_threadsafe(
            np.asarray(prompt, np.int32), gen_len, priority=priority,
            slo=slo, row_len=row_len, stream=True, sink=sink)
        try:
            while True:
                ev = await q.get()
                yield ev
                if ev.kind in _TERMINAL:
                    return
        finally:
            # reached on early generator close / task cancellation too
            self.engine.cancel_threadsafe(uid)

    def stats_snapshot(self) -> Dict:
        """JSON-safe engine stats copy (reads race the engine thread
        benignly: ints and histogram appends under the GIL).  Scalar
        counters pass through; latency histograms (DESIGN.md §11)
        surface as their counts, with percentiles merged on top."""
        s = self.engine.stats
        pct = s.percentiles()
        out: Dict = {}
        for f in dataclasses.fields(s):
            v = getattr(s, f.name)
            if isinstance(v, (bool, int, float)):
                out[f.name] = v
            elif isinstance(v, Histogram):
                out[f"{f.name}_count"] = len(v)
        out.update(pct)
        out["queued"] = len(self.engine.queue)
        out["running"] = len(self.engine._running)
        return out

    # ------------------------------------------------------------------
    # Minimal HTTP/1.1 layer (stdlib streams only)
    # ------------------------------------------------------------------

    async def _handle_conn(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        try:
            request_line = (await reader.readline()).decode("latin1")
            if not request_line:
                return
            try:
                method, path, _ = request_line.split(None, 2)
            except ValueError:
                writer.write(_error_response(400, "malformed request line"))
                await writer.drain()
                return
            headers = {}
            while True:
                line = (await reader.readline()).decode("latin1").strip()
                if not line:
                    break
                k, _, v = line.partition(":")
                headers[k.strip().lower()] = v.strip()
            body = b""
            try:
                n = int(headers.get("content-length", 0) or 0)
            except ValueError:
                writer.write(_error_response(400, "bad Content-Length"))
                await writer.drain()
                return
            if n < 0 or n > self.max_body:
                # reject BEFORE reading: an oversized body never gets
                # buffered, it just costs the client its connection
                writer.write(_error_response(
                    413, f"body exceeds {self.max_body} bytes"))
                await writer.drain()
                return
            if n:
                body = await reader.readexactly(n)
            if method == "POST" and path == "/generate":
                await self._route_generate(writer, body)
            elif method == "GET" and path == "/stats":
                payload = json.dumps(self.stats_snapshot()).encode()
                writer.write(_response_head("application/json")
                             + payload)
                await writer.drain()
            elif method == "GET" and path == "/metrics":
                # Prometheus text exposition (DESIGN.md §11); the
                # registry collector reads live engine state under the
                # GIL, same benign race as /stats
                payload = self.engine.render_metrics().encode()
                writer.write(_response_head(
                    "text/plain; version=0.0.4; charset=utf-8")
                    + payload)
                await writer.drain()
            elif method == "GET" and path == "/debug/requests":
                payload = json.dumps(
                    self.engine.request_states()).encode()
                writer.write(_response_head("application/json")
                             + payload)
                await writer.drain()
            elif method == "GET" and path == "/debug/pool":
                # memory observability (DESIGN.md §12): pool/tier
                # occupancy, fragmentation, per-signature bytes
                payload = json.dumps(
                    self.engine.pool_debug_state()).encode()
                writer.write(_response_head("application/json")
                             + payload)
                await writer.drain()
            else:
                writer.write(b"HTTP/1.1 404 Not Found\r\n"
                             b"Connection: close\r\n\r\n")
                await writer.drain()
        except (asyncio.IncompleteReadError, ConnectionResetError,
                BrokenPipeError, ValueError):
            pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError):
                pass

    async def _route_generate(self, writer: asyncio.StreamWriter,
                              body: bytes) -> None:
        # validate EVERYTHING before the 200 head goes out — a bad
        # request must get a clean 4xx, never a half-written stream
        try:
            req = json.loads(body.decode())
            if not isinstance(req, dict):
                raise ValueError("body must be a JSON object")
            prompt = req["prompt"]
            if (not isinstance(prompt, list)
                    or not all(isinstance(t, int)
                               and not isinstance(t, bool)
                               for t in prompt)):
                raise ValueError("prompt must be a list of ints")
            gen_len = req["gen_len"]
            if (isinstance(gen_len, bool) or not isinstance(gen_len, int)
                    or gen_len <= 0):
                raise ValueError("gen_len must be a positive int")
            priority = int(req.get("priority", 0))
            row_len = req.get("row_len")
            if row_len is not None:
                row_len = int(row_len)
            slo = None
            if req.get("slo"):
                slo = SLO(
                    ttft=float(req["slo"].get("ttft", float("inf"))),
                    deadline=float(req["slo"].get("deadline",
                                                  float("inf"))))
        except (ValueError, KeyError, TypeError, AttributeError,
                UnicodeDecodeError) as e:
            writer.write(_error_response(400, f"bad request: {e}"))
            await writer.drain()
            return
        writer.write(_response_head("application/x-ndjson"))
        await writer.drain()
        agen = self.generate(prompt, gen_len, priority=priority,
                             slo=slo, row_len=row_len)
        try:
            # a dropped connection raises from drain(); the explicit
            # aclose() below (not GC) then cancels the request on the
            # engine
            async for ev in agen:
                writer.write(json.dumps(_event_json(ev)).encode()
                             + b"\n")
                await writer.drain()
        finally:
            await agen.aclose()


def _response_head(ctype: str) -> bytes:
    return (f"HTTP/1.1 200 OK\r\nContent-Type: {ctype}\r\n"
            f"Connection: close\r\n\r\n").encode()


def _error_response(status: int, msg: str) -> bytes:
    reason = {400: "Bad Request",
              413: "Payload Too Large"}.get(status, "Error")
    body = json.dumps({"error": msg}).encode()
    return (f"HTTP/1.1 {status} {reason}\r\n"
            f"Content-Type: application/json\r\n"
            f"Content-Length: {len(body)}\r\n"
            f"Connection: close\r\n\r\n").encode() + body


def _event_json(ev: RequestEvent) -> Dict:
    return {"kind": ev.kind, "uid": ev.uid, "step": ev.step,
            "ts": ev.ts, "positions": list(ev.positions),
            "tokens": list(ev.tokens)}


# ----------------------------------------------------------------------
# Client helpers (examples/serve_stream.py, launch/serve.py --serve)
# ----------------------------------------------------------------------

async def stream_request(host: str, port: int, prompt, gen_len: int, *,
                         priority: int = 0,
                         slo: Optional[Dict] = None) -> AsyncIterator[Dict]:
    """Stream one request against a running front-end over HTTP; yields
    decoded ndjson event dicts."""
    reader, writer = await asyncio.open_connection(host, port)
    payload = json.dumps({
        "prompt": [int(t) for t in np.asarray(prompt).reshape(-1)],
        "gen_len": gen_len, "priority": priority, "slo": slo,
    }).encode()
    writer.write((f"POST /generate HTTP/1.1\r\nHost: {host}\r\n"
                  f"Content-Type: application/json\r\n"
                  f"Content-Length: {len(payload)}\r\n"
                  f"Connection: close\r\n\r\n").encode() + payload)
    await writer.drain()
    try:
        # skip response headers
        while True:
            line = await reader.readline()
            if not line or line in (b"\r\n", b"\n"):
                break
        while True:
            line = await reader.readline()
            if not line:
                return
            line = line.strip()
            if line:
                yield json.loads(line.decode())
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionResetError, BrokenPipeError):
            pass


async def _fetch(host: str, port: int, path: str) -> bytes:
    reader, writer = await asyncio.open_connection(host, port)
    writer.write((f"GET {path} HTTP/1.1\r\nHost: {host}\r\n"
                  f"Connection: close\r\n\r\n").encode())
    await writer.drain()
    raw = await reader.read()
    writer.close()
    await writer.wait_closed()
    head, _, body = raw.partition(b"\r\n\r\n")
    return body


async def fetch_stats(host: str, port: int) -> Dict:
    return json.loads((await _fetch(host, port, "/stats")).decode())


async def fetch_metrics(host: str, port: int) -> str:
    """Raw Prometheus text from ``GET /metrics``."""
    return (await _fetch(host, port, "/metrics")).decode()


async def fetch_debug_requests(host: str, port: int) -> Dict:
    return json.loads(
        (await _fetch(host, port, "/debug/requests")).decode())


async def fetch_debug_pool(host: str, port: int) -> Dict:
    """Decoded JSON from ``GET /debug/pool`` (DESIGN.md §12)."""
    return json.loads(
        (await _fetch(host, port, "/debug/pool")).decode())

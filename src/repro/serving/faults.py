"""Deterministic fault injection for the serving runtime (DESIGN.md §10).

The engine's state machine (page refcounts, copy-on-write holds,
PROMOTING handshakes, preemption snapshots) is exactly the kind of
deeply stateful machinery where a transient fault — an alloc failure, a
corrupted host page, a NaN-poisoned step, a stuck lane — can silently
leak pages or wedge the loop.  This module provides the *injection*
half of the fault-tolerance story: a seeded :class:`FaultPlan` threaded
through the engine's seams, replayable bit-for-bit from its seed so
chaos runs are regression tests, not dice rolls.

Fault sites (one seam each in the engine/tier):

  ``pool_alloc``    page-pool allocation transiently fails (admission,
                    publication and promotion allocs all probe it); the
                    supervisor's bounded retry-with-backoff absorbs it.
  ``host_store``    the host tier refuses a demotion write (the victim
                    drops instead — the §9 graceful path).
  ``host_corrupt``  a freshly demoted host page is bit-flipped in place;
                    the checksum verification on promotion catches it
                    and the engine falls back to a cold prefill.
  ``step_nan``      one live row's cache pages are poisoned with NaN;
                    the next step's hidden states go non-finite and the
                    supervisor's canvas guard quarantines the row.
  ``lane_stall``    the lane's device step stops being dispatched
                    (sticky — models a hung device) until the
                    supervisor's virtual-clock watchdog force-preempts.
  ``disconnect``    every currently streaming request hangs up at once
                    (a mid-stream disconnect burst -> cancellation).

Determinism: every probe of site ``s`` draws from a counter-keyed hash
``crc32(f"{seed}:{s}:{k}")`` where ``k`` is the site's own probe
counter — no global RNG state, no wall clock.  Two engine runs that
make the same probe sequence (single-threaded, virtual clock) therefore
fire the same faults at the same sites, abort the same uids, and leave
the same survivors (``tests/test_faults.py`` asserts all three).
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List, Mapping, Optional, Tuple

import numpy as np

FAULT_SITES = ("pool_alloc", "host_store", "host_corrupt", "step_nan",
               "lane_stall", "disconnect")


def _hash01(seed: int, site: str, k: int) -> float:
    """Deterministic uniform [0, 1) draw for probe ``k`` of ``site`` —
    crc32 so it is stable across platforms and Python hash seeds."""
    return zlib.crc32(f"{seed}:{site}:{k}".encode()) / 2 ** 32


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A replayable chaos schedule.

    ``rates`` maps a fault site to its per-probe firing probability
    (what a storm uses); ``at`` maps a site to explicit probe indices
    that fire exactly once each (what targeted tests use).  A site may
    appear in both — either trigger fires it.  ``max_fires`` optionally
    caps the total fires per site, so a "burst" plan can inject a
    bounded storm and then go quiet (letting the degradation ladder
    walk back down).
    """
    seed: int = 0
    rates: Mapping[str, float] = dataclasses.field(default_factory=dict)
    at: Mapping[str, Tuple[int, ...]] = dataclasses.field(
        default_factory=dict)
    max_fires: Mapping[str, int] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        for m in (self.rates, self.at, self.max_fires):
            for site in m:
                if site not in FAULT_SITES:
                    raise ValueError(f"unknown fault site {site!r}; "
                                     f"known: {FAULT_SITES}")
        # freeze the mappings so a plan is hashable-by-value in spirit
        object.__setattr__(self, "rates", dict(self.rates))
        object.__setattr__(self, "at",
                           {s: tuple(v) for s, v in self.at.items()})
        object.__setattr__(self, "max_fires", dict(self.max_fires))


class FaultInjector:
    """Runtime state for one engine run under a :class:`FaultPlan`.

    The engine probes ``fire(site)`` at each seam; the injector keeps
    one monotone probe counter per site and a log of every fire
    ``(site, probe_index)`` — the log IS the replay fingerprint two
    runs under the same plan must share."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._probes: Dict[str, int] = {s: 0 for s in FAULT_SITES}
        self.fired: Dict[str, int] = {s: 0 for s in FAULT_SITES}
        self.log: List[Tuple[str, int]] = []
        # optional observer called with every logged (site, probe) —
        # the engine routes fires into the trace stream with the same
        # schema as the log, so trace and replay log diff line-for-line
        # (DESIGN.md §11)
        self.on_fire = None
        # sticky lane stalls: lane-key id -> True until the watchdog
        # clears it (models a device reset recovering the lane)
        self._stalled: Dict[object, bool] = {}

    # ---- probes ------------------------------------------------------

    def fire(self, site: str) -> bool:
        """One probe of ``site``; True when the plan says to inject."""
        k = self._probes[site]
        self._probes[site] = k + 1
        if self.fired[site] >= self.plan.max_fires.get(site, 1 << 30):
            return False
        hit = k in self.plan.at.get(site, ())
        rate = self.plan.rates.get(site, 0.0)
        if not hit and rate > 0.0:
            hit = _hash01(self.plan.seed, site, k) < rate
        if hit:
            self.fired[site] += 1
            self.log.append((site, k))
            if self.on_fire is not None:
                self.on_fire(site, k)
        return hit

    @property
    def total_fired(self) -> int:
        return sum(self.fired.values())

    # ---- lane stalls (sticky until watchdog recovery) ----------------

    def stall_lane(self, lane_id: object) -> bool:
        """Probe ``lane_stall`` for a running lane; once fired the lane
        stays stalled (every step skipped) until :meth:`clear_stall` —
        only the watchdog's forced preemption can recover it."""
        if self._stalled.get(lane_id):
            return True
        if self.fire("lane_stall"):
            self._stalled[lane_id] = True
            return True
        return False

    def clear_stall(self, lane_id: object) -> None:
        self._stalled.pop(lane_id, None)

    # ---- payloads ----------------------------------------------------

    def corrupt_array(self, a: np.ndarray) -> None:
        """Flip the first machine word of ``a`` in place — the minimal
        bit-rot a checksum must catch.  Deterministic (no randomness:
        the *site* of corruption is chosen by the probe counter)."""
        flat = a.reshape(-1).view(np.uint8)
        flat[: min(8, flat.size)] ^= 0xFF


def choose_index(seed: int, salt: str, k: int, n: int) -> int:
    """Deterministically pick an index in [0, n) for fire ``k`` — used
    to select WHICH live row a ``step_nan`` fault poisons."""
    assert n > 0
    return zlib.crc32(f"{seed}:{salt}:{k}".encode()) % n

"""SLO-aware serving policy (DESIGN.md §8).

Online serving is judged by *goodput* — requests completed within their
latency SLO per unit time — not by batch completion time.  This module
defines the request-level SLO contract and the admission policy that
maps it onto the engine's existing priority + preemption machinery:

  * :class:`SLO` — per-request targets: time-to-first-token (TTFT) and
    an end-to-end completion deadline, both in seconds from submission.
  * :class:`SLOPolicy` — the scheduling policy.  Near-deadline requests
    (TTFT slack below ``urgency_frac`` of their target) get a priority
    *boost*, which both reorders the admission queue ahead of slack-rich
    requests and lets them preempt strictly lower-priority running rows
    (the engine's normal preemption path).  Within one effective
    priority, candidates order by TTFT slack (earliest-deadline-first)
    instead of FIFO.  Hopeless requests — the TTFT deadline already
    missed while still queued, or the e2e deadline already passed — are
    *shed*: they can no longer count toward goodput, so finishing them
    only burns capacity that savable requests need.
  * :class:`StepClock` — a virtual clock for deterministic benchmarks:
    the engine reads time through an injectable ``clock`` callable, and
    arrival-process benchmarks advance a StepClock by a fixed tick per
    engine step so goodput numbers are machine-independent
    (``benchmarks/bench_serving.py``).

The policy only reads duck-typed request fields (``priority``, ``slo``,
``submitted_at``, ``first_token_at``) so it stays import-cycle-free of
the engine.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class SLO:
    """Per-request latency targets, in clock seconds from submission.

    ``ttft``     — time to first committed token.
    ``deadline`` — end-to-end completion deadline.

    ``inf`` disables a bound; a request with no :class:`SLO` at all is
    treated as trivially met when it completes (completing it *is* the
    goodput).
    """
    ttft: float = math.inf
    deadline: float = math.inf

    def met(self, ttft: float, e2e: float) -> bool:
        return ttft <= self.ttft and e2e <= self.deadline


@dataclasses.dataclass(frozen=True)
class SLOPolicy:
    """SLO-aware admission policy knobs.

    ``boost``        — priority increment for urgent (low-TTFT-slack)
                       requests; rides the engine's existing strict
                       priority ordering and preemption rules.
    ``urgency_frac`` — a request is urgent once its remaining TTFT
                       slack drops below ``urgency_frac * slo.ttft``
                       (scale-free: tight targets urge sooner in
                       absolute terms).
    ``shed``         — drop hopeless requests (missed TTFT while still
                       queued / e2e deadline passed) instead of serving
                       them to completion for zero goodput.
    """
    boost: int = 1
    urgency_frac: float = 0.5
    shed: bool = True

    # -- request-level predicates (duck-typed: engine Request) ---------

    def ttft_slack(self, req, now: float) -> float:
        """Seconds until the TTFT deadline (inf when untargeted or
        already met)."""
        if req.slo is None or not math.isfinite(req.slo.ttft):
            return math.inf
        if req.first_token_at is not None:      # TTFT already settled
            return math.inf
        return (req.submitted_at + req.slo.ttft) - now

    def urgent(self, req, now: float) -> bool:
        slack = self.ttft_slack(req, now)
        return (math.isfinite(slack)
                and slack < self.urgency_frac * req.slo.ttft)

    def effective_priority(self, req, now: float) -> int:
        return req.priority + (self.boost if self.urgent(req, now) else 0)

    def hopeless(self, req, now: float, margin: float = 0.0) -> bool:
        """True when the request can no longer contribute goodput.

        ``margin`` (seconds) tightens both deadlines — the degradation
        ladder's L3 rung sheds *earlier* under fault pressure rather
        than serving requests that will likely miss anyway
        (DESIGN.md §10)."""
        if req.slo is None:
            return False
        if (req.first_token_at is None
                and now > req.submitted_at + req.slo.ttft - margin):
            return True                          # TTFT missed in queue
        return now > req.submitted_at + req.slo.deadline - margin


class StepClock:
    """Deterministic virtual clock: ``tick`` seconds per ``advance()``.

    Inject as ``ServingEngine(clock=...)`` and advance once per engine
    step (e.g. from an ``on_step`` hook) — every latency the engine
    records (TTFT/TPOT/e2e/queue-wait) then counts engine steps instead
    of host wall time, so arrival-process benchmarks are byte-stable
    across machines."""

    def __init__(self, tick: float = 1.0):
        self.t = 0.0
        self.tick = tick

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float = None) -> None:
        self.t += self.tick if dt is None else dt

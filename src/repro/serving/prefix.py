"""Shared-prefix radix cache for the paged serving runtime (DESIGN.md §6).

At production scale most traffic shares long system prompts and few-shot
templates, yet every request re-prefills its full prompt.  This module
indexes *prefill-time* cache pages by prompt content so later requests
can attach them instead of recomputing:

  * The index is a radix trie per **layout root**.  A root key is
    ``(row_len, strategy.prefix_key())`` — ``row_len`` is the request's
    page-aligned canvas span (== its ``kv_len``).  In a bidirectional
    DLM the prefill state of every position attends over the whole
    valid canvas, and in the engine's canvas construction every
    position past the prompt up to ``row_len`` is [MASK] at prefill
    time — so ``row_len`` is exactly the "canvas layout" part of the
    match key (it subsumes ``gen_len``: two requests with the same
    prompt and row span have byte-identical prefill states regardless
    of how the span splits into prompt slack and active generation).
  * Trie edges are page-sized token runs: a node at depth ``d`` owns
    ONE physical page holding the prefill states of logical page ``d``,
    valid for any prompt that starts with the node's token path.
  * A node additionally carries **tail entries**: for a prompt that
    *ends* at this node (loose, sub-page tokens as the key), the pages
    covering the rest of the row span — at prefill those rows are all
    [MASK], so together path + tail reproduce the publisher's ENTIRE
    prefill.  A tail match is a *full hit*: the request skips its
    prefill forward completely.

Exactness (the headline guarantee, ``tests/test_prefix.py``): a full
hit whose path+tail pages were published by one request with the same
full prompt and row span is **byte-identical** to a cold prefill, so
the subsequent decode matches a cold decode bit-for-bit.  A *partial*
hit (the lookup prompt extends past the matched path, or path pages
come from publishers with different suffixes) reuses states computed
under a different canvas suffix — exactly the committed-token staleness
the paper's drift identification manages; the unmatched suffix is
recomputed bit-exactly against the matched pages
(``decoding.prefill_partial``) and drifted prefix rows refresh through
the normal strategy machinery.

Pages are owned by the index at refcount 1 (``PagePool`` holds) and
gain one hold per attached reader; readers drop their hold when they
copy-on-write before their first commit.  Under admission pressure the
engine evicts least-recently-used entries whose pages have no readers
(``evict``) — deepest-first, so a surviving node's path to the root
always has pages.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.pool import PagePool

TokenRun = Tuple[int, ...]


@dataclasses.dataclass
class _Tail:
    """Full-run completion for a prompt ending at the owning node."""
    pages: List[int]
    last_used: int


@dataclasses.dataclass
class _Node:
    """One logical page of prompt tokens; ``page`` holds its states."""
    page: Optional[int] = None
    last_used: int = 0
    children: Dict[TokenRun, "_Node"] = dataclasses.field(
        default_factory=dict)
    tails: Dict[TokenRun, _Tail] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class PrefixMatch:
    """Lookup result: ``pages`` map logical pages [0, len(pages)) of the
    request's row; ``full`` means the whole row span is covered (skip
    the prefill forward entirely)."""
    pages: Tuple[int, ...]
    full: bool

    @property
    def n_pages(self) -> int:
        return len(self.pages)


class PrefixIndex:
    """Radix trie over page-sized prompt token runs -> physical pages."""

    def __init__(self, page_size: int):
        self.page_size = page_size
        self.roots: Dict[Tuple, _Node] = {}
        self._clock = 0          # monotonic LRU clock (lookup/insert)
        self.hits = 0
        self.full_hits = 0
        self.misses = 0
        self.evicted_pages = 0

    # ---- keys ---------------------------------------------------------

    def _split(self, prompt: np.ndarray) -> Tuple[List[TokenRun], TokenRun]:
        toks = [int(t) for t in np.asarray(prompt).reshape(-1)]
        ps = self.page_size
        n_full = len(toks) // ps
        runs = [tuple(toks[i * ps: (i + 1) * ps]) for i in range(n_full)]
        return runs, tuple(toks[n_full * ps:])

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    # ---- queries ------------------------------------------------------

    def lookup(self, root_key: Tuple, prompt: np.ndarray,
               partial_ok: bool = True) -> Optional[PrefixMatch]:
        """Longest page-aligned match for ``prompt`` under the layout
        root.  Returns a full-run match when the prompt ends exactly at
        the matched node and a tail entry exists; otherwise the matched
        prefix pages (None when empty or ``partial_ok`` is False)."""
        now = self._tick()
        node = self.roots.get(root_key)
        runs, loose = self._split(prompt)
        pages: List[int] = []
        if node is not None:
            for run in runs:
                child = node.children.get(run)
                if child is None or child.page is None:
                    node = None if child is None else child
                    break
                child.last_used = now
                pages.append(child.page)
                node = child
            else:
                tail = node.tails.get(loose) if node is not None else None
                if tail is not None:
                    tail.last_used = now
                    self.hits += 1
                    self.full_hits += 1
                    return PrefixMatch(tuple(pages + tail.pages), True)
        if pages and partial_ok:
            self.hits += 1
            return PrefixMatch(tuple(pages), False)
        self.misses += 1
        return None

    # ---- publication --------------------------------------------------

    def missing_slots(self, root_key: Tuple, prompt: np.ndarray,
                      n_pages: int) -> List[int]:
        """Read-only probe: the depth indices in [0, n_pages) a
        publication of this (prompt, run) would actually adopt — path
        nodes without a page, plus the whole tail when the loose-token
        entry is absent.  Lets the engine allocate + device-copy only
        the missing pages instead of a full run per duplicate prompt
        (same-batch retries / n>1 sampling)."""
        runs, loose = self._split(prompt)
        node = self.roots.get(root_key)
        out: List[int] = []
        for depth, run in enumerate(runs):
            child = node.children.get(run) if node is not None else None
            if child is None or child.page is None:
                out.append(depth)
            node = child
        if node is None or loose not in node.tails:
            out.extend(range(len(runs), n_pages))
        return out

    def evictable_total(self, pool: PagePool) -> int:
        """Read-only cascade bound: pages :meth:`evict` could free if
        asked for everything — rc-1 tails and rc-1 node pages whose
        whole subtree is itself freeable (leaf-first order makes the
        cascade exact)."""
        total = 0

        def walk(node: _Node) -> bool:
            """True if the subtree pins any page the pool can't free."""
            nonlocal total
            stuck = False
            for tail in node.tails.values():
                if all(pool.refcount(p) == 1 for p in tail.pages):
                    total += len(tail.pages)
                else:
                    stuck = True
            for child in node.children.values():
                if walk(child):
                    stuck = True
            if node.page is not None:
                if not stuck and pool.refcount(node.page) == 1:
                    total += 1
                else:
                    stuck = True
            return stuck

        for root in self.roots.values():
            walk(root)
        return total

    def insert(self, root_key: Tuple, prompt: np.ndarray,
               pages: Sequence[Optional[int]]) -> List[int]:
        """Publish a full prefill run: ``pages[i]`` is the physical page
        holding logical page ``i``'s states (prompt path first, then the
        all-[MASK] tail to the row span), or None for depths the caller
        knows are already present.  Existing nodes keep their pages
        (first publisher wins — replacing them would silently retarget
        live lookups).  Returns the pages NOT adopted; the caller must
        release them back to the pool."""
        now = self._tick()
        runs, loose = self._split(prompt)
        assert len(pages) >= len(runs), (len(pages), len(runs))
        node = self.roots.setdefault(root_key, _Node())
        rejected: List[int] = []
        for depth, run in enumerate(runs):
            child = node.children.setdefault(run, _Node())
            page = pages[depth]
            if page is not None:
                if child.page is None:
                    child.page = page
                else:
                    rejected.append(page)
            child.last_used = now
            node = child
        tail_pages = [p for p in pages[len(runs):] if p is not None]
        if tail_pages:
            if loose in node.tails:
                rejected.extend(tail_pages)
            else:
                node.tails[loose] = _Tail(tail_pages, now)
        return rejected

    # ---- eviction -----------------------------------------------------

    def _evictable(self, pool: PagePool):
        """(last_used, kind, ...) units safe to drop: tails, and leaf
        node pages (no page-bearing descendants, no tails) — all with no
        reader holds (pool refcount 1 = the index's own hold)."""
        units = []

        def walk(node: _Node):
            blocked = False     # a page-bearing descendant or tail below
            for tail_key, tail in node.tails.items():
                if all(pool.refcount(p) == 1 for p in tail.pages):
                    units.append((tail.last_used, "tail", node, tail_key))
                blocked = True
            for child in node.children.values():
                if walk(child):
                    blocked = True
            if node.page is not None:
                if not blocked and pool.refcount(node.page) == 1:
                    units.append((node.last_used, "node", node, None))
                return True
            return blocked

        for root in self.roots.values():
            walk(root)
        return units

    def evict(self, pool: PagePool, n_pages: int) -> int:
        """Free at least ``n_pages`` pages of LRU unreferenced entries
        (deepest-first by construction).  Returns pages actually freed —
        may be fewer when everything left has readers."""
        freed = 0
        while freed < n_pages:
            units = self._evictable(pool)
            if not units:
                break
            units.sort(key=lambda u: u[0])
            _, kind, node, tail_key = units[0]
            if kind == "tail":
                tail = node.tails.pop(tail_key)
                pool.release(tail.pages)
                freed += len(tail.pages)
                self.evicted_pages += len(tail.pages)
            else:
                pool.release([node.page])
                node.page = None
                freed += 1
                self.evicted_pages += 1
        return freed

    def clear(self, pool: PagePool) -> int:
        """Release every index hold (readers keep theirs) and drop the
        trie.  Returns the number of holds released."""
        n = 0

        def walk(node: _Node):
            nonlocal n
            if node.page is not None:
                pool.release([node.page])
                n += 1
            for tail in node.tails.values():
                pool.release(tail.pages)
                n += len(tail.pages)
            for child in node.children.values():
                walk(child)

        for root in self.roots.values():
            walk(root)
        self.roots = {}
        return n

    # ---- stats --------------------------------------------------------

    @property
    def held_pages(self) -> int:
        n = 0

        def walk(node: _Node):
            nonlocal n
            n += int(node.page is not None)
            n += sum(len(t.pages) for t in node.tails.values())
            for child in node.children.values():
                walk(child)

        for root in self.roots.values():
            walk(root)
        return n

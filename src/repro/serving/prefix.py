"""Shared-prefix radix cache for the paged serving runtime (DESIGN.md §6).

At production scale most traffic shares long system prompts and few-shot
templates, yet every request re-prefills its full prompt.  This module
indexes *prefill-time* cache pages by prompt content so later requests
can attach them instead of recomputing:

  * The index is a radix trie per **layout root**.  A root key is
    ``(row_len, strategy.prefix_key())`` — ``row_len`` is the request's
    page-aligned canvas span (== its ``kv_len``).  In a bidirectional
    DLM the prefill state of every position attends over the whole
    valid canvas, and in the engine's canvas construction every
    position past the prompt up to ``row_len`` is [MASK] at prefill
    time — so ``row_len`` is exactly the "canvas layout" part of the
    match key (it subsumes ``gen_len``: two requests with the same
    prompt and row span have byte-identical prefill states regardless
    of how the span splits into prompt slack and active generation).
  * Trie edges are page-sized token runs: a node at depth ``d`` owns
    ONE physical page holding the prefill states of logical page ``d``,
    valid for any prompt that starts with the node's token path.
  * A node additionally carries **tail entries**: for a prompt that
    *ends* at this node (loose, sub-page tokens as the key), the pages
    covering the rest of the row span — at prefill those rows are all
    [MASK], so together path + tail reproduce the publisher's ENTIRE
    prefill.  A tail match is a *full hit*: the request skips its
    prefill forward completely.

Exactness (the headline guarantee, ``tests/test_prefix.py``): a full
hit whose path+tail pages were published by one request with the same
full prompt and row span is **byte-identical** to a cold prefill, so
the subsequent decode matches a cold decode bit-for-bit.  A *partial*
hit (the lookup prompt extends past the matched path, or path pages
come from publishers with different suffixes) reuses states computed
under a different canvas suffix — exactly the committed-token staleness
the paper's drift identification manages; the unmatched suffix is
recomputed bit-exactly against the matched pages
(``decoding.prefill_partial``) and drifted prefix rows refresh through
the normal strategy machinery.

Pages are owned by the index at refcount 1 (``PagePool`` holds) and
gain one hold per attached reader; readers drop their hold when they
copy-on-write before their first commit.  Under admission pressure the
engine evicts least-recently-used entries whose pages have no readers
(``evict``) — deepest-first, so a surviving node's path to the root
always has pages.

**Host tier (DESIGN.md §9).** When a :class:`~repro.serving.hier.\
TierManager` is attached (``self.tier``), eviction DEMOTES victims to
host RAM instead of freeing their states: the entry stays in the trie
with ``host`` refs in place of device pages, and a later hit promotes
them back device-ward (``sites_intact`` / ``install_promoted`` are the
engine's promotion handshake).  Victim order becomes stability-first
(Sparse-dLLM-style: stable pages are cheap to re-prefill, so they go
cold first), LRU within a stability bucket.  Deepest-first eviction
keeps the invariant that along any path DEVICE pages form a contiguous
logical prefix and host refs a suffix, with a surviving device tail
implying an all-device path.  Entries carry an ``exact`` flag: pages
demoted f32 (or from an already-int8 device cache) promote
byte-identical; a page that ever passed through the int8 cold
representation is permanently partial-hit class (allclose).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.pool import PagePool

TokenRun = Tuple[int, ...]


@dataclasses.dataclass
class _Tail:
    """Full-run completion for a prompt ending at the owning node.
    Either ``pages`` (device-resident) or ``host`` (demoted to the §9
    host tier) holds the states; ``exact`` is False once they have
    passed through the int8 cold representation."""
    pages: List[int]
    last_used: int
    host: Optional[List["HostPageRef"]] = None
    exact: bool = True


@dataclasses.dataclass
class _Node:
    """One logical page of prompt tokens; ``page`` holds its states
    (or ``host`` after a demotion to the §9 host tier)."""
    page: Optional[int] = None
    last_used: int = 0
    children: Dict[TokenRun, "_Node"] = dataclasses.field(
        default_factory=dict)
    tails: Dict[TokenRun, _Tail] = dataclasses.field(default_factory=dict)
    host: Optional["HostPageRef"] = None
    exact: bool = True


@dataclasses.dataclass(frozen=True)
class PrefixMatch:
    """Lookup result: ``pages`` map logical pages [0, len(pages)) of the
    request's row; ``full`` means the whole row span is covered (skip
    the prefill forward entirely).  ``host_refs`` extend the match with
    host-tier pages the engine must PROMOTE before attaching (they
    cover logical pages [len(pages), n_pages) in order); ``sites``
    records where each matched page/ref lives in the trie so the
    promotion can validate (``sites_intact``) and install
    (``install_promoted``) against concurrent evictions.  ``exact`` is
    False when any matched state passed through int8 — the hit is then
    partial-hit class (allclose), not byte-identical."""
    pages: Tuple[int, ...]
    full: bool
    exact: bool = True
    host_refs: Tuple["HostPageRef", ...] = ()
    sites: Tuple[Tuple, ...] = ()

    @property
    def n_pages(self) -> int:
        return len(self.pages) + len(self.host_refs)

    @property
    def needs_promotion(self) -> bool:
        return bool(self.host_refs)


class PrefixIndex:
    """Radix trie over page-sized prompt token runs -> physical pages."""

    def __init__(self, page_size: int):
        self.page_size = page_size
        self.roots: Dict[Tuple, _Node] = {}
        self._clock = 0          # monotonic LRU clock (lookup/insert)
        self.hits = 0
        self.full_hits = 0
        self.misses = 0
        self.evicted_pages = 0   # device pages freed by evict (total)
        self.demoted_pages = 0   # ... of which moved host-ward (§9)
        self.dropped_pages = 0   # ... of which died (+ host-ref prunes)
        self.promoted_pages = 0  # host pages brought back device-ward
        # Optional[hier.TierManager] — wired by the engine; None keeps
        # the PR 5 single-tier behaviour (evict == drop) byte-for-byte.
        self.tier = None
        # degradation ladder L2 (DESIGN.md §10): while True, eviction
        # drops victims instead of demoting them host-ward — shedding
        # the host tier's work under sustained fault pressure.
        self.demote_paused = False

    # ---- keys ---------------------------------------------------------

    def _split(self, prompt: np.ndarray) -> Tuple[List[TokenRun], TokenRun]:
        toks = [int(t) for t in np.asarray(prompt).reshape(-1)]
        ps = self.page_size
        n_full = len(toks) // ps
        runs = [tuple(toks[i * ps: (i + 1) * ps]) for i in range(n_full)]
        return runs, tuple(toks[n_full * ps:])

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    # ---- queries ------------------------------------------------------

    def lookup(self, root_key: Tuple, prompt: np.ndarray,
               partial_ok: bool = True,
               promote_ok: bool = True) -> Optional[PrefixMatch]:
        """Longest page-aligned match for ``prompt`` under the layout
        root.  Returns a full-run match when the prompt ends exactly at
        the matched node and a tail entry exists; otherwise the matched
        prefix pages (None when empty or ``partial_ok`` is False).

        With ``promote_ok`` (and a host tier attached) the walk
        continues through host-resident entries: the returned
        ``host_refs``/``sites`` describe the promotion the engine must
        perform before the covered pages are attachable."""
        now = self._tick()
        node = self.roots.get(root_key)
        runs, loose = self._split(prompt)
        pages: List[int] = []
        host_refs: List = []
        sites: List[Tuple] = []
        exact = True
        if node is not None:
            for run in runs:
                child = node.children.get(run)
                if child is None:
                    node = None
                    break
                if child.page is not None:
                    child.last_used = now
                    pages.append(child.page)
                    sites.append(("dev", child, child.page))
                    exact = exact and child.exact
                elif child.host is not None and promote_ok:
                    child.last_used = now
                    host_refs.append(child.host)
                    sites.append(("node", child))
                    exact = exact and child.host.exact
                else:
                    node = child
                    break
                node = child
            else:
                tail = node.tails.get(loose)
                if tail is not None and tail.pages and not host_refs:
                    tail.last_used = now
                    self.hits += 1
                    self.full_hits += 1
                    return PrefixMatch(tuple(pages + tail.pages), True,
                                       exact=exact and tail.exact)
                if tail is not None and tail.host and promote_ok:
                    tail.last_used = now
                    self.hits += 1
                    self.full_hits += 1
                    return PrefixMatch(
                        tuple(pages), True,
                        exact=exact and all(r.exact for r in tail.host),
                        host_refs=tuple(host_refs) + tuple(tail.host),
                        sites=tuple(sites) + (("tail", node, loose),))
        if (pages or host_refs) and partial_ok:
            self.hits += 1
            return PrefixMatch(tuple(pages), False, exact=exact,
                               host_refs=tuple(host_refs),
                               sites=tuple(sites))
        self.misses += 1
        return None

    # ---- publication --------------------------------------------------

    def missing_slots(self, root_key: Tuple, prompt: np.ndarray,
                      n_pages: int) -> List[int]:
        """Read-only probe: the depth indices in [0, n_pages) a
        publication of this (prompt, run) would actually adopt — path
        nodes without a page, plus the whole tail when the loose-token
        entry is absent.  Lets the engine allocate + device-copy only
        the missing pages instead of a full run per duplicate prompt
        (same-batch retries / n>1 sampling)."""
        runs, loose = self._split(prompt)
        node = self.roots.get(root_key)
        out: List[int] = []
        for depth, run in enumerate(runs):
            child = node.children.get(run) if node is not None else None
            if child is None or child.page is None:
                # host-resident nodes count as missing: a fresh device
                # publish supersedes the cold copy (insert frees it)
                out.append(depth)
            node = child
        if (node is None or loose not in node.tails
                or not node.tails[loose].pages):
            out.extend(range(len(runs), n_pages))
        return out

    def evictable_total(self, pool: PagePool) -> int:
        """Read-only cascade bound: pages :meth:`evict` could free if
        asked for everything — rc-1 tails and rc-1 node pages whose
        whole subtree is itself freeable (leaf-first order makes the
        cascade exact)."""
        total = 0

        def walk(node: _Node) -> bool:
            """True if the subtree pins any page the pool can't free."""
            nonlocal total
            stuck = False
            for tail in node.tails.values():
                if not tail.pages:
                    continue        # host-resident: no device hold
                if all(pool.refcount(p) == 1 for p in tail.pages):
                    total += len(tail.pages)
                else:
                    stuck = True
            for child in node.children.values():
                if walk(child):
                    stuck = True
            if node.page is not None:
                if not stuck and pool.refcount(node.page) == 1:
                    total += 1
                else:
                    stuck = True
            return stuck

        for root in self.roots.values():
            walk(root)
        return total

    def insert(self, root_key: Tuple, prompt: np.ndarray,
               pages: Sequence[Optional[int]]) -> List[int]:
        """Publish a full prefill run: ``pages[i]`` is the physical page
        holding logical page ``i``'s states (prompt path first, then the
        all-[MASK] tail to the row span), or None for depths the caller
        knows are already present.  Existing nodes keep their pages
        (first publisher wins — replacing them would silently retarget
        live lookups).  Returns the pages NOT adopted; the caller must
        release them back to the pool."""
        now = self._tick()
        runs, loose = self._split(prompt)
        assert len(pages) >= len(runs), (len(pages), len(runs))
        node = self.roots.setdefault(root_key, _Node())
        rejected: List[int] = []
        for depth, run in enumerate(runs):
            child = node.children.setdefault(run, _Node())
            page = pages[depth]
            if page is not None:
                if child.page is None:
                    if child.host is not None:
                        # fresh device states supersede the cold copy
                        self.tier.free_refs([child.host])
                        child.host = None
                    child.page = page
                    child.exact = True
                else:
                    rejected.append(page)
            child.last_used = now
            node = child
        tail_pages = [p for p in pages[len(runs):] if p is not None]
        if tail_pages:
            old = node.tails.get(loose)
            if old is not None and not old.pages:
                if old.host:
                    self.tier.free_refs(old.host)
                node.tails.pop(loose)
                old = None
            if old is not None:
                rejected.extend(tail_pages)
            else:
                node.tails[loose] = _Tail(tail_pages, now)
        return rejected

    # ---- eviction -----------------------------------------------------

    def _evictable(self, pool: PagePool):
        """(last_used, kind, ...) units safe to drop: tails, and leaf
        node pages (no page-bearing descendants, no tails) — all with no
        reader holds (pool refcount 1 = the index's own hold)."""
        units = []

        def walk(node: _Node):
            blocked = False     # a page-bearing descendant or tail below
            for tail_key, tail in node.tails.items():
                if not tail.pages:
                    continue    # host-resident: no device hold, no block
                if all(pool.refcount(p) == 1 for p in tail.pages):
                    units.append((tail.last_used, "tail", node, tail_key))
                blocked = True
            for child in node.children.values():
                if walk(child):
                    blocked = True
            if node.page is not None:
                if not blocked and pool.refcount(node.page) == 1:
                    units.append((node.last_used, "node", node, None))
                return True
            return blocked

        for root in self.roots.values():
            walk(root)
        return units

    def _unit_key(self, unit):
        """Victim order.  Single-tier: pure LRU (PR 5 behaviour).  With
        a host tier: stability-first — Sparse-dLLM's observation that
        stable state is the cheap-to-reproduce kind, so it goes cold
        before drift-heavy state — with LRU inside a stability bucket
        (rounded to 0.1 so near-ties fall back to recency)."""
        last_used, kind, node, tail_key = unit
        if self.tier is None:
            return (0.0, last_used)
        pages = node.tails[tail_key].pages if kind == "tail" else [node.page]
        stab = sum(self.tier.stability(p) for p in pages) / max(len(pages), 1)
        return (-round(stab, 1), last_used)

    def evict(self, pool: PagePool, n_pages: int) -> int:
        """Free at least ``n_pages`` device pages of unreferenced
        entries (deepest-first by construction).  With a host tier
        attached, victims DEMOTE host-ward and stay in the trie; the
        tier may refuse (host budget full, or stable-under-pressure)
        and the victim drops as in the single-tier path.  A dropped
        NODE severs the lookup path through it, so host refs in its
        subtree are pruned.  Returns device pages actually freed — may
        be fewer when everything left has readers."""
        freed = 0
        tier = None if self.demote_paused else self.tier
        while freed < n_pages:
            units = self._evictable(pool)
            if not units:
                break
            units.sort(key=self._unit_key)
            _, kind, node, tail_key = units[0]
            if kind == "tail":
                tail = node.tails[tail_key]
                pages = list(tail.pages)
                refs = (tier.demote(pages, exact_in=tail.exact)
                        if tier is not None else None)
                if refs is not None:
                    tail.pages = []
                    tail.host = refs
                    tail.exact = all(r.exact for r in refs)
                    self.demoted_pages += len(pages)
                else:
                    node.tails.pop(tail_key)
                    if self.tier is not None:
                        self.tier.forget(pages)
                    self.dropped_pages += len(pages)
            else:
                pages = [node.page]
                refs = (tier.demote(pages, exact_in=node.exact)
                        if tier is not None else None)
                if refs is not None:
                    node.host = refs[0]
                    node.exact = refs[0].exact
                    self.demoted_pages += 1
                else:
                    if self.tier is not None:
                        self.tier.forget(pages)
                        self.dropped_pages += self._prune_host(node)
                    self.dropped_pages += 1
                node.page = None
            pool.release(pages)
            freed += len(pages)
            self.evicted_pages += len(pages)
        return freed

    def _prune_host(self, node: _Node) -> int:
        """A dropped node severs the lookup path through it: host refs
        at or below it can never be matched again, so free them now
        (counted as drops) to keep the host tier leak-free.  Device
        pages below a droppable node are impossible (deepest-first)."""
        n = 0

        def scrub(nd: _Node, subtree: bool):
            nonlocal n
            for key in list(nd.tails):
                tail = nd.tails[key]
                if tail.host:
                    self.tier.free_refs(tail.host)
                    n += len(tail.host)
                    del nd.tails[key]
            if subtree and nd.host is not None:
                self.tier.free_refs([nd.host])
                nd.host = None
                n += 1
            for child in nd.children.values():
                scrub(child, True)

        scrub(node, False)
        return n

    # ---- promotion (host tier, DESIGN.md §9) --------------------------

    def sites_intact(self, match: PrefixMatch) -> bool:
        """True while ``match`` still holds exactly the device pages and
        host refs recorded at lookup time.  Evictions between planning
        and the engine's promotion service window invalidate the match
        — the engine replans instead of promoting stale refs."""
        i = 0
        for site in match.sites:
            kind = site[0]
            if kind == "dev":
                _, node, page = site
                if node.page != page:
                    return False
            elif kind == "node":
                node = site[1]
                if node.host is not match.host_refs[i]:
                    return False
                i += 1
            else:
                _, node, tail_key = site
                tail = node.tails.get(tail_key)
                if tail is None or not tail.host:
                    return False
                k = len(tail.host)
                if tuple(tail.host) != match.host_refs[i:i + k]:
                    return False
                i += k
        return i == len(match.host_refs)

    def install_promoted(self, match: PrefixMatch,
                         new_pages: Sequence[int]) -> List[int]:
        """Point ``match``'s host-resident entries at the freshly
        written device pages (the engine has already scattered the
        promoted blocks into the arenas and owns the index hold).
        Entries keep the exactness class their refs carried — a page
        that ever passed through int8 stays partial-hit class.  Returns
        the full logical page run (device prefix + promoted pages, in
        row order)."""
        assert len(new_pages) == len(match.host_refs)
        now = self._tick()
        i = 0
        for site in match.sites:
            kind = site[0]
            if kind == "dev":
                continue
            if kind == "node":
                node = site[1]
                node.page = new_pages[i]
                node.exact = match.host_refs[i].exact
                node.host = None
                node.last_used = now
                i += 1
            else:
                _, node, tail_key = site
                tail = node.tails[tail_key]
                k = len(tail.host)
                tail.pages = list(new_pages[i:i + k])
                tail.exact = all(r.exact for r in tail.host)
                tail.host = None
                tail.last_used = now
                i += k
        self.promoted_pages += len(new_pages)
        return list(match.pages) + list(new_pages)

    def scrub_host_sites(self, match: PrefixMatch) -> int:
        """Corruption fallback (DESIGN.md §10): drop ``match``'s
        host-resident trie entries WITHOUT freeing tier slots — the
        tier already freed them when the promotion's checksum
        verification failed.  The entries must go regardless: their
        refs now point at freed (or corrupt) host slots, and a later
        lookup must miss, not re-promote rot.  Returns the refs
        dropped (counted as drops)."""
        n = 0
        for site in match.sites:
            kind = site[0]
            if kind == "node":
                node = site[1]
                if node.host is not None:
                    node.host = None
                    n += 1
            elif kind == "tail":
                _, node, tail_key = site
                tail = node.tails.get(tail_key)
                if tail is not None and tail.host:
                    n += len(tail.host)
                    tail.host = None
                    if not tail.pages:
                        node.tails.pop(tail_key)
        self.dropped_pages += n
        return n

    def clear(self, pool: PagePool) -> int:
        """Release every index hold (readers keep theirs), free every
        host-tier ref, and drop the trie.  Returns the number of device
        holds released."""
        n = 0

        def walk(node: _Node):
            nonlocal n
            if node.page is not None:
                pool.release([node.page])
                n += 1
            if node.host is not None:
                self.tier.free_refs([node.host])
            for tail in node.tails.values():
                if tail.pages:
                    pool.release(tail.pages)
                    n += len(tail.pages)
                if tail.host:
                    self.tier.free_refs(tail.host)
            for child in node.children.values():
                walk(child)

        for root in self.roots.values():
            walk(root)
        self.roots = {}
        return n

    # ---- stats --------------------------------------------------------

    @property
    def held_pages(self) -> int:
        n = 0

        def walk(node: _Node):
            nonlocal n
            n += int(node.page is not None)
            n += sum(len(t.pages) for t in node.tails.values())
            for child in node.children.values():
                walk(child)

        for root in self.roots.values():
            walk(root)
        return n

    def device_pages(self) -> List[int]:
        """Every device page the trie holds (one index hold each) — the
        supervisor's page-accounting invariant closes against this
        (DESIGN.md §10)."""
        out: List[int] = []

        def walk(node: _Node):
            if node.page is not None:
                out.append(node.page)
            for tail in node.tails.values():
                out.extend(tail.pages)
            for child in node.children.values():
                walk(child)

        for root in self.roots.values():
            walk(root)
        return out

    @property
    def host_held_pages(self) -> int:
        """Host-tier pages the trie currently references (the host pool
        must hold exactly these — tests/test_hier.py leak detector)."""
        n = 0

        def walk(node: _Node):
            nonlocal n
            n += int(node.host is not None)
            n += sum(len(t.host or ()) for t in node.tails.values())
            for child in node.children.values():
                walk(child)

        for root in self.roots.values():
            walk(root)
        return n

    def telemetry_gauges(self):
        """Index-occupancy gauges for the §11 registry
        (``name -> (help, value)``)."""
        return {
            "spa_prefix_held_pages":
                ("device pages held by the index", self.held_pages),
            "spa_prefix_host_held_pages":
                ("host-tier pages referenced by the index",
                 self.host_held_pages),
        }

"""Hierarchical cache: host-RAM page tier + dynamic eviction (DESIGN.md §9).

The device :class:`~repro.serving.pool.PagePool` caps the prefix index
at one HBM arena: under multi-tenant traffic ``PrefixIndex.evict``
permanently frees LRU entries, so the index can never hold more
prefixes than HBM fits — the rigid-capacity limitation SPA-Cache argues
against at the layer level, recurring at the memory-system level.  This
module adds the second tier:

  * :class:`HostPagePool` — per-signature page arenas mirrored in host
    memory (numpy stands in for pinned allocations on this CPU
    container; on TPU the same layout maps onto ``pinned_host`` buffer
    donation).  Capacity is counted in *exact-page units*; an int8 page
    costs half a unit, so the cold tier stretches ~2x per byte.
  * :class:`TierManager` — the demote/promote broker between the device
    pool and the host pool.  On prefix-index eviction it reads the
    victim pages device->host (one bucketed
    :func:`~repro.core.cache.read_arena_pages` gather) and stores them
    exact or int8; on a host-resident prefix hit the engine promotes
    them back with :func:`~repro.core.cache.write_arena_pages`,
    overlapped with the in-flight decode step (DESIGN.md §8/§9).
  * Sparse-dLLM-style **dynamic eviction**: a per-page stability score
    derived from the singular-proxy identifiers the strategy already
    keeps.  Stable pages (near-parallel identifier rows — e.g. the
    all-[MASK] tail pages of a prefill) are demote-FIRST, quantize to
    int8 under ``host_dtype="auto"``, and are dropped outright instead
    of demoted when the host tier is full — recomputing a stable page
    via prefill is the cheap case, so the host budget goes to the
    drift-heavy pages that are expensive to reproduce.

Exactness classes (DESIGN.md §9): a page demoted exact (f32, or an
already-int8 device cache) promotes byte-identical, so a full prefix
hit through the host tier keeps the §6 byte-parity guarantee.  A page
demoted int8 promotes within the documented per-row quantization bound
(``max|row|/254`` per element) — its entries are permanently marked
inexact and any hit through them is *partial-hit class*: decode states
allclose, not byte-identical (``tests/test_hier.py``).
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.cache import dequantize_rows_np, quantize_rows_np

# host-buffer suffix for the int8 representation's per-row scales —
# distinct from the device "_scale" buffers an int8 cache signature
# already carries (those pass through the host tier untouched).
_SCALE_SUFFIX = "_hscale"


def page_stability(proxy_block: np.ndarray) -> float:
    """Sparse-dLLM-style stability score for ONE page from its
    identifier (singular-proxy) rows: the mean cosine of each row's
    proxy to the page-mean proxy direction, clipped to [0, 1].

    ``proxy_block`` is ``[Lk, page, r]`` (or any ``[..., rows, r]``).
    Rows that all point the same way carry little mutual information —
    the canonical case is a prefill's all-[MASK] tail pages, whose rows
    see near-identical context — so the page is cheap to reproduce and
    safe to quantize; drift-heterogeneous pages score low and keep
    their exact representation.  Pages without identifier buffers score
    0.0 (least stable: never dropped in favour of a scored page)."""
    x = np.asarray(proxy_block).astype(np.float32)
    if x.size == 0:
        return 0.0
    x = x.reshape(-1, x.shape[-1])
    norms = np.linalg.norm(x, axis=-1)
    live = norms > 1e-8
    if not live.any():
        return 0.0
    unit = x[live] / norms[live, None]
    mean = unit.mean(axis=0)
    mn = np.linalg.norm(mean)
    if mn < 1e-8:
        return 0.0
    cos = unit @ (mean / mn)
    return float(np.clip(cos.mean(), 0.0, 1.0))


class HostPageCorruption(RuntimeError):
    """A host-tier page failed its checksum on promotion (DESIGN.md
    §10).  The tier has already freed the WHOLE entry's slots — corrupt
    bytes must never reach the device — and the engine falls back to a
    cold prefill."""


@dataclasses.dataclass(frozen=True)
class HostPageRef:
    """One demoted page's host-tier address.

    ``sig``: the device cache signature whose arenas the page came from
    (and must promote back into); ``repr_``: "exact" | "int8";
    ``slot``: slot index in the (sig, repr_) host arena; ``units``:
    half-page accounting units the slot occupies; ``exact``: whether a
    promotion reproduces the ORIGINAL device bytes (False once a page
    has ever passed through int8); ``stability``: the score the page
    was demoted with (kept so a re-demotion after promotion reuses it);
    ``checksum``: crc32 of the stored host bytes, verified before any
    promotion reaches the device (0 = unverified legacy ref).
    """
    sig: Tuple
    repr_: str
    slot: int
    units: int
    exact: bool
    stability: float
    checksum: int = 0


class HostPagePool:
    """Host-memory mirror of :class:`~repro.serving.pool.PagePool`:
    one numpy arena per cache buffer per (signature, representation),
    with a global capacity counted in exact-page units.

    ``n_pages`` is the budget in EXACT pages; internal accounting uses
    half-page units (exact page = 2 units, int8 page = 1 unit) so an
    int8 cold tier holds ~2x the pages of the same byte budget.  Arenas
    materialize lazily from the first demoted block's shapes and grow
    by doubling — host RAM is the abundant resource here, the budget
    models the *transfer + residency* cost, not an allocator limit."""

    def __init__(self, n_pages: int):
        if n_pages <= 0:
            raise ValueError("host tier needs n_pages > 0")
        self.n_pages = n_pages
        self.capacity_units = 2 * n_pages
        self.used_units = 0
        self.peak_units = 0
        # (sig, repr) -> {"arenas": {kind: {name: np [Lk, slots, ...]}},
        #                 "free": [slot], "n_slots": int}
        self._store: Dict[Tuple, Dict] = {}
        self.pages_in = 0      # lifetime demotions accepted
        self.pages_out = 0     # lifetime promotions served

    # ---- accounting --------------------------------------------------

    @property
    def used_pages(self) -> int:
        """Live host slots (pages resident in the tier)."""
        return sum(e["n_slots"] - len(e["free"])
                   for e in self._store.values())

    @property
    def utilization(self) -> float:
        return self.used_units / max(self.capacity_units, 1)

    def fits(self, units: int) -> bool:
        return self.used_units + units <= self.capacity_units

    def reset_telemetry(self) -> None:
        self.peak_units = self.used_units
        self.pages_in = 0
        self.pages_out = 0

    def telemetry_gauges(self):
        """Host-tier occupancy gauges for the §11 registry
        (``name -> (help, value)``)."""
        return {
            "spa_tier_units_used":
                ("host-tier cost units in use (f32 page = 2, int8 = 1)",
                 self.used_units),
            "spa_tier_units_capacity":
                ("host-tier unit budget", self.capacity_units),
            "spa_tier_utilization_ratio":
                ("units used / budget", self.utilization),
            "spa_tier_resident_pages":
                ("pages resident in the host tier", self.used_pages),
            "spa_tier_peak_units_used":
                ("high-water host-tier cost units", self.peak_units),
        }

    def debug_state(self) -> Dict:
        """JSON-safe host-tier introspection for ``/debug/pool``:
        unit accounting plus per-(signature, representation) slot
        occupancy — never the arena contents."""
        stores = {}
        for (sig, repr_), e in self._store.items():
            stores[f"{sig}/{repr_}"] = {
                "n_slots": e["n_slots"],
                "free_slots": len(e["free"]),
                "resident": e["n_slots"] - len(e["free"]),
            }
        return {
            "unit_budget": self.capacity_units,
            "units_used": self.used_units,
            "peak_units": self.peak_units,
            "utilization": round(self.utilization, 6),
            "resident_pages": self.used_pages,
            "pages_in": self.pages_in,
            "pages_out": self.pages_out,
            "stores": stores,
        }

    # ---- slots -------------------------------------------------------

    def _entry(self, sig: Tuple, repr_: str, block_one):
        key = (sig, repr_)
        e = self._store.get(key)
        if e is None:
            e = {"arenas": {}, "free": [], "n_slots": 0}
            self._store[key] = e
        if not e["arenas"]:
            e["arenas"] = {
                kind: {name: np.zeros((b.shape[0], 0) + b.shape[2:],
                                      b.dtype)
                       for name, b in bufs.items()}
                for kind, bufs in block_one.items()}
        return e

    def _grow(self, e: Dict, n: int) -> None:
        grow = max(n, e["n_slots"], 4)
        for bufs in e["arenas"].values():
            for name, a in list(bufs.items()):
                bufs[name] = np.concatenate(
                    [a, np.zeros((a.shape[0], grow) + a.shape[2:],
                                 a.dtype)], axis=1)
        e["free"].extend(range(e["n_slots"], e["n_slots"] + grow))
        e["n_slots"] += grow

    def store(self, sig: Tuple, repr_: str, units_per_page: int,
              blocks) -> Optional[List[int]]:
        """Adopt ``blocks`` ({kind: {name: [Lk, n, ...]}}) into the
        (sig, repr_) arena; returns the slots, or None when the unit
        budget can't cover them (the caller drops the pages)."""
        n = next(iter(next(iter(blocks.values())).values())).shape[1]
        if not self.fits(n * units_per_page):
            return None
        e = self._entry(sig, repr_, blocks)
        if len(e["free"]) < n:
            self._grow(e, n - len(e["free"]))
        slots = [e["free"].pop() for _ in range(n)]
        idx = np.asarray(slots)
        for kind, bufs in blocks.items():
            for name, b in bufs.items():
                e["arenas"][kind][name][:, idx] = b
        self.used_units += n * units_per_page
        self.peak_units = max(self.peak_units, self.used_units)
        self.pages_in += n
        return slots

    def load(self, sig: Tuple, repr_: str, slots: List[int]):
        """Blocks ({kind: {name: [Lk, n, ...]}}) for host slots, in
        order.  Read-only: pair with :meth:`free` to evict them."""
        e = self._store[(sig, repr_)]
        idx = np.asarray(slots)
        return {kind: {name: a[:, idx].copy() for name, a in bufs.items()}
                for kind, bufs in e["arenas"].items()}

    def free(self, sig: Tuple, repr_: str, slots: List[int],
             units_per_page: int) -> None:
        e = self._store[(sig, repr_)]
        for s in slots:
            assert s not in e["free"], f"double free of host slot {s}"
            e["free"].append(s)
        self.used_units -= len(slots) * units_per_page
        assert self.used_units >= 0

    def corrupt_slot(self, sig: Tuple, repr_: str, slot: int) -> None:
        """Bit-flip one resident slot's first buffer in place — the
        ``host_corrupt`` fault payload (DESIGN.md §10), the minimal rot
        the promotion checksum must catch.  Copy-modify-writeback:
        column views of the arenas are not contiguous."""
        e = self._store[(sig, repr_)]
        for bufs in e["arenas"].values():
            for a in bufs.values():
                blk = a[:, slot].copy()
                flat = blk.reshape(-1).view(np.uint8)
                flat[: min(8, flat.size)] ^= 0xFF
                a[:, slot] = blk
                return


class TierManager:
    """Demotion/promotion policy between the device pool and the host
    tier (DESIGN.md §9).

    The engine wires ``read_pages(sig, pages) -> blocks`` to the LIVE
    arenas (the running lane's session mid-lane, the pool's stored
    arenas otherwise) and registers per-page stability + signature at
    prefix publication time; :class:`~repro.serving.prefix.PrefixIndex`
    calls :meth:`demote` from its eviction loop and the engine calls
    :meth:`promote` from its overlap window.

    ``host_dtype``: "f32" keeps every demoted page exact, "int8"
    quantizes every float page, "auto" (default) quantizes pages whose
    stability clears ``stable_threshold`` and keeps drift-heavy pages
    exact.  A device signature that is already int8 always demotes
    exact (it is bytes, and costs the int8 unit rate)."""

    def __init__(self, host: HostPagePool, *, host_dtype: str = "auto",
                 stable_threshold: float = 0.9,
                 read_pages: Optional[Callable] = None):
        assert host_dtype in ("f32", "int8", "auto"), host_dtype
        self.host = host
        self.host_dtype = host_dtype
        self.stable_threshold = stable_threshold
        self.read_pages = read_pages     # (sig, pages) -> np blocks
        self._sig_of: Dict[int, Tuple] = {}       # device page -> sig
        self._stability: Dict[int, float] = {}    # device page -> score
        self.demoted_pages = 0
        self.promoted_pages = 0
        self.dropped_full = 0      # demotions refused: host tier full
        self.dropped_stable = 0    # demotions skipped: stable under pressure
        # fault seam (DESIGN.md §10): a FaultInjector wired by the
        # engine; demote probes "host_store" (refuse the write -> drop,
        # the graceful §9 path) and "host_corrupt" (bit-flip the fresh
        # slot, caught by the promotion checksum)
        self.injector = None
        self.store_faults = 0          # injected demotion-write refusals
        self.checksum_failures = 0     # corrupt pages caught on promote

    # ---- engine registration ----------------------------------------

    def note_published(self, sig: Tuple, pages: List[int],
                       proxy_blocks: Optional[Dict[int, np.ndarray]]
                       ) -> None:
        """Register freshly published index pages: their signature (so
        a later demotion reads the right arenas) and their stability
        score from the identifier rows (``proxy_blocks`` maps page ->
        [Lk, page_rows, r], or None for proxy-less strategies)."""
        for p in pages:
            self._sig_of[p] = sig
            blk = (proxy_blocks or {}).get(p)
            self._stability[p] = (page_stability(blk)
                                  if blk is not None else 0.0)

    def forget(self, pages: List[int]) -> None:
        """Device pages left the index without demoting (dropped)."""
        for p in pages:
            self._sig_of.pop(p, None)
            self._stability.pop(p, None)

    def stability(self, page: int) -> float:
        return self._stability.get(page, 0.0)

    # ---- representation policy --------------------------------------

    def _sig_is_int8(self, sig: Tuple) -> bool:
        # cache_signature = (proxy_dim, incremental, uses_cache, dtype)
        return len(sig) >= 4 and sig[3] == "int8"

    def _repr_for(self, sig: Tuple, stability: float,
                  exact_in: bool) -> Tuple[str, int, bool]:
        """(repr_, units_per_page, exact_out) for one page."""
        if self._sig_is_int8(sig):
            # already int8 bytes: exact round-trip at the cold rate
            return "exact", 1, exact_in
        if self.host_dtype == "f32":
            return "exact", 2, exact_in
        if self.host_dtype == "int8":
            return "int8", 1, False
        if stability >= self.stable_threshold:
            return "int8", 1, False
        return "exact", 2, exact_in

    # ---- demote ------------------------------------------------------

    def demote(self, pages: List[int],
               exact_in: bool = True) -> Optional[List[HostPageRef]]:
        """Move one eviction unit's device pages host-ward.  Returns
        one :class:`HostPageRef` per page, or None to DROP the whole
        unit (unknown signature, read path unwired, or the host budget
        can't take it — a tail is all-or-nothing: a partial tail can
        never serve a full hit).  The caller releases the device pages
        either way; the refs own the host slots until :meth:`promote`
        or :meth:`free_refs`."""
        if not pages or self.read_pages is None:
            return None
        sig = self._sig_of.get(pages[0])
        if sig is None or any(self._sig_of.get(p) != sig for p in pages):
            return None
        plan = [self._repr_for(sig, self.stability(p), exact_in)
                for p in pages]
        need = sum(u for _, u, _ in plan)
        if not self.host.fits(need):
            # under host pressure stable pages skip the tier entirely
            # (Sparse-dLLM: stable state is the cheap-to-recompute kind)
            if all(self.stability(p) >= self.stable_threshold
                   for p in pages):
                self.dropped_stable += len(pages)
            else:
                self.dropped_full += len(pages)
            return None
        if self.injector is not None and self.injector.fire("host_store"):
            # injected write failure: the tier refuses, the victim
            # drops — the same graceful path as a full host budget
            self.store_faults += 1
            self.dropped_full += len(pages)
            return None
        blocks = self.read_pages(sig, list(pages))
        refs: List[HostPageRef] = []
        for i, (p, (repr_, units, exact_out)) in enumerate(
                zip(pages, plan)):
            one = {kind: {name: b[:, i:i + 1] for name, b in bufs.items()}
                   for kind, bufs in blocks.items()}
            if repr_ == "int8":
                one = _quantize_blocks(one)
            slots = self.host.store(sig, repr_, units, one)
            assert slots is not None        # fits() checked above
            refs.append(HostPageRef(sig=sig, repr_=repr_, slot=slots[0],
                                    units=units, exact=exact_out,
                                    stability=self.stability(p),
                                    checksum=_blocks_checksum(one)))
        if self.injector is not None and self.injector.fire("host_corrupt"):
            r = refs[0]
            self.host.corrupt_slot(r.sig, r.repr_, r.slot)
        self.demoted_pages += len(pages)
        self.forget(pages)
        return refs

    # ---- promote -----------------------------------------------------

    def promote(self, refs: List[HostPageRef]):
        """Read the refs' pages back as DEVICE-layout blocks
        ({kind: {name: [Lk, n, page, ...]}}, int8 hosts dequantized)
        and free their host slots.  All refs must share one signature
        (one prefix entry, one arena set).

        Every ref's checksum is verified BEFORE any slot is freed or
        any byte heads device-ward; a mismatch frees the whole entry's
        slots (a partial promotion can never serve the hit) and raises
        :class:`HostPageCorruption` — the engine falls back to a cold
        prefill (DESIGN.md §10)."""
        assert refs
        sig = refs[0].sig
        assert all(r.sig == sig for r in refs)
        loaded = [self.host.load(sig, r.repr_, [r.slot]) for r in refs]
        bad = sum(1 for r, one in zip(refs, loaded)
                  if r.checksum and _blocks_checksum(one) != r.checksum)
        if bad:
            for r in refs:
                self.host.free(sig, r.repr_, [r.slot], r.units)
            self.checksum_failures += bad
            raise HostPageCorruption(
                f"{bad}/{len(refs)} host pages failed checksum "
                f"verification on promotion")
        outs = []
        for r, one in zip(refs, loaded):
            if r.repr_ == "int8":
                one = _dequantize_blocks(one)
            outs.append(one)
            self.host.free(sig, r.repr_, [r.slot], r.units)
        blocks = {
            kind: {name: np.concatenate([o[kind][name] for o in outs],
                                        axis=1)
                   for name in outs[0][kind]}
            for kind in outs[0]}
        self.promoted_pages += len(refs)
        self.host.pages_out += len(refs)
        return sig, blocks

    def note_promoted(self, sig: Tuple, pages: List[int],
                      refs: List[HostPageRef]) -> None:
        """Promoted pages are device pages again: keep their signature
        and carried stability so a re-demotion skips the re-score."""
        for p, r in zip(pages, refs):
            self._sig_of[p] = sig
            self._stability[p] = r.stability

    def free_refs(self, refs: List[HostPageRef]) -> None:
        """Drop host refs without promoting (index clear / supersede)."""
        for r in refs:
            self.host.free(r.sig, r.repr_, [r.slot], r.units)


def _blocks_checksum(blocks) -> int:
    """Order-stable crc32 over every buffer of one page's block tree —
    the host-page integrity checksum (DESIGN.md §10).  Computed over
    the STORED representation (post-quantization), so verification on
    promotion needs no recompute of the quantizer."""
    ck = 1
    for kind in sorted(blocks):
        for name in sorted(blocks[kind]):
            a = np.ascontiguousarray(blocks[kind][name])
            ck = zlib.crc32(a.tobytes(), ck)
    return ck


def _quantize_blocks(blocks):
    """int8-quantize every float buffer of a block tree (per-row scale
    stored as ``{name}_hscale``); integer buffers pass through."""
    out = {}
    for kind, bufs in blocks.items():
        out[kind] = {}
        for name, b in bufs.items():
            if np.issubdtype(np.asarray(b).dtype, np.integer):
                out[kind][name] = np.asarray(b)
            else:
                q, s = quantize_rows_np(b)
                out[kind][name] = q
                out[kind][name + _SCALE_SUFFIX] = s
    return out


def _dequantize_blocks(blocks):
    out = {}
    for kind, bufs in blocks.items():
        out[kind] = {}
        for name, b in bufs.items():
            if name.endswith(_SCALE_SUFFIX):
                continue
            s = bufs.get(name + _SCALE_SUFFIX)
            out[kind][name] = (b if s is None
                               else dequantize_rows_np(b, s))
    return out

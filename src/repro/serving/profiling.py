"""Compute-path profiling beneath the §11 telemetry facade (DESIGN.md §12).

Three cooperating pieces, all OFF by default (construct nothing and the
decode path is untouched):

  * :class:`StepProfiler` — device-time decomposition of the decode
    step.  In host-loop mode (``DecodeSession.run``/``step``) the
    session fences consecutive segments — ``refresh`` (cache rebuild +
    its sync), ``dispatch`` (Python → jitted-step call returning
    futures) and ``device_wait`` (``block_until_ready`` on the step
    result) — with ``time.perf_counter`` at each boundary, so the
    segments TILE the step: their sum equals the independently measured
    total up to clock granularity (tests assert this).  In
    ``run_compiled`` mode the whole ``lax.while_loop`` is one dispatch,
    so only loop-level timing is attributable (per-step averages are
    derived).  Observations land in the §11 registry
    (``spa_profile_*``) and, when a tracer is live, as slices on a
    dedicated device track in the Perfetto export.
  * :class:`KernelPhaseProbes` — per-phase attribution of the SPA
    pipeline (identify → gather → attend → scatter → page gather).
    The jitted serve step is one fused executable, so phases cannot be
    fenced inside it without changing the program; the probes instead
    REPLAY each phase through the session's own ``KernelBackend`` stage
    at cfg/strategy-derived shapes, jitted standalone and timed with a
    compile/steady split.  They never touch live session state —
    byte-identity with profiling on is structural, not incidental.
  * :class:`ProfileStore` — persisted per-(kernel, shape, backend,
    block-config) timing records (``BENCH_artifacts/
    kernel_profiles.json``), written by ``benchmarks/bench_kernels.py``
    and read by ``launch/hillclimb.py`` as its warm-start cache.

Everything here is host-side: observations happen between jitted calls,
never inside them, so decode outputs are byte-identical with profiling
on (tests/test_profiling.py asserts it per strategy × run mode ×
backend).
"""
from __future__ import annotations

import contextlib
import json
import os
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.serving.telemetry import (PID_DEVICE, Telemetry, TraceEvent)

__all__ = [
    "time_compile_steady", "StepProfiler", "KernelPhaseProbes",
    "ProfileStore", "default_profile_path",
]


def time_compile_steady(fn: Callable, *args,
                        reps: int = 5) -> Tuple[float, float]:
    """(first-call seconds, best-of-reps steady seconds) for a jitted
    callable.  The first call pays trace + lowering + backend compile;
    hiding it behind an untimed warmup (what the kernel bench used to
    do) makes amortization claims dishonest — ProfileStore records keep
    both numbers."""
    import jax
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    compile_s = time.perf_counter() - t0
    best = float("inf")
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return compile_s, best


class StepProfiler:
    """Fenced step-segment observation into registry + device track.

    ``sample_every=N`` fences every Nth step (1 = all); unsampled steps
    run the exact unprofiled path.  The profiler is handed to
    ``DecodeSession(profiler=...)`` / ``ServingEngine(profiler=...)``;
    sessions call :meth:`observe_step` / :meth:`observe_loop` with
    durations they measured around their own jitted calls.
    """

    SEGMENTS = ("refresh", "dispatch", "device_wait")

    def __init__(self, telemetry: Optional[Telemetry] = None, *,
                 sample_every: int = 1,
                 jax_trace_dir: Optional[str] = None):
        self.telemetry = telemetry or Telemetry.disabled()
        self.registry = self.telemetry.registry
        self.tracer = self.telemetry.tracer
        self.sample_every = max(int(sample_every), 1)
        self.jax_trace_dir = jax_trace_dir
        self.steps_observed = 0
        self.loops_observed = 0
        self._lane_tids: Dict[str, int] = {}

    # ---- sampling ----------------------------------------------------

    def should_sample(self, step_idx: int) -> bool:
        return step_idx % self.sample_every == 0

    # ---- observation (called by DecodeSession) -----------------------

    def _tid(self, lane: str) -> int:
        tid = self._lane_tids.get(lane)
        if tid is None:
            tid = len(self._lane_tids) + 1
            self._lane_tids[lane] = tid
            self.tracer.name_track(PID_DEVICE, tid, f"device:{lane}")
        return tid

    def _hist(self, segment: str):
        return self.registry.histogram(
            "spa_profile_step_seconds",
            "fenced decode-step segment durations (host-loop mode)",
            labels={"segment": segment})

    def observe_step(self, lane: str, segments: Dict[str, float],
                     total_s: float) -> None:
        """One fenced host-loop step: ``segments`` tile ``total_s``."""
        self.steps_observed += 1
        for seg, dt in segments.items():
            self._hist(seg).observe(dt)
        self._hist("total").observe(total_s)
        if self.tracer.enabled:
            tid = self._tid(lane)
            end = float(self.tracer.clock())
            t = end - total_s
            for seg, dt in segments.items():
                self.tracer.events.append(TraceEvent(
                    name=f"step:{seg}", ph="X", ts=t, dur=dt,
                    pid=PID_DEVICE, tid=tid, cat="device"))
                t += dt

    def observe_loop(self, lane: str, steps: int,
                     total_s: float) -> None:
        """One ``run_compiled`` while_loop: loop-level only (per-step
        averages derived; phases are not attributable — DESIGN.md §12)."""
        self.loops_observed += 1
        self.registry.histogram(
            "spa_profile_loop_seconds",
            "whole compiled-loop durations (run_compiled mode)",
        ).observe(total_s)
        self.registry.counter(
            "spa_profile_loop_steps_total",
            "decode steps executed inside compiled loops").inc(steps)
        if steps > 0:
            self.registry.histogram(
                "spa_profile_loop_step_seconds",
                "derived per-step average inside compiled loops",
            ).observe(total_s / steps)
        if self.tracer.enabled:
            tid = self._tid(lane)
            end = float(self.tracer.clock())
            self.tracer.events.append(TraceEvent(
                name=f"loop[{steps} steps]", ph="X", ts=end - total_s,
                dur=total_s, pid=PID_DEVICE, tid=tid, cat="device"))

    # ---- optional jax.profiler wrap ----------------------------------

    @contextlib.contextmanager
    def jax_trace(self):
        """Wrap a run in ``jax.profiler.trace`` when a trace dir was
        requested and the runtime supports it; no-op otherwise."""
        if not self.jax_trace_dir:
            yield
            return
        try:
            import jax.profiler
            cm = jax.profiler.trace(self.jax_trace_dir)
        except Exception:
            yield
            return
        with cm:
            yield

    # ---- summaries ---------------------------------------------------

    def step_breakdown(self) -> Dict[str, Dict[str, float]]:
        """{segment: {count, mean_s, p50_s, p95_s, share}} from the
        recorded histograms (share = segment sum / total-segment sum).
        Empty when nothing was observed — zero-request safe."""
        out: Dict[str, Dict[str, float]] = {}
        total_sum = 0.0
        hists = {}
        for seg in self.SEGMENTS + ("total",):
            h = self._hist(seg)
            if h.count:
                hists[seg] = h
                if seg == "total":
                    total_sum = h.sum
        for seg, h in hists.items():
            out[seg] = {
                "count": h.count, "mean_s": h.mean,
                "p50_s": h.percentile(50), "p95_s": h.percentile(95),
                "share": (h.sum / total_sum) if total_sum else 0.0,
            }
        return out

    def format_summary(self) -> str:
        """Human-oriented decomposition for serve.py ``--profile``."""
        lines: List[str] = []
        bd = self.step_breakdown()
        if bd:
            lines.append("step-time decomposition (host-loop, fenced):")
            for seg in self.SEGMENTS + ("total",):
                row = bd.get(seg)
                if row is None:
                    continue
                lines.append(
                    f"  {seg:<12s} n={row['count']:<6d}"
                    f" mean={row['mean_s'] * 1e3:8.3f}ms"
                    f" p95={row['p95_s'] * 1e3:8.3f}ms"
                    f" share={row['share']:6.1%}")
        loop_h = self.registry.histogram(
            "spa_profile_loop_seconds",
            "whole compiled-loop durations (run_compiled mode)")
        if loop_h.count:
            step_h = self.registry.histogram(
                "spa_profile_loop_step_seconds",
                "derived per-step average inside compiled loops")
            lines.append(
                f"compiled loops: n={loop_h.count}"
                f" mean={loop_h.mean * 1e3:.3f}ms"
                f" per-step={step_h.mean * 1e3:.3f}ms (derived)")
        if not lines:
            return "  (no profiled steps recorded)"
        return "\n".join("  " + ln for ln in lines)


class KernelPhaseProbes:
    """Synthetic per-phase replay of the SPA pipeline through a
    KernelBackend (identify → gather → attend → scatter → page_gather).

    Shapes derive from (cfg, strategy): proxy rank, head layout and
    d_model are the real ones; canvas length and selection width are
    probe parameters.  Each probe is jitted standalone and timed with
    the compile/steady split, recording
    ``spa_profile_phase_seconds{phase=,backend=}`` histograms.
    """

    def __init__(self, cfg, *, strategy=None, backend=None,
                 batch: int = 2, seq: int = 128,
                 n_selected: Optional[int] = None, page: int = 16,
                 registry=None):
        from repro.core.strategy import resolve_strategy
        from repro.kernels.backend import resolve_backend
        self.cfg = cfg
        self.strategy = resolve_strategy(cfg, strategy)
        self.backend = (resolve_backend(backend) if backend is not None
                        else self.strategy.backend)
        self.batch = batch
        self.seq = seq
        self.n_selected = n_selected or max(8, seq // 4)
        self.page = page
        self.registry = registry

    def _build(self) -> Dict[str, Tuple[Callable, tuple]]:
        import jax
        import jax.numpy as jnp
        cfg, strat, bk = self.cfg, self.strategy, self.backend
        b, n, k = self.batch, self.seq, self.n_selected
        d, hh, kvh, hd = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                          cfg.head_dim)
        keys = jax.random.split(jax.random.PRNGKey(0), 8)
        x = jax.random.normal(keys[0], (b, n, d))
        idx = jnp.sort(jax.random.randint(keys[1], (b, k), 0, n))
        norm_w = jax.random.normal(keys[2], (d,)) * 0.1
        q = jax.random.normal(keys[3], (b, k, hh, hd))
        kk = jax.random.normal(keys[4], (b, n, kvh, hd))
        vv = jax.random.normal(keys[5], (b, n, kvh, hd))
        probes: Dict[str, Tuple[Callable, tuple]] = {}
        r = strat.proxy_dim(cfg)
        if r:
            p_now = jax.random.normal(keys[6], (b, n, r))
            p_cached = jax.random.normal(keys[7], (b, n, r))
            probes["identify"] = (
                jax.jit(lambda pn, pc: bk.score_drift(strat, pn, pc)),
                (p_now, p_cached))
        probes["gather"] = (
            jax.jit(lambda h, i, w: bk.gather_norm(h, i, w,
                                                   cfg.norm_eps)),
            (x, idx, norm_w))
        probes["attend"] = (
            jax.jit(lambda a, c, e, i: bk.attention(a, c, e,
                                                    q_positions=i)),
            (q, kk, vv, idx))
        rows_k = jax.random.normal(keys[6], (b, k, kvh, hd))
        rows_h = jax.random.normal(keys[7], (b, k, d))
        probes["scatter"] = (
            jax.jit(lambda bk_, bv_, bh_, i, rk, rv, rh: bk.scatter_multi(
                {"k": bk_, "v": bv_, "h": bh_}, i,
                {"k": rk, "v": rv, "h": rh})),
            (kk, vv, x, idx, rows_k, rows_k, rows_h))
        n_log = max(n // self.page, 1)
        n_pages = b * n_log + 1
        arena = jax.random.normal(keys[0], (1, n_pages, self.page, hd))
        ptab = jax.random.randint(keys[1], (b, n_log), 0, n_pages)
        probes["page_gather"] = (
            jax.jit(lambda a, pt: bk.gather_pages(a, pt)), (arena, ptab))
        return probes

    def run(self, reps: int = 3) -> Dict[str, Dict[str, float]]:
        """Time every phase probe; returns (and records)
        {phase: {compile_s, steady_s}}."""
        out: Dict[str, Dict[str, float]] = {}
        bname = getattr(self.backend, "name",
                        type(self.backend).__name__)
        for phase, (fn, args) in self._build().items():
            compile_s, steady_s = time_compile_steady(fn, *args,
                                                      reps=reps)
            out[phase] = {"compile_s": compile_s, "steady_s": steady_s}
            if self.registry is not None:
                labels = {"phase": phase, "backend": bname}
                self.registry.histogram(
                    "spa_profile_phase_seconds",
                    "synthetic per-phase replay (steady state)",
                    labels=labels).observe(steady_s)
                self.registry.histogram(
                    "spa_profile_phase_compile_seconds",
                    "synthetic per-phase replay (first call)",
                    labels=labels).observe(compile_s)
        return out


def default_profile_path() -> str:
    """``BENCH_artifacts/kernel_profiles.json`` at the repo root (next
    to the other bench artifacts), wherever the caller runs from."""
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    return os.path.join(root, "BENCH_artifacts", "kernel_profiles.json")


class ProfileStore:
    """JSON-persisted timing records keyed on canonical key strings.

    Records are arbitrary JSON dicts keyed by sorted ``k=v`` pairs
    (``backend=xla|kernel=sparse_attention|shape=b2n256...``) — the
    kernel bench writes per-(kernel, shape, backend, block-config)
    entries and ``launch/hillclimb.py`` reads/writes per-(arch, shape,
    mesh, variant) entries into the same file, which is what makes the
    store the autotuner's warm-start cache.
    """

    VERSION = 1

    def __init__(self, path: Optional[str] = None):
        self.path = path or default_profile_path()
        self._records: Dict[str, Dict[str, Any]] = {}
        self.load()

    @staticmethod
    def key_of(**key: Any) -> str:
        return "|".join(f"{k}={key[k]}" for k in sorted(key))

    def load(self) -> None:
        if not os.path.exists(self.path):
            return
        try:
            with open(self.path) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError):
            return
        if isinstance(data, dict):
            recs = data.get("records")
            if isinstance(recs, dict):
                self._records = recs

    def save(self) -> None:
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        with open(self.path, "w") as f:
            json.dump({"version": self.VERSION,
                       "records": self._records}, f, indent=1,
                      sort_keys=True)

    def get(self, **key: Any) -> Optional[Dict[str, Any]]:
        return self._records.get(self.key_of(**key))

    def put(self, record: Dict[str, Any], **key: Any) -> None:
        self._records[self.key_of(**key)] = {
            "key": {k: key[k] for k in sorted(key)}, **record}

    def __len__(self) -> int:
        return len(self._records)

    @property
    def records(self) -> Dict[str, Dict[str, Any]]:
        return dict(self._records)

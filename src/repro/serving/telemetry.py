"""Unified metrics registry + span tracer for the serving stack (DESIGN.md §11).

Two cooperating pieces, both stamped from the engine's injectable clock
so a chaos replay and its trace can be diffed line-for-line:

  * :class:`MetricsRegistry` — counters / gauges / histograms with
    labels.  Histograms are fixed-bucket for Prometheus exposition but
    ALSO retain raw samples, so ``percentile(q)`` is exact (matches
    ``numpy.percentile``) — this single-sources the p50/p95 math that
    used to be copy-pasted across ``EngineStats``.  The registry
    renders Prometheus text format (``render()``) and a JSON-able
    ``snapshot()`` for benches.
  * :class:`Tracer` — per-request lifecycle spans and per-iteration
    engine-phase spans on (pid, tid) tracks, exported as Chrome trace
    event JSON (``{"traceEvents": [...]}``) that loads directly in
    Perfetto / chrome://tracing.  Spans nest per track; the tracer
    refuses double-closes and can report orphans, which the tests
    assert on.

Naming conventions (enforced by convention, documented in DESIGN.md §11):
metric names are ``spa_<subsystem>_<quantity>[_<unit>]`` with
subsystem one of ``engine|pool|prefix|tier|slo|fault|cache``; durations
are ``_seconds``, sizes ``_pages``/``_tokens``, ratios ``_ratio``.

Everything here is host-side bookkeeping: nothing touches the compiled
decode loop, so decode outputs are byte-identical with telemetry on
(tests/test_telemetry.py asserts engine-level parity).
"""
from __future__ import annotations

import dataclasses
import json
import math
import time
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "Span", "TraceEvent", "Tracer", "Telemetry",
    "DEFAULT_LATENCY_BUCKETS", "percentile",
]

LabelKV = Tuple[Tuple[str, str], ...]

# Latency-ish default buckets (seconds / steps): 1e-4 .. ~1e3, log-spaced.
DEFAULT_LATENCY_BUCKETS: Tuple[float, ...] = tuple(
    round(10.0 ** (e / 3.0), 6) for e in range(-12, 10)
)


def percentile(samples: Iterable[float], q: float) -> float:
    """Exact percentile with linear interpolation — the same estimator
    as ``numpy.percentile(..., method="linear")``.  Single source for
    every p50/p95 in the serving stack."""
    xs = sorted(float(x) for x in samples)
    if not xs:
        return 0.0
    if len(xs) == 1:
        return xs[0]
    pos = (q / 100.0) * (len(xs) - 1)
    lo = int(math.floor(pos))
    hi = min(lo + 1, len(xs) - 1)
    frac = pos - lo
    return xs[lo] * (1.0 - frac) + xs[hi] * frac


def _labels_kv(labels: Optional[Dict[str, str]]) -> LabelKV:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _render_labels(kv: LabelKV) -> str:
    if not kv:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in kv)
    return "{" + inner + "}"


class Counter:
    """Monotonic counter.  ``inc`` only; negative increments are bugs."""

    kind = "counter"

    def __init__(self, name: str, help: str = "",
                 labels: Optional[Dict[str, str]] = None):
        self.name = name
        self.help = help
        self.labels = _labels_kv(labels)
        self.value: float = 0.0

    def inc(self, n: float = 1.0) -> None:
        assert n >= 0, f"counter {self.name} decremented by {n}"
        self.value += n

    def set(self, v: float) -> None:
        """Absolute set — for counters mirrored from an existing
        monotonic source (EngineStats ints)."""
        self.value = float(v)


class Gauge:
    """Point-in-time value (occupancy, depth, level)."""

    kind = "gauge"

    def __init__(self, name: str, help: str = "",
                 labels: Optional[Dict[str, str]] = None):
        self.name = name
        self.help = help
        self.labels = _labels_kv(labels)
        self.value: float = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Histogram:
    """Fixed-bucket histogram that also retains raw samples.

    The buckets feed Prometheus exposition (cumulative ``_bucket``
    series); the retained samples make ``percentile`` EXACT, matching
    ``numpy.percentile`` — serving runs here are small enough (10^2-10^4
    observations) that retaining floats is cheaper than being wrong
    about tail latency.  ``max_samples`` caps retention for long-lived
    daemons; past the cap percentiles degrade gracefully to the
    bucket-implied estimate.

    Also list-compatible (``len`` / ``append`` / iteration) so existing
    call sites and tests treating ``EngineStats.e2e_latencies`` as a
    list keep working unchanged.
    """

    kind = "histogram"

    def __init__(self, name: str = "", help: str = "",
                 buckets: Tuple[float, ...] = DEFAULT_LATENCY_BUCKETS,
                 labels: Optional[Dict[str, str]] = None,
                 max_samples: int = 100_000):
        self.name = name
        self.help = help
        self.labels = _labels_kv(labels)
        self.buckets = tuple(sorted(buckets))
        self.bucket_counts = [0] * (len(self.buckets) + 1)  # +inf tail
        self.count = 0
        self.sum = 0.0
        self.max_samples = max_samples
        self.samples: List[float] = []

    def observe(self, x: float) -> None:
        x = float(x)
        self.count += 1
        self.sum += x
        i = self._bucket_index(x)
        self.bucket_counts[i] += 1
        if len(self.samples) < self.max_samples:
            self.samples.append(x)

    # list-compat shims (EngineStats latency fields were List[float])
    append = observe

    def extend(self, xs: Iterable[float]) -> None:
        for x in xs:
            self.observe(x)

    def __len__(self) -> int:
        return self.count

    def __iter__(self):
        return iter(self.samples)

    def __bool__(self) -> bool:
        return self.count > 0

    def _bucket_index(self, x: float) -> int:
        lo, hi = 0, len(self.buckets)
        while lo < hi:
            mid = (lo + hi) // 2
            if x <= self.buckets[mid]:
                hi = mid
            else:
                lo = mid + 1
        return lo

    def percentile(self, q: float) -> float:
        """Exact when samples are fully retained (the common case);
        bucket-upper-bound estimate past ``max_samples``."""
        if self.count <= len(self.samples):
            return percentile(self.samples, q)
        target = (q / 100.0) * self.count
        seen = 0
        for i, c in enumerate(self.bucket_counts):
            seen += c
            if seen >= target:
                return (self.buckets[i] if i < len(self.buckets)
                        else self.buckets[-1])
        return self.buckets[-1] if self.buckets else 0.0

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0


class MetricsRegistry:
    """Get-or-create store of metrics keyed on (name, labels)."""

    def __init__(self) -> None:
        self._metrics: Dict[Tuple[str, LabelKV], Any] = {}
        self._help: Dict[str, str] = {}
        # collectors run just before render()/snapshot() so gauges that
        # mirror live engine state (occupancy, queue depth) are fresh.
        self._collectors: List[Callable[[], None]] = []

    def _get(self, cls, name: str, help: str,
             labels: Optional[Dict[str, str]], **kw):
        key = (name, _labels_kv(labels))
        m = self._metrics.get(key)
        if m is None:
            m = cls(name, help or self._help.get(name, ""),
                    labels=labels, **kw)
            self._metrics[key] = m
            if help:
                self._help[name] = help
        assert m.kind == cls.kind, \
            f"metric {name} re-registered as {cls.kind}, was {m.kind}"
        return m

    def counter(self, name: str, help: str = "",
                labels: Optional[Dict[str, str]] = None) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Optional[Dict[str, str]] = None) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Optional[Dict[str, str]] = None,
                  buckets: Tuple[float, ...] = DEFAULT_LATENCY_BUCKETS,
                  ) -> Histogram:
        return self._get(Histogram, name, help, labels, buckets=buckets)

    def adopt(self, hist: Histogram, name: str, help: str = "",
              labels: Optional[Dict[str, str]] = None) -> Histogram:
        """Register an externally-owned histogram (EngineStats owns its
        latency histograms so `eng.stats = type(eng.stats)()` resets
        still work; the registry renders whatever is adopted last)."""
        hist.name = name
        if help:
            hist.help = help
        hist.labels = _labels_kv(labels)
        self._metrics[(name, hist.labels)] = hist
        if help:
            self._help[name] = help
        return hist

    def add_collector(self, fn: Callable[[], None]) -> None:
        self._collectors.append(fn)

    def collect(self) -> None:
        for fn in self._collectors:
            fn()

    # ---- exposition ---------------------------------------------------

    def _grouped(self) -> Dict[str, List[Any]]:
        groups: Dict[str, List[Any]] = {}
        for (name, _), m in sorted(self._metrics.items()):
            groups.setdefault(name, []).append(m)
        return groups

    @staticmethod
    def _fmt(v: float) -> str:
        if v == math.inf:
            return "+Inf"
        if float(v).is_integer() and abs(v) < 1e15:
            return str(int(v))
        return repr(float(v))

    def render(self) -> str:
        """Prometheus text exposition format 0.0.4."""
        self.collect()
        out: List[str] = []
        for name, metrics in self._grouped().items():
            kind = metrics[0].kind
            help_txt = self._help.get(name) or metrics[0].help
            if help_txt:
                out.append(f"# HELP {name} {help_txt}")
            out.append(f"# TYPE {name} {kind}")
            for m in metrics:
                if kind == "histogram":
                    cum = 0
                    for ub, c in zip(m.buckets, m.bucket_counts):
                        cum += c
                        kv = m.labels + (("le", self._fmt(ub)),)
                        out.append(f"{name}_bucket{_render_labels(kv)}"
                                   f" {cum}")
                    kv = m.labels + (("le", "+Inf"),)
                    out.append(f"{name}_bucket{_render_labels(kv)}"
                               f" {m.count}")
                    out.append(f"{name}_sum{_render_labels(m.labels)}"
                               f" {self._fmt(m.sum)}")
                    out.append(f"{name}_count{_render_labels(m.labels)}"
                               f" {m.count}")
                else:
                    out.append(f"{name}{_render_labels(m.labels)}"
                               f" {self._fmt(m.value)}")
        return "\n".join(out) + "\n"

    def snapshot(self) -> Dict[str, Any]:
        """JSON-able registry dump (bench output embeds this)."""
        self.collect()
        snap: Dict[str, Any] = {}
        for (name, kv), m in sorted(self._metrics.items()):
            key = name + _render_labels(kv)
            if m.kind == "histogram":
                snap[key] = {
                    "count": m.count, "sum": round(m.sum, 9),
                    "mean": round(m.mean, 9),
                    "p50": round(m.percentile(50), 9),
                    "p95": round(m.percentile(95), 9),
                }
            else:
                snap[key] = m.value
        return snap

    def format_summary(self, skip_zero: bool = False) -> str:
        """Human-oriented registry dump for serve.py end-of-run output.
        Renders cleanly with zero observations everywhere;
        ``skip_zero`` drops never-incremented metrics for a compact
        default summary."""
        self.collect()
        lines: List[str] = []
        by_sub: Dict[str, List[str]] = {}
        for (name, kv), m in sorted(self._metrics.items()):
            parts = name.split("_")
            sub = parts[1] if len(parts) > 2 and parts[0] == "spa" \
                else "misc"
            label = name + _render_labels(kv)
            if m.kind == "histogram":
                if skip_zero and not m.count:
                    continue
                if m.count:
                    row = (f"  {label:<52s} n={m.count:<7d}"
                           f" mean={m.mean:.4g}"
                           f" p50={m.percentile(50):.4g}"
                           f" p95={m.percentile(95):.4g}")
                else:
                    row = f"  {label:<52s} n=0"
            else:
                if skip_zero and not m.value:
                    continue
                row = f"  {label:<52s} {self._fmt(m.value)}"
            by_sub.setdefault(sub, []).append(row)
        if not by_sub:
            return "  (no metrics recorded)"
        for sub in sorted(by_sub):
            lines.append(f"[{sub}]")
            lines.extend(by_sub[sub])
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------

# Track (pid) assignments for the Chrome trace. Perfetto shows one
# process group per pid; request tracks get tid = request uid.
PID_ENGINE = 1
PID_REQUESTS = 2
PID_EVENTS = 3
PID_DEVICE = 4      # step/loop device-time slices (serving/profiling.py)


@dataclasses.dataclass
class TraceEvent:
    """One Chrome-trace event. ``ph``: X=complete span, i=instant,
    C=counter, M=metadata.  ``ts``/``dur`` are in engine-clock seconds
    here; export converts to microseconds."""
    name: str
    ph: str
    ts: float
    pid: int
    tid: int
    dur: float = 0.0
    cat: str = ""
    args: Optional[Dict[str, Any]] = None

    def to_chrome(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {
            "name": self.name, "ph": self.ph,
            "ts": round(self.ts * 1e6, 3),
            "pid": self.pid, "tid": self.tid,
        }
        if self.ph == "X":
            d["dur"] = round(self.dur * 1e6, 3)
        if self.cat:
            d["cat"] = self.cat
        if self.ph == "i":
            d["s"] = "t"  # thread-scoped instant
        if self.args is not None:
            d["args"] = self.args
        return d


@dataclasses.dataclass
class Span:
    name: str
    pid: int
    tid: int
    t0: float
    cat: str = ""
    args: Optional[Dict[str, Any]] = None
    closed: bool = False


class Tracer:
    """Span tracer over (pid, tid) tracks with per-track nesting.

    ``begin``/``end`` maintain a stack per track; ``end`` closes the
    innermost open span (optionally checked by name) and emits a
    complete-event.  Ending an already-closed span raises — the
    continuity tests lean on that.  When disabled every call is a
    near-free early return, which is what keeps the telemetry-off
    fast path at zero cost.
    """

    def __init__(self, clock: Optional[Callable[[], float]] = None,
                 enabled: bool = True):
        self.enabled = enabled
        self.clock = clock or time.time
        self.events: List[TraceEvent] = []
        self._stacks: Dict[Tuple[int, int], List[Span]] = {}
        self._track_names: Dict[Tuple[int, int], str] = {}

    def _now(self) -> float:
        return float(self.clock())

    def name_track(self, pid: int, tid: int, name: str) -> None:
        if not self.enabled:
            return
        self._track_names[(pid, tid)] = name

    def begin(self, pid: int, tid: int, name: str, cat: str = "",
              args: Optional[Dict[str, Any]] = None) -> Optional[Span]:
        if not self.enabled:
            return None
        sp = Span(name=name, pid=pid, tid=tid, t0=self._now(),
                  cat=cat, args=dict(args) if args else None)
        self._stacks.setdefault((pid, tid), []).append(sp)
        return sp

    def end(self, pid: int, tid: int, name: Optional[str] = None,
            args: Optional[Dict[str, Any]] = None) -> Optional[Span]:
        if not self.enabled:
            return None
        stack = self._stacks.get((pid, tid)) or []
        if not stack:
            raise RuntimeError(
                f"end('{name}') on track ({pid},{tid}) with no open span")
        sp = stack[-1]
        if name is not None and sp.name != name:
            raise RuntimeError(
                f"end('{name}') but innermost open span on track "
                f"({pid},{tid}) is '{sp.name}'")
        if sp.closed:
            raise RuntimeError(f"span '{sp.name}' double-closed")
        stack.pop()
        sp.closed = True
        if args:
            sp.args = {**(sp.args or {}), **args}
        self.events.append(TraceEvent(
            name=sp.name, ph="X", ts=sp.t0, dur=self._now() - sp.t0,
            pid=pid, tid=tid, cat=sp.cat, args=sp.args))
        return sp

    def close_track(self, pid: int, tid: int,
                    args: Optional[Dict[str, Any]] = None) -> int:
        """Close every open span on a track, innermost first (request
        teardown on abort/shed — guarantees no orphans)."""
        if not self.enabled:
            return 0
        n = 0
        while self._stacks.get((pid, tid)):
            self.end(pid, tid, args=args)
            n += 1
        return n

    def instant(self, pid: int, tid: int, name: str, cat: str = "",
                args: Optional[Dict[str, Any]] = None) -> None:
        if not self.enabled:
            return
        self.events.append(TraceEvent(
            name=name, ph="i", ts=self._now(), pid=pid, tid=tid,
            cat=cat, args=dict(args) if args else None))

    def counter(self, pid: int, name: str,
                values: Dict[str, float]) -> None:
        """Counter-track sample (occupancy timelines)."""
        if not self.enabled:
            return
        self.events.append(TraceEvent(
            name=name, ph="C", ts=self._now(), pid=pid, tid=0,
            args={k: float(v) for k, v in values.items()}))

    # ---- inspection (tests) -------------------------------------------

    def open_spans(self) -> List[Span]:
        return [sp for st in self._stacks.values() for sp in st]

    def span_events(self, pid: Optional[int] = None,
                    tid: Optional[int] = None) -> List[TraceEvent]:
        return [e for e in self.events if e.ph == "X"
                and (pid is None or e.pid == pid)
                and (tid is None or e.tid == tid)]

    def event_stream(self) -> List[Tuple]:
        """Canonical (ph, name, ts, pid, tid, args) tuples — the
        determinism tests diff two of these."""
        return [(e.ph, e.name, round(e.ts, 9), e.pid, e.tid,
                 tuple(sorted((e.args or {}).items())))
                for e in self.events]

    # ---- export -------------------------------------------------------

    def to_chrome_trace(self) -> Dict[str, Any]:
        evs: List[Dict[str, Any]] = []
        for (pid, tid), name in sorted(self._track_names.items()):
            evs.append({"name": "thread_name", "ph": "M", "pid": pid,
                        "tid": tid, "args": {"name": name}})
        for pid, pname in ((PID_ENGINE, "engine"),
                           (PID_REQUESTS, "requests"),
                           (PID_EVENTS, "events"),
                           (PID_DEVICE, "device")):
            evs.append({"name": "process_name", "ph": "M", "pid": pid,
                        "tid": 0, "args": {"name": pname}})
        evs.extend(e.to_chrome() for e in self.events)
        return {"traceEvents": evs, "displayTimeUnit": "ms"}

    def export(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)


class Telemetry:
    """Facade bundling registry + tracer + cache-dynamics cadence.

    ``Telemetry.disabled()`` is the default everywhere: the registry
    still exists (metric objects are only materialized when something
    renders them) but the tracer early-returns and cache-dynamics
    sampling is off, so the engine hot loop pays one attribute check.
    """

    def __init__(self, *, registry: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None,
                 clock: Optional[Callable[[], float]] = None,
                 dynamics_every: int = 0):
        self.registry = registry or MetricsRegistry()
        self.tracer = tracer or Tracer(clock=clock, enabled=False)
        if clock is not None:
            self.tracer.clock = clock
        # 0 = off; N = sample DecodeSession.cache_dynamics() every N
        # committed steps (host-side proxy diffing — DESIGN.md §11).
        self.dynamics_every = int(dynamics_every)

    @classmethod
    def disabled(cls) -> "Telemetry":
        return cls(tracer=Tracer(enabled=False))

    @classmethod
    def enabled(cls, clock: Optional[Callable[[], float]] = None,
                dynamics_every: int = 1) -> "Telemetry":
        return cls(tracer=Tracer(clock=clock, enabled=True),
                   clock=clock, dynamics_every=dynamics_every)

    @property
    def tracing(self) -> bool:
        return self.tracer.enabled

"""Engine supervisor: invariants, quarantine, watchdog, degradation.

This is the *containment* half of the fault-tolerance story (the
injection half lives in :mod:`repro.serving.faults`).  An
:class:`EngineSupervisor` attaches to a :class:`ServingEngine` and is
called once per step-loop iteration at the loop's quiescent point.  It
provides four services (DESIGN.md §10):

invariant checker
    Page accounting must close on every check: the pool's used count
    equals the union of engine-held pages (running + queued request
    ``pages``/``holds``) and index-held pages (radix-trie nodes/tails),
    with per-page refcounts matching exactly; the host tier's resident
    slots stay in lockstep with the trie's host entries; each request's
    emitted-token count is monotone; a completed request's output never
    mutates after finalization.  A violation raises
    :class:`InvariantViolation` immediately — leaks are bugs, not
    telemetry.

NaN/Inf canvas guard
    ``serve_step`` exports per-row finiteness of the step's hidden
    states; the guard marks any live row that went non-finite as
    fault-poisoned.  The engine aborts *only* that request and re-queues
    its lane-mates from preemption snapshots, so one poisoned canvas
    never taints a batch.

virtual-clock watchdog
    Counts consecutive loop iterations with no progress (no commits, no
    finish, no swap).  Past the budget it tells the engine to
    force-preempt every live row and tear the lane down — stuck lanes
    (injected or real) become bounded-latency preemptions instead of
    deadlocks.

degradation ladder
    Windowed fault pressure (injector fires + engine-detected events)
    walks service level L0→L3, shedding capability in a declared order:

      L1  pause prefix publication (stop growing shared state)
      L2  + bypass the host tier (no demotions, no promotions)
      L3  + shed low-priority queued work, tighten SLO shedding

    and walks back one rung per quiet ``cooldown`` window.  Every
    transition lands in ``EngineStats.degradation_events``.
"""
from __future__ import annotations

import dataclasses
import zlib
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.serving import telemetry


class InvariantViolation(AssertionError):
    """A serving-runtime accounting invariant failed to close."""


@dataclasses.dataclass(frozen=True)
class SupervisorConfig:
    max_alloc_retries: int = 3     # admission alloc retries before abort
    watchdog_budget: int = 24      # no-progress iterations before recovery
    check_every: int = 1           # invariant-check cadence (iterations)
    pressure_window: int = 32      # steps a fault event stays "hot"
    escalate_at: int = 3           # hot events to climb one rung
    cooldown: int = 24             # quiet steps to descend one rung
    shed_below: int = 0            # L3: shed queued priority < this
    hopeless_margin: float = 0.0   # L3: extra slack (s) for SLO shedding


class EngineSupervisor:
    """Wraps a :class:`ServingEngine` step loop with fault containment.

    Construction attaches the supervisor to the engine
    (``engine.supervisor = self``); the engine then calls
    :meth:`nan_guard`, :meth:`watchdog` and :meth:`on_iteration` from
    inside ``_run_lane`` and consults the ladder flags it maintains.
    """

    def __init__(self, engine, cfg: Optional[SupervisorConfig] = None):
        self.engine = engine
        self.cfg = cfg or SupervisorConfig()
        engine.supervisor = self
        self.level = 0
        self._events: Deque[int] = deque()   # steps of pressure events
        self._fired_seen = 0                 # injector fires adopted
        self._no_progress = 0
        self._iter = 0
        self._emitted_seen: Dict[int, int] = {}
        self._done_crc: Dict[int, int] = {}
        self._last_change = -(1 << 30)       # step of last ladder move

    # ---- NaN/Inf canvas guard ---------------------------------------

    def nan_guard(self, info, slots) -> List[int]:
        """Mark live rows whose step hidden states went non-finite.

        Only rows with a live request are examined: released/inactive
        rows legitimately produce non-finite activations (fully masked
        attention).  Returns the poisoned row indices; the engine
        aborts those requests and preempts their lane-mates."""
        row_finite = info.get("row_finite")
        if row_finite is None:
            return []
        finite = np.asarray(row_finite)
        bad = []
        for i, req in enumerate(slots):
            if req is None or req.canceled or req.fault is not None:
                continue
            if not bool(finite[i]):
                req.fault = "nan"
                bad.append(i)
        if bad:
            self.note_pressure("step_nan")
        return bad

    # ---- virtual-clock watchdog -------------------------------------

    def lane_started(self) -> None:
        self._no_progress = 0

    def watchdog(self, progressed: bool) -> bool:
        """True when the lane exhausted its no-progress budget and must
        be force-preempted (the engine performs the recovery)."""
        if progressed:
            self._no_progress = 0
            return False
        self._no_progress += 1
        if self._no_progress >= self.cfg.watchdog_budget:
            self._no_progress = 0
            return True
        return False

    # ---- fault pressure + degradation ladder ------------------------

    def note_pressure(self, kind: str) -> None:  # noqa: ARG002 - telemetry tag
        self._events.append(self.engine.stats.steps)

    def on_iteration(self) -> None:
        """Per-iteration quiescent hook: adopt injector fires into the
        pressure window, update the ladder, run the invariant check."""
        eng = self.engine
        step = eng.stats.steps
        if eng.faults is not None:
            fired = eng.faults.total_fired
            for _ in range(fired - self._fired_seen):
                self._events.append(step)
            self._fired_seen = fired
            eng.stats.faults_injected = fired
        lo = step - self.cfg.pressure_window
        while self._events and self._events[0] <= lo:
            self._events.popleft()
        if (len(self._events) >= self.cfg.escalate_at and self.level < 3
                and step > self._last_change):
            self._set_level(self.level + 1, step)
        elif (not self._events and self.level > 0
              and step - self._last_change >= self.cfg.cooldown):
            self._set_level(self.level - 1, step)
        self._iter += 1
        if self._iter % max(1, self.cfg.check_every) == 0:
            self.check_invariants()

    def _set_level(self, new: int, step: int) -> None:
        eng = self.engine
        if new > self.level:
            eng.stats.degradations += 1
        else:
            eng.stats.restorations += 1
        # ladder transitions share the fault-event trace schema
        # (DESIGN.md §11) so a chaos replay and its trace can be diffed
        tr = getattr(eng, "_tr", None)
        if tr is not None:
            tr.instant(telemetry.PID_EVENTS, 2, "ladder", cat="fault",
                       args={"from": self.level, "to": new,
                             "step": step})
        self.level = new
        self._last_change = step
        eng.stats.degrade_level = new
        eng.stats.degradation_events.append((step, new))
        eng._publish_paused = new >= 1
        eng._host_tier_paused = new >= 2
        if eng.prefix is not None:
            eng.prefix.demote_paused = new >= 2
        eng._shed_low_priority = new >= 3
        eng._shed_below = self.cfg.shed_below
        eng._hopeless_margin = (self.cfg.hopeless_margin
                                if new >= 3 else 0.0)

    # ---- invariant checker ------------------------------------------

    def check_invariants(self) -> None:
        """Assert the engine's cross-tier accounting closes *now*."""
        eng = self.engine
        eng.stats.invariant_checks += 1
        # emitted-token masks are monotone per request
        for req in list(eng._running.values()):
            if req.emitted is not None:
                n = int(req.emitted.sum())
                seen = self._emitted_seen.get(req.uid, 0)
                if n < seen:
                    raise InvariantViolation(
                        f"req {req.uid}: emitted mask shrank "
                        f"{seen} -> {n}")
                self._emitted_seen[req.uid] = n
        # completed outputs never mutate after finalization
        for req in eng.done[-64:]:
            if req.output is None:
                continue
            crc = zlib.crc32(np.ascontiguousarray(req.output).tobytes())
            prev = self._done_crc.setdefault(req.uid, crc)
            if prev != crc:
                raise InvariantViolation(
                    f"req {req.uid}: completed output mutated")
        if not eng.paged:
            return
        # device page accounting: pool.used == engine-held + index-held
        # with exact per-page refcounts
        expected: Dict[int, int] = {}
        for req in list(eng._running.values()) + list(eng.queue):
            for p in req.pages or []:
                expected[p] = expected.get(p, 0) + 1
            for p in req.holds or []:
                expected[p] = expected.get(p, 0) + 1
        if eng.prefix is not None:
            for p in eng.prefix.device_pages():
                expected[p] = expected.get(p, 0) + 1
        actual = eng.pool.refcounts
        if expected != actual:
            only_exp = {p: c for p, c in expected.items()
                        if actual.get(p) != c}
            only_act = {p: c for p, c in actual.items()
                        if expected.get(p) != c}
            raise InvariantViolation(
                f"page refcounts do not close: expected!={only_exp} "
                f"actual!={only_act}")
        if eng.pool.used != len(expected):
            raise InvariantViolation(
                f"pool.used={eng.pool.used} but "
                f"{len(expected)} pages accounted")
        # host tier in lockstep with the trie's host entries
        if eng.host_pool is not None and eng.prefix is not None:
            if eng.host_pool.used_pages != eng.prefix.host_held_pages:
                raise InvariantViolation(
                    f"host tier: {eng.host_pool.used_pages} resident "
                    f"pages vs {eng.prefix.host_held_pages} trie refs")

"""Block-pool allocator for the paged serving runtime (DESIGN.md §5).

The pool owns ONE device-resident arena of fixed-size pages per cache
buffer (K / V / H / proxy / int8 scales), plus the host-side free-list
that hands pages to requests.  A "page" is a composite unit: physical
page id ``p`` addresses slot ``p`` in EVERY buffer arena of a cache
signature, so allocation accounting is a single integer per request
(``row_len // page_size``) regardless of how many buffers the strategy
keeps.

Invariants:
  * physical page 0 is the reserved ZERO page — never allocated, never
    written (paged scatters drop writes to it); every logical page past
    a request's ``kv_len`` aliases it, which is what lets heterogeneous
    ``gen_len`` requests share a lane without padding to the lane max.
  * pages are refcounted: ``alloc`` hands out pages at refcount 1,
    ``retain`` adds holds (the prefix index and its readers — DESIGN.md
    §6), ``release`` drops them and returns the page to the free list at
    zero.  WRITERS are still exclusive: a page with more than one hold
    is read-only by convention, and a session that needs to commit into
    one first copies it to a private page (copy-on-write — the page
    table is host-owned, so the patch happens between jitted steps).
  * arenas are per cache SIGNATURE (identifier width + incremental
    buffer + quantization): requests whose strategies share a signature
    share the arena; page ACCOUNTING is global across signatures either
    way, so admission always respects the configured budget.

JAX arrays are immutable, so the "arena" the pool hands out is a
reference that the active ``DecodeSession`` threads through its jitted
steps; :meth:`store_arenas` takes the latest value back when a lane
finishes so the next lane reuses the same allocation instead of growing
a second copy.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core import cache as cache_lib
from repro.core.strategy import CacheStrategy, resolve_strategy


def cache_signature(cfg: ModelConfig,
                    strategy: CacheStrategy) -> Tuple[int, bool, bool, str]:
    """Arena-shape key: strategies agreeing on this share one arena.
    ``cache_dtype`` is pool-wide today, but it shapes the buffer set
    (int8 scales) so it belongs to the key."""
    return (strategy.proxy_dim(cfg), bool(strategy.incremental),
            bool(strategy.uses_cache), cfg.cache_dtype)


class OutOfPages(RuntimeError):
    """A single request needs more pages than the whole pool owns."""


class PagePool:
    """Free-list page allocator + lazily materialized device arenas."""

    def __init__(self, cfg: ModelConfig, *, n_pages: int, page_size: int,
                 strategy: Optional[CacheStrategy] = None):
        if n_pages < 2:
            raise ValueError("pool needs >= 2 pages (page 0 is reserved)")
        self.cfg = cfg
        self.n_pages = n_pages
        self.page_size = page_size
        self.default_strategy = resolve_strategy(cfg, strategy)
        # page 0 is the zero page; 1..n_pages-1 are allocatable
        self._free: List[int] = list(range(n_pages - 1, 0, -1))
        self._rc: Dict[int, int] = {}   # holds per allocated page
        self._arenas: Dict[Tuple, Dict] = {}
        self.peak_used = 0
        self._util_samples: List[float] = []
        # fault seam (DESIGN.md §10): when set, alloc() probes
        # fault_hook.fire("pool_alloc") and fails transiently on a hit —
        # admission/publication/promotion all see the same exhaustion
        # signal they already handle (None) for a genuinely full pool.
        self.fault_hook = None

    # ---- accounting --------------------------------------------------

    @property
    def capacity(self) -> int:
        return self.n_pages - 1

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def used(self) -> int:
        return self.capacity - self.available

    @property
    def utilization(self) -> float:
        return self.used / max(self.capacity, 1)

    def pages_for(self, row_len: int) -> int:
        """Composite pages covering a page-aligned row span."""
        return -(-row_len // self.page_size)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Allocate n pages at refcount 1 (all-or-nothing). None when
        short."""
        if n > len(self._free):
            return None
        if n and self.fault_hook is not None \
                and self.fault_hook.fire("pool_alloc"):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._rc[p] = 1
        self.peak_used = max(self.peak_used, self.used)
        return pages

    def retain(self, pages: List[int]) -> None:
        """Add one hold per page (a shared reader or the prefix index)."""
        for p in pages:
            assert self._rc.get(p, 0) > 0, f"retain of unallocated page {p}"
            self._rc[p] += 1

    def release(self, pages: List[int]) -> None:
        """Drop one hold per page; a page returns to the free list when
        its last hold goes."""
        for p in pages:
            assert 0 < p < self.n_pages, p
            rc = self._rc.get(p, 0)
            assert rc > 0 and p not in self._free, (p, rc)
            if rc == 1:
                del self._rc[p]
                self._free.append(p)
            else:
                self._rc[p] = rc - 1

    def free(self, pages: List[int]) -> None:
        """Free exclusively-owned pages.  Unlike :meth:`release` (drop
        ONE hold), ``free`` asserts the caller is the LAST holder —
        freeing a page the prefix index or another reader still holds
        is the double-release footgun that used to corrupt the
        free-list silently.  Shared pages must go through ``release``.
        """
        for p in pages:
            rc = self._rc.get(p, 0)
            assert rc == 1, (
                f"free of page {p} with refcount {rc}; "
                "shared pages must be release()d, not free()d")
        self.release(pages)

    def refcount(self, page: int) -> int:
        return self._rc.get(page, 0)

    @property
    def refcounts(self) -> Dict[int, int]:
        """{page: holds} for every allocated page (copy)."""
        return dict(self._rc)

    def note_step(self) -> None:
        """Sample utilization once per engine step (steady-state stat)."""
        self._util_samples.append(self.utilization)

    def reset_telemetry(self) -> None:
        """Zero peak/steady tracking (e.g. after a warm-up run) without
        touching allocations or arenas."""
        self.peak_used = self.used
        self._util_samples.clear()

    def free_fragmentation(self) -> Dict[str, int]:
        """Free-list fragmentation (DESIGN.md §12): the number of
        contiguous free runs and the longest one.  A pool whose max
        run shrinks while its free count holds steady is fragmenting —
        the signal a future compactor would key on."""
        free = sorted(self._free)
        runs = 0
        max_run = 0
        cur = 0
        prev = None
        for p in free:
            if prev is not None and p == prev + 1:
                cur += 1
            else:
                runs += 1
                cur = 1
            max_run = max(max_run, cur)
            prev = p
        return {"free_pages": len(free), "free_runs": runs,
                "max_contiguous_run": max_run}

    def arena_bytes(self) -> Dict[str, int]:
        """Device bytes per materialized cache signature (summed over
        every buffer arena of the signature)."""
        out: Dict[str, int] = {}
        for sig, arenas in self._arenas.items():
            total = 0
            for bufs in arenas.values():
                for arr in bufs.values():
                    total += int(getattr(arr, "nbytes", 0) or 0)
            out[str(sig)] = total
        return out

    def telemetry_gauges(self):
        """Occupancy gauges for the §11 registry, ``name -> (help,
        value)`` — the pool owns its exposition names so the engine
        collector and any future scraper read one definition."""
        frag = self.free_fragmentation()
        return {
            "spa_pool_pages_used":
                ("allocated composite pages", self.used),
            "spa_pool_pages_capacity":
                ("allocatable pages", self.capacity),
            "spa_pool_utilization_ratio":
                ("used / capacity", self.utilization),
            "spa_pool_peak_utilization_ratio":
                ("high-water used / capacity",
                 self.peak_used / max(self.capacity, 1)),
            "spa_pool_peak_pages_used":
                ("high-water allocated pages", self.peak_used),
            "spa_pool_free_runs":
                ("contiguous free-page runs", frag["free_runs"]),
            "spa_pool_max_contiguous_free_run":
                ("longest contiguous free-page run",
                 frag["max_contiguous_run"]),
            "spa_pool_arena_bytes_total":
                ("device bytes across all cache-signature arenas",
                 sum(self.arena_bytes().values())),
        }

    def debug_state(self) -> Dict:
        """JSON-safe pool introspection for the ``/debug/pool``
        endpoint: accounting, fragmentation, per-signature bytes and
        the refcount histogram (never the arena contents)."""
        rc_hist: Dict[str, int] = {}
        for rc in self._rc.values():
            rc_hist[str(rc)] = rc_hist.get(str(rc), 0) + 1
        return {
            "capacity": self.capacity,
            "used": self.used,
            "available": self.available,
            "peak_used": self.peak_used,
            "utilization": round(self.utilization, 6),
            "steady_utilization": round(self.steady_utilization, 6),
            "page_size": self.page_size,
            "fragmentation": self.free_fragmentation(),
            "arena_bytes": self.arena_bytes(),
            "refcount_histogram": rc_hist,
        }

    @property
    def steady_utilization(self) -> float:
        if not self._util_samples:
            return 0.0
        return sum(self._util_samples) / len(self._util_samples)

    # ---- arenas ------------------------------------------------------

    def arenas_for(self, strategy: Optional[CacheStrategy] = None):
        """The device arenas for the strategy's cache signature
        (materialized on first use; {} for cache-less strategies)."""
        strategy = resolve_strategy(self.cfg, strategy
                                    if strategy is not None
                                    else self.default_strategy)
        if not strategy.uses_cache:
            return {}
        sig = cache_signature(self.cfg, strategy)
        if sig not in self._arenas:
            self._arenas[sig] = cache_lib.init_paged_arenas(
                self.cfg, self.n_pages, self.page_size, strategy)
        return self._arenas[sig]

    def store_arenas(self, strategy: CacheStrategy, arenas) -> None:
        """Adopt the latest arena arrays back from a finished lane so
        the next lane with the same signature reuses the allocation."""
        if arenas:
            self._arenas[cache_signature(self.cfg, strategy)] = arenas

    def peek_arenas(self, sig: Tuple):
        """Stored arenas for a raw signature (None if never built).
        NOTE: stale while a lane is mid-flight — the live values ride
        the session's step futures; the engine's tier read/write hooks
        route through the active session in that window (§9)."""
        return self._arenas.get(sig)

    def put_arenas(self, sig: Tuple, arenas) -> None:
        """Store updated arena arrays for a raw signature (promotion
        writes between lanes go through here)."""
        self._arenas[sig] = arenas

    def page_table_row(self, pages: List[int], canvas_len: int
                       ) -> List[int]:
        """One request's page-table row: its pages in logical order,
        zero-page entries for the tail past its row span."""
        n_log = cache_lib.n_logical_pages(canvas_len, self.page_size)
        assert len(pages) <= n_log, (len(pages), n_log)
        return list(pages) + [0] * (n_log - len(pages))

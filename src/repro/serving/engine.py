"""Batched DLM serving engine on DecodeSession (DESIGN.md §3.2, §5).

Requests (prompt + gen_len + optional per-request DecodeSettings /
CacheStrategy / UnmaskScheduler / priority) are padded onto fixed canvas
rows and served by a ``DecodeSession`` at **step granularity**: when a
row finishes, its slot is swapped for the next queued request mid-loop
(``DecodeSession.replace_rows``) while sibling rows keep stepping with
their evolved caches — no whole-batch re-prefill barrier.

Because the jitted step closes over settings, strategy and scheduler
statically, the queue is partitioned into *lanes* keyed on the full
``(DecodeSettings, CacheStrategy, UnmaskScheduler)`` triple: a lane's
batch only ever admits requests with an identical triple (one compiled
step per lane; all three are frozen hashable dataclasses).  Within a
lane, rows are independent (attention, top-k selection and commits are
all per-row), so for deterministic schedulers continuous batching is
byte-identical to serving the same requests in static batches —
asserted by ``tests/test_strategy_parity.py``.  Stochastic schedulers
(``uses_rng``) draw from ONE batch-global rng chain per lane, so their
sampled outputs depend on batch composition and swap order; runs are
reproducible per engine configuration but NOT invariant to scheduling.

Paged mode (``pool_pages > 0``, DESIGN.md §5): cache memory is a
managed resource.  A :class:`~repro.serving.pool.PagePool` owns one
device arena of fixed-size pages; each request allocates only the pages
covering its own (page-aligned) prompt+gen span, so heterogeneous
``gen_len`` requests share a lane without padding their cache to the
lane max — the canvas tail past a row's ``kv_len`` aliases the pool's
zero page and is masked out of attention and selection.  Admission is
gated on free pages; when the head of the queue cannot fit, the engine
preempts the lowest-priority running request (its pages are released,
its canvas+commit-ring snapshot requeued at the front) instead of
failing.  A resumed request re-prefills its cache from the snapshot —
byte-identical to a periodic refresh at the resume step, so a
preempted-then-resumed request matches a twin that refreshed there
(``tests/test_serving.py``).

Prefix reuse (``prefix_cache=True``, DESIGN.md §6): a
:class:`~repro.serving.prefix.PrefixIndex` maps (row span, strategy,
prompt token runs) to refcounted page runs holding PREFILL-TIME states.
Admission consults the index: a full hit attaches every page and skips
the prefill forward entirely; a partial hit attaches the matched prefix
read-only and prefills only the unmatched suffix
(``decoding.prefill_partial``).  Attached shared pages are copied into
the request's own reserve pages right before its first decode write
(copy-on-write in ``DecodeSession``), so index pages never change.
Cold requests publish their prefill pages (a page copy, skipped under
page pressure) back into the index at admission — harvest-time states
have evolved with the decode and would silently break the full-hit
byte-parity guarantee, so publication snapshots BEFORE the first step.
Under admission pressure, least-recently-used index entries with no
readers are evicted before any running request is preempted.

Online serving (DESIGN.md §8): the engine doubles as the backend of an
asyncio streaming front-end (``serving/frontend.py``).  Three pieces:

  * **Thread-safe intake** — ``submit_threadsafe``/``cancel_threadsafe``
    enqueue closures on a mailbox the engine thread drains at its
    *overlap point*; the engine's own state is only ever touched from
    the engine thread.
  * **Double-buffered dispatch** — each loop iteration dispatches the
    jitted device step, then does its host-side work (mailbox drain,
    SLO shedding, prefix planning for the next admission candidate)
    BEFORE the first host sync on the step's outputs, so admission and
    planning overlap the in-flight device step instead of sitting on
    the critical path.
  * **Per-token events** — requests submitted with a ``sink`` (or
    ``stream=True`` with an engine-level ``event_sink``) get a
    :class:`RequestEvent` per newly committed token batch, produced by
    diffing the canvas against a per-request emitted mask.  The mask
    lives on the ``Request``, so a preempted-then-resumed request's
    stream has no duplicated and no lost tokens (its committed canvas
    is snapshot/restored; ``tests/test_serving.py``).

SLO-aware scheduling (``serving/slo.py``): requests may carry an
:class:`~repro.serving.slo.SLO` (TTFT target + e2e deadline).  With an
engine-level :class:`~repro.serving.slo.SLOPolicy`, near-deadline
requests are boosted onto the existing strict-priority + preemption
machinery (and EDF-ordered within a priority), while hopeless requests
— TTFT already missed in queue, or e2e deadline passed — are shed
instead of burning pool pages for zero goodput.  ``EngineStats`` tracks
per-request TTFT/TPOT percentiles and goodput-under-SLO
(``benchmarks/bench_serving.py``).

Cancellation: ``cancel(uid)`` aborts a queued OR running request —
pages, prefix read holds and the canvas row are all released, and the
pool drain invariant (used == index-held pages after a full drain)
still holds (``tests/test_pool.py`` leak detector).

Slot bookkeeping uses the session's explicit active-position mask;
token ids are never overloaded as "committed filler" sentinels.
"""
from __future__ import annotations

import dataclasses
import functools
import queue as queue_mod
import threading
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import cache as cache_lib
from repro.core import runtime
from repro.core.cache import PagedCache, n_logical_pages
from repro.core.strategy import CacheStrategy, resolve_strategy
from repro.dlm.decoding import DecodeSettings, partial_prefill_supported
from repro.dlm.scheduler import UnmaskScheduler, resolve_scheduler
from repro.dlm.session import DecodeSession, SharedPrefix
from repro.serving.faults import FaultInjector, FaultPlan, choose_index
from repro.serving.hier import (HostPageCorruption, HostPagePool,
                                TierManager)
from repro.serving.pool import OutOfPages, PagePool, cache_signature
from repro.serving.prefix import PrefixIndex, PrefixMatch
from repro.serving.slo import SLO, SLOPolicy
from repro.serving.supervisor import EngineSupervisor, SupervisorConfig
from repro.serving.telemetry import (PID_ENGINE, PID_EVENTS, PID_REQUESTS,
                                     Histogram, Telemetry)

# (settings, strategy, scheduler): everything the compiled step closes
# over statically — one DecodeSession (one executable) per distinct key.
LaneKey = Tuple[DecodeSettings, CacheStrategy, UnmaskScheduler]


@dataclasses.dataclass(frozen=True)
class RequestEvent:
    """One streaming event for a request (DESIGN.md §8).

    ``kind``: "token" (``positions``/``tokens`` carry the gen-span
    offsets and values committed since the last event), "done" (final
    output in ``tokens``), "shed" (SLO policy dropped it), or
    "canceled".  Delivered to ``Request.sink`` if set, else the
    engine-level ``event_sink`` for ``stream=True`` requests — always
    on the engine thread (the front-end bridges to asyncio)."""
    kind: str
    uid: int
    step: int
    ts: float
    positions: Tuple[int, ...] = ()   # offsets into the gen span
    tokens: Tuple[int, ...] = ()


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # [P] int32
    gen_len: int
    settings: Optional[DecodeSettings] = None
    strategy: Optional[CacheStrategy] = None
    scheduler: Optional[UnmaskScheduler] = None
    priority: int = 0               # higher = preempts lower
    submitted_at: float = dataclasses.field(default_factory=time.time)
    started_at: Optional[float] = None   # first admission to a slot
    completed_at: Optional[float] = None
    output: Optional[np.ndarray] = None
    lane: Optional[LaneKey] = None  # resolved ONCE at submit()
    # paged bookkeeping
    row_len: int = 0                # page-aligned prompt+gen span
    n_pages: int = 0                # composite pages needed
    pages: Optional[List[int]] = None
    # shared-prefix attachment (DESIGN.md §6): read holds on index pages
    # mapped at logical [0, shared_n); pages[:shared_n] is the COW
    # reserve.  Released at COW time (or harvest/preempt if earlier).
    holds: Optional[List[int]] = None
    shared_n: int = 0
    shared_full: bool = False       # the hit covers the whole row span
    preemptions: int = 0
    served_steps: int = 0           # per-request max_steps budget
    snapshot: Optional[Dict[str, np.ndarray]] = None  # preempt resume
    # online serving (DESIGN.md §8)
    slo: Optional[SLO] = None       # TTFT target + e2e deadline
    stream: bool = False            # emit per-token events
    sink: Optional[Callable] = None  # per-request event callback
    canceled: bool = False          # set by cancel(); loop releases slot
    shed: bool = False              # canceled BY the SLO policy
    first_token_at: Optional[float] = None
    last_commit_at: Optional[float] = None
    tokens_done: int = 0            # committed so far (TPOT denominator)
    # per-request emitted mask [gen_len]: which gen-span offsets have
    # already been streamed — survives preemption, so a resumed
    # request's stream never duplicates or drops a token
    emitted: Optional[np.ndarray] = None
    plan_epoch: Optional[int] = None  # prefix plan validity (see §8)
    boosted: bool = False           # urgency transition already seen
    # host tier (DESIGN.md §9): a plan whose match lives (partly) in
    # host RAM parks here in the PROMOTING admission state until the
    # engine services it (overlap window or synchronously at admission)
    pending_promotion: Optional["PrefixMatch"] = None
    no_promote: bool = False        # sticky: promotion failed once —
    #                                 this admission runs device-only
    # fault containment (DESIGN.md §10): the fault class that aborted
    # this request ("nan", "pool_alloc", ...), plus the bounded
    # retry-with-backoff state for transient admission alloc failures
    fault: Optional[str] = None
    alloc_retries: int = 0
    retry_after_step: int = 0       # backoff gate on the step clock


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    tokens_committed: int = 0
    requests_done: int = 0
    swaps: int = 0                  # mid-loop slot replacements
    preemptions: int = 0            # out-of-pages victim evictions
    admission_stalls: int = 0       # admission attempts blocked on pages
    # shared-prefix index (DESIGN.md §6)
    prefix_hits: int = 0            # admissions that attached index pages
    prefix_full_hits: int = 0       # ... covering the whole row span
    prefix_tokens_saved: int = 0    # prompt+canvas rows NOT re-prefilled
    prefix_published: int = 0       # pages copied into the index
    prefix_publish_skipped: int = 0  # publications dropped (pool short)
    prefix_evicted_pages: int = 0   # index pages evicted under pressure
    # host tier (DESIGN.md §9): evicted splits into demoted vs dropped
    prefix_demoted_pages: int = 0   # ... demoted to the host tier
    prefix_dropped_pages: int = 0   # ... dropped (tier off/full/stable)
    prefix_promoted_pages: int = 0  # host pages promoted back
    prefix_promotions: int = 0      # promotion events serviced
    promotion_stalls: int = 0       # promotions abandoned (no headroom)
    peak_pool_util: float = 0.0
    steady_pool_util: float = 0.0
    peak_host_util: float = 0.0     # host-tier unit budget high-water
    # online serving / SLO accounting (DESIGN.md §8)
    requests_shed: int = 0          # dropped by the SLO policy
    requests_canceled: int = 0      # client cancel / disconnect
    slo_met: int = 0                # completed within their SLO
    slo_missed: int = 0             # completed but past TTFT/deadline
    # latency distributions are telemetry histograms (DESIGN.md §11):
    # fixed buckets feed Prometheus exposition while retained samples
    # keep percentiles EXACT (and `len(stats.e2e_latencies)` list-compat)
    e2e_latencies: Histogram = dataclasses.field(
        default_factory=functools.partial(
            Histogram, "spa_engine_e2e_latency_seconds",
            "request end-to-end latency (submit to harvest)"))
    queue_waits: Histogram = dataclasses.field(
        default_factory=functools.partial(
            Histogram, "spa_engine_queue_wait_seconds",
            "queue wait (submit to first admission)"))
    ttft_latencies: Histogram = dataclasses.field(
        default_factory=functools.partial(
            Histogram, "spa_engine_ttft_seconds",
            "time to first committed token"))
    tpot_latencies: Histogram = dataclasses.field(
        default_factory=functools.partial(
            Histogram, "spa_engine_tpot_seconds",
            "per-request time per output token"))
    # fault tolerance (DESIGN.md §10)
    faults_injected: int = 0        # injector fires (replay fingerprint)
    requests_faulted: int = 0       # aborted by fault containment
    alloc_faults: int = 0           # transient admission alloc failures
    host_checksum_failures: int = 0  # corrupt host pages caught
    cold_prefill_fallbacks: int = 0  # corrupted promotions served cold
    nan_quarantines: int = 0        # poisoned rows aborted by the guard
    disconnect_bursts: int = 0      # injected mass client hangups
    watchdog_fires: int = 0         # stuck lanes force-preempted
    invariant_checks: int = 0       # supervisor accounting audits run
    publish_paused_skips: int = 0   # publications skipped at ladder L1+
    degrade_level: int = 0          # current ladder rung (0 = full)
    degradations: int = 0           # upward ladder transitions
    restorations: int = 0           # downward ladder transitions
    degradation_events: List[Tuple[int, int]] = dataclasses.field(
        default_factory=list)       # (step, new level), both directions

    def tps(self, wall: float) -> float:
        return self.tokens_committed / max(wall, 1e-9)

    def goodput(self, wall: float) -> float:
        """Requests completed WITHIN their SLO per second — the online
        headline metric (a request without an SLO counts as met when it
        completes; shed/canceled/late requests never count)."""
        return self.slo_met / max(wall, 1e-9)

    def percentiles(self) -> Dict[str, float]:
        """p50/p95 end-to-end, queue-wait, TTFT and TPOT (seconds) —
        single-sourced through :meth:`Histogram.percentile`, which is
        exact (matches ``numpy.percentile``) over retained samples."""
        out: Dict[str, float] = {}
        for name, h in (("e2e", self.e2e_latencies),
                        ("wait", self.queue_waits),
                        ("ttft", self.ttft_latencies),
                        ("tpot", self.tpot_latencies)):
            out[f"{name}_p50"] = h.percentile(50)
            out[f"{name}_p95"] = h.percentile(95)
        return out


# EngineStats field -> Prometheus metric mirror (DESIGN.md §11 naming:
# spa_<subsystem>_<quantity>[_<unit>], monotonic counters end in _total).
_STATS_COUNTERS: Tuple[Tuple[str, str, str], ...] = (
    ("steps", "spa_engine_steps_total", "engine iterations"),
    ("tokens_committed", "spa_engine_tokens_committed_total",
     "tokens committed across all requests"),
    ("requests_done", "spa_engine_requests_done_total",
     "requests harvested with output"),
    ("swaps", "spa_engine_swaps_total", "mid-loop slot replacements"),
    ("preemptions", "spa_engine_preemptions_total",
     "running requests evicted for pages/priority"),
    ("admission_stalls", "spa_engine_admission_stalls_total",
     "admission attempts blocked on pages"),
    ("prefix_hits", "spa_prefix_hits_total",
     "admissions that attached index pages"),
    ("prefix_full_hits", "spa_prefix_full_hits_total",
     "prefix hits covering the whole row span"),
    ("prefix_tokens_saved", "spa_prefix_tokens_saved_total",
     "prompt+canvas rows not re-prefilled"),
    ("prefix_published", "spa_prefix_published_pages_total",
     "pages copied into the index"),
    ("prefix_publish_skipped", "spa_prefix_publish_skipped_total",
     "publications dropped (pool short)"),
    ("prefix_evicted_pages", "spa_prefix_evicted_pages_total",
     "index pages evicted under pressure"),
    ("prefix_demoted_pages", "spa_tier_demoted_pages_total",
     "evicted pages demoted to the host tier"),
    ("prefix_dropped_pages", "spa_tier_dropped_pages_total",
     "evicted pages dropped outright"),
    ("prefix_promoted_pages", "spa_tier_promoted_pages_total",
     "host pages promoted back to device"),
    ("prefix_promotions", "spa_tier_promotions_total",
     "promotion events serviced"),
    ("promotion_stalls", "spa_tier_promotion_stalls_total",
     "promotions abandoned (no headroom)"),
    ("requests_shed", "spa_slo_requests_shed_total",
     "requests dropped by the SLO policy / ladder"),
    ("requests_canceled", "spa_engine_requests_canceled_total",
     "client cancels / disconnects"),
    ("slo_met", "spa_slo_met_total", "completions within SLO"),
    ("slo_missed", "spa_slo_missed_total",
     "completions past TTFT/deadline (incl. shed)"),
    ("requests_faulted", "spa_fault_requests_faulted_total",
     "requests aborted by fault containment"),
    ("alloc_faults", "spa_fault_alloc_failures_total",
     "transient admission alloc failures"),
    ("host_checksum_failures", "spa_fault_host_checksum_failures_total",
     "corrupt host pages caught at promotion"),
    ("cold_prefill_fallbacks", "spa_fault_cold_prefill_fallbacks_total",
     "corrupted promotions served by cold prefill"),
    ("nan_quarantines", "spa_fault_nan_quarantines_total",
     "poisoned rows aborted by the NaN guard"),
    ("disconnect_bursts", "spa_fault_disconnect_bursts_total",
     "injected mass client hangups"),
    ("watchdog_fires", "spa_fault_watchdog_fires_total",
     "stuck lanes force-preempted"),
    ("invariant_checks", "spa_fault_invariant_checks_total",
     "supervisor accounting audits run"),
    ("publish_paused_skips", "spa_fault_publish_paused_skips_total",
     "publications skipped at ladder L1+"),
    ("degradations", "spa_fault_degradations_total",
     "upward ladder transitions"),
    ("restorations", "spa_fault_restorations_total",
     "downward ladder transitions"),
)

_RATIO_BUCKETS = (0.05, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9,
                  1.0, 1.5, 2.0, 4.0)
_DRIFT_BUCKETS = (1e-6, 1e-5, 1e-4, 1e-3, 3e-3, 0.01, 0.03, 0.1, 0.3,
                  0.6, 1.0, 1.5, 2.0)
_HIT_DEPTH_BUCKETS = (0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 canvas_len: int = 64,
                 settings: Optional[DecodeSettings] = None,
                 strategy: Optional[CacheStrategy] = None,
                 scheduler: Optional[UnmaskScheduler] = None,
                 continuous: bool = True,
                 pool_pages: int = 0, page_size: int = 16,
                 prefix_cache: bool = False,
                 host_pages: int = 0, host_dtype: str = "auto",
                 slo_policy: Optional[SLOPolicy] = None,
                 clock: Optional[Callable[[], float]] = None,
                 fault_plan: Optional[FaultPlan] = None,
                 supervise: bool = False,
                 supervisor_cfg: Optional[SupervisorConfig] = None,
                 telemetry: Optional[Telemetry] = None,
                 profiler=None):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.canvas_len = canvas_len
        self.settings = settings or DecodeSettings()
        self.strategy = resolve_strategy(cfg, strategy)
        self.scheduler = scheduler    # None -> derived from settings
        self.continuous = continuous
        self.paged = pool_pages > 0
        self.page_size = page_size
        self.pool: Optional[PagePool] = None
        self.prefix: Optional[PrefixIndex] = None
        if self.paged:
            n_logical_pages(canvas_len, page_size)  # divisibility check
            self.pool = PagePool(cfg, n_pages=pool_pages,
                                 page_size=page_size,
                                 strategy=self.strategy)
            if prefix_cache:
                self.prefix = PrefixIndex(page_size)
        # host-RAM page tier (DESIGN.md §9): evicted index entries
        # demote host-ward instead of dying; hits promote back
        self.host_pool: Optional[HostPagePool] = None
        self.tier: Optional[TierManager] = None
        if self.paged and self.prefix is not None and host_pages > 0:
            self.host_pool = HostPagePool(host_pages)
            self.tier = TierManager(self.host_pool, host_dtype=host_dtype,
                                    read_pages=self._tier_read)
            self.prefix.tier = self.tier
        # tier IO routing: mid-lane the live arenas ride the active
        # session's step futures, not the pool's stored copies
        self._active_sess: Optional[DecodeSession] = None
        self._active_sig: Optional[Tuple] = None
        # partial (suffix-only) reuse needs a window-free all-attention
        # stack and a float cache (DESIGN.md §6); full-run hits are an
        # exact page copy and work for any architecture/dtype
        self._partial_ok = (partial_prefill_supported(cfg)
                            and cfg.cache_dtype != "int8")
        self.queue: deque[Request] = deque()
        self.done: List[Request] = []
        self.stats = EngineStats()
        self._next_uid = 0            # monotonic: uids never recycle
        # admission re-scan gate: set by submit(), cleared after each
        # admission attempt — a stalled queue is not re-scanned (and
        # admission_stalls not re-counted) every step, only when a
        # finish/preemption or a new arrival can change the outcome
        self._admission_dirty = True
        self._sessions: Dict[LaneKey, DecodeSession] = {}
        # offline proxy artefacts are per STRATEGY, shared across lanes
        self._proxies: Dict[CacheStrategy, object] = {}
        # online serving (DESIGN.md §8)
        self.slo_policy = slo_policy
        self._clock = clock or time.time
        # unified telemetry (DESIGN.md §11): a registry (always present
        # — /metrics and bench snapshots read live engine state through
        # a collector) + a span tracer (disabled by default; every
        # trace call in the hot loop is gated on ``tracer.enabled``).
        # The tracer is re-stamped from the ENGINE clock so traces are
        # deterministic under virtual-clock replay.
        self.telemetry = telemetry or Telemetry.disabled()
        self.telemetry.tracer.clock = self._clock
        self._tr = self.telemetry.tracer
        self.telemetry.registry.add_collector(self._collect_metrics)
        # compute-path profiling (DESIGN.md §12): a StepProfiler from
        # serving/profiling.py, handed to every lane session.  None
        # (default) keeps the exact unprofiled step path.
        self.profiler = profiler
        self._lane_ids: Dict[LaneKey, int] = {}
        self.event_sink: Optional[Callable[[RequestEvent], None]] = None
        # thread-safe intake: closures enqueued by submit_threadsafe /
        # cancel_threadsafe, drained on the engine thread at the
        # double-buffer overlap point (and while idle in run_online)
        self._mailbox: "queue_mod.Queue[Callable[[], None]]" = \
            queue_mod.Queue()
        self._uid_lock = threading.Lock()
        self._running: Dict[int, Request] = {}   # uid -> in-flight req
        self._stop: Optional[threading.Event] = None
        self._prefix_epoch = 0        # bumps on any index mutation
        # fault tolerance (DESIGN.md §10): seeded injector threaded
        # through the seams + a supervisor wrapping the step loop.
        # A fault plan without a supervisor would deadlock on a lane
        # stall, so injection implies supervision.
        self.faults: Optional[FaultInjector] = None
        if fault_plan is not None:
            self.faults = FaultInjector(fault_plan)
            if self.pool is not None:
                self.pool.fault_hook = self.faults
            if self.tier is not None:
                self.tier.injector = self.faults
            # every injector fire becomes a trace event with the same
            # (site, probe) schema as FaultInjector.log, so a chaos
            # replay and its trace can be diffed (DESIGN.md §11)
            self.faults.on_fire = self._trace_fault
        # degradation-ladder flags, maintained by the supervisor
        self._publish_paused = False
        self._host_tier_paused = False
        self._shed_low_priority = False
        self._shed_below = 0
        self._hopeless_margin = 0.0
        self.supervisor: Optional[EngineSupervisor] = None
        if supervise or supervisor_cfg is not None or fault_plan is not None:
            EngineSupervisor(self, supervisor_cfg)  # attaches itself

    def _now(self) -> float:
        return self._clock()

    # ------------------------------------------------------------------
    # Telemetry (DESIGN.md §11)
    # ------------------------------------------------------------------

    def _trace_fault(self, site: str, probe: int) -> None:
        """Injector fire → instant trace event, schema-identical to the
        FaultInjector.log entry ``(site, probe)``."""
        self._tr.instant(PID_EVENTS, 1, f"fault:{site}", cat="fault",
                         args={"site": site, "probe": probe,
                               "step": self.stats.steps})

    def _lane_id(self, lane: LaneKey) -> int:
        lid = self._lane_ids.get(lane)
        if lid is None:
            lid = self._lane_ids[lane] = len(self._lane_ids)
            self._tr.name_track(PID_ENGINE, lid, f"lane{lid}")
        return lid

    def _phase_end(self, lid: int, name: str) -> None:
        """Close an engine-phase span and fold its duration into the
        step-time-breakdown histogram."""
        tr = self._tr
        tr.end(PID_ENGINE, lid, name)
        self.telemetry.registry.histogram(
            "spa_engine_phase_seconds",
            "per-iteration step-time breakdown",
            labels={"phase": name}).observe(tr.events[-1].dur)

    def _note_cache_dynamics(self, sess: DecodeSession,
                             strategy: CacheStrategy, n_live: int) -> None:
        """Fold one DecodeSession.cache_dynamics() probe into the
        registry: per-layer refresh-budget utilization, proxy drift
        distribution, selection overlap.  Host-side, post-sync only."""
        dyn = sess.cache_dynamics()
        if dyn is None:
            return
        reg = self.telemetry.registry
        if dyn["refreshed"]:
            # a full refresh rewrites every row — budget utilization and
            # drift are about the *incremental* selection, so count the
            # event and skip the diff-derived metrics
            reg.counter("spa_cache_refresh_steps_total",
                        "steps that ran a full cache refresh").inc()
            return
        try:
            ks = strategy.k_schedule(self.cfg, self.canvas_len)
        except (NotImplementedError, AttributeError):
            ks = None
        for kind, layers in dyn["kinds"].items():
            for layer, d in enumerate(layers):
                labels = {"kind": kind, "layer": str(layer)}
                if ks is not None and layer < len(ks) and n_live:
                    util = d["changed"] / max(int(ks[layer]) * n_live, 1)
                    reg.histogram(
                        "spa_cache_budget_utilization_ratio",
                        "refreshed rows / (k_schedule budget * live "
                        "rows) per step", labels=labels,
                        buckets=_RATIO_BUCKETS).observe(util)
                if d["drift"]:
                    h = reg.histogram(
                        "spa_cache_proxy_drift",
                        "1 - cos(prev proxy row, new proxy row) over "
                        "refreshed rows", labels=labels,
                        buckets=_DRIFT_BUCKETS)
                    for x in d["drift"]:
                        h.observe(x)
                if d["overlap"] is not None:
                    reg.histogram(
                        "spa_cache_selection_overlap_ratio",
                        "Jaccard overlap of consecutive refreshed-row "
                        "sets", labels=labels,
                        buckets=_RATIO_BUCKETS).observe(d["overlap"])

    def _collect_metrics(self) -> None:
        """Registry collector: mirror live engine state (EngineStats
        counters, pool/tier occupancy, queue depth) into the registry
        right before every render()/snapshot().  EngineStats stays the
        engine-thread-owned source of truth (and stays zero-arg
        resettable); the registry is the exposition view over it."""
        reg, s = self.telemetry.registry, self.stats
        for field, metric, help_txt in _STATS_COUNTERS:
            reg.counter(metric, help_txt).set(getattr(s, field))
        if self.faults is not None:
            reg.counter("spa_fault_injected_total",
                        "fault-injector fires").set(self.faults.total_fired)
        reg.gauge("spa_fault_degrade_level",
                  "graceful-degradation ladder rung (0 = full service)"
                  ).set(s.degrade_level)
        reg.gauge("spa_engine_queue_depth",
                  "queued requests").set(len(self.queue))
        reg.gauge("spa_engine_running_requests",
                  "admitted in-flight requests").set(len(self._running))
        for h, name in ((s.e2e_latencies, "spa_engine_e2e_latency_seconds"),
                        (s.queue_waits, "spa_engine_queue_wait_seconds"),
                        (s.ttft_latencies, "spa_engine_ttft_seconds"),
                        (s.tpot_latencies, "spa_engine_tpot_seconds")):
            # re-adopt every collect: `eng.stats = EngineStats()` warm-up
            # resets swap the histogram objects out from under us
            reg.adopt(h, name, h.help)
        # each tier owns its exposition names (pool.py / prefix.py /
        # hier.py telemetry_gauges) — the collector just mirrors them
        for obj in (self.pool, self.prefix, self.host_pool):
            if obj is not None:
                for name, (help_txt, val) in obj.telemetry_gauges().items():
                    reg.gauge(name, help_txt).set(val)
        # compile/retrace accounting + live-executable count (§12):
        # spa_runtime_* series from the process-wide tracker
        runtime.compile_tracker().export_metrics(reg)
        if self.pool is not None:
            for sig, nbytes in self.pool.arena_bytes().items():
                reg.gauge("spa_pool_arena_bytes",
                          "device bytes per cache-signature arena",
                          labels={"signature": sig}).set(nbytes)

    def render_metrics(self) -> str:
        """Prometheus text exposition of the live registry (the
        frontend's ``GET /metrics``).  Reads race the engine thread
        benignly, like ``stats_snapshot`` — ints/floats only."""
        return self.telemetry.registry.render()

    def export_trace(self, path: str) -> None:
        """Write the tracer's Chrome-trace JSON (Perfetto-loadable)."""
        self._tr.export(path)

    def request_states(self, done_tail: int = 32) -> Dict[str, List[Dict]]:
        """JSON-able per-request lifecycle view (``GET /debug/requests``):
        queued / running / recently finished, with timings."""
        def row(r: Request, state: str) -> Dict:
            return {
                "uid": r.uid, "state": state, "priority": r.priority,
                "gen_len": r.gen_len, "pages": r.n_pages,
                "shared_pages": r.shared_n,
                "preemptions": r.preemptions,
                "tokens_done": r.tokens_done,
                "submitted_at": r.submitted_at,
                "started_at": r.started_at,
                "first_token_at": r.first_token_at,
                "completed_at": r.completed_at,
                "shed": r.shed, "canceled": r.canceled,
                "fault": r.fault,
                "slo": (None if r.slo is None else
                        {"ttft": (None if r.slo.ttft == float("inf")
                                  else r.slo.ttft),
                         "deadline": (None
                                      if r.slo.deadline == float("inf")
                                      else r.slo.deadline)}),
            }
        return {
            "queued": [row(r, "queued") for r in list(self.queue)],
            "running": [row(r, "running")
                        for r in list(self._running.values())],
            "done": [row(r, "done") for r in self.done[-done_tail:]],
        }

    def pool_debug_state(self) -> Dict:
        """JSON-able memory-observability view (``GET /debug/pool``,
        DESIGN.md §12): device-pool occupancy + fragmentation +
        per-signature bytes, host-tier slot accounting, tier-manager
        counters and the tracked live-executable count.  Reads race
        the engine thread benignly (ints/floats/strings only)."""
        out: Dict = {
            "paged": self.paged,
            "live_executables": runtime.live_executable_count(),
        }
        if self.pool is not None:
            out["pool"] = self.pool.debug_state()
        if self.host_pool is not None:
            out["host_pool"] = self.host_pool.debug_state()
        if self.tier is not None:
            t = self.tier
            out["tier"] = {
                "demoted_pages": t.demoted_pages,
                "promoted_pages": t.promoted_pages,
                "dropped_full": t.dropped_full,
                "dropped_stable": t.dropped_stable,
                "store_faults": t.store_faults,
                "checksum_failures": t.checksum_failures,
            }
        return out

    def submit(self, prompt: np.ndarray, gen_len: int,
               settings: Optional[DecodeSettings] = None,
               strategy: Optional[CacheStrategy] = None,
               scheduler: Optional[UnmaskScheduler] = None,
               priority: int = 0,
               row_len: Optional[int] = None,
               slo: Optional[SLO] = None,
               stream: bool = False,
               sink: Optional[Callable] = None) -> int:
        """Queue one request.  Rejects requests that can never be
        scheduled (``gen_len`` outside the canvas, or a page footprint
        beyond the whole pool) with a clear error instead of letting
        them starve the queue forever.

        ``row_len`` (paged mode) reserves a larger page-aligned canvas
        span than prompt+gen needs — cross-turn chat reserves the same
        span every turn so the prefix index's layout keys line up
        (DESIGN.md §6).  ``slo``/``stream``/``sink`` are the online
        serving surface (DESIGN.md §8).  Engine-thread only — remote
        threads use ``submit_threadsafe``."""
        req = self._build_request(prompt, gen_len, settings, strategy,
                                  scheduler, priority=priority,
                                  row_len=row_len, slo=slo,
                                  stream=stream, sink=sink)
        self._enqueue(req)
        return req.uid

    def submit_threadsafe(self, prompt: np.ndarray, gen_len: int,
                          **kw) -> int:
        """``submit`` from any thread: validation and lane resolution
        run on the caller (errors raise there), the queue append rides
        the mailbox onto the engine thread.  Returns the uid
        immediately — events may start arriving before this returns
        only on the request's own ``sink``, which is attached first."""
        req = self._build_request(prompt, gen_len, **kw)
        self._mailbox.put(lambda: self._enqueue(req))
        return req.uid

    def cancel(self, uid: int) -> bool:
        """Abort a queued or running request: its pages, prefix holds
        and canvas row are released and it finalizes with no output
        (``canceled`` on the request; "canceled" event).  Engine-thread
        only — remote threads use ``cancel_threadsafe``.  Returns False
        for unknown/already-finished uids."""
        for r in list(self.queue):
            if r.uid == uid:
                self.queue.remove(r)
                self._drop_plan(r)
                r.canceled = True
                self._finalize_aborted(r)
                return True
        r = self._running.get(uid)
        if r is not None and not r.canceled:
            r.canceled = True     # the step loop releases slot + pages
            return True
        return False

    def cancel_threadsafe(self, uid: int) -> None:
        self._mailbox.put(lambda: self.cancel(uid))

    def _enqueue(self, req: Request) -> None:
        self._admission_dirty = True
        self.queue.append(req)
        tr = self._tr
        if tr.enabled:
            tr.name_track(PID_REQUESTS, req.uid, f"req {req.uid}")
            tr.begin(PID_REQUESTS, req.uid, "request", cat="lifecycle",
                     args={"prompt_len": int(len(req.prompt)),
                           "gen_len": req.gen_len,
                           "priority": req.priority})
            tr.begin(PID_REQUESTS, req.uid, "queued", cat="lifecycle")

    def _drain_mailbox(self) -> None:
        while True:
            try:
                fn = self._mailbox.get_nowait()
            except queue_mod.Empty:
                return
            fn()

    def _build_request(self, prompt: np.ndarray, gen_len: int,
                       settings: Optional[DecodeSettings] = None,
                       strategy: Optional[CacheStrategy] = None,
                       scheduler: Optional[UnmaskScheduler] = None,
                       priority: int = 0,
                       row_len: Optional[int] = None,
                       slo: Optional[SLO] = None,
                       stream: bool = False,
                       sink: Optional[Callable] = None) -> Request:
        # full validation runs HERE, on the submitting thread — both
        # submit() and submit_threadsafe() route through this, so an
        # invalid request raises at the caller and a malformed mailbox
        # entry can never abort the engine loop mid-step (DESIGN.md §10)
        if not isinstance(gen_len, (int, np.integer)) \
                or isinstance(gen_len, bool):
            raise ValueError(f"gen_len must be an int, got "
                             f"{type(gen_len).__name__}")
        if gen_len <= 0 or gen_len > self.canvas_len:
            raise ValueError(
                f"gen_len {gen_len} cannot be scheduled on a "
                f"canvas_len={self.canvas_len} engine (need "
                f"0 < gen_len <= canvas_len)")
        prompt = np.asarray(prompt)
        if prompt.ndim != 1:
            raise ValueError(f"prompt must be a 1-D token array, got "
                             f"shape {prompt.shape}")
        if prompt.size and not np.issubdtype(prompt.dtype, np.integer):
            raise ValueError(f"prompt must hold integer token ids, got "
                             f"dtype {prompt.dtype}")
        # monotonic counter — NOT len(done)+len(queue): with requests
        # in-flight (popped but not done) that length dips and reuses
        # live uids (regression-tested in tests/test_serving.py).
        # Locked so submit_threadsafe callers never race the engine.
        with self._uid_lock:
            uid = self._next_uid
            self._next_uid += 1
        req = Request(uid, np.asarray(prompt, np.int32), gen_len,
                      settings, strategy, scheduler, priority=priority,
                      submitted_at=self._now(), slo=slo, stream=stream,
                      sink=sink)
        req.lane = self._lane_of(req)   # freeze vs later default changes
        if self.paged:
            p_len = min(len(req.prompt), self.canvas_len - gen_len)
            span = max(p_len + gen_len, row_len or 0)
            req.row_len = min(
                -(-span // self.page_size) * self.page_size,
                self.canvas_len)
            strategy_r = req.lane[1]
            req.n_pages = (self.pool.pages_for(req.row_len)
                           if strategy_r.uses_cache else 0)
            if req.n_pages > self.pool.capacity:
                raise OutOfPages(
                    f"request uid={uid} needs {req.n_pages} pages; pool "
                    f"capacity is {self.pool.capacity} — it can never "
                    f"be admitted (grow --pool-pages or shrink the "
                    f"request)")
        else:
            req.row_len = self.canvas_len
        return req

    # ------------------------------------------------------------------

    def _lane_of(self, req: Request) -> LaneKey:
        """Resolve a request's lane: per-request overrides win WHOLESALE
        (a request that passes settings gets that settings' commit
        policy, including ``parallel_threshold=0.0`` = sequential),
        engine defaults fill the gaps, legacy settings knobs map to
        their scheduler equivalent.  The parallel knobs are normalized
        OUT of the keyed settings once the scheduler is resolved
        (serve_step never reads them again), so a request submitted
        with ``parallel_threshold=0.1`` shares an executable with one
        submitted with ``ParallelThresholdScheduler(0.1)``."""
        settings = req.settings or self.settings
        strategy = req.strategy or self.strategy
        # precedence: request scheduler > request settings knobs >
        # engine scheduler > engine settings knobs > confidence default
        if req.scheduler is not None:
            scheduler = req.scheduler
        elif req.settings is not None:
            scheduler = resolve_scheduler(req.settings)
        else:
            scheduler = resolve_scheduler(self.settings, self.scheduler)
        settings = dataclasses.replace(settings, parallel_threshold=0.0,
                                       max_parallel=0)
        return settings, strategy, scheduler

    def _proxies_for(self, strategy: CacheStrategy):
        if strategy not in self._proxies:
            self._proxies[strategy] = strategy.build_proxies(
                self.params, self.cfg)
        return self._proxies[strategy]

    def _session_for(self, lane: LaneKey) -> DecodeSession:
        if lane not in self._sessions:
            settings, strategy, scheduler = lane
            label = (f"{getattr(strategy, 'name', 'strategy')}"
                     f"/{getattr(strategy.backend, 'name', 'backend')}"
                     f"/{type(scheduler).__name__}"
                     f"#{self._lane_id(lane)}")
            self._sessions[lane] = DecodeSession(
                self.params, self.cfg, strategy=strategy,
                settings=settings, scheduler=scheduler,
                spa_proxies=self._proxies_for(strategy),
                profiler=self.profiler, label=label)
        return self._sessions[lane]

    # ------------------------------------------------------------------
    # Online serving: events, SLO shedding, cancellation (DESIGN.md §8)
    # ------------------------------------------------------------------

    def _emit(self, req: Request, kind: str,
              positions: Tuple[int, ...] = (),
              tokens: Tuple[int, ...] = ()) -> None:
        sink = req.sink or (self.event_sink if req.stream else None)
        if sink is None:
            return
        sink(RequestEvent(kind=kind, uid=req.uid, step=self.stats.steps,
                          ts=self._now(), positions=positions,
                          tokens=tokens))

    def _eff_priority(self, req: Request, now: float) -> int:
        if self.slo_policy is None:
            return req.priority
        return self.slo_policy.effective_priority(req, now)

    def _shed_hopeless(self) -> None:
        """Drop queued requests that can no longer contribute goodput
        (missed TTFT while waiting / e2e deadline passed).  At ladder
        L3 (DESIGN.md §10) low-priority queued work is shed outright
        and the SLO deadlines tighten by ``hopeless_margin``."""
        if self._shed_low_priority:
            for r in list(self.queue):
                if r.priority < self._shed_below:
                    self.queue.remove(r)
                    self._drop_plan(r)
                    r.shed = True
                    self._finalize_aborted(r)
        if self.slo_policy is None or not self.slo_policy.shed:
            return
        now = self._now()
        for r in list(self.queue):
            if r.slo is not None and self.slo_policy.hopeless(
                    r, now, margin=self._hopeless_margin):
                self.queue.remove(r)
                self._drop_plan(r)
                r.shed = True
                self._finalize_aborted(r)

    def _finalize_aborted(self, req: Request) -> None:
        """Common exit for canceled and shed requests: release every
        resource (read holds were dropped by the caller for queued
        requests; running requests still own pages) and finalize with
        no output."""
        if self.paged:
            self._release_holds(req)
            if req.pages:
                self.pool.free(req.pages)
                req.pages = None
        req.completed_at = self._now()
        self._running.pop(req.uid, None)
        self._admission_dirty = True   # a slot/pages may have freed
        self.done.append(req)
        if self._tr.enabled:
            # the request may be mid-"queued" or mid-"running"; close
            # whatever is open on its track so no span is orphaned
            outcome = ("shed" if req.shed
                       else "fault" if req.fault is not None
                       else "canceled")
            self._tr.close_track(PID_REQUESTS, req.uid,
                                 args={"outcome": outcome})
        if req.shed:
            self.stats.requests_shed += 1
            if req.slo is not None:   # a shed request IS a missed SLO
                self.stats.slo_missed += 1
            self._emit(req, "shed")
        elif req.fault is not None:
            # fault containment killed it (§10): distinct from a client
            # cancel so chaos tests can assert the aborted-uid set
            self.stats.requests_faulted += 1
            self._emit(req, "aborted")
        else:
            self.stats.requests_canceled += 1
            self._emit(req, "canceled")

    def _host_overlap(self, lane: LaneKey,
                      slots: List[Optional[Request]]) -> None:
        """Host-side work double-buffered against the in-flight device
        step (DESIGN.md §8): runs after the step is dispatched but
        before the first host sync on its outputs.  Everything here is
        host-only — mailbox intake, SLO shedding, and the prefix-trie
        lookup + read holds for the next admission candidate (which
        ``_admit_one`` then reuses via ``plan_epoch``)."""
        self._drain_mailbox()
        self._shed_hopeless()
        pol = self.slo_policy
        if pol is not None and self.queue and not self._admission_dirty:
            # a queued request crossing the urgency threshold changes
            # the admission outcome (boost can preempt a running row) —
            # re-scan even though no finish/arrival event fired
            now = self._now()
            for r in self.queue:
                if not r.boosted and pol.urgent(r, now):
                    r.boosted = True
                    self._admission_dirty = True
        if pol is not None and pol.shed:
            now = self._now()
            for s in slots:
                if (s is not None and not s.canceled and s.slo is not None
                        and now > s.submitted_at + s.slo.deadline):
                    s.canceled = True    # running past deadline: shed
                    s.shed = True
        if self._stop is not None and self._stop.is_set():
            for s in slots:              # clean shutdown: abort in-flight
                if s is not None:
                    s.canceled = True
        if (self.paged and self.prefix is not None
                and self._admission_dirty):
            for req in self._lane_candidates(lane)[:1]:
                if req.n_pages:
                    # plans AND services a PROMOTING candidate inside
                    # the dispatch window: the host->device write rides
                    # the live arenas in dataflow order, overlapping
                    # the in-flight decode step (DESIGN.md §9)
                    self._plan_with_promotion(req)

    def _stream_tokens(self, slots: List[Optional[Request]],
                       sess: DecodeSession,
                       p_lens: List[int]) -> None:
        """Emit token events for streaming slots: diff the gen span of
        the canvas against each request's emitted mask.  Canvas
        diffing (not the commit ring) so wide parallel commits that
        overflow the ring never drop stream tokens."""
        live = [(i, s) for i, s in enumerate(slots)
                if s is not None and not s.canceled and s.fault is None
                and (s.sink is not None
                     or (s.stream and self.event_sink is not None))]
        if not live:
            return
        toks = sess.host_tokens()
        mask_id = self.cfg.mask_id
        for i, req in live:
            span = toks[i, p_lens[i]: p_lens[i] + req.gen_len]
            if req.emitted is None:
                req.emitted = np.zeros((req.gen_len,), bool)
            fresh = (span != mask_id) & ~req.emitted
            if not fresh.any():
                continue
            pos = np.nonzero(fresh)[0]
            req.emitted[pos] = True
            self._emit(req, "token", positions=tuple(int(p) for p in pos),
                       tokens=tuple(int(t) for t in span[pos]))

    # ------------------------------------------------------------------
    # Shared-prefix index (DESIGN.md §6)
    # ------------------------------------------------------------------

    def _prompt_in_canvas(self, req: Request) -> np.ndarray:
        """The prompt tokens that actually land on the canvas (the
        index key must describe the canvas, not the raw request)."""
        return req.prompt[: self.canvas_len - req.gen_len]

    def _prefix_key(self, req: Request):
        return (req.row_len, req.lane[1].prefix_key())

    def _prefix_plan(self, req: Request) -> None:
        """Consult the index for an admission candidate: on a hit, take
        read holds on the matched pages — they will be mapped at the
        row's logical prefix, with ``req.pages[:shared_n]`` as the
        copy-on-write reserve.  Runs BEFORE the shortage check so the
        holds protect the matched entry from this admission's own index
        eviction; a stalled candidate releases them again.  Resumed
        requests never match: their canvas holds committed generation
        the publisher prefilled as [MASK]."""
        self._drop_plan(req)    # releases stale holds, never leaks them
        if (self.prefix is None or req.preemptions > 0
                or not req.n_pages):
            return
        match = self.prefix.lookup(self._prefix_key(req),
                                   self._prompt_in_canvas(req),
                                   partial_ok=self._partial_ok,
                                   promote_ok=(self.tier is not None
                                               and not req.no_promote
                                               and not
                                               self._host_tier_paused))
        if match is None:
            return
        if match.needs_promotion:
            # PROMOTING: the match lives (partly) in the host tier —
            # no holds yet; _promote_now converts this to a device plan
            req.pending_promotion = match
            return
        self.pool.retain(list(match.pages))
        req.holds = list(match.pages)
        req.shared_n = match.n_pages
        req.shared_full = match.full

    def _drop_plan(self, req: Request) -> None:
        self._release_holds(req)
        req.shared_n, req.shared_full = 0, False
        req.plan_epoch = None
        req.pending_promotion = None

    def _count_prefix_hit(self, req: Request) -> None:
        """Admission succeeded: account the planned hit."""
        self.telemetry.registry.histogram(
            "spa_prefix_hit_depth_pages",
            "index pages attached per admission (0 = miss)",
            buckets=_HIT_DEPTH_BUCKETS).observe(req.shared_n
                                                if req.holds else 0)
        if not req.holds:
            return
        self.stats.prefix_hits += 1
        if req.shared_full:
            self.stats.prefix_full_hits += 1
            self.stats.prefix_tokens_saved += req.row_len
        else:
            self.stats.prefix_tokens_saved += (req.shared_n
                                               * self.page_size)

    def _attach_spec(self, req: Request, row: int):
        """(page-table row, SharedPrefix|None) for one slot."""
        if not req.holds:
            return self._pt_row(req), None
        m = req.shared_n
        pt_pages = req.holds + (req.pages or [])[m:]
        spec = SharedPrefix(row=row, pages=tuple(req.holds),
                            reserve=tuple((req.pages or [])[:m]))
        return self.pool.page_table_row(pt_pages, self.canvas_len), spec

    def _on_cow(self, slots: List[Optional[Request]],
                specs) -> None:
        """Session copy-on-write fired: drop the read holds — the rows
        now run entirely on their own pages."""
        for s in specs:
            req = slots[s.row]
            if req is not None and req.holds:
                self.pool.release(req.holds)
                req.holds = None

    def _release_holds(self, req: Request) -> None:
        if req.holds:
            self.pool.release(req.holds)
            req.holds = None

    def _maybe_publish(self, req: Request, sess: DecodeSession) -> None:
        """Publish an attached request's prefill-time pages into the
        index (admission time — BEFORE the first decode write evolves
        them; harvest-time states would break full-hit byte parity).
        Cold requests publish their whole run (prompt path + all-[MASK]
        tail); partial hits publish only the depths past their match,
        extending the trie.  A page copy pays for it; skipped when the
        pool has no slack."""
        if self.prefix is None or req.preemptions > 0 or not req.n_pages:
            return
        if self._publish_paused:
            # ladder L1 (§10): stop growing shared state under fault
            # pressure — the cheapest capability to shed, since misses
            # only cost prefill compute, never correctness
            self.stats.publish_paused_skips += 1
            return
        n_run = req.row_len // self.page_size
        m = req.shared_n if req.holds else 0
        if m >= n_run:
            return                       # full hit: already indexed
        key = self._prefix_key(req)
        prompt = self._prompt_in_canvas(req)
        # read-only probe first: duplicate prompts admitted in one batch
        # all plan before the first publishes, so later ones would
        # otherwise alloc + device-copy a full run just to have insert
        # reject every page
        missing = [d for d in self.prefix.missing_slots(key, prompt,
                                                        n_run) if d >= m]
        if not missing:
            return
        pub = self.pool.alloc(len(missing))
        if pub is None:
            self.stats.prefix_publish_skipped += 1
            return
        sess.copy_cache_pages([(req.pages or [])[d] for d in missing],
                              pub)
        pages: List[Optional[int]] = [None] * n_run
        for d, p in zip(missing, pub):
            pages[d] = p
        rejected = self.prefix.insert(key, prompt, pages)
        if rejected:
            self.pool.release(rejected)
        adopted = [p for p in pub if p not in rejected]
        if adopted and self.tier is not None:
            # register signature + per-page stability (from the
            # identifier rows just copied) so a later demotion knows
            # which arenas to read and how cold-worthy each page is
            self.tier.note_published(
                cache_signature(self.cfg, req.lane[1]), adopted,
                self._proxy_blocks(sess, adopted))
        self.stats.prefix_published += len(pub) - len(rejected)
        self._prefix_epoch += 1       # pre-planned misses may now hit

    def drop_prefix_cache(self) -> int:
        """Release every index hold, free every host-tier ref, and
        clear the trie (tests, or explicit memory reclamation).
        Returns device pages released."""
        if self.prefix is None:
            return 0
        self._prefix_epoch += 1
        return self.prefix.clear(self.pool)

    # ------------------------------------------------------------------
    # Host tier: demote/promote IO + promotion service (DESIGN.md §9)
    # ------------------------------------------------------------------

    def _tier_read(self, sig: Tuple, pages: List[int]):
        """Demotion read: whole physical pages as host (numpy) blocks.
        Mid-lane the live arenas are the active session's step futures
        — the pool's stored copies are stale — so reads route through
        the session (np.asarray syncs on the in-flight step)."""
        if self._active_sess is not None and self._active_sig == sig:
            blocks = self._active_sess.read_cache_pages(pages)
        else:
            arenas = self.pool.peek_arenas(sig)
            assert arenas is not None, (
                "demoting pages from a signature with no arenas")
            blocks = cache_lib.read_arena_pages(arenas, pages)
        return {kind: {name: np.asarray(b) for name, b in bufs.items()}
                for kind, bufs in blocks.items()}

    def _tier_write(self, sig: Tuple, pages: List[int], blocks) -> None:
        """Promotion write: scatter host blocks into the signature's
        device arenas.  Through the live session mid-lane the write is
        dispatched (not synced), landing in dataflow order after the
        in-flight step — promotions overlap decode."""
        if self._active_sess is not None and self._active_sig == sig:
            self._active_sess.write_cache_pages(pages, blocks)
            return
        arenas = self.pool.peek_arenas(sig)
        assert arenas is not None, (
            "promoting pages into a signature with no arenas")
        self.pool.put_arenas(
            sig, cache_lib.write_arena_pages(arenas, pages, blocks))

    def _proxy_blocks(self, sess: DecodeSession, pages: List[int]):
        """Per-page singular-proxy identifier rows for stability
        scoring (hier.page_stability) — None for proxy-less caches."""
        cache = sess.state.cache
        sub = {kind: {"proxy": bufs["proxy"]}
               for kind, bufs in cache.arenas.items() if "proxy" in bufs}
        if not sub:
            return None
        kind = next(iter(sub))
        blk = np.asarray(
            cache_lib.read_arena_pages(sub, list(pages))[kind]["proxy"])
        return {p: blk[:, i] for i, p in enumerate(pages)}

    def _evict_index(self, n_pages: int) -> int:
        """Index eviction with the §9 telemetry split: evicted device
        pages divide into demoted (moved host-ward) and dropped.
        Delta-accounted off the prefix counters so warm-up resets of
        ``stats`` don't double-count."""
        d0 = self.prefix.demoted_pages
        x0 = self.prefix.dropped_pages
        freed = self.prefix.evict(self.pool, n_pages)
        self.stats.prefix_demoted_pages += self.prefix.demoted_pages - d0
        self.stats.prefix_dropped_pages += self.prefix.dropped_pages - x0
        if freed:
            self.stats.prefix_evicted_pages += freed
            self._prefix_epoch += 1
            self._tr.instant(
                PID_EVENTS, 2, "demote", cat="tier",
                args={"freed": freed, "step": self.stats.steps,
                      "demoted": self.prefix.demoted_pages - d0,
                      "dropped": self.prefix.dropped_pages - x0})
        return freed

    def _promote_now(self, req: Request) -> bool:
        """Service a PROMOTING request: allocate device pages for the
        match's host refs, write the (dequantized) blocks into the
        signature's arenas, re-point the trie entries, and leave the
        request with a normal device plan + read holds.  Returns True
        on success.  On failure the plan is dropped — a stale match
        replans; a headroom failure marks the request ``no_promote`` so
        its replan runs device-only instead of retrying forever."""
        match = req.pending_promotion
        req.pending_promotion = None
        if match is None:
            return False
        if not self.prefix.sites_intact(match):
            req.plan_epoch = None       # trie moved: replan fresh
            return False
        n = len(match.host_refs)
        # hold the match's device prefix while we make headroom — the
        # eviction below must not cannibalize our own plan
        self.pool.retain(list(match.pages))
        short = max(0, n - self.pool.available)
        if short and self.prefix.evictable_total(self.pool) >= short:
            self._evict_index(short)
        pages = self.pool.alloc(n)
        if pages is None or not self.prefix.sites_intact(match):
            if pages is not None:
                self.pool.free(pages)
            else:
                req.no_promote = True
            self.pool.release(list(match.pages))
            req.plan_epoch = None
            self.stats.promotion_stalls += 1
            return False
        refs = list(match.host_refs)
        try:
            sig, blocks = self.tier.promote(refs)
        except HostPageCorruption:
            # §10: corrupt host bytes never reach the device.  The tier
            # already freed the whole entry's slots; scrub the trie's
            # now-dangling host refs (no free_refs — the slots are
            # gone), drop the fresh alloc and the match holds, and fall
            # back to a cold prefill on replan.
            self.pool.free(pages)
            self.pool.release(list(match.pages))
            self.prefix.scrub_host_sites(match)
            self.stats.host_checksum_failures += 1
            self.stats.cold_prefill_fallbacks += 1
            if self.supervisor is not None:
                self.supervisor.note_pressure("host_corrupt")
            req.plan_epoch = None
            self._prefix_epoch += 1     # the scrubbed entries are gone
            self._admission_dirty = True
            return False
        self._tier_write(sig, pages, blocks)
        all_pages = self.prefix.install_promoted(match, pages)
        self.tier.note_promoted(sig, pages, refs)
        self.pool.retain(pages)         # index owns rc1; reader hold
        req.holds = all_pages
        req.shared_n = len(all_pages)
        req.shared_full = match.full
        self.stats.prefix_promoted_pages += n
        self.stats.prefix_promotions += 1
        self._tr.instant(PID_REQUESTS, req.uid, "promote", cat="tier",
                         args={"pages": n, "step": self.stats.steps})
        self._prefix_epoch += 1         # planned misses may now hit
        req.plan_epoch = self._prefix_epoch
        self._admission_dirty = True
        return True

    def _plan_with_promotion(self, req: Request) -> None:
        """Plan an admission candidate, resolving a PROMOTING state
        synchronously.  A failed promotion replans once against the
        fresh trie (a second PROMOTING outcome is only possible after
        another concurrent mutation — promote again or give up cold)."""
        if req.plan_epoch != self._prefix_epoch:
            self._prefix_plan(req)
            req.plan_epoch = self._prefix_epoch
        if req.pending_promotion is None:
            return
        if not self._promote_now(req) and req.plan_epoch is None:
            self._prefix_plan(req)
            req.plan_epoch = self._prefix_epoch
            if req.pending_promotion is not None \
                    and not self._promote_now(req):
                # two promotion failures in one planning pass: give up
                # on the host tier for this admission and replan
                # device-only (no_promote is sticky, so this
                # terminates) instead of admitting plan-less
                req.no_promote = True
                self._prefix_plan(req)
                req.plan_epoch = self._prefix_epoch

    # ------------------------------------------------------------------
    # Admission control + preemption (paged mode)
    # ------------------------------------------------------------------

    def _lane_candidates(self, lane: LaneKey) -> List[Request]:
        """Lane-matching queued requests in admission order: strict
        (effective) priority first; within a priority, queue order —
        or, under an SLO policy, earliest TTFT deadline first (EDF),
        with queue order breaking slack ties.  The SLO boost folds into
        the effective priority, so a near-deadline request jumps ahead
        of (and may preempt) slack-rich peers."""
        matches = [(i, r) for i, r in enumerate(self.queue)
                   if r.lane == lane]
        if self.slo_policy is None:
            return [r for _, r in
                    sorted(matches, key=lambda ir: (-ir[1].priority,
                                                    ir[0]))]
        pol, now = self.slo_policy, self._now()
        return [r for _, r in sorted(matches, key=lambda ir: (
            -pol.effective_priority(ir[1], now),
            pol.ttft_slack(ir[1], now), ir[0]))]

    def _preempt(self, slot: int, victim: Request,
                 slots: List[Optional[Request]],
                 sess: DecodeSession) -> None:
        """Evict a running request: snapshot its canvas + commit ring,
        release its slot/pages, requeue it at the FRONT of the queue."""
        snap = sess.snapshot_rows([slot])
        victim.snapshot = {k: v[0] for k, v in snap.items()}
        sess.release_rows([slot])
        self._release_holds(victim)      # un-COW'd shared pages go back
        victim.shared_n = 0
        if self.paged:                   # dense lanes have no pool (the
            self.pool.free(victim.pages or [])   # watchdog preempts too)
        victim.pages = None
        victim.preemptions += 1
        self.stats.preemptions += 1
        slots[slot] = None
        self._running.pop(victim.uid, None)
        self.queue.appendleft(victim)
        tr = self._tr
        if tr.enabled:
            tr.end(PID_REQUESTS, victim.uid, "running",
                   args={"exit": "preempt"})
            tr.instant(PID_REQUESTS, victim.uid, "preempt",
                       cat="lifecycle",
                       args={"step": self.stats.steps,
                             "preemptions": victim.preemptions})
            tr.begin(PID_REQUESTS, victim.uid, "queued", cat="lifecycle",
                     args={"resumed": True})

    # ------------------------------------------------------------------
    # fault handling (§10)

    def _inject_nan(self, slots: List[Optional[Request]],
                    sess: DecodeSession) -> None:
        """Arm a deterministic NaN poisoning of one live row's cache
        pages.  The poison is applied inside ``sess.step()`` AFTER the
        refresh rebuild (so refresh_interval=1 lanes can't wash it out)
        — modelling bit-rot on the freshly built arena.  Rows still
        holding un-COW'd shared pages are never picked: poisoning a
        shared page would taint other requests through the index."""
        if not self.paged or self.faults is None:
            return
        victims = [s for s in slots
                   if s is not None and not s.canceled and s.fault is None
                   and s.pages and not s.holds]
        if not victims:
            return
        k = self.faults.fired["step_nan"] - 1   # this probe already fired
        pick = victims[choose_index(self.faults.plan.seed, "nan_row",
                                    k, len(victims))]
        sess.poison_pages_after_refresh(pick.pages)

    def _disconnect_burst(self, slots: List[Optional[Request]]) -> None:
        """Client disconnect burst: every streaming request in the batch
        loses its consumer at once.  Modelled as cancellation — the dead
        scan reaps the rows and their pages on this same iteration."""
        hit = 0
        for s in slots:
            if (s is not None and not s.canceled and s.fault is None
                    and (s.stream or s.sink is not None)):
                s.canceled = True
                hit += 1
        if hit:
            self.stats.disconnect_bursts += 1
            if self.supervisor is not None:
                self.supervisor.note_pressure("disconnect")

    def _watchdog_recover(self, lane: LaneKey,
                          slots: List[Optional[Request]],
                          sess: DecodeSession) -> None:
        """Watchdog fired: the lane made no progress for a full budget
        window (stuck device / livelocked batch).  Recovery is a device
        reset in miniature: finalize rows already canceled or faulted,
        force-preempt the rest back to the queue via their snapshots,
        and clear any injected stall so the rebuilt lane can run."""
        self.stats.watchdog_fires += 1
        dead = [i for i, s in enumerate(slots)
                if s is not None and (s.canceled or s.fault is not None)]
        for i in dead:
            req = slots[i]
            slots[i] = None
            self._finalize_aborted(req)
        if dead:
            if self.paged:
                sess.release_rows(dead)
            else:
                sess.deactivate_rows(dead)
        for i, r in enumerate(slots):
            if r is not None:
                self._preempt(i, r, slots, sess)
        if self.faults is not None:
            self.faults.clear_stall(lane)
        if self.supervisor is not None:
            self.supervisor.note_pressure("watchdog")
            self.supervisor.lane_started()

    def _admit_one(self, lane: LaneKey, slots: List[Optional[Request]],
                   sess: Optional[DecodeSession],
                   protected: Tuple[int, ...] = ()) -> Optional[Request]:
        """Admit one lane request: it needs a free SLOT and (paged mode)
        enough free PAGES.  When either is short, strictly
        lower-priority running requests are preempted — lowest priority
        first, most recently started first within a priority (the
        oldest work keeps its progress) — until the candidate fits; if
        the eligible victims can't cover it, the candidate stalls and
        smaller/lower-priority candidates get a chance.  Returns the
        admitted request (popped from the queue, pages allocated) or
        None.

        ``protected`` slots are admitted-but-not-yet-attached this swap
        round: the session has no state for them, so they cannot be
        preemption victims."""
        stalled = False
        now = self._now()
        for req in self._lane_candidates(lane):
            if req.retry_after_step > self.stats.steps:
                stalled = True      # backing off a transient alloc fault
                continue
            slot_free = any(s is None for s in slots)
            if not self.paged:
                if not slot_free:
                    return None     # dense mode: no preemption
                self.queue.remove(req)
                self._admit_bookkeep(req)
                return req
            # plan the prefix hit FIRST: the read holds protect the
            # matched entry from this admission's own index eviction.
            # A plan made at the current index epoch (the double-buffer
            # overlap pre-plans the head candidate while the device
            # step is in flight) is reused as-is; a PROMOTING plan is
            # serviced synchronously here (the overlap window is the
            # async fast path for the head candidate).
            self._plan_with_promotion(req)
            page_short = (max(0, req.n_pages - self.pool.available)
                          if req.n_pages else 0)
            victims = []
            if sess is not None:
                req_eff = self._eff_priority(req, now)
                victims = [(i, r) for i, r in enumerate(slots)
                           if r is not None and i not in protected
                           and self._eff_priority(r, now) < req_eff]
                victims.sort(key=lambda ir: (
                    self._eff_priority(ir[1], now),
                    -(ir[1].started_at or 0.0)))
            if page_short and self.prefix is not None:
                # admission pressure: evict LRU reader-less index
                # entries before touching any RUNNING request — but
                # only when eviction (plus the preemptible victims)
                # can actually admit this candidate; destroying LRU
                # entries for a request that stalls anyway trades
                # future hits for nothing
                freeable = sum(len(r.pages or []) for _, r in victims)
                feasible = (
                    (slot_free or victims)
                    and self.pool.available + freeable
                    + self.prefix.evictable_total(self.pool)
                    >= req.n_pages)
                freed = (self._evict_index(page_short)
                         if feasible else 0)
                if freed:
                    page_short = max(0, req.n_pages - self.pool.available)
            if page_short or not slot_free:
                if sess is None:
                    self._drop_plan(req)
                    stalled = True
                    continue
                freeable = sum(len(r.pages or []) for _, r in victims)
                if (self.pool.available + freeable < req.n_pages
                        or (not slot_free and not victims)):
                    self._drop_plan(req)
                    stalled = True
                    continue        # a smaller/later candidate may fit
                for i, r in victims:
                    self._preempt(i, r, slots, sess)
                    if (self.pool.available >= req.n_pages
                            and any(s is None for s in slots)):
                        break
            pages = self.pool.alloc(req.n_pages) if req.n_pages else []
            if pages is None:
                # transient alloc failure (the §10 pool_alloc fault — a
                # genuine shortage was resolved above by eviction /
                # preemption): bounded retry with exponential backoff
                # on the virtual step clock, then a clean fault abort
                self._drop_plan(req)
                self.stats.alloc_faults += 1
                req.alloc_retries += 1
                max_r = (self.supervisor.cfg.max_alloc_retries
                         if self.supervisor is not None else 3)
                if req.alloc_retries > max_r:
                    self.queue.remove(req)
                    req.fault = "pool_alloc"
                    self._finalize_aborted(req)
                else:
                    req.retry_after_step = (
                        self.stats.steps + (1 << (req.alloc_retries - 1)))
                if self.supervisor is not None:
                    self.supervisor.note_pressure("pool_alloc")
                stalled = True
                continue
            self.queue.remove(req)
            req.pages = pages
            self._count_prefix_hit(req)
            self._admit_bookkeep(req)
            return req
        if stalled:
            self.stats.admission_stalls += 1
        return None

    def _admit_bookkeep(self, req: Request) -> None:
        self._running[req.uid] = req   # cancel() finds in-flight by uid
        tr = self._tr
        if tr.enabled:
            tr.end(PID_REQUESTS, req.uid, "queued")
            kind = ("resume" if req.preemptions > 0
                    else "full_hit" if req.shared_full
                    else "partial_prefill" if req.shared_n
                    else "prefill")
            tr.begin(PID_REQUESTS, req.uid, "running", cat="lifecycle",
                     args={"prefill": kind, "pages": req.n_pages,
                           "shared_pages": req.shared_n})

    # ------------------------------------------------------------------
    # Canvas rows
    # ------------------------------------------------------------------

    def _canvas_row(self, req: Request):
        """(tokens [N], active [N], committed_or_None, prompt_len) for
        one slot.  A preempted request resumes from its snapshot: the
        partially committed canvas, active mask and commit ring."""
        if req.snapshot is not None:
            snap = req.snapshot
            req.snapshot = None
            p_len = min(len(req.prompt), self.canvas_len - req.gen_len)
            return (snap["tokens"].copy(), snap["active"].copy(),
                    snap["committed"].copy(), p_len)
        mask_id = self.cfg.mask_id
        row = np.full((self.canvas_len,), mask_id, np.int32)
        p = req.prompt[: self.canvas_len - req.gen_len]
        row[: len(p)] = p
        active = np.zeros((self.canvas_len,), bool)
        active[len(p): len(p) + req.gen_len] = True
        return row, active, None, len(p)

    def _pt_row(self, req: Request) -> List[int]:
        return self.pool.page_table_row(req.pages or [], self.canvas_len)

    def _harvest(self, req: Request, toks_row: np.ndarray,
                 p_len: int) -> None:
        req.output = toks_row[p_len: p_len + req.gen_len]
        req.completed_at = self._now()
        e2e = req.completed_at - req.submitted_at
        self.stats.e2e_latencies.append(e2e)
        if req.started_at is not None:
            self.stats.queue_waits.append(
                req.started_at - req.submitted_at)
        ttft = float("inf")
        if req.first_token_at is not None:
            ttft = req.first_token_at - req.submitted_at
            self.stats.ttft_latencies.append(ttft)
            if req.last_commit_at is not None and req.tokens_done > 1:
                self.stats.tpot_latencies.append(
                    (req.last_commit_at - req.first_token_at)
                    / (req.tokens_done - 1))
        if req.slo is None or req.slo.met(ttft, e2e):
            self.stats.slo_met += 1
        else:
            self.stats.slo_missed += 1
        if self.paged:
            self._release_holds(req)
            if req.pages:
                self.pool.free(req.pages)
                req.pages = None
        self._running.pop(req.uid, None)
        self.done.append(req)
        self.stats.requests_done += 1
        tr = self._tr
        if tr.enabled:
            tr.end(PID_REQUESTS, req.uid, "running",
                   args={"exit": "done", "steps": req.served_steps})
            tr.end(PID_REQUESTS, req.uid, "request",
                   args={"outcome": "done", "tokens": req.tokens_done,
                         "preemptions": req.preemptions})
        self._emit(req, "done",
                   tokens=tuple(int(t) for t in req.output))

    # ------------------------------------------------------------------

    def run(self, max_steps: int = 256, on_step=None) -> EngineStats:
        """Serve the queue to completion.  ``on_step(engine)`` (if given)
        fires after every engine step — submissions made from it join
        the live run and are admitted mid-loop (the arrival path that
        exercises preemption)."""
        t0 = self._now()
        while True:
            self._drain_mailbox()
            self._shed_hopeless()
            if not self.queue:
                break
            lane = self.queue[0].lane
            steps0 = self.stats.steps
            self._run_lane(lane, max_steps, on_step)
            if self.queue and self.stats.steps == steps0:
                # every candidate is backing off a transient alloc
                # fault: idle-tick the virtual step clock so backoffs
                # can expire instead of busy-spinning forever (bounded
                # by max_alloc_retries → fault abort)
                self.stats.steps += 1
        self._wall = self._now() - t0
        self._note_pool_stats()
        return self.stats

    def run_online(self, stop: threading.Event, *, max_steps: int = 256,
                   idle_wait: float = 0.01, on_step=None) -> EngineStats:
        """Serve arrivals until ``stop`` is set — the online front-end's
        engine-thread loop (DESIGN.md §8).  While idle it blocks on the
        mailbox; while serving, arrivals ride the double-buffer overlap
        point into the live batch.  On stop, in-flight requests are
        aborted cleanly (canceled, resources released) and queued
        requests stay queued with their prefix plans dropped — the
        engine can be resumed or drained later."""
        self._stop = stop
        t0 = self._now()
        try:
            while not stop.is_set():
                self._drain_mailbox()
                self._shed_hopeless()
                if self.queue:
                    steps0 = self.stats.steps
                    self._run_lane(self.queue[0].lane, max_steps, on_step)
                    if self.queue and self.stats.steps == steps0:
                        self.stats.steps += 1   # alloc-backoff idle tick
                    continue
                try:
                    fn = self._mailbox.get(timeout=idle_wait)
                except queue_mod.Empty:
                    continue
                fn()
        finally:
            self._stop = None
            self._drain_mailbox()
            for r in list(self.queue):   # shutdown never leaks holds
                self._drop_plan(r)
            self._wall = self._now() - t0
            self._note_pool_stats()
        return self.stats

    def _note_pool_stats(self) -> None:
        if self.faults is not None:
            self.stats.faults_injected = self.faults.total_fired
        if self.paged:
            self.stats.peak_pool_util = (self.pool.peak_used
                                         / max(self.pool.capacity, 1))
            self.stats.steady_pool_util = self.pool.steady_utilization
        if self.host_pool is not None:
            self.stats.peak_host_util = (
                self.host_pool.peak_units
                / max(self.host_pool.capacity_units, 1))

    def _run_lane(self, lane: LaneKey, max_steps: int,
                  on_step=None) -> None:
        sess = self._session_for(lane)
        strategy = lane[1]
        tr = self._tr
        lid = self._lane_id(lane)
        slots: List[Optional[Request]] = [None] * self.max_batch
        batch: List[Request] = []
        while len(batch) < self.max_batch:
            req = self._admit_one(lane, slots, sess=None)
            if req is None:
                break
            batch.append(req)
        if not batch:
            return
        # dense lanes size the canvas to the actual batch (an underfilled
        # lane never pays full-width placeholder rows); paged lanes keep
        # max_batch rows so slots freed later (pages permitting) can
        # admit without a reshape/recompile
        b = self.max_batch if self.paged else len(batch)
        slots = [None] * b
        now = self._now()
        mask_id = self.cfg.mask_id
        tokens = np.full((b, self.canvas_len), mask_id, np.int32)
        active = np.zeros((b, self.canvas_len), bool)
        committed0 = np.full((b, lane[0].commit_ring), -1, np.int32)
        kv = np.zeros((b,), np.int32)
        n_log = (n_logical_pages(self.canvas_len, self.page_size)
                 if self.paged else 0)
        pt = np.zeros((b, n_log), np.int32)
        p_lens = [0] * b
        ages = [0] * b                 # max_steps budget is PER REQUEST
        shared_specs: List[SharedPrefix] = []
        for i, req in enumerate(batch):
            row, act, com, p_len = self._canvas_row(req)
            tokens[i], active[i] = row, act
            if com is not None:
                committed0[i] = com
            slots[i] = req
            p_lens[i] = p_len
            ages[i] = req.served_steps
            kv[i] = req.row_len
            if self.paged and strategy.uses_cache:
                pt[i], spec = self._attach_spec(req, i)
                if spec is not None:
                    shared_specs.append(spec)
            if req.started_at is None:
                req.started_at = now
        if self.paged:
            sess.cow_callback = functools.partial(self._on_cow, slots)
            arenas = (self.pool.arenas_for(strategy)
                      if strategy.uses_cache else None)
            sess.attach(tokens, active=active, kv_len=kv,
                        arenas=arenas, page_table=pt,
                        shared=shared_specs or None)
            if strategy.uses_cache:
                # tier reads/writes route through this session until
                # the lane ends (the pool's copies are stale, §9)
                self._active_sess = sess
                self._active_sig = cache_signature(self.cfg, strategy)
            for req in batch:
                self._maybe_publish(req, sess)
        else:
            sess.attach(tokens, active=active)
        if (committed0 != -1).any():
            sess.state = sess.state._replace(
                committed=sess.state.committed.at[:].set(committed0))

        sup = self.supervisor
        if sup is not None:
            sup.lane_started()
        while any(s is not None for s in slots):
            if self.faults is not None and self.faults.stall_lane(lane):
                # stuck lane (§10): the device step is never dispatched
                # (models a hung device).  Host-side work and the
                # virtual clock still advance, so the watchdog fires
                # within its budget and force-preempts the lane.
                self._host_overlap(lane, slots)
                self.stats.steps += 1
                if on_step is not None:
                    on_step(self)
                if sup is not None:
                    if sup.watchdog(progressed=False):
                        self._watchdog_recover(lane, slots, sess)
                    sup.on_iteration()
                continue
            if self.faults is not None and self.faults.fire("step_nan"):
                self._inject_nan(slots, sess)
            if tr.enabled:
                tr.begin(PID_ENGINE, lid, "dispatch", cat="phase")
            info = sess.step()
            # double-buffered dispatch (DESIGN.md §8): the jitted step
            # is dispatched but NOT synced yet — mailbox intake, SLO
            # shedding and next-candidate prefix planning run on the
            # host while the device step is in flight.
            if tr.enabled:
                self._phase_end(lid, "dispatch")
                tr.begin(PID_ENGINE, lid, "host_overlap", cat="phase")
            self._host_overlap(lane, slots)
            if tr.enabled:
                self._phase_end(lid, "host_overlap")
            self.stats.steps += 1
            if self.paged:
                self.pool.note_step()
            if tr.enabled:
                tr.begin(PID_ENGINE, lid, "host_sync", cat="phase")
            n_comm = np.asarray(info["n_committed"])  # first host sync
            if tr.enabled:
                self._phase_end(lid, "host_sync")
                if self.paged:
                    tr.counter(PID_ENGINE, "pool_pages",
                               {"used": self.pool.used,
                                "free": self.pool.available})
                if self.host_pool is not None:
                    tr.counter(PID_ENGINE, "host_tier_units",
                               {"used": self.host_pool.used_units})
                tr.counter(PID_ENGINE, "queue_depth",
                           {"queued": len(self.queue),
                            "running": len(self._running)})
            self.stats.tokens_committed += int(n_comm.sum())
            # cache-dynamics sampling (DESIGN.md §11): host-side proxy
            # diffing AFTER the step's first host sync — never on the
            # dispatch path, never into the compiled graph
            dyn = self.telemetry.dynamics_every
            if dyn and strategy.uses_cache \
                    and self.stats.steps % dyn == 0:
                self._note_cache_dynamics(
                    sess, strategy,
                    n_live=sum(s is not None for s in slots))
            if self.faults is not None and self.faults.fire("disconnect"):
                self._disconnect_burst(slots)
            nan_rows = (sup.nan_guard(info, slots)
                        if sup is not None and self.paged else [])
            if on_step is not None:
                on_step(self)
            now = self._now()
            for i, s in enumerate(slots):     # TTFT / TPOT bookkeeping
                if s is None or s.fault is not None or n_comm[i] <= 0:
                    continue
                if s.first_token_at is None:
                    s.first_token_at = now
                s.last_commit_at = now
                s.tokens_done += int(n_comm[i])
            self._stream_tokens(slots, sess, p_lens)
            n_masked = np.asarray(sess.state.n_masked)
            finished, dead = [], []
            for i, s in enumerate(slots):
                if s is None:
                    continue
                ages[i] += 1
                s.served_steps = ages[i]
                # a request that exhausts its own step budget is
                # harvested as-is (same semantics as the old
                # run-to-max_steps static batch loop)
                if s.canceled or s.fault is not None:
                    dead.append(i)
                elif n_masked[i] <= 0 or ages[i] >= max_steps:
                    finished.append(i)
            progressed = bool(int(n_comm.sum()) > 0 or finished or dead)
            if sup is not None:
                if tr.enabled:
                    tr.begin(PID_ENGINE, lid, "supervisor", cat="phase")
                fired = sup.watchdog(progressed)
                if fired:
                    self._watchdog_recover(lane, slots, sess)
                else:
                    sup.on_iteration()
                if tr.enabled:
                    self._phase_end(lid, "supervisor")
                if fired:
                    continue
            if not (finished or dead) and not (self.continuous
                                               and self._admission_dirty):
                continue
            if finished or dead:
                toks = sess.host_tokens()
                for i in finished:
                    self._harvest(slots[i], toks[i], p_lens[i])
                    slots[i] = None
                for i in dead:
                    req = slots[i]
                    slots[i] = None
                    self._finalize_aborted(req)
                if self.paged:
                    # zero the finished rows' page-table entries BEFORE
                    # their freed pages can be re-allocated below — a
                    # stale entry would let the dead row's next
                    # write-back corrupt the new owner's pages
                    sess.release_rows(finished + dead)
            if nan_rows:
                # NaN quarantine (§10): the poisoned rows died above;
                # force-preempt every surviving lane-mate so the batch
                # rebuilds from preemption snapshots — one poisoned
                # canvas never taints its neighbours' outputs.
                self.stats.nan_quarantines += len(nan_rows)
                for i, r in enumerate(slots):
                    if r is not None:
                        self._preempt(i, r, slots, sess)
                continue
            swap_rows, swap_tokens, swap_active = [], [], []
            swap_kv, swap_pt, swap_com = [], [], []
            swap_shared: List[SharedPrefix] = []
            while self.continuous:
                # fill every empty slot — and let _admit_one MAKE one by
                # preempting a lower-priority row when a high-priority
                # arrival finds the batch/pool full — until admission
                # stalls or the queue drains
                req = self._admit_one(lane, slots, sess,
                                      protected=tuple(swap_rows))
                if req is None:
                    break
                empty = [i for i, s in enumerate(slots) if s is None]
                i = empty[0]
                row, act, com, p_len = self._canvas_row(req)
                slots[i] = req
                p_lens[i] = p_len
                ages[i] = req.served_steps
                if req.started_at is None:
                    req.started_at = self._now()
                swap_rows.append(i)
                swap_tokens.append(row)
                swap_active.append(act)
                swap_kv.append(req.row_len)
                if self.paged and strategy.uses_cache:
                    pt_row, spec = self._attach_spec(req, i)
                    swap_pt.append(pt_row)
                    if spec is not None:
                        swap_shared.append(spec)
                else:
                    swap_pt.append([0] * n_log)
                swap_com.append(com if com is not None else np.full(
                    (committed0.shape[1],), -1, np.int32))
            self._admission_dirty = False
            if swap_rows:
                if self.paged:
                    sess.replace_rows(
                        swap_rows, np.stack(swap_tokens),
                        np.stack(swap_active),
                        row_kv_len=np.asarray(swap_kv, np.int32),
                        row_page_table=np.asarray(swap_pt, np.int32),
                        row_committed=np.stack(swap_com),
                        row_shared=swap_shared or None)
                    for i in swap_rows:
                        self._maybe_publish(slots[i], sess)
                else:
                    sess.replace_rows(swap_rows, np.stack(swap_tokens),
                                      np.stack(swap_active))
                self.stats.swaps += len(swap_rows)
            parked = [i for i in finished + dead if i not in swap_rows
                      and slots[i] is None]
            if parked and not self.paged:   # paged rows released above
                sess.deactivate_rows(parked)
        if (self.paged and strategy.uses_cache and sess.state is not None
                and isinstance(sess.state.cache, PagedCache)):
            self.pool.store_arenas(strategy, sess.state.cache.arenas)
        self._active_sess = None
        self._active_sig = None

"""Batched DLM serving engine with SPA-Cache.

Requests (prompt + gen_len) are padded onto a fixed canvas, batched up to
``max_batch``, prefilled once, then refined step-by-step with the SPA
sparse update; finished sequences are swapped out and pending requests
swapped in (continuous batching at step granularity).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import spa_layer
from repro.dlm import decoding


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # [P] int32
    gen_len: int
    submitted_at: float = dataclasses.field(default_factory=time.time)
    completed_at: Optional[float] = None
    output: Optional[np.ndarray] = None


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    tokens_committed: int = 0
    requests_done: int = 0

    def tps(self, wall: float) -> float:
        return self.tokens_committed / max(wall, 1e-9)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 canvas_len: int = 64,
                 settings: Optional[decoding.DecodeSettings] = None):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.canvas_len = canvas_len
        self.settings = settings or decoding.DecodeSettings()
        self.proxies = spa_layer.build_spa_proxies(params, cfg)
        self.queue: deque[Request] = deque()
        self.done: List[Request] = []
        self.stats = EngineStats()
        self._step_fn = jax.jit(functools.partial(
            decoding.serve_step, params, cfg, settings=self.settings,
            spa_proxies=self.proxies))

    def submit(self, prompt: np.ndarray, gen_len: int) -> int:
        uid = len(self.done) + len(self.queue)
        self.queue.append(Request(uid, np.asarray(prompt, np.int32),
                                  gen_len))
        return uid

    def _make_batch(self) -> List[Request]:
        batch = []
        while self.queue and len(batch) < self.max_batch:
            batch.append(self.queue.popleft())
        return batch

    def _canvas_for(self, batch: List[Request]) -> jnp.ndarray:
        mask_id = self.cfg.mask_id
        canvas = np.full((len(batch), self.canvas_len), mask_id,
                         np.int32)
        for i, req in enumerate(batch):
            p = req.prompt[: self.canvas_len - req.gen_len]
            canvas[i, : len(p)] = p
            # positions after prompt+gen stay masked but are not required
            end = len(p) + req.gen_len
            canvas[i, end:] = 0  # pad with token 0 (committed filler)
        return jnp.asarray(canvas)

    def run(self, max_steps: int = 256) -> EngineStats:
        t0 = time.time()
        while self.queue:
            batch = self._make_batch()
            canvas = self._canvas_for(batch)
            use_cache = self.cfg.spa.identifier != "none"
            if use_cache:
                _, cache = decoding.prefill(
                    self.params, self.cfg, {"tokens": canvas},
                    self.proxies)
            else:
                cache = {}
            n_masked = jnp.asarray(
                [min(r.gen_len, self.canvas_len - len(r.prompt))
                 for r in batch], jnp.int32)
            state = decoding.DecodeState(
                tokens=canvas, cache=cache,
                step=jnp.zeros((), jnp.int32),
                committed=jnp.full((len(batch), 8), -1, jnp.int32),
                n_masked=n_masked)
            for _ in range(max_steps):
                state, info = self._step_fn(state)
                self.stats.steps += 1
                self.stats.tokens_committed += int(
                    jnp.sum(info["n_committed"]))
                if int(jax.device_get(jnp.max(state.n_masked))) <= 0:
                    break
            toks = np.asarray(state.tokens)
            for i, req in enumerate(batch):
                start = len(req.prompt)
                req.output = toks[i, start: start + req.gen_len]
                req.completed_at = time.time()
                self.done.append(req)
                self.stats.requests_done += 1
        self._wall = time.time() - t0
        return self.stats

"""Batched DLM serving engine on DecodeSession (DESIGN.md §3.2, §5).

Requests (prompt + gen_len + optional per-request DecodeSettings /
CacheStrategy / UnmaskScheduler / priority) are padded onto fixed canvas
rows and served by a ``DecodeSession`` at **step granularity**: when a
row finishes, its slot is swapped for the next queued request mid-loop
(``DecodeSession.replace_rows``) while sibling rows keep stepping with
their evolved caches — no whole-batch re-prefill barrier.

Because the jitted step closes over settings, strategy and scheduler
statically, the queue is partitioned into *lanes* keyed on the full
``(DecodeSettings, CacheStrategy, UnmaskScheduler)`` triple: a lane's
batch only ever admits requests with an identical triple (one compiled
step per lane; all three are frozen hashable dataclasses).  Within a
lane, rows are independent (attention, top-k selection and commits are
all per-row), so for deterministic schedulers continuous batching is
byte-identical to serving the same requests in static batches —
asserted by ``tests/test_strategy_parity.py``.  Stochastic schedulers
(``uses_rng``) draw from ONE batch-global rng chain per lane, so their
sampled outputs depend on batch composition and swap order; runs are
reproducible per engine configuration but NOT invariant to scheduling.

Paged mode (``pool_pages > 0``, DESIGN.md §5): cache memory is a
managed resource.  A :class:`~repro.serving.pool.PagePool` owns one
device arena of fixed-size pages; each request allocates only the pages
covering its own (page-aligned) prompt+gen span, so heterogeneous
``gen_len`` requests share a lane without padding their cache to the
lane max — the canvas tail past a row's ``kv_len`` aliases the pool's
zero page and is masked out of attention and selection.  Admission is
gated on free pages; when the head of the queue cannot fit, the engine
preempts the lowest-priority running request (its pages are released,
its canvas+commit-ring snapshot requeued at the front) instead of
failing.  A resumed request re-prefills its cache from the snapshot —
byte-identical to a periodic refresh at the resume step, so a
preempted-then-resumed request matches a twin that refreshed there
(``tests/test_serving.py``).

Slot bookkeeping uses the session's explicit active-position mask;
token ids are never overloaded as "committed filler" sentinels.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cache import PagedCache, n_logical_pages
from repro.core.strategy import CacheStrategy, resolve_strategy
from repro.dlm.decoding import DecodeSettings
from repro.dlm.scheduler import UnmaskScheduler, resolve_scheduler
from repro.dlm.session import DecodeSession
from repro.serving.pool import OutOfPages, PagePool

# (settings, strategy, scheduler): everything the compiled step closes
# over statically — one DecodeSession (one executable) per distinct key.
LaneKey = Tuple[DecodeSettings, CacheStrategy, UnmaskScheduler]


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # [P] int32
    gen_len: int
    settings: Optional[DecodeSettings] = None
    strategy: Optional[CacheStrategy] = None
    scheduler: Optional[UnmaskScheduler] = None
    priority: int = 0               # higher = preempts lower
    submitted_at: float = dataclasses.field(default_factory=time.time)
    started_at: Optional[float] = None   # first admission to a slot
    completed_at: Optional[float] = None
    output: Optional[np.ndarray] = None
    lane: Optional[LaneKey] = None  # resolved ONCE at submit()
    # paged bookkeeping
    row_len: int = 0                # page-aligned prompt+gen span
    n_pages: int = 0                # composite pages needed
    pages: Optional[List[int]] = None
    preemptions: int = 0
    served_steps: int = 0           # per-request max_steps budget
    snapshot: Optional[Dict[str, np.ndarray]] = None  # preempt resume


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    tokens_committed: int = 0
    requests_done: int = 0
    swaps: int = 0                  # mid-loop slot replacements
    preemptions: int = 0            # out-of-pages victim evictions
    admission_stalls: int = 0       # admission attempts blocked on pages
    peak_pool_util: float = 0.0
    steady_pool_util: float = 0.0
    e2e_latencies: List[float] = dataclasses.field(default_factory=list)
    queue_waits: List[float] = dataclasses.field(default_factory=list)

    def tps(self, wall: float) -> float:
        return self.tokens_committed / max(wall, 1e-9)

    def percentiles(self) -> Dict[str, float]:
        """p50/p95 end-to-end + queue-wait latency (seconds)."""
        out: Dict[str, float] = {}
        for name, xs in (("e2e", self.e2e_latencies),
                         ("wait", self.queue_waits)):
            if xs:
                out[f"{name}_p50"] = float(np.percentile(xs, 50))
                out[f"{name}_p95"] = float(np.percentile(xs, 95))
            else:
                out[f"{name}_p50"] = out[f"{name}_p95"] = 0.0
        return out


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 canvas_len: int = 64,
                 settings: Optional[DecodeSettings] = None,
                 strategy: Optional[CacheStrategy] = None,
                 scheduler: Optional[UnmaskScheduler] = None,
                 continuous: bool = True,
                 pool_pages: int = 0, page_size: int = 16):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.canvas_len = canvas_len
        self.settings = settings or DecodeSettings()
        self.strategy = resolve_strategy(cfg, strategy)
        self.scheduler = scheduler    # None -> derived from settings
        self.continuous = continuous
        self.paged = pool_pages > 0
        self.page_size = page_size
        self.pool: Optional[PagePool] = None
        if self.paged:
            n_logical_pages(canvas_len, page_size)  # divisibility check
            self.pool = PagePool(cfg, n_pages=pool_pages,
                                 page_size=page_size,
                                 strategy=self.strategy)
        self.queue: deque[Request] = deque()
        self.done: List[Request] = []
        self.stats = EngineStats()
        self._next_uid = 0            # monotonic: uids never recycle
        # admission re-scan gate: set by submit(), cleared after each
        # admission attempt — a stalled queue is not re-scanned (and
        # admission_stalls not re-counted) every step, only when a
        # finish/preemption or a new arrival can change the outcome
        self._admission_dirty = True
        self._sessions: Dict[LaneKey, DecodeSession] = {}
        # offline proxy artefacts are per STRATEGY, shared across lanes
        self._proxies: Dict[CacheStrategy, object] = {}

    def submit(self, prompt: np.ndarray, gen_len: int,
               settings: Optional[DecodeSettings] = None,
               strategy: Optional[CacheStrategy] = None,
               scheduler: Optional[UnmaskScheduler] = None,
               priority: int = 0) -> int:
        # monotonic counter — NOT len(done)+len(queue): with requests
        # in-flight (popped but not done) that length dips and reuses
        # live uids (regression-tested in tests/test_serving.py).
        uid = self._next_uid
        self._next_uid += 1
        req = Request(uid, np.asarray(prompt, np.int32), gen_len,
                      settings, strategy, scheduler, priority=priority)
        req.lane = self._lane_of(req)   # freeze vs later default changes
        self._admission_dirty = True
        if self.paged:
            p_len = min(len(req.prompt), self.canvas_len - gen_len)
            span = p_len + gen_len
            req.row_len = min(
                -(-span // self.page_size) * self.page_size,
                self.canvas_len)
            strategy_r = req.lane[1]
            req.n_pages = (self.pool.pages_for(req.row_len)
                           if strategy_r.uses_cache else 0)
            if req.n_pages > self.pool.capacity:
                raise OutOfPages(
                    f"request uid={uid} needs {req.n_pages} pages; pool "
                    f"capacity is {self.pool.capacity}")
        else:
            req.row_len = self.canvas_len
        self.queue.append(req)
        return uid

    # ------------------------------------------------------------------

    def _lane_of(self, req: Request) -> LaneKey:
        """Resolve a request's lane: per-request overrides win WHOLESALE
        (a request that passes settings gets that settings' commit
        policy, including ``parallel_threshold=0.0`` = sequential),
        engine defaults fill the gaps, legacy settings knobs map to
        their scheduler equivalent.  The parallel knobs are normalized
        OUT of the keyed settings once the scheduler is resolved
        (serve_step never reads them again), so a request submitted
        with ``parallel_threshold=0.1`` shares an executable with one
        submitted with ``ParallelThresholdScheduler(0.1)``."""
        settings = req.settings or self.settings
        strategy = req.strategy or self.strategy
        # precedence: request scheduler > request settings knobs >
        # engine scheduler > engine settings knobs > confidence default
        if req.scheduler is not None:
            scheduler = req.scheduler
        elif req.settings is not None:
            scheduler = resolve_scheduler(req.settings)
        else:
            scheduler = resolve_scheduler(self.settings, self.scheduler)
        settings = dataclasses.replace(settings, parallel_threshold=0.0,
                                       max_parallel=0)
        return settings, strategy, scheduler

    def _proxies_for(self, strategy: CacheStrategy):
        if strategy not in self._proxies:
            self._proxies[strategy] = strategy.build_proxies(
                self.params, self.cfg)
        return self._proxies[strategy]

    def _session_for(self, lane: LaneKey) -> DecodeSession:
        if lane not in self._sessions:
            settings, strategy, scheduler = lane
            self._sessions[lane] = DecodeSession(
                self.params, self.cfg, strategy=strategy,
                settings=settings, scheduler=scheduler,
                spa_proxies=self._proxies_for(strategy))
        return self._sessions[lane]

    # ------------------------------------------------------------------
    # Admission control + preemption (paged mode)
    # ------------------------------------------------------------------

    def _lane_candidates(self, lane: LaneKey) -> List[Request]:
        """Lane-matching queued requests in admission order: strict
        priority first, submission (queue) order within a priority."""
        matches = [r for r in self.queue if r.lane == lane]
        return sorted(matches, key=lambda r: -r.priority)

    def _preempt(self, slot: int, victim: Request,
                 slots: List[Optional[Request]],
                 sess: DecodeSession) -> None:
        """Evict a running request: snapshot its canvas + commit ring,
        release its slot/pages, requeue it at the FRONT of the queue."""
        snap = sess.snapshot_rows([slot])
        victim.snapshot = {k: v[0] for k, v in snap.items()}
        sess.release_rows([slot])
        self.pool.free(victim.pages or [])
        victim.pages = None
        victim.preemptions += 1
        self.stats.preemptions += 1
        slots[slot] = None
        self.queue.appendleft(victim)

    def _admit_one(self, lane: LaneKey, slots: List[Optional[Request]],
                   sess: Optional[DecodeSession],
                   protected: Tuple[int, ...] = ()) -> Optional[Request]:
        """Admit one lane request: it needs a free SLOT and (paged mode)
        enough free PAGES.  When either is short, strictly
        lower-priority running requests are preempted — lowest priority
        first, most recently started first within a priority (the
        oldest work keeps its progress) — until the candidate fits; if
        the eligible victims can't cover it, the candidate stalls and
        smaller/lower-priority candidates get a chance.  Returns the
        admitted request (popped from the queue, pages allocated) or
        None.

        ``protected`` slots are admitted-but-not-yet-attached this swap
        round: the session has no state for them, so they cannot be
        preemption victims."""
        stalled = False
        for req in self._lane_candidates(lane):
            slot_free = any(s is None for s in slots)
            if not self.paged:
                if not slot_free:
                    return None     # dense mode: no preemption
                self.queue.remove(req)
                return req
            page_short = (max(0, req.n_pages - self.pool.available)
                          if req.n_pages else 0)
            if page_short or not slot_free:
                if sess is None:
                    stalled = True
                    continue
                victims = [(i, r) for i, r in enumerate(slots)
                           if r is not None and i not in protected
                           and r.priority < req.priority]
                victims.sort(key=lambda ir: (
                    ir[1].priority, -(ir[1].started_at or 0.0)))
                freeable = sum(len(r.pages or []) for _, r in victims)
                if (self.pool.available + freeable < req.n_pages
                        or (not slot_free and not victims)):
                    stalled = True
                    continue        # a smaller/later candidate may fit
                for i, r in victims:
                    self._preempt(i, r, slots, sess)
                    if (self.pool.available >= req.n_pages
                            and any(s is None for s in slots)):
                        break
            pages = self.pool.alloc(req.n_pages) if req.n_pages else []
            assert pages is not None
            self.queue.remove(req)
            req.pages = pages
            return req
        if stalled:
            self.stats.admission_stalls += 1
        return None

    # ------------------------------------------------------------------
    # Canvas rows
    # ------------------------------------------------------------------

    def _canvas_row(self, req: Request):
        """(tokens [N], active [N], committed_or_None, prompt_len) for
        one slot.  A preempted request resumes from its snapshot: the
        partially committed canvas, active mask and commit ring."""
        if req.snapshot is not None:
            snap = req.snapshot
            req.snapshot = None
            p_len = min(len(req.prompt), self.canvas_len - req.gen_len)
            return (snap["tokens"].copy(), snap["active"].copy(),
                    snap["committed"].copy(), p_len)
        mask_id = self.cfg.mask_id
        row = np.full((self.canvas_len,), mask_id, np.int32)
        p = req.prompt[: self.canvas_len - req.gen_len]
        row[: len(p)] = p
        active = np.zeros((self.canvas_len,), bool)
        active[len(p): len(p) + req.gen_len] = True
        return row, active, None, len(p)

    def _pt_row(self, req: Request) -> List[int]:
        return self.pool.page_table_row(req.pages or [], self.canvas_len)

    def _harvest(self, req: Request, toks_row: np.ndarray,
                 p_len: int) -> None:
        req.output = toks_row[p_len: p_len + req.gen_len]
        req.completed_at = time.time()
        self.stats.e2e_latencies.append(
            req.completed_at - req.submitted_at)
        if req.started_at is not None:
            self.stats.queue_waits.append(
                req.started_at - req.submitted_at)
        if self.paged and req.pages:
            self.pool.free(req.pages)
            req.pages = None
        self.done.append(req)
        self.stats.requests_done += 1

    # ------------------------------------------------------------------

    def run(self, max_steps: int = 256, on_step=None) -> EngineStats:
        """Serve the queue to completion.  ``on_step(engine)`` (if given)
        fires after every engine step — submissions made from it join
        the live run and are admitted mid-loop (the arrival path that
        exercises preemption)."""
        t0 = time.time()
        while self.queue:
            lane = self.queue[0].lane
            self._run_lane(lane, max_steps, on_step)
        self._wall = time.time() - t0
        if self.paged:
            self.stats.peak_pool_util = (self.pool.peak_used
                                         / max(self.pool.capacity, 1))
            self.stats.steady_pool_util = self.pool.steady_utilization
        return self.stats

    def _run_lane(self, lane: LaneKey, max_steps: int,
                  on_step=None) -> None:
        sess = self._session_for(lane)
        strategy = lane[1]
        slots: List[Optional[Request]] = [None] * self.max_batch
        batch: List[Request] = []
        while len(batch) < self.max_batch:
            req = self._admit_one(lane, slots, sess=None)
            if req is None:
                break
            batch.append(req)
        if not batch:
            return
        # dense lanes size the canvas to the actual batch (an underfilled
        # lane never pays full-width placeholder rows); paged lanes keep
        # max_batch rows so slots freed later (pages permitting) can
        # admit without a reshape/recompile
        b = self.max_batch if self.paged else len(batch)
        slots = [None] * b
        now = time.time()
        mask_id = self.cfg.mask_id
        tokens = np.full((b, self.canvas_len), mask_id, np.int32)
        active = np.zeros((b, self.canvas_len), bool)
        committed0 = np.full((b, lane[0].commit_ring), -1, np.int32)
        kv = np.zeros((b,), np.int32)
        n_log = (n_logical_pages(self.canvas_len, self.page_size)
                 if self.paged else 0)
        pt = np.zeros((b, n_log), np.int32)
        p_lens = [0] * b
        ages = [0] * b                 # max_steps budget is PER REQUEST
        for i, req in enumerate(batch):
            row, act, com, p_len = self._canvas_row(req)
            tokens[i], active[i] = row, act
            if com is not None:
                committed0[i] = com
            slots[i] = req
            p_lens[i] = p_len
            ages[i] = req.served_steps
            kv[i] = req.row_len
            if self.paged and strategy.uses_cache:
                pt[i] = self._pt_row(req)
            if req.started_at is None:
                req.started_at = now
        if self.paged:
            arenas = (self.pool.arenas_for(strategy)
                      if strategy.uses_cache else None)
            sess.attach(tokens, active=active, kv_len=kv,
                        arenas=arenas, page_table=pt)
        else:
            sess.attach(tokens, active=active)
        if (committed0 != -1).any():
            sess.state = sess.state._replace(
                committed=sess.state.committed.at[:].set(committed0))

        while any(s is not None for s in slots):
            info = sess.step()
            self.stats.steps += 1
            if self.paged:
                self.pool.note_step()
            self.stats.tokens_committed += int(
                np.sum(np.asarray(info["n_committed"])))
            if on_step is not None:
                on_step(self)
            n_masked = np.asarray(sess.state.n_masked)
            finished = []
            for i, s in enumerate(slots):
                if s is None:
                    continue
                ages[i] += 1
                s.served_steps = ages[i]
                # a request that exhausts its own step budget is
                # harvested as-is (same semantics as the old
                # run-to-max_steps static batch loop)
                if n_masked[i] <= 0 or ages[i] >= max_steps:
                    finished.append(i)
            if not finished and not (self.continuous
                                     and self._admission_dirty):
                continue
            if finished:
                toks = np.asarray(sess.tokens)
                for i in finished:
                    self._harvest(slots[i], toks[i], p_lens[i])
                    slots[i] = None
                if self.paged:
                    # zero the finished rows' page-table entries BEFORE
                    # their freed pages can be re-allocated below — a
                    # stale entry would let the dead row's next
                    # write-back corrupt the new owner's pages
                    sess.release_rows(finished)
            swap_rows, swap_tokens, swap_active = [], [], []
            swap_kv, swap_pt, swap_com = [], [], []
            while self.continuous:
                # fill every empty slot — and let _admit_one MAKE one by
                # preempting a lower-priority row when a high-priority
                # arrival finds the batch/pool full — until admission
                # stalls or the queue drains
                req = self._admit_one(lane, slots, sess,
                                      protected=tuple(swap_rows))
                if req is None:
                    break
                empty = [i for i, s in enumerate(slots) if s is None]
                i = empty[0]
                row, act, com, p_len = self._canvas_row(req)
                slots[i] = req
                p_lens[i] = p_len
                ages[i] = req.served_steps
                if req.started_at is None:
                    req.started_at = time.time()
                swap_rows.append(i)
                swap_tokens.append(row)
                swap_active.append(act)
                swap_kv.append(req.row_len)
                swap_pt.append(self._pt_row(req) if self.paged
                               and strategy.uses_cache
                               else [0] * n_log)
                swap_com.append(com if com is not None else np.full(
                    (committed0.shape[1],), -1, np.int32))
            self._admission_dirty = False
            if swap_rows:
                if self.paged:
                    sess.replace_rows(
                        swap_rows, np.stack(swap_tokens),
                        np.stack(swap_active),
                        row_kv_len=np.asarray(swap_kv, np.int32),
                        row_page_table=np.asarray(swap_pt, np.int32),
                        row_committed=np.stack(swap_com))
                else:
                    sess.replace_rows(swap_rows, np.stack(swap_tokens),
                                      np.stack(swap_active))
                self.stats.swaps += len(swap_rows)
            parked = [i for i in finished if i not in swap_rows
                      and slots[i] is None]
            if parked and not self.paged:   # paged rows released above
                sess.deactivate_rows(parked)
        if (self.paged and strategy.uses_cache and sess.state is not None
                and isinstance(sess.state.cache, PagedCache)):
            self.pool.store_arenas(strategy, sess.state.cache.arenas)

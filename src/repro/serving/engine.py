"""Batched DLM serving engine on DecodeSession (DESIGN.md §3.2).

Requests (prompt + gen_len + optional per-request DecodeSettings) are
padded onto fixed canvas rows and served by a ``DecodeSession`` at
**step granularity**: when a row finishes, its slot is swapped for the
next queued request mid-loop (``DecodeSession.replace_rows``) while
sibling rows keep stepping with their evolved caches — no whole-batch
re-prefill barrier.

Because the jitted step closes over ``DecodeSettings`` statically, the
queue is partitioned into *lanes* by settings: a lane's batch only ever
admits requests with identical settings (one compiled step per lane).
Within a lane, rows are independent (attention, top-k selection and
commits are all per-row), so continuous batching is byte-identical to
serving the same requests in static batches — asserted by
``tests/test_strategy_parity.py``.

Slot bookkeeping uses the session's explicit active-position mask;
token ids are never overloaded as "committed filler" sentinels.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.strategy import CacheStrategy, resolve_strategy
from repro.dlm.decoding import DecodeSettings
from repro.dlm.session import DecodeSession


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # [P] int32
    gen_len: int
    settings: Optional[DecodeSettings] = None
    submitted_at: float = dataclasses.field(default_factory=time.time)
    completed_at: Optional[float] = None
    output: Optional[np.ndarray] = None


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    tokens_committed: int = 0
    requests_done: int = 0
    swaps: int = 0                  # mid-loop slot replacements

    def tps(self, wall: float) -> float:
        return self.tokens_committed / max(wall, 1e-9)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 canvas_len: int = 64,
                 settings: Optional[DecodeSettings] = None,
                 strategy: Optional[CacheStrategy] = None,
                 continuous: bool = True):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.canvas_len = canvas_len
        self.settings = settings or DecodeSettings()
        self.strategy = resolve_strategy(cfg, strategy)
        self.continuous = continuous
        self.proxies = self.strategy.build_proxies(params, cfg)
        self.queue: deque[Request] = deque()
        self.done: List[Request] = []
        self.stats = EngineStats()
        self._sessions: Dict[DecodeSettings, DecodeSession] = {}

    def submit(self, prompt: np.ndarray, gen_len: int,
               settings: Optional[DecodeSettings] = None) -> int:
        uid = len(self.done) + len(self.queue)
        self.queue.append(Request(uid, np.asarray(prompt, np.int32),
                                  gen_len, settings))
        return uid

    # ------------------------------------------------------------------

    def _session_for(self, settings: DecodeSettings) -> DecodeSession:
        if settings not in self._sessions:
            self._sessions[settings] = DecodeSession(
                self.params, self.cfg, strategy=self.strategy,
                settings=settings, spa_proxies=self.proxies)
        return self._sessions[settings]

    def _pop_matching(self, settings: DecodeSettings, k: int
                      ) -> List[Request]:
        """Dequeue up to k requests whose settings match the lane."""
        taken, keep = [], deque()
        while self.queue and len(taken) < k:
            req = self.queue.popleft()
            if (req.settings or self.settings) == settings:
                taken.append(req)
            else:
                keep.append(req)
        keep.extend(self.queue)
        self.queue = keep
        return taken

    def _canvas_row(self, req: Request):
        """(tokens [N], active [N], prompt_len) for one slot."""
        mask_id = self.cfg.mask_id
        row = np.full((self.canvas_len,), mask_id, np.int32)
        p = req.prompt[: self.canvas_len - req.gen_len]
        row[: len(p)] = p
        active = np.zeros((self.canvas_len,), bool)
        active[len(p): len(p) + req.gen_len] = True
        return row, active, len(p)

    def _harvest(self, req: Request, toks_row: np.ndarray,
                 p_len: int) -> None:
        req.output = toks_row[p_len: p_len + req.gen_len]
        req.completed_at = time.time()
        self.done.append(req)
        self.stats.requests_done += 1

    # ------------------------------------------------------------------

    def run(self, max_steps: int = 256) -> EngineStats:
        t0 = time.time()
        while self.queue:
            lane = self.queue[0].settings or self.settings
            self._run_lane(lane, max_steps)
        self._wall = time.time() - t0
        return self.stats

    def _run_lane(self, settings: DecodeSettings, max_steps: int) -> None:
        batch = self._pop_matching(settings, self.max_batch)
        if not batch:
            return
        sess = self._session_for(settings)
        rows = [self._canvas_row(r) for r in batch]
        tokens = np.stack([r[0] for r in rows])
        active = np.stack([r[1] for r in rows])
        slots: List[Optional[Request]] = list(batch)
        p_lens: List[int] = [r[2] for r in rows]
        ages = [0] * len(batch)        # max_steps budget is PER REQUEST
        sess.attach(tokens, active=active)

        while any(s is not None for s in slots):
            info = sess.step()
            self.stats.steps += 1
            self.stats.tokens_committed += int(
                np.sum(np.asarray(info["n_committed"])))
            n_masked = np.asarray(sess.state.n_masked)
            finished = []
            for i, s in enumerate(slots):
                if s is None:
                    continue
                ages[i] += 1
                # a request that exhausts its own step budget is
                # harvested as-is (same semantics as the old
                # run-to-max_steps static batch loop)
                if n_masked[i] <= 0 or ages[i] >= max_steps:
                    finished.append(i)
            if not finished:
                continue
            toks = np.asarray(sess.tokens)
            swap_rows, swap_tokens, swap_active = [], [], []
            for i in finished:
                self._harvest(slots[i], toks[i], p_lens[i])
                slots[i] = None
                nxt = (self._pop_matching(settings, 1)
                       if self.continuous else [])
                if nxt:
                    req = nxt[0]
                    row, act, p_len = self._canvas_row(req)
                    slots[i] = req
                    p_lens[i] = p_len
                    ages[i] = 0
                    swap_rows.append(i)
                    swap_tokens.append(row)
                    swap_active.append(act)
            if swap_rows:
                sess.replace_rows(swap_rows, np.stack(swap_tokens),
                                  np.stack(swap_active))
                self.stats.swaps += len(swap_rows)
            parked = [i for i in finished if i not in swap_rows]
            if parked:
                sess.deactivate_rows(parked)

"""Batched DLM serving engine on DecodeSession (DESIGN.md §3.2).

Requests (prompt + gen_len + optional per-request DecodeSettings /
CacheStrategy / UnmaskScheduler) are padded onto fixed canvas rows and
served by a ``DecodeSession`` at **step granularity**: when a row
finishes, its slot is swapped for the next queued request mid-loop
(``DecodeSession.replace_rows``) while sibling rows keep stepping with
their evolved caches — no whole-batch re-prefill barrier.

Because the jitted step closes over settings, strategy and scheduler
statically, the queue is partitioned into *lanes* keyed on the full
``(DecodeSettings, CacheStrategy, UnmaskScheduler)`` triple: a lane's
batch only ever admits requests with an identical triple (one compiled
step per lane; all three are frozen hashable dataclasses).  Within a
lane, rows are independent (attention, top-k selection and commits are
all per-row), so for deterministic schedulers continuous batching is
byte-identical to serving the same requests in static batches —
asserted by ``tests/test_strategy_parity.py``.  Stochastic schedulers
(``uses_rng``) draw from ONE batch-global rng chain per lane, so their
sampled outputs depend on batch composition and swap order; runs are
reproducible per engine configuration but NOT invariant to scheduling.

Slot bookkeeping uses the session's explicit active-position mask;
token ids are never overloaded as "committed filler" sentinels.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.strategy import CacheStrategy, resolve_strategy
from repro.dlm.decoding import DecodeSettings
from repro.dlm.scheduler import UnmaskScheduler, resolve_scheduler
from repro.dlm.session import DecodeSession

# (settings, strategy, scheduler): everything the compiled step closes
# over statically — one DecodeSession (one executable) per distinct key.
LaneKey = Tuple[DecodeSettings, CacheStrategy, UnmaskScheduler]


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # [P] int32
    gen_len: int
    settings: Optional[DecodeSettings] = None
    strategy: Optional[CacheStrategy] = None
    scheduler: Optional[UnmaskScheduler] = None
    submitted_at: float = dataclasses.field(default_factory=time.time)
    completed_at: Optional[float] = None
    output: Optional[np.ndarray] = None
    lane: Optional[LaneKey] = None  # resolved ONCE at submit()


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    tokens_committed: int = 0
    requests_done: int = 0
    swaps: int = 0                  # mid-loop slot replacements

    def tps(self, wall: float) -> float:
        return self.tokens_committed / max(wall, 1e-9)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 canvas_len: int = 64,
                 settings: Optional[DecodeSettings] = None,
                 strategy: Optional[CacheStrategy] = None,
                 scheduler: Optional[UnmaskScheduler] = None,
                 continuous: bool = True):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.canvas_len = canvas_len
        self.settings = settings or DecodeSettings()
        self.strategy = resolve_strategy(cfg, strategy)
        self.scheduler = scheduler    # None -> derived from settings
        self.continuous = continuous
        self.queue: deque[Request] = deque()
        self.done: List[Request] = []
        self.stats = EngineStats()
        self._sessions: Dict[LaneKey, DecodeSession] = {}
        # offline proxy artefacts are per STRATEGY, shared across lanes
        self._proxies: Dict[CacheStrategy, object] = {}

    def submit(self, prompt: np.ndarray, gen_len: int,
               settings: Optional[DecodeSettings] = None,
               strategy: Optional[CacheStrategy] = None,
               scheduler: Optional[UnmaskScheduler] = None) -> int:
        uid = len(self.done) + len(self.queue)
        req = Request(uid, np.asarray(prompt, np.int32), gen_len,
                      settings, strategy, scheduler)
        req.lane = self._lane_of(req)   # freeze vs later default changes
        self.queue.append(req)
        return uid

    # ------------------------------------------------------------------

    def _lane_of(self, req: Request) -> LaneKey:
        """Resolve a request's lane: per-request overrides win WHOLESALE
        (a request that passes settings gets that settings' commit
        policy, including ``parallel_threshold=0.0`` = sequential),
        engine defaults fill the gaps, legacy settings knobs map to
        their scheduler equivalent.  The parallel knobs are normalized
        OUT of the keyed settings once the scheduler is resolved
        (serve_step never reads them again), so a request submitted
        with ``parallel_threshold=0.1`` shares an executable with one
        submitted with ``ParallelThresholdScheduler(0.1)``."""
        settings = req.settings or self.settings
        strategy = req.strategy or self.strategy
        # precedence: request scheduler > request settings knobs >
        # engine scheduler > engine settings knobs > confidence default
        if req.scheduler is not None:
            scheduler = req.scheduler
        elif req.settings is not None:
            scheduler = resolve_scheduler(req.settings)
        else:
            scheduler = resolve_scheduler(self.settings, self.scheduler)
        settings = dataclasses.replace(settings, parallel_threshold=0.0,
                                       max_parallel=0)
        return settings, strategy, scheduler

    def _proxies_for(self, strategy: CacheStrategy):
        if strategy not in self._proxies:
            self._proxies[strategy] = strategy.build_proxies(
                self.params, self.cfg)
        return self._proxies[strategy]

    def _session_for(self, lane: LaneKey) -> DecodeSession:
        if lane not in self._sessions:
            settings, strategy, scheduler = lane
            self._sessions[lane] = DecodeSession(
                self.params, self.cfg, strategy=strategy,
                settings=settings, scheduler=scheduler,
                spa_proxies=self._proxies_for(strategy))
        return self._sessions[lane]

    def _pop_matching(self, lane: LaneKey, k: int) -> List[Request]:
        """Dequeue up to k requests whose (submit-time) lane matches."""
        taken, keep = [], deque()
        while self.queue and len(taken) < k:
            req = self.queue.popleft()
            if req.lane == lane:
                taken.append(req)
            else:
                keep.append(req)
        keep.extend(self.queue)
        self.queue = keep
        return taken

    def _canvas_row(self, req: Request):
        """(tokens [N], active [N], prompt_len) for one slot."""
        mask_id = self.cfg.mask_id
        row = np.full((self.canvas_len,), mask_id, np.int32)
        p = req.prompt[: self.canvas_len - req.gen_len]
        row[: len(p)] = p
        active = np.zeros((self.canvas_len,), bool)
        active[len(p): len(p) + req.gen_len] = True
        return row, active, len(p)

    def _harvest(self, req: Request, toks_row: np.ndarray,
                 p_len: int) -> None:
        req.output = toks_row[p_len: p_len + req.gen_len]
        req.completed_at = time.time()
        self.done.append(req)
        self.stats.requests_done += 1

    # ------------------------------------------------------------------

    def run(self, max_steps: int = 256) -> EngineStats:
        t0 = time.time()
        while self.queue:
            lane = self.queue[0].lane
            self._run_lane(lane, max_steps)
        self._wall = time.time() - t0
        return self.stats

    def _run_lane(self, lane: LaneKey, max_steps: int) -> None:
        batch = self._pop_matching(lane, self.max_batch)
        if not batch:
            return
        sess = self._session_for(lane)
        rows = [self._canvas_row(r) for r in batch]
        tokens = np.stack([r[0] for r in rows])
        active = np.stack([r[1] for r in rows])
        slots: List[Optional[Request]] = list(batch)
        p_lens: List[int] = [r[2] for r in rows]
        ages = [0] * len(batch)        # max_steps budget is PER REQUEST
        sess.attach(tokens, active=active)

        while any(s is not None for s in slots):
            info = sess.step()
            self.stats.steps += 1
            self.stats.tokens_committed += int(
                np.sum(np.asarray(info["n_committed"])))
            n_masked = np.asarray(sess.state.n_masked)
            finished = []
            for i, s in enumerate(slots):
                if s is None:
                    continue
                ages[i] += 1
                # a request that exhausts its own step budget is
                # harvested as-is (same semantics as the old
                # run-to-max_steps static batch loop)
                if n_masked[i] <= 0 or ages[i] >= max_steps:
                    finished.append(i)
            if not finished:
                continue
            toks = np.asarray(sess.tokens)
            swap_rows, swap_tokens, swap_active = [], [], []
            for i in finished:
                self._harvest(slots[i], toks[i], p_lens[i])
                slots[i] = None
                nxt = (self._pop_matching(lane, 1)
                       if self.continuous else [])
                if nxt:
                    req = nxt[0]
                    row, act, p_len = self._canvas_row(req)
                    slots[i] = req
                    p_lens[i] = p_len
                    ages[i] = 0
                    swap_rows.append(i)
                    swap_tokens.append(row)
                    swap_active.append(act)
            if swap_rows:
                sess.replace_rows(swap_rows, np.stack(swap_tokens),
                                  np.stack(swap_active))
                self.stats.swaps += len(swap_rows)
            parked = [i for i in finished if i not in swap_rows]
            if parked:
                sess.deactivate_rows(parked)

"""Training substrate: optimizer math, loss decreases, checkpointing."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.data.synthetic import token_batches
from repro.models import transformer
from repro.training import checkpoint
from repro.training.optimizer import (AdamWConfig, adamw_update,
                                      init_opt_state, lr_at)
from repro.training.trainer import Trainer, train_step


def test_lr_schedule():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_at(cfg, jnp.asarray(s))) for s in (0, 9, 50, 99)]
    assert lrs[0] < lrs[1]                      # warmup
    assert lrs[1] >= lrs[2] >= lrs[3]           # cosine decay
    assert lrs[3] >= cfg.lr * cfg.min_lr_ratio * 0.99


def test_adamw_reduces_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=1000,
                      weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = init_opt_state(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_grad_accumulation_matches_full_batch():
    cfg = reduced(get_arch("internlm2-1.8b"))
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(cfg, key)
    opt = init_opt_state(params)
    batch = {"tokens": jax.random.randint(key, (4, 16), 0,
                                          cfg.vocab_size - 1)}
    import dataclasses
    cfg2 = dataclasses.replace(cfg, microbatch=2)
    p1, _, m1 = train_step(params, opt, batch, key, cfg=cfg,
                           opt_cfg=AdamWConfig())
    p2, _, m2 = train_step(params, opt, batch, key, cfg=cfg2,
                           opt_cfg=AdamWConfig())
    # Different mask RNG per microbatch -> losses differ, but both finite
    assert np.isfinite(float(m1["loss"]))
    assert np.isfinite(float(m2["loss"]))


def test_loss_decreases_tiny_training():
    cfg = reduced(get_arch("internlm2-1.8b"), vocab_size=64, d_model=64,
                  d_ff=128)
    trainer = Trainer(cfg, AdamWConfig(lr=3e-3, warmup_steps=5,
                                       total_steps=180)).init(
        jax.random.PRNGKey(0))
    data = token_batches(cfg, batch_size=8, seq_len=32, seed=0)
    hist = trainer.fit(data, n_steps=160, rng=jax.random.PRNGKey(1),
                       log_every=0)
    first = np.mean(hist["loss"][:5])
    last = np.mean(hist["loss"][-5:])
    assert last < first, (first, last)


def test_checkpoint_roundtrip():
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "b": {"c": jnp.ones((4,), jnp.bfloat16),
              "d": [jnp.zeros((2,), jnp.int32), jnp.ones((1,))]},
    }
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "ckpt.npz")
        checkpoint.save_checkpoint(path, tree, {"step": 7})
        loaded, meta = checkpoint.load_checkpoint(path)
    assert meta["step"] == 7
    assert loaded["b"]["c"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(loaded["a"]),
                                  np.asarray(tree["a"]))
    np.testing.assert_array_equal(
        np.asarray(loaded["b"]["d"][0]), np.asarray(tree["b"]["d"][0]))


def test_synthetic_data_learnable_structure():
    from repro.data.synthetic import SyntheticTokens
    gen = SyntheticTokens(256, seed=0)
    batch = gen.batch(4, 64)
    assert batch.shape == (4, 64)
    assert batch.max() < 256
    # Markov structure: same context -> successor from a small set
    gen2 = SyntheticTokens(256, seed=0)
    b2 = gen2.batch(4, 64)
    np.testing.assert_array_equal(batch[:, :2], b2[:, :2])

"""Trip-count-aware HLO cost parser sanity checks on real jitted HLO."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import HloModule, analyze_hlo


def compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops():
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    txt = compiled_text(lambda x, y: x @ y, a, b)
    res = analyze_hlo(txt)
    expect = 2 * 128 * 256 * 64
    assert res["flops"] == pytest.approx(expect, rel=0.01)


def test_scan_multiplies_trip_count():
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)

    def fn(w, x):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=17)
        return h

    res = analyze_hlo(compiled_text(fn, w, x))
    expect = 17 * 2 * 8 * 64 * 64
    assert res["flops"] == pytest.approx(expect, rel=0.05)


def test_nested_scans_multiply():
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((4, 32), jnp.float32)

    def fn(w, x):
        def outer(h, _):
            def inner(g, _):
                return g @ w, None
            g, _ = jax.lax.scan(inner, h, None, length=3)
            return g, None
        h, _ = jax.lax.scan(outer, x, None, length=5)
        return h

    res = analyze_hlo(compiled_text(fn, w, x))
    expect = 5 * 3 * 2 * 4 * 32 * 32
    assert res["flops"] == pytest.approx(expect, rel=0.05)


def test_no_collectives_on_single_device():
    a = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    res = analyze_hlo(compiled_text(lambda x: x @ x, a))
    assert res["collective_bytes"] == 0


def test_bytes_positive():
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    res = analyze_hlo(compiled_text(lambda x: jnp.tanh(x) + 1, a))
    assert res["bytes_accessed"] >= 64 * 64 * 4

"""Paged cache pool (DESIGN.md §5): allocator, paged kernels, and
paged-vs-dense decode byte-parity for every registered strategy on both
kernel backends."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.core import cache as cache_lib
from repro.core import strategy as strategy_lib
from repro.core.strategy import (AttnOutCache, SPACache, ValueProxyCache,
                                 WindowCache)
from repro.dlm.session import DecodeSession
from repro.kernels import proxy_score as ps
from repro.kernels import scatter_update as sc
from repro.kernels.backend import XLA_BACKEND
from repro.models import transformer
from repro.serving.pool import OutOfPages, PagePool

PAGE = 4
CANVAS = 16
N_LOG = CANVAS // PAGE


# ---------------------------------------------------------------------------
# Allocator
# ---------------------------------------------------------------------------

def test_pool_allocator_basics(tiny_cfg):
    pool = PagePool(tiny_cfg, n_pages=5, page_size=PAGE)
    assert pool.capacity == 4 and pool.available == 4
    a = pool.alloc(3)
    assert a is not None and len(a) == 3 and 0 not in a
    assert pool.alloc(2) is None          # all-or-nothing
    b = pool.alloc(1)
    assert pool.available == 0 and pool.used == 4
    assert pool.peak_used == 4
    pool.free(a)
    assert pool.available == 3
    c = pool.alloc(3)
    assert sorted(c) == sorted(a)         # pages recycle
    pool.free(b + c)
    assert pool.available == pool.capacity


def test_pool_page_table_row(tiny_cfg):
    pool = PagePool(tiny_cfg, n_pages=9, page_size=PAGE)
    pages = pool.alloc(2)
    row = pool.page_table_row(pages, CANVAS)
    assert row[:2] == pages and row[2:] == [0, 0]  # tail = zero page


def test_pool_arena_shapes_and_sharing(tiny_cfg):
    pool = PagePool(tiny_cfg, n_pages=6, page_size=PAGE,
                    strategy=SPACache(rank=16))
    arenas = pool.arenas_for(SPACache(rank=16))
    (kind, bufs), = arenas.items()
    lk = tiny_cfg.n_layers_of_kind(kind)
    assert bufs["k"].shape[:3] == (lk, 6, PAGE)
    assert bufs["proxy"].shape == (lk, 6, PAGE, 16)
    # same signature -> same arena object; different -> new arenas
    assert pool.arenas_for(SPACache(rank=16, rho_peak=0.9)) is arenas
    assert pool.arenas_for(WindowCache()) is not arenas
    assert pool.arenas_for(strategy_lib.NoCache()) == {}


# ---------------------------------------------------------------------------
# Paged kernels vs XLA oracle
# ---------------------------------------------------------------------------

@pytest.fixture()
def paged_fixture():
    rng = np.random.default_rng(0)
    arena = jnp.asarray(rng.normal(size=(3, 9, PAGE, 8)).astype(np.float32))
    arena = arena.at[:, 0].set(0.0)       # zero page
    pt = jnp.asarray([[1, 2, 0, 0], [3, 4, 5, 6]], jnp.int32)
    return rng, arena, pt


def test_gather_scatter_pages_kernels_match_oracle(paged_fixture):
    rng, arena, pt = paged_fixture
    dense_o = XLA_BACKEND.gather_pages(arena, pt)
    dense_k = sc.gather_pages(arena, pt, interpret=True)
    np.testing.assert_array_equal(np.asarray(dense_o),
                                  np.asarray(dense_k))
    new = jnp.asarray(
        rng.normal(size=(3, 2, CANVAS, 8)).astype(np.float32))
    back_o = XLA_BACKEND.scatter_pages(arena, pt, new)
    back_k = sc.scatter_pages(arena, pt, new, interpret=True)
    np.testing.assert_array_equal(np.asarray(back_o), np.asarray(back_k))
    # zero page never written
    assert np.abs(np.asarray(back_k)[:, 0]).max() == 0.0
    # roundtrip: valid pages carry the new values
    again = sc.gather_pages(back_k, pt, interpret=True)
    np.testing.assert_array_equal(np.asarray(again)[0, 0, :8],
                                  np.asarray(new)[0, 0, :8])


def test_scatter_rows_paged_matches_oracle(paged_fixture):
    rng, arena, pt = paged_fixture
    arena1 = arena[0]
    # sorted rows, an out-of-range sentinel, and zero-page rows (row 0's
    # logical pages 2/3 alias the zero page -> dropped)
    idx = jnp.asarray([[0, 1, 2, 3, 9, CANVAS],
                       [2, 4, 5, 6, 7, 15]], jnp.int32)
    rows = jnp.asarray(rng.normal(size=(2, 6, 8)).astype(np.float32))
    out_o = XLA_BACKEND.scatter_rows_paged(arena1, pt, idx, rows)
    out_k = sc.scatter_rows_paged(arena1, pt, idx, rows, interpret=True)
    np.testing.assert_array_equal(np.asarray(out_o), np.asarray(out_k))
    assert np.abs(np.asarray(out_k)[0]).max() == 0.0  # zero page intact


def test_proxy_score_paged_matches_dense(paged_fixture):
    rng, _, pt = paged_fixture
    d, r = 8, 8
    x = jnp.asarray(rng.normal(size=(2, CANVAS, d)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(d, r)).astype(np.float32))
    parena = jnp.asarray(
        rng.normal(size=(9, PAGE, r)).astype(np.float32)).at[0].set(0.0)
    dense = XLA_BACKEND.gather_pages(parena[None], pt)[0]
    s_p, p_p = ps.proxy_score_paged(x, w, parena, pt, interpret=True)
    s_d, p_d = ps.proxy_score(x, w, dense, interpret=True)
    np.testing.assert_array_equal(np.asarray(s_p), np.asarray(s_d))
    np.testing.assert_array_equal(np.asarray(p_p), np.asarray(p_d))
    c_p = ps.cosine_drift_paged(p_p, parena, pt, interpret=True)
    c_d = ps.cosine_drift(p_p, dense, interpret=True)
    np.testing.assert_array_equal(np.asarray(c_p), np.asarray(c_d))


# ---------------------------------------------------------------------------
# Paged decode == dense decode, every strategy x both backends
# ---------------------------------------------------------------------------

def _test_instance(ident: str):
    inc = ident.endswith("+inc")
    base = ident.split("+")[0]
    cls = strategy_lib.REGISTRY[base]
    if cls is SPACache:
        return SPACache(rank=16, schedule="uniform", rho_peak=0.3,
                        incremental_ident=inc)
    if cls is ValueProxyCache:
        return ValueProxyCache(projection=base, rho=0.3)
    if cls is WindowCache:
        return WindowCache(locality_window=8, rho=0.3)
    if cls is AttnOutCache:
        return AttnOutCache(rho=0.5)
    return cls()


def _paged_session_run(cfg, params, strat, backend, rows, gen_lens,
                       kv_lens, run_compiled=False):
    """Serve the rows through a PagedCache session; rows shorter than the
    canvas own only the pages covering kv_len (tail = zero page)."""
    b = len(rows)
    tokens = np.full((b, CANVAS), cfg.mask_id, np.int32)
    active = np.zeros((b, CANVAS), bool)
    for i, (p, g) in enumerate(zip(rows, gen_lens)):
        tokens[i, : len(p)] = p
        active[i, len(p): len(p) + g] = True
    pool = PagePool(cfg, n_pages=1 + b * N_LOG, page_size=PAGE,
                    strategy=strat)
    arenas = pool.arenas_for(strat)
    pt = np.zeros((b, N_LOG), np.int32)
    for i in range(b):
        pages = pool.alloc(kv_lens[i] // PAGE) or []
        pt[i] = pool.page_table_row(pages, CANVAS)
    sess = DecodeSession(params, cfg, strategy=strat, backend=backend)
    sess.attach(tokens, active=jnp.asarray(active),
                kv_len=np.asarray(kv_lens, np.int32),
                arenas=arenas or None, page_table=pt)
    toks, _ = sess.run_compiled() if run_compiled else sess.run()
    return np.asarray(toks)


ALL_IDENTS = sorted(strategy_lib.REGISTRY) + ["singular+inc"]


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("ident", ALL_IDENTS)
def test_paged_decode_matches_dense(tiny_cfg, tiny_params, ident, backend):
    """Acceptance: paged and dense layouts decode byte-identically for
    every registered strategy on the XLA oracle AND the Pallas-interpret
    kernel suite (full-length rows: dense has no kv_len masking)."""
    cfg, params = tiny_cfg, tiny_params
    strat = _test_instance(ident)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                cfg.vocab_size - 1)
    sess = DecodeSession(params, cfg, strategy=strat)
    sess.prefill(prompt, gen_len=CANVAS - 8)
    dense_toks, _ = sess.run()

    rows = [np.asarray(prompt[0]), np.asarray(prompt[1])]
    paged = _paged_session_run(cfg, params, strat, backend, rows,
                               [CANVAS - 8] * 2, [CANVAS] * 2)
    np.testing.assert_array_equal(np.asarray(dense_toks), paged)


def test_paged_short_rows_match_alone(tiny_cfg, tiny_params):
    """Mixed-gen_len batching: same-lane rows of different lengths are
    byte-identical to running each alone (tail pages alias the zero page
    and are masked out of attention + selection)."""
    cfg, params = tiny_cfg, tiny_params
    strat = SPACache(rank=16, schedule="uniform", rho_peak=0.3)
    rng = np.random.default_rng(3)
    p0 = rng.integers(0, cfg.vocab_size - 1, 4).astype(np.int32)
    p1 = rng.integers(0, cfg.vocab_size - 1, 8).astype(np.int32)
    mixed = _paged_session_run(cfg, params, strat, "xla", [p0, p1],
                               [4, 8], [8, 16])
    alone0 = _paged_session_run(cfg, params, strat, "xla", [p0], [4], [8])
    alone1 = _paged_session_run(cfg, params, strat, "xla", [p1], [8],
                                [16])
    np.testing.assert_array_equal(mixed[0, :8], alone0[0, :8])
    np.testing.assert_array_equal(mixed[1], alone1[0])


def test_paged_run_compiled_matches_host_loop(tiny_cfg, tiny_params):
    """The device-resident while_loop steps the PagedCache carry (incl.
    the lax.cond refresh -> arena scatter) identically to the host."""
    cfg, params = tiny_cfg, tiny_params
    strat = SPACache(rank=16, schedule="uniform", rho_peak=0.3,
                     refresh_interval=3)
    rng = np.random.default_rng(5)
    rows = [rng.integers(0, cfg.vocab_size - 1, 4).astype(np.int32)]
    host = _paged_session_run(cfg, params, strat, "xla", rows, [8], [12])
    dev = _paged_session_run(cfg, params, strat, "xla", rows, [8], [12],
                             run_compiled=True)
    np.testing.assert_array_equal(host, dev)


def test_paged_int8_cache_matches_dense(tiny_cfg, tiny_params):
    cfg = dataclasses.replace(tiny_cfg, cache_dtype="int8")
    params = tiny_params
    strat = SPACache(rank=16, schedule="uniform", rho_peak=0.3)
    prompt = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 0,
                                cfg.vocab_size - 1)
    sess = DecodeSession(params, cfg, strategy=strat)
    sess.prefill(prompt, gen_len=8)
    dense_toks, _ = sess.run()
    paged = _paged_session_run(cfg, params, strat, "pallas",
                               [np.asarray(prompt[0])], [8], [CANVAS])
    np.testing.assert_array_equal(np.asarray(dense_toks), paged)


def test_preempt_resume_matches_refresh_twin(tiny_cfg, tiny_params):
    """A preempted-then-resumed request (pages released, cache rebuilt
    from the canvas snapshot at resume) is byte-identical to a twin that
    ran a periodic refresh at the same step — the documented resume
    semantics (DESIGN.md §5)."""
    cfg, params = tiny_cfg, tiny_params
    strat = SPACache(rank=16, schedule="uniform", rho_peak=0.3)
    rng = np.random.default_rng(7)
    p = rng.integers(0, cfg.vocab_size - 1, 4).astype(np.int32)

    def setup():
        pool = PagePool(cfg, n_pages=1 + N_LOG, page_size=PAGE,
                        strategy=strat)
        arenas = pool.arenas_for(strat)
        pages = pool.alloc(N_LOG)
        pt = np.asarray([pool.page_table_row(pages, CANVAS)], np.int32)
        tokens = np.full((1, CANVAS), cfg.mask_id, np.int32)
        tokens[0, :4] = p
        active = np.zeros((1, CANVAS), bool)
        active[0, 4:12] = True
        sess = DecodeSession(params, cfg, strategy=strat)
        sess.attach(tokens, active=jnp.asarray(active),
                    kv_len=np.asarray([CANVAS], np.int32),
                    arenas=arenas, page_table=pt)
        return sess, pt

    # twin A: 3 steps, preempt (snapshot + release), resume, finish
    sa, pt = setup()
    for _ in range(3):
        sa.step()
    snap = sa.snapshot_rows([0])
    sa.release_rows([0])
    sa.replace_rows([0], snap["tokens"], snap["active"],
                    row_kv_len=np.asarray([CANVAS], np.int32),
                    row_page_table=pt,
                    row_committed=snap["committed"])
    toks_a, _ = sa.run()

    # twin B: 3 steps, periodic refresh at the same point, finish
    sb, _ = setup()
    for _ in range(3):
        sb.step()
    sb.refresh()
    toks_b, _ = sb.run()
    np.testing.assert_array_equal(np.asarray(toks_a), np.asarray(toks_b))


def test_submit_larger_than_pool_raises(tiny_cfg, tiny_params):
    from repro.serving.engine import ServingEngine
    eng = ServingEngine(tiny_cfg, tiny_params, max_batch=1,
                        canvas_len=CANVAS, pool_pages=3, page_size=PAGE,
                        strategy=SPACache(rank=16))
    with pytest.raises(OutOfPages):
        eng.submit(np.arange(8, dtype=np.int32), gen_len=8)


def test_pool_refcounts(tiny_cfg):
    pool = PagePool(tiny_cfg, n_pages=5, page_size=PAGE)
    pages = pool.alloc(2)
    assert all(pool.refcount(p) == 1 for p in pages)
    pool.retain(pages)
    pool.release(pages)               # reader hold dropped, still owned
    assert pool.used == 2 and all(pool.refcount(p) == 1 for p in pages)
    pool.release(pages)               # last hold: pages return
    assert pool.used == 0 and not pool.refcounts
    with pytest.raises(AssertionError):
        pool.retain(pages)            # retaining freed pages is a bug


@pytest.mark.parametrize("host_pages", [0, 16])
def test_engine_page_accounting_leak_free(tiny_cfg, tiny_params,
                                          host_pages):
    """Leak detector: an engine run mixing completions, preemptions,
    prefix hits, publications and index evictions fully drains with
    every page back in the free list and every refcount at zero (the
    prefix index's own holds released via ``drop_prefix_cache``).  With
    the §9 host tier attached the same churn must ALSO keep the host
    pool in lockstep with the trie's host refs, and the drop empties
    both tiers."""
    from repro.serving.engine import ServingEngine
    strat = SPACache(rank=16, schedule="uniform", rho_peak=0.3)
    eng = ServingEngine(tiny_cfg, tiny_params, max_batch=2,
                        canvas_len=CANVAS, pool_pages=13, page_size=PAGE,
                        strategy=strat, prefix_cache=True,
                        host_pages=host_pages)

    def both_tiers_consistent():
        assert eng.pool.used == eng.prefix.held_pages
        assert all(rc == 1 for rc in eng.pool.refcounts.values())
        if eng.host_pool is not None:
            assert (eng.host_pool.used_pages
                    == eng.prefix.host_held_pages)
    rng = np.random.default_rng(21)
    shared = rng.integers(0, tiny_cfg.vocab_size - 1, 8).astype(np.int32)
    decoy = rng.integers(0, tiny_cfg.vocab_size - 1, 8).astype(np.int32)
    eng.submit(shared, gen_len=8)     # cold, publishes 4 pages
    eng.submit(decoy, gen_len=8)      # cold, publishes 4 more (LRU-er)
    eng.run()
    # full hit (its plan protects the shared entry) + a small filler;
    # admitting them under pressure evicts the decoy's pages
    eng.submit(shared, gen_len=8)
    eng.submit(rng.integers(0, tiny_cfg.vocab_size - 1, 4)
               .astype(np.int32), gen_len=4)
    big = rng.integers(0, tiny_cfg.vocab_size - 1, 8).astype(np.int32)
    s0 = eng.stats.steps              # stats accumulate across runs

    def on_step(e):
        if e.stats.steps == s0 + 2:   # full batch + 2 free pages:
            e.submit(big, gen_len=8, priority=5)   # evicts AND preempts

    eng.run(on_step=on_step)
    assert eng.stats.requests_done == 5
    assert eng.stats.prefix_full_hits >= 1
    assert eng.stats.preemptions > 0
    assert eng.stats.prefix_evicted_pages > 0
    # after the drain, the ONLY pages still held belong to the index
    both_tiers_consistent()

    # --- cancellation (DESIGN.md §8) must uphold the same invariant:
    # cancel-while-running releases the row's pages mid-decode,
    # cancel-while-queued drops the request (and its prefix-plan holds)
    # before it ever owns a row
    run_victim = eng.submit(rng.integers(0, tiny_cfg.vocab_size - 1, 8)
                            .astype(np.int32), gen_len=8)
    filler = rng.integers(0, tiny_cfg.vocab_size - 1, 4).astype(np.int32)
    eng.submit(filler, gen_len=4)
    queue_victim = eng.submit(shared, gen_len=8)  # full hit: plan holds
    s1 = eng.stats.steps

    def on_step_cancel(e):
        if e.stats.steps == s1 + 2:
            assert e.cancel(run_victim)       # in-flight: owns pages
            assert e.cancel(queue_victim)     # still queued
    eng.run(on_step=on_step_cancel)
    assert eng.stats.requests_canceled == 2
    canceled = {r.uid: r for r in eng.done
                if r.uid in (run_victim, queue_victim)}
    assert canceled[run_victim].canceled
    assert canceled[run_victim].output is None
    assert canceled[queue_victim].canceled
    assert not eng.cancel(run_victim)         # already finalized
    both_tiers_consistent()

    if host_pages:
        # --- cancel during PROMOTING (DESIGN.md §10): a queued request
        # parked on a host-tier match holds NO device pages yet; the
        # cancel must clear the parked plan without touching either
        # tier, and the host entry must stay promotable afterwards.
        parked = None
        for p in (shared, decoy, big, filler):
            u = eng.submit(p, gen_len=len(p))
            req = next(r for r in eng.queue if r.uid == u)
            eng._prefix_plan(req)
            if parked is None and req.pending_promotion is not None:
                parked = (u, req, p)
            else:
                eng._drop_plan(req)
            assert eng.cancel(u)
        assert parked is not None, "churn left no host-resident entry"
        u, req, p = parked
        assert req.canceled and req.pending_promotion is None
        assert not req.holds and req.pages is None
        both_tiers_consistent()
        # a fresh request still warms from the host tier
        p0 = eng.stats.prefix_promotions
        eng.submit(p, gen_len=len(p))
        eng.run()
        assert eng.stats.prefix_promotions == p0 + 1
        both_tiers_consistent()

    eng.drop_prefix_cache()
    assert eng.pool.used == 0
    assert eng.pool.available == eng.pool.capacity
    assert not eng.pool.refcounts
    if eng.host_pool is not None:
        # drop emptied BOTH tiers, and the churn exercised them
        assert eng.host_pool.used_pages == 0
        assert eng.host_pool.used_units == 0
        assert eng.stats.prefix_demoted_pages > 0

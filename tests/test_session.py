"""DecodeSession behaviour: refresh single-source-of-truth, streaming
events, active-position masks, semi-AR block schedule."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.core.strategy import SPACache
from repro.dlm.decoding import DecodeSettings
from repro.dlm.session import DecodeSession, StepEvent
from repro.models import transformer


@pytest.fixture(scope="module")
def small():
    cfg = reduced(get_arch("internlm2-1.8b"))
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(2), (2, 10), 0,
                                cfg.vocab_size - 1)
    return cfg, params, prompt


def test_settings_refresh_interval_fires(small):
    """DecodeSettings.refresh_interval is honoured (it used to be dead:
    decode() read only cfg.spa.refresh_interval)."""
    cfg, params, prompt = small
    assert cfg.spa.refresh_interval == 0      # config says never
    sess = DecodeSession(params, cfg,
                         settings=DecodeSettings(refresh_interval=2))
    sess.prefill(prompt, gen_len=6)
    toks, info = sess.run()
    assert int((toks == cfg.mask_id).sum()) == 0
    # steps 2 and 4 (at least) trigger a rebuild
    assert sess.refresh_count == (info["steps"] - 1) // 2
    assert sess.refresh_count >= 1


def test_strategy_refresh_interval_is_fallback(small):
    """With settings.refresh_interval == 0 the strategy default applies."""
    cfg, params, prompt = small
    sess = DecodeSession(
        params, cfg,
        strategy=SPACache(rank=16, schedule="uniform", rho_peak=0.3,
                          refresh_interval=3))
    assert sess.refresh_interval == 3
    sess.prefill(prompt, gen_len=6)
    sess.run()
    assert sess.refresh_count >= 1


def test_settings_override_strategy_refresh(small):
    cfg, params, prompt = small
    sess = DecodeSession(
        params, cfg,
        strategy=SPACache(rank=16, refresh_interval=3),
        settings=DecodeSettings(refresh_interval=5))
    assert sess.refresh_interval == 5         # one source of truth


def test_events_stream(small):
    cfg, params, prompt = small
    sess = DecodeSession(params, cfg)
    sess.prefill(prompt, gen_len=5)
    events = list(sess.events())
    assert all(isinstance(e, StepEvent) for e in events)
    assert events[-1].done
    assert sum(int(e.n_committed.sum()) for e in events) == 2 * 5
    assert [e.step for e in events] == list(range(1, len(events) + 1))


def test_active_mask_restricts_commits(small):
    """Positions outside the active mask are never committed, even though
    they hold [MASK] tokens — no token-id sentinel hacks."""
    cfg, params, prompt = small
    sess = DecodeSession(params, cfg)
    sess.prefill(prompt, gen_len=8)
    p_len = prompt.shape[1]
    sess.set_active_span(p_len, p_len + 4)    # only first 4 slots open
    toks, _ = sess.run()
    toks = np.asarray(toks)
    assert (toks[:, p_len: p_len + 4] != cfg.mask_id).all()
    assert (toks[:, p_len + 4:] == cfg.mask_id).all()


def test_run_blocks_commits_left_to_right(small):
    cfg, params, prompt = small
    sess = DecodeSession(params, cfg)
    sess.prefill(prompt, gen_len=8)
    toks, info = sess.run_blocks(block_len=4)
    assert int((np.asarray(toks) == cfg.mask_id).sum()) == 0
    np.testing.assert_array_equal(np.asarray(toks[:, :10]),
                                  np.asarray(prompt))
    # block boundaries trigger cache refreshes (one per non-first block)
    assert sess.refresh_count >= 1


def test_token_zero_is_a_legal_output(small):
    """Token id 0 must survive as a committed value (the old engine used
    it as a 'committed filler' sentinel)."""
    cfg, params, prompt = small
    sess = DecodeSession(params, cfg)
    state = sess.prefill(prompt, gen_len=4)
    # plant a committed token 0 inside the generation span
    p_len = prompt.shape[1]
    tokens = state.tokens.at[:, p_len].set(0)
    sess.state = state._replace(
        tokens=tokens, n_masked=state.n_masked - 1)
    toks, _ = sess.run()
    toks = np.asarray(toks)
    assert (toks[:, p_len] == 0).all()        # not clobbered
    assert int((toks == cfg.mask_id).sum()) == 0

"""DecodeSession behaviour: refresh single-source-of-truth, streaming
events, active-position masks, semi-AR block schedule."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.core.strategy import SPACache
from repro.dlm.decoding import DecodeSettings
from repro.dlm.session import DecodeSession, StepEvent
from repro.models import transformer


@pytest.fixture(scope="module")
def small():
    cfg = reduced(get_arch("internlm2-1.8b"))
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(2), (2, 10), 0,
                                cfg.vocab_size - 1)
    return cfg, params, prompt


def test_settings_refresh_interval_fires(small):
    """DecodeSettings.refresh_interval is honoured (it used to be dead:
    decode() read only cfg.spa.refresh_interval)."""
    cfg, params, prompt = small
    assert cfg.spa.refresh_interval == 0      # config says never
    sess = DecodeSession(params, cfg,
                         settings=DecodeSettings(refresh_interval=2))
    sess.prefill(prompt, gen_len=6)
    toks, info = sess.run()
    assert int((toks == cfg.mask_id).sum()) == 0
    # steps 2 and 4 (at least) trigger a rebuild
    assert sess.refresh_count == (info["steps"] - 1) // 2
    assert sess.refresh_count >= 1


def test_strategy_refresh_interval_is_fallback(small):
    """With settings.refresh_interval == 0 the strategy default applies."""
    cfg, params, prompt = small
    sess = DecodeSession(
        params, cfg,
        strategy=SPACache(rank=16, schedule="uniform", rho_peak=0.3,
                          refresh_interval=3))
    assert sess.refresh_interval == 3
    sess.prefill(prompt, gen_len=6)
    sess.run()
    assert sess.refresh_count >= 1


def test_settings_override_strategy_refresh(small):
    cfg, params, prompt = small
    sess = DecodeSession(
        params, cfg,
        strategy=SPACache(rank=16, refresh_interval=3),
        settings=DecodeSettings(refresh_interval=5))
    assert sess.refresh_interval == 5         # one source of truth


def test_refresh_interval_minus_one_disables(small):
    """refresh_interval=-1 means NEVER refresh — it does not fall back
    to the strategy default the way 0 does."""
    cfg, params, prompt = small
    sess = DecodeSession(
        params, cfg,
        strategy=SPACache(rank=16, schedule="uniform", rho_peak=0.3,
                          refresh_interval=2),
        settings=DecodeSettings(refresh_interval=-1))
    assert sess.refresh_interval == 0
    sess.prefill(prompt, gen_len=6)
    sess.run()
    assert sess.refresh_count == 0
    # and the compiled loop agrees
    sess.prefill(prompt, gen_len=6)
    sess.run_compiled()
    assert sess.refresh_count == 0


def test_events_stream(small):
    cfg, params, prompt = small
    sess = DecodeSession(params, cfg)
    sess.prefill(prompt, gen_len=5)
    events = list(sess.events())
    assert all(isinstance(e, StepEvent) for e in events)
    assert events[-1].done
    assert sum(int(e.n_committed.sum()) for e in events) == 2 * 5
    assert [e.step for e in events] == list(range(1, len(events) + 1))


def test_active_mask_restricts_commits(small):
    """Positions outside the active mask are never committed, even though
    they hold [MASK] tokens — no token-id sentinel hacks."""
    cfg, params, prompt = small
    sess = DecodeSession(params, cfg)
    sess.prefill(prompt, gen_len=8)
    p_len = prompt.shape[1]
    sess.set_active_span(p_len, p_len + 4)    # only first 4 slots open
    toks, _ = sess.run()
    toks = np.asarray(toks)
    assert (toks[:, p_len: p_len + 4] != cfg.mask_id).all()
    assert (toks[:, p_len + 4:] == cfg.mask_id).all()


def test_run_blocks_commits_left_to_right(small):
    cfg, params, prompt = small
    sess = DecodeSession(params, cfg)
    sess.prefill(prompt, gen_len=8)
    toks, info = sess.run_blocks(block_len=4)
    assert int((np.asarray(toks) == cfg.mask_id).sum()) == 0
    np.testing.assert_array_equal(np.asarray(toks[:, :10]),
                                  np.asarray(prompt))
    # block boundaries trigger cache refreshes (one per non-first block)
    assert sess.refresh_count >= 1


def test_decode_state_extras_not_shared(small):
    """The old ``extras: Dict = {}`` NamedTuple default was ONE dict
    shared by every DecodeState; a session mutating it leaked into
    sibling sessions.  Defaults must be None and sessions must own a
    fresh dict."""
    from repro.dlm.decoding import DecodeState
    assert DecodeState._field_defaults["extras"] is None
    cfg, params, prompt = small
    s1 = DecodeSession(params, cfg)
    s2 = DecodeSession(params, cfg)
    shared = {}
    st1 = s1.prefill(prompt, gen_len=4, extras=shared)
    st2 = s2.prefill(prompt, gen_len=4)
    st1.extras["leak"] = jnp.zeros(())
    assert "leak" not in st2.extras           # no cross-session leak
    assert "leak" not in shared               # caller's dict not aliased


def _vision_setup():
    """Tiny vision-frontend model: extras carry real patch embeddings."""
    cfg = reduced(get_arch("internvl2-76b"))
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    f = max(cfg.frontend_tokens, 4)
    return cfg, params, f


def _vision_canvas(cfg, rng, n_text, gen_len):
    p_len = n_text - gen_len
    row = np.full((n_text,), cfg.mask_id, np.int32)
    row[:p_len] = rng.integers(0, cfg.vocab_size - 1, p_len)
    active = np.zeros((n_text,), bool)
    active[p_len:] = True
    return row, active


def test_replace_rows_with_extras():
    """Row surgery splices BOTH the canvas and the per-row extras (VLM
    patches), and the swapped row's decode is byte-identical to a fresh
    session attached directly to the replacement canvas."""
    cfg, params, f = _vision_setup()
    rng = np.random.default_rng(5)
    n_text, gen_len = 16, 4
    r0, a0 = _vision_canvas(cfg, rng, n_text, gen_len)
    r1a, a1a = _vision_canvas(cfg, rng, n_text, gen_len)
    r1b, a1b = _vision_canvas(cfg, rng, n_text, gen_len)
    patches = rng.standard_normal((3, f, cfg.d_model)).astype(np.float32) \
        * 0.02
    p0, p1a, p1b = patches[0], patches[1], patches[2]

    sess = DecodeSession(params, cfg)
    sess.attach(np.stack([r0, r1a]), active=np.stack([a0, a1a]),
                extras={"patches": jnp.asarray(np.stack([p0, p1a]))})
    sess.step()
    sess.step()
    sess.replace_rows([1], r1b[None], a1b[None],
                      row_extras={"patches": p1b[None]})
    np.testing.assert_array_equal(
        np.asarray(sess.state.extras["patches"][1]), p1b)
    toks, _ = sess.run()

    ref = DecodeSession(params, cfg)
    ref.attach(np.stack([r1b, r1b]), active=np.stack([a1b, a1b]),
               extras={"patches": jnp.asarray(np.stack([p1b, p1b]))})
    ref_toks, _ = ref.run()
    # rows are independent: the spliced row replays the fresh decode
    np.testing.assert_array_equal(np.asarray(toks)[1],
                                  np.asarray(ref_toks)[0])
    assert int((np.asarray(toks) == cfg.mask_id).sum()) == 0


def test_deactivate_rows_parks_slot(small):
    """A parked slot stops committing (its masks survive) while the
    sibling row decodes to completion."""
    cfg, params, prompt = small
    sess = DecodeSession(params, cfg)
    sess.prefill(prompt, gen_len=6)
    p_len = prompt.shape[1]
    sess.deactivate_rows([1])
    assert int(np.asarray(sess.state.n_masked)[1]) == 0
    toks, _ = sess.run()
    toks = np.asarray(toks)
    assert (toks[0, p_len:] != cfg.mask_id).all()     # row 0 finished
    assert (toks[1, p_len:] == cfg.mask_id).all()     # row 1 parked
    # the parked row can be revived later via set_active
    b, n = toks.shape
    active = jnp.zeros((b, n), bool).at[1, p_len:].set(True)
    sess.set_active(active)
    assert int(np.asarray(sess.state.n_masked)[1]) == 6
    toks2, _ = sess.run()
    assert int((np.asarray(toks2) == cfg.mask_id).sum()) == 0


def test_token_zero_is_a_legal_output(small):
    """Token id 0 must survive as a committed value (the old engine used
    it as a 'committed filler' sentinel)."""
    cfg, params, prompt = small
    sess = DecodeSession(params, cfg)
    state = sess.prefill(prompt, gen_len=4)
    # plant a committed token 0 inside the generation span
    p_len = prompt.shape[1]
    tokens = state.tokens.at[:, p_len].set(0)
    sess.state = state._replace(
        tokens=tokens, n_masked=state.n_masked - 1)
    toks, _ = sess.run()
    toks = np.asarray(toks)
    assert (toks[:, p_len] == 0).all()        # not clobbered
    assert int((toks == cfg.mask_id).sum()) == 0

"""Identifier scoring unit tests (paper §3.2)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import identifiers


def test_proxy_project_shapes():
    h = jnp.ones((2, 8, 16))
    w = jnp.ones((16, 4))
    assert identifiers.proxy_project(h, "singular",
                                     proxy_mat=w).shape == (2, 8, 4)
    assert identifiers.proxy_project(h, "value",
                                     w_value=w).shape == (2, 8, 4)
    assert identifiers.proxy_project(h, "attn_in").shape == (2, 8, 16)


def test_drift_scores_detect_change():
    rng = np.random.default_rng(0)
    p_old = jnp.asarray(rng.standard_normal((1, 8, 16)).astype(np.float32))
    p_new = p_old.at[:, 3].add(10.0)
    scores = identifiers.drift_scores(p_new, p_old)
    assert scores.shape == (1, 8)
    # position 3 has the lowest similarity
    assert int(jnp.argmin(scores[0])) == 3
    np.testing.assert_allclose(np.asarray(scores[0, :3]), 1.0, atol=1e-5)


def test_drift_scores_scale_invariant():
    rng = np.random.default_rng(1)
    p = jnp.asarray(rng.standard_normal((1, 4, 8)).astype(np.float32))
    scores = identifiers.drift_scores(p * 3.0, p)
    np.testing.assert_allclose(np.asarray(scores), 1.0, atol=1e-5)


def test_locality_scores():
    committed = jnp.asarray([[5, -1, -1]])
    scores = identifiers.locality_scores(16, committed, window=4)
    assert scores.shape == (1, 16)
    s = np.asarray(scores[0])
    assert s[5] == 0.0                       # at the commit
    assert s[5] < s[7] < s[12]               # monotone in distance
    # far positions saturate at 1 (keep cached)
    assert s[15] == 1.0


def test_locality_all_unused():
    committed = jnp.full((2, 4), -1, jnp.int32)
    scores = identifiers.locality_scores(8, committed, window=4)
    assert float(jnp.min(scores)) == 1.0     # nothing recently committed

"""Async streaming front-end (DESIGN.md §8): engine-thread bridge,
in-process streaming, the stdlib HTTP layer, and disconnect-cancel."""
import asyncio

import numpy as np
import pytest

from repro.core.strategy import SPACache
from repro.serving.engine import ServingEngine
from repro.serving.frontend import AsyncFrontend, fetch_stats, \
    stream_request
from repro.serving.slo import SLO, SLOPolicy

PAGE, CANVAS = 4, 16


def _engine(cfg, params, max_batch=2):
    return ServingEngine(
        cfg, params, max_batch=max_batch, canvas_len=CANVAS,
        strategy=SPACache(rank=16, schedule="uniform", rho_peak=0.3,
                          refresh_interval=1),
        pool_pages=max_batch * (CANVAS // PAGE) + 1, page_size=PAGE,
        prefix_cache=True, slo_policy=SLOPolicy())


def test_frontend_streams_tokens_in_process(tiny_cfg, tiny_params):
    """generate() yields per-token events as decode progresses, ending
    in one "done" whose reassembled stream equals the engine output."""
    eng = _engine(tiny_cfg, tiny_params)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, tiny_cfg.vocab_size - 1, 4)
               .astype(np.int32) for _ in range(3)]

    async def client(front, prompt):
        stream, kinds = {}, []
        async for ev in front.generate(prompt, 6,
                                       slo=SLO(ttft=60.0)):
            kinds.append(ev.kind)
            if ev.kind == "token":
                for pos, tok in zip(ev.positions, ev.tokens):
                    assert pos not in stream      # no duplicates
                    stream[pos] = tok
        return kinds, stream

    async def main():
        async with AsyncFrontend(eng, max_steps=2048) as front:
            return await asyncio.gather(
                *(client(front, p) for p in prompts))

    results = asyncio.run(main())
    outputs = {tuple(int(t) for t in r.prompt): r.output
               for r in eng.done}
    assert len(eng.done) == 3
    for (kinds, stream), prompt in zip(results, prompts):
        assert kinds[-1] == "done"
        assert kinds.count("done") == 1
        assert len(kinds) > 2                     # streamed, not batched
        got = np.asarray([stream[i] for i in sorted(stream)])
        np.testing.assert_array_equal(
            got, outputs[tuple(int(t) for t in prompt)])
    # engine thread stopped cleanly; nothing leaked
    assert eng.pool.used == eng.prefix.held_pages
    assert eng.stats.slo_met == 3


def test_frontend_http_roundtrip(tiny_cfg, tiny_params):
    """POST /generate streams ndjson over a real localhost socket;
    GET /stats reports the new TTFT/TPOT percentiles."""
    eng = _engine(tiny_cfg, tiny_params)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, tiny_cfg.vocab_size - 1, 4).astype(np.int32)

    async def main():
        front = AsyncFrontend(eng, max_steps=2048)
        await front.start(serve_http=True)
        try:
            events = []
            async for ev in stream_request(
                    front.host, front.port, prompt, 6,
                    slo={"ttft": 60.0, "deadline": 240.0}):
                events.append(ev)
            stats = await fetch_stats(front.host, front.port)
        finally:
            await front.stop()
        return events, stats

    events, stats = asyncio.run(main())
    assert events[-1]["kind"] == "done"
    # token events arrive in COMMIT order (low-confidence-last), so
    # reassemble the gen span by position
    stream = {pos: tok for ev in events if ev["kind"] == "token"
              for pos, tok in zip(ev["positions"], ev["tokens"])}
    assert sorted(stream) == list(range(6))
    np.testing.assert_array_equal(
        np.asarray([stream[i] for i in range(6)]), eng.done[0].output)
    assert stats["requests_done"] == 1
    for key in ("ttft_p50", "ttft_p95", "tpot_p50", "tpot_p95"):
        assert key in stats
    assert stats["ttft_p50"] > 0.0


def test_frontend_disconnect_cancels_request(tiny_cfg, tiny_params):
    """A client that hangs up mid-stream (HTTP) or closes its generator
    (in-process) cancels the request on the engine; pages and prefix
    holds are released."""
    eng = _engine(tiny_cfg, tiny_params)
    rng = np.random.default_rng(2)
    pr = [rng.integers(0, tiny_cfg.vocab_size - 1, 4).astype(np.int32)
          for _ in range(2)]

    async def main():
        front = AsyncFrontend(eng, max_steps=2048)
        await front.start(serve_http=True)
        try:
            # in-process: close the generator after the first token
            agen = front.generate(pr[0], 10)
            async for ev in agen:
                if ev.kind == "token":
                    break
            await agen.aclose()
            # HTTP: drop the socket after the first token event
            hgen = stream_request(front.host, front.port, pr[1], 10)
            async for ev in hgen:
                if ev["kind"] == "token":
                    break
            await hgen.aclose()
            for _ in range(200):                 # until both aborts land
                if eng.stats.requests_canceled == 2:
                    break
                await asyncio.sleep(0.05)
        finally:
            await front.stop()

    asyncio.run(main())
    assert eng.stats.requests_canceled == 2
    assert eng.stats.requests_done == 0
    assert all(r.canceled and r.output is None for r in eng.done)
    assert eng.pool.used == eng.prefix.held_pages
    eng.drop_prefix_cache()
    assert eng.pool.used == 0


async def _raw(host, port, payload: bytes) -> bytes:
    reader, writer = await asyncio.open_connection(host, port)
    writer.write(payload)
    await writer.drain()
    raw = await reader.read()
    writer.close()
    try:
        await writer.wait_closed()
    except (ConnectionResetError, BrokenPipeError):
        pass
    return raw


def test_frontend_rejects_malformed_requests(tiny_cfg, tiny_params):
    """Hostile bytes on the socket get clean 4xx responses — never a
    half-written stream, never an engine-thread exception — and the
    server keeps serving valid requests afterwards (DESIGN.md §10)."""
    eng = _engine(tiny_cfg, tiny_params)
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, tiny_cfg.vocab_size - 1, 4).astype(np.int32)

    def post(body: bytes, clen=None) -> bytes:
        clen = len(body) if clen is None else clen
        return (f"POST /generate HTTP/1.1\r\nHost: x\r\n"
                f"Content-Length: {clen}\r\n\r\n").encode() + body

    cases = [
        (b"\r\n", b"400"),                              # no request line
        (post(b"{not json}"), b"400"),
        (post(b"[1, 2, 3]"), b"400"),                   # not an object
        (post(b'{"prompt": "abc", "gen_len": 4}'), b"400"),
        (post(b'{"prompt": [1, true], "gen_len": 4}'), b"400"),
        (post(b'{"prompt": [1, 2], "gen_len": 0}'), b"400"),
        (post(b'{"prompt": [1, 2]}'), b"400"),          # missing gen_len
        (post(b"x", clen=4096), b"413"),                # body > max_body
        ((b"POST /generate HTTP/1.1\r\nHost: x\r\n"
          b"Content-Length: nope\r\n\r\n"), b"400"),
    ]

    async def main():
        front = AsyncFrontend(eng, max_steps=2048, max_body=1024)
        await front.start(serve_http=True)
        try:
            results = []
            for payload, code in cases:
                raw = await _raw(front.host, front.port, payload)
                results.append((raw.split(b"\r\n", 1)[0], code))
            # the server survives: a valid request still streams
            events = []
            async for ev in stream_request(front.host, front.port,
                                           prompt, 6):
                events.append(ev)
        finally:
            await front.stop()
        return results, events

    results, events = asyncio.run(main())
    for status_line, code in results:
        assert code in status_line, (status_line, code)
    assert events[-1]["kind"] == "done"
    assert eng.stats.requests_done == 1   # junk never reached the engine


def test_submit_threadsafe_validates_on_caller(tiny_cfg, tiny_params):
    """Invalid submissions raise on the CALLING thread — a malformed
    mailbox entry can never abort the engine loop mid-step."""
    eng = _engine(tiny_cfg, tiny_params)
    good = np.asarray([1, 2], np.int32)
    with pytest.raises(ValueError):
        eng.submit_threadsafe(good, 0)                  # gen_len <= 0
    with pytest.raises(ValueError):
        eng.submit_threadsafe(good, True)               # bool is not int
    with pytest.raises(ValueError):
        eng.submit_threadsafe(good, CANVAS + 1)         # over canvas
    with pytest.raises(ValueError):
        eng.submit_threadsafe(np.asarray([[1], [2]], np.int32), 4)
    eng._drain_mailbox()
    assert not eng.queue                  # nothing reached the engine


def test_submit_threadsafe_and_cancel_queued(tiny_cfg, tiny_params):
    """Mailbox intake: submissions from a foreign thread are enqueued
    on the engine thread; canceling a queued uid before the engine
    drains it aborts cleanly with a "canceled" event."""
    eng = _engine(tiny_cfg, tiny_params)
    rng = np.random.default_rng(3)
    events = []
    uid = eng.submit_threadsafe(
        rng.integers(0, tiny_cfg.vocab_size - 1, 4).astype(np.int32),
        6, stream=True, sink=events.append)
    eng.cancel_threadsafe(uid)
    eng._drain_mailbox()
    assert eng.stats.requests_canceled == 1
    assert [ev.kind for ev in events] == ["canceled"]
    assert not eng.queue

"""Sharding rules over the production mesh shapes (AbstractMesh — no
devices needed) + divisibility guarantees for every assigned arch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ARCHS, ASSIGNED, SHAPES, get_arch, supports_shape
from repro.distributed import sharding as shd


def _abstract_mesh(sizes, names):
    try:
        return AbstractMesh(sizes, names)              # jax >= 0.5
    except TypeError:                                  # jax 0.4.x
        return AbstractMesh(tuple(zip(names, sizes)))


def mesh_single():
    return _abstract_mesh((16, 16), ("data", "model"))


def mesh_multi():
    return _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


class FakeLeaf:
    def __init__(self, shape):
        self.shape = shape


@pytest.mark.parametrize("mesh_fn", [mesh_single, mesh_multi])
def test_row_column_rules(mesh_fn):
    mesh = mesh_fn()
    # row-parallel: contraction dim sharded
    spec = shd.param_pspec("wq", FakeLeaf((4096, 2048)), mesh,
                           zero3=False, stacked=False)
    assert spec[0] == "model" and spec[1] is None
    # column-parallel
    spec = shd.param_pspec("w_up", FakeLeaf((4096, 16384)), mesh,
                           zero3=False, stacked=False)
    assert spec[1] == "model"
    # stacked leading dim never sharded
    spec = shd.param_pspec("wq", FakeLeaf((24, 4096, 2048)), mesh,
                           zero3=False, stacked=True)
    assert spec[0] is None and spec[1] == "model"


def test_moe_expert_parallel_when_divisible():
    mesh = mesh_single()
    spec = shd.param_pspec("w_gate", FakeLeaf((94, 128, 4096, 1536)),
                           mesh, zero3=True, stacked=True)
    assert spec[1] == "model"       # 128 experts / 16
    spec8 = shd.param_pspec("w_gate", FakeLeaf((56, 8, 6144, 16384)),
                            mesh, zero3=False, stacked=True)
    assert spec8[1] != "model"      # 8 experts not divisible -> TP


def test_indivisible_falls_back():
    mesh = mesh_single()
    # hubert vocab=504 not divisible by 16
    spec = shd.param_pspec("embed", FakeLeaf((504, 1280)), mesh,
                           zero3=False, stacked=False)
    for entry in spec:
        if entry is not None:
            axes = (entry,) if isinstance(entry, str) else entry
            sz = int(np.prod([mesh.shape[a] for a in axes]))
            dim = spec.index(entry)
            assert FakeLeaf((504, 1280)).shape[dim] % sz == 0


@pytest.mark.parametrize("shape_name", list(SHAPES))
@pytest.mark.parametrize("mesh_fn", [mesh_single, mesh_multi])
def test_data_specs_divisible(shape_name, mesh_fn):
    mesh = mesh_fn()
    shape = SHAPES[shape_name]
    spec = shd.data_pspec(shape, mesh, 2)
    sizes = (shape.global_batch, shape.seq_len)
    for dim, entry in enumerate(spec):
        if entry is None:
            continue
        axes = (entry,) if isinstance(entry, str) else entry
        sz = int(np.prod([mesh.shape[a] for a in axes]))
        assert sizes[dim] % sz == 0


@pytest.mark.parametrize("arch", ASSIGNED)
@pytest.mark.parametrize("mesh_fn", [mesh_single, mesh_multi])
def test_every_param_spec_divisible(arch, mesh_fn):
    """Choose specs for every real parameter of every arch; all sharded
    dims must divide the axis product — guarantees lowering."""
    import functools
    from repro.models import transformer
    cfg = get_arch(arch)
    mesh = mesh_fn()
    abs_params = jax.eval_shape(
        functools.partial(transformer.init_params, cfg),
        jax.ShapeDtypeStruct((2,), jnp.uint32))

    def check(path, leaf):
        stacked = any(getattr(p, "key", None) == "blocks" for p in path)
        name = ""
        for p in reversed(path):
            key = getattr(p, "key", None)
            if isinstance(key, str):
                name = key
                break
        spec = shd.param_pspec(name, leaf, mesh, zero3=cfg.zero3,
                               stacked=stacked)
        for dim, entry in enumerate(spec):
            if entry is None:
                continue
            axes = (entry,) if isinstance(entry, str) else entry
            sz = int(np.prod([mesh.shape[a] for a in axes]))
            assert leaf.shape[dim] % sz == 0, (name, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(check, abs_params)


def test_long_context_shards_sequence():
    mesh = mesh_single()
    spec = shd.data_pspec(SHAPES["long_500k"], mesh, 2)
    assert spec[0] is None and spec[1] is not None


def test_cache_spec():
    mesh = mesh_single()
    spec = shd.cache_pspec(SHAPES["decode_32k"], mesh, 5)
    assert spec[0] is None            # layer stack dim
    assert spec[1] is not None        # batch
    assert spec[2] == "model"         # sequence over model

"""End-to-end behaviour: train a tiny DLM, then serve it with SPA-Cache
and verify the cache path (a) matches vanilla at full budget, (b) tracks
it closely at the paper's budget, (c) actually computes fewer rows."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.configs.base import SPAConfig
from repro.core import budget, spa_layer
from repro.data.synthetic import token_batches
from repro.dlm import decoding
from repro.models import transformer
from repro.training.optimizer import AdamWConfig
from repro.training.trainer import Trainer


@pytest.fixture(scope="module")
def trained():
    cfg = reduced(get_arch("internlm2-1.8b"), vocab_size=64, d_model=64,
                  n_layers=2, d_ff=128)
    trainer = Trainer(cfg, AdamWConfig(lr=3e-3, warmup_steps=5,
                                       total_steps=220)).init(
        jax.random.PRNGKey(0))
    data = token_batches(cfg, batch_size=8, seq_len=32, seed=0)
    hist = trainer.fit(data, n_steps=200, rng=jax.random.PRNGKey(1),
                       log_every=0)
    return cfg, trainer.params, hist


def test_training_converges(trained):
    _, _, hist = trained
    assert np.mean(hist["loss"][-5:]) < np.mean(hist["loss"][:5])


def test_trained_model_decodes(trained):
    cfg, params, _ = trained
    prompt = jnp.asarray(
        token_batches(cfg, 2, 8, seed=3).__next__()["tokens"])
    toks, info = decoding.decode(params, cfg, prompt, gen_len=8)
    assert int((toks == cfg.mask_id).sum()) == 0


def test_spa_decode_agreement_at_paper_budget(trained):
    """With a generous adaptive budget, SPA decode should commit mostly
    the same tokens as vanilla on a trained model."""
    cfg, params, _ = trained
    prompt = jnp.asarray(
        token_batches(cfg, 2, 8, seed=4).__next__()["tokens"])
    cfg_spa = dataclasses.replace(cfg, spa=SPAConfig(
        identifier="singular", rank=16, schedule="adaptive",
        rho_peak=0.5, rho_first=0.2, rho_last=0.3))
    cfg_v = dataclasses.replace(cfg, spa=SPAConfig(identifier="none"))
    t_spa, _ = decoding.decode(params, cfg_spa, prompt, gen_len=10)
    t_v, _ = decoding.decode(params, cfg_v, prompt, gen_len=10)
    agree = (np.asarray(t_spa) == np.asarray(t_v)).mean()
    assert agree > 0.6, agree     # tiny model; paper reports ~parity


def test_adaptive_budget_computes_fewer_rows(trained):
    cfg, params, _ = trained
    n = 4096   # large enough that the x16 shardability rounding is noise
    adaptive = SPAConfig(identifier="singular", rank=16,
                         schedule="adaptive", rho_peak=0.25,
                         rho_first=0.05, rho_last=0.1)
    uniform = SPAConfig(identifier="singular", rank=16,
                        schedule="uniform", rho_peak=0.25)
    ks_a = budget.k_schedule(adaptive, cfg.n_layers, n)
    ks_u = budget.k_schedule(uniform, cfg.n_layers, n)
    assert sum(ks_a) < sum(ks_u)


def test_serve_step_updates_cache_and_commits(trained):
    cfg, params, _ = trained
    proxies = spa_layer.build_spa_proxies(params, cfg)
    prompt = jnp.asarray(
        token_batches(cfg, 2, 8, seed=5).__next__()["tokens"])
    state = decoding.init_decode_state(cfg, params, prompt, 6, proxies)
    masked_before = int(jnp.sum(state.tokens == cfg.mask_id))
    new_state, info = decoding.serve_step(
        params, cfg, state, decoding.DecodeSettings(), proxies)
    masked_after = int(jnp.sum(new_state.tokens == cfg.mask_id))
    assert masked_after < masked_before
    assert int(new_state.step) == 1

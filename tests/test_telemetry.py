"""Unified telemetry layer (DESIGN.md §11).

Covers the four contracts the telemetry PR makes:

* exactness — histogram percentiles are single-sourced and match
  ``numpy.percentile`` on the raw samples;
* zero interference — a fully-instrumented engine run decodes
  byte-identically to its telemetry-off twin (everything is host-side);
* span-tree integrity — every request lifecycle is one well-nested
  span tree per uid across preempt/resume and demote->promote, with no
  orphaned or double-closed spans;
* determinism — a seeded chaos run and its replay emit identical event
  streams, and the fault instants mirror the injector's replay log
  line-for-line.
"""
import asyncio
import itertools
import json
import re

import numpy as np
import pytest

from repro.core.strategy import SPACache
from repro.serving.engine import ServingEngine
from repro.serving.faults import FaultPlan
from repro.serving.telemetry import (PID_ENGINE, PID_EVENTS, PID_REQUESTS,
                                     Histogram, MetricsRegistry, Telemetry,
                                     Tracer, percentile)

PAGE, CANVAS = 4, 16


def _strat():
    # refresh_interval=1 -> outputs are a pure function of the canvas,
    # so preemption/promotion reordering cannot shift surviving bits
    return SPACache(rank=16, schedule="uniform", rho_peak=0.3,
                    refresh_interval=1)


def _counter_clock():
    c = itertools.count()
    return lambda: next(c) * 1e-3


# ---------------------------------------------------------------------------
# Histogram / registry units (satellite: single-sourced percentiles)
# ---------------------------------------------------------------------------

def test_percentile_matches_numpy():
    rng = np.random.default_rng(3)
    for n in (1, 2, 5, 17, 100):
        xs = rng.exponential(1.0, n).tolist()
        h = Histogram("t_seconds")
        h.extend(xs)
        for q in (0, 10, 50, 90, 95, 99, 100):
            want = float(np.percentile(xs, q))
            assert percentile(xs, q) == pytest.approx(want, rel=1e-12)
            assert h.percentile(q) == pytest.approx(want, rel=1e-12)
    assert percentile([], 50) == 0.0


def test_histogram_is_list_compatible():
    h = Histogram("t_seconds")
    assert not h and len(h) == 0
    h.append(2.0)                       # EngineStats call sites use append
    h.observe(4.0)
    assert h and len(h) == 2
    assert sorted(h) == [2.0, 4.0]
    assert h.mean == pytest.approx(3.0)


def test_registry_prometheus_render_is_valid():
    reg = MetricsRegistry()
    reg.counter("spa_engine_steps_total", "iterations").inc(3)
    reg.gauge("spa_pool_pages_used", "pages", labels={"tier": "hbm"}).set(7)
    h = reg.histogram("spa_engine_ttft_seconds", "ttft",
                      buckets=(0.1, 1.0, 10.0))
    for x in (0.05, 0.5, 5.0, 50.0):
        h.observe(x)
    text = reg.render()
    _assert_prometheus_text(text)
    # cumulative buckets, closed by +Inf == _count
    counts = [int(m.group(1)) for m in re.finditer(
        r'spa_engine_ttft_seconds_bucket\{le="[^"]+"\} (\d+)', text)]
    assert counts == sorted(counts) and counts[-1] == 4
    assert 'le="+Inf"' in text
    assert "spa_engine_ttft_seconds_count 4" in text


def _assert_prometheus_text(text):
    """Prometheus text-format 0.0.4: every line is HELP/TYPE metadata or
    ``name{labels} value`` with a float-parseable value."""
    sample = re.compile(
        r"^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^{}]*\})? \S+$")
    assert text.endswith("\n")
    for line in text.rstrip("\n").split("\n"):
        if line.startswith("# HELP ") or line.startswith("# TYPE "):
            continue
        assert sample.match(line), f"bad exposition line: {line!r}"
        float(line.rsplit(" ", 1)[1])   # value parses


def test_format_summary_safe_when_empty():
    assert "no metrics recorded" in MetricsRegistry().format_summary()


def test_tracer_span_integrity_errors():
    tr = Tracer(clock=_counter_clock())
    with pytest.raises(RuntimeError, match="no open span"):
        tr.end(1, 1, "request")
    tr.begin(1, 1, "request")
    tr.begin(1, 1, "queued")
    with pytest.raises(RuntimeError, match="innermost"):
        tr.end(1, 1, "request")         # out-of-order close
    assert tr.close_track(1, 1) == 2    # innermost-first teardown
    assert tr.open_spans() == []
    names = [e.name for e in tr.span_events(1, 1)]
    assert names == ["queued", "request"]


# ---------------------------------------------------------------------------
# One churn run per module: preempt + evict/demote + promote, fully
# traced, plus its telemetry-off twin for the parity assertions.
# ---------------------------------------------------------------------------

def _churn(cfg, params, telemetry, clock=None):
    eng = ServingEngine(cfg, params, max_batch=2, canvas_len=CANVAS,
                        strategy=_strat(), pool_pages=9, page_size=PAGE,
                        prefix_cache=True, host_pages=16,
                        host_dtype="f32", telemetry=telemetry,
                        clock=clock)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size - 1, 8).astype(np.int32)
               for _ in range(4)]
    eng.submit(prompts[0], gen_len=8)
    eng.run()                           # cold p0: prefill + publish
    for p in prompts[1:3]:
        eng.submit(p, gen_len=8)        # full pool evicts+demotes p0
    s0 = eng.stats.steps

    def on_step(e):
        if e.stats.steps == s0 + 2:     # priority arrival on a full pool
            e.submit(prompts[3], gen_len=8, priority=5)

    eng.run(on_step=on_step)
    eng.submit(prompts[0], gen_len=8)   # warm p0: promote from host tier
    eng.run()
    return eng


@pytest.fixture(scope="module")
def traced_run(tiny_cfg, tiny_params):
    on = _churn(tiny_cfg, tiny_params, Telemetry.enabled(dynamics_every=1))
    off = _churn(tiny_cfg, tiny_params, None)
    # the workload must actually exercise the interesting transitions
    assert on.stats.preemptions > 0, "churn never preempted"
    assert on.stats.prefix_demoted_pages > 0, "churn never demoted"
    assert on.stats.prefix_promotions > 0, "churn never promoted"
    return on, off


def test_telemetry_on_is_byte_identical(traced_run):
    on, off = traced_run
    outs_on = {r.uid: np.asarray(r.output).tobytes() for r in on.done}
    outs_off = {r.uid: np.asarray(r.output).tobytes() for r in off.done}
    assert outs_on == outs_off and len(outs_on) == 5
    assert on.stats.steps == off.stats.steps
    assert on.stats.preemptions == off.stats.preemptions


def test_request_span_trees_continuous(traced_run):
    """One span tree per uid: exactly one closed ``request`` root,
    ``queued``/``running`` alternating through preempt/resume, nothing
    left open after the engine drains."""
    on, _ = traced_run
    tr = on.telemetry.tracer
    assert tr.open_spans() == []        # no orphans anywhere
    for r in on.done:
        evs = tr.span_events(PID_REQUESTS, r.uid)
        names = [e.name for e in evs]
        assert names.count("request") == 1
        n_queued, n_running = names.count("queued"), names.count("running")
        assert n_queued == 1 + r.preemptions
        assert n_running == 1 + r.preemptions
        root = next(e for e in evs if e.name == "request")
        assert root.args["outcome"] == "done"
        # children nest inside the root span's [ts, ts+dur] window
        for e in evs:
            assert e.ts >= root.ts - 1e-9
            assert e.ts + e.dur <= root.ts + root.dur + 1e-9
        if r.preemptions:
            inst = [e for e in tr.events if e.ph == "i"
                    and e.pid == PID_REQUESTS and e.tid == r.uid
                    and e.name == "preempt"]
            assert len(inst) == r.preemptions


def test_demote_promote_trace_continuity(traced_run):
    on, _ = traced_run
    tr = on.telemetry.tracer
    demotes = [e for e in tr.events
               if e.ph == "i" and e.name == "demote"]
    assert demotes and all(e.pid == PID_EVENTS for e in demotes)
    assert sum(e.args["demoted"] for e in demotes) \
        == on.stats.prefix_demoted_pages
    promotes = [e for e in tr.events
                if e.ph == "i" and e.name == "promote"]
    assert len(promotes) == on.stats.prefix_promotions
    for e in promotes:
        assert e.pid == PID_REQUESTS
        # the promoted request's own span tree stayed intact
        names = [x.name for x in tr.span_events(PID_REQUESTS, e.tid)]
        assert names.count("request") == 1


def test_engine_phase_spans_and_counters(traced_run):
    on, _ = traced_run
    tr = on.telemetry.tracer
    phases = {e.name for e in tr.span_events(PID_ENGINE)}
    assert {"dispatch", "host_overlap", "host_sync"} <= phases
    pool_samples = [e for e in tr.events
                    if e.ph == "C" and e.name == "pool_pages"]
    assert pool_samples and all(
        set(e.args) == {"used", "free"} for e in pool_samples)
    snap = on.telemetry.registry.snapshot()
    for phase in ("dispatch", "host_overlap", "host_sync"):
        key = f'spa_engine_phase_seconds{{phase="{phase}"}}'
        assert snap[key]["count"] > 0
    # refresh_interval=1 rebuilds the cache every step, so the dynamics
    # probe correctly classifies every step as a refresh and skips the
    # diff-derived metrics (they describe the *incremental* selection)
    assert snap["spa_cache_refresh_steps_total"] > 0
    assert not any(k.startswith("spa_cache_proxy_drift") for k in snap)


def test_cache_dynamics_metrics_on_incremental_decode(tiny_cfg,
                                                      tiny_params):
    """Without per-step refreshes the dynamics probe records per-layer
    budget utilization, proxy drift, and step-to-step selection
    overlap."""
    eng = ServingEngine(
        tiny_cfg, tiny_params, max_batch=2, canvas_len=CANVAS,
        strategy=SPACache(rank=16, schedule="uniform", rho_peak=0.3),
        telemetry=Telemetry.enabled(dynamics_every=1))
    rng = np.random.default_rng(9)
    for _ in range(2):
        eng.submit(rng.integers(0, tiny_cfg.vocab_size - 1, 6)
                   .astype(np.int32), gen_len=8)
    eng.run()
    snap = eng.telemetry.registry.snapshot()
    for prefix in ("spa_cache_budget_utilization_ratio",
                   "spa_cache_proxy_drift",
                   "spa_cache_selection_overlap_ratio"):
        keys = [k for k in snap if k.startswith(prefix)]
        assert keys, f"no {prefix} samples"
        assert sum(snap[k]["count"] for k in keys) > 0
        # ratios live in a sane range
        if prefix.endswith("overlap_ratio"):
            assert all(0.0 <= snap[k]["p95"] <= 1.0 for k in keys)


def test_stats_histograms_single_source(traced_run):
    """EngineStats percentiles ARE the histogram percentiles — the same
    numbers numpy computes on the retained raw samples."""
    on, _ = traced_run
    s = on.stats
    assert isinstance(s.e2e_latencies, Histogram)
    pct = s.percentiles()
    assert pct["e2e_p50"] == pytest.approx(
        float(np.percentile(list(s.e2e_latencies), 50)), rel=1e-12)
    assert pct["ttft_p95"] == pytest.approx(
        float(np.percentile(list(s.ttft_latencies), 95)), rel=1e-12)


def test_perfetto_export_schema(traced_run, tmp_path):
    on, _ = traced_run
    path = tmp_path / "trace.json"
    on.export_trace(str(path))
    doc = json.loads(path.read_text())
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    evs = doc["traceEvents"]
    assert {e["ph"] for e in evs} <= {"X", "i", "C", "M"}
    meta = {e["args"]["name"] for e in evs if e["ph"] == "M"
            and e["name"] == "process_name"}
    assert {"engine", "requests", "events"} <= meta
    for e in evs:
        if e["ph"] == "X":
            assert e["dur"] >= 0 and e["ts"] >= 0
        if e["ph"] == "i":
            assert e["s"] == "t"
    # the acceptance trace covers >=1 preempted and >=1 promoted request
    assert any(e["ph"] == "i" and e["name"] == "preempt" for e in evs)
    assert any(e["ph"] == "i" and e["name"] == "promote" for e in evs)


# ---------------------------------------------------------------------------
# Chaos determinism: same seed -> identical event stream
# ---------------------------------------------------------------------------

def test_chaos_replay_identical_event_stream(tiny_cfg, tiny_params):
    plan = FaultPlan(seed=3, rates={"pool_alloc": 0.25, "step_nan": 0.1,
                                    "host_store": 0.5})

    def chaos_run():
        eng = ServingEngine(
            tiny_cfg, tiny_params, max_batch=2, canvas_len=CANVAS,
            strategy=_strat(), pool_pages=13, page_size=PAGE,
            prefix_cache=True, host_pages=8, host_dtype="f32",
            fault_plan=plan, supervise=True,
            telemetry=Telemetry.enabled(dynamics_every=0),
            clock=_counter_clock())
        rng = np.random.default_rng(11)
        for _ in range(4):
            eng.submit(rng.integers(0, tiny_cfg.vocab_size - 1, 8)
                       .astype(np.int32), gen_len=8)
        eng.run()
        return eng

    a, b = chaos_run(), chaos_run()
    assert a.faults.total_fired > 0, "the storm never hit"
    assert a.faults.log == b.faults.log          # replay fingerprint
    assert a.telemetry.tracer.event_stream() \
        == b.telemetry.tracer.event_stream()
    # fault instants mirror the injector log line-for-line, same schema
    fired = [(e.args["site"], e.args["probe"])
             for e in a.telemetry.tracer.events
             if e.ph == "i" and e.name.startswith("fault:")]
    assert fired == a.faults.log


# ---------------------------------------------------------------------------
# Live /metrics + /debug/requests during a streaming run
# ---------------------------------------------------------------------------

def test_live_metrics_and_debug_endpoints(tiny_cfg, tiny_params):
    from repro.serving.frontend import (AsyncFrontend, fetch_debug_requests,
                                        fetch_metrics, stream_request)
    eng = ServingEngine(tiny_cfg, tiny_params, max_batch=2,
                        canvas_len=CANVAS, strategy=_strat(),
                        pool_pages=13, page_size=PAGE, prefix_cache=True,
                        telemetry=Telemetry.enabled(dynamics_every=1))
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, tiny_cfg.vocab_size - 1, 6).astype(np.int32)

    async def main():
        front = AsyncFrontend(eng, max_steps=2048)
        await front.start(serve_http=True)
        try:
            mid_text, mid_dbg = None, None
            async for ev in stream_request(front.host, front.port,
                                           prompt, 6):
                if ev["kind"] == "token" and mid_text is None:
                    # scrape WHILE the request is streaming
                    mid_text = await fetch_metrics(front.host, front.port)
                    mid_dbg = await fetch_debug_requests(front.host,
                                                         front.port)
            end_text = await fetch_metrics(front.host, front.port)
        finally:
            await front.stop()
        return mid_text, mid_dbg, end_text

    mid_text, mid_dbg, end_text = asyncio.run(main())
    for text in (mid_text, end_text):
        _assert_prometheus_text(text)
        assert "spa_engine_steps_total" in text
        assert 'spa_engine_ttft_seconds_bucket{le="+Inf"}' in text
    assert set(mid_dbg) == {"queued", "running", "done"}
    live = mid_dbg["running"] + mid_dbg["done"]
    assert any(r["uid"] == eng.done[0].uid for r in live) or live
    m = re.search(r"spa_engine_requests_done_total (\d+)", end_text)
    assert m and int(m.group(1)) == 1

"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.proxy_score import (cosine_drift, gather_norm,
                                       proxy_score)
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.scatter_update import scatter_update, scatter_update_multi
from repro.kernels.sparse_attention import sparse_attention


@pytest.mark.parametrize("n,d,r", [(64, 32, 8), (200, 96, 32),
                                   (33, 128, 16), (8, 64, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_proxy_score(n, d, r, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(ks[0], (n, d), dtype)
    w = jax.random.normal(ks[1], (d, r), dtype)
    pc = jax.random.normal(ks[2], (n, r), dtype)
    s, p = proxy_score(x, w, pc, interpret=True)
    s_r, p_r = ref.proxy_score_ref(x, w, pc)
    tol = 1e-4 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(s, s_r, rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(p, np.float32),
                               np.asarray(p_r, np.float32),
                               rtol=tol * 10, atol=tol * 10)


@pytest.mark.parametrize("kq,n,h,kvh,hd", [
    (16, 64, 4, 4, 16),      # MHA
    (50, 300, 4, 2, 32),     # GQA, ragged
    (8, 128, 8, 1, 16),      # MQA
])
def test_sparse_attention_shapes(kq, n, h, kvh, hd):
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(ks[0], (kq, h, hd))
    k = jax.random.normal(ks[1], (n, kvh, hd))
    v = jax.random.normal(ks[2], (n, kvh, hd))
    qp = jnp.sort(jax.random.randint(ks[3], (kq,), 0, n))
    out = sparse_attention(q, k, v, qp, interpret=True, block_q=16,
                           block_k=32)
    out_ref = ref.sparse_attention_ref(q, k, v, qp)
    np.testing.assert_allclose(out, out_ref, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("window,soft_cap", [(0, 0.0), (32, 0.0),
                                             (16, 30.0), (0, 50.0)])
def test_sparse_attention_features(window, soft_cap):
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    q = jax.random.normal(ks[0], (24, 4, 16))
    k = jax.random.normal(ks[1], (160, 2, 16))
    v = jax.random.normal(ks[2], (160, 2, 16))
    qp = jnp.sort(jax.random.randint(ks[3], (24,), 0, 160))
    out = sparse_attention(q, k, v, qp, window=window,
                           soft_cap=soft_cap, interpret=True,
                           block_q=8, block_k=32)
    out_ref = ref.sparse_attention_ref(q, k, v, qp, window=window,
                                       soft_cap=soft_cap)
    np.testing.assert_allclose(out, out_ref, rtol=2e-3, atol=2e-3)


def test_sparse_attention_int8():
    from repro.core.cache import quantize_rows
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    q = jax.random.normal(ks[0], (16, 2, 16))
    k = jax.random.normal(ks[1], (96, 2, 16))
    v = jax.random.normal(ks[2], (96, 2, 16))
    qp = jnp.sort(jax.random.randint(ks[3], (16,), 0, 96))
    kq, kscale = quantize_rows(k)
    vq, vscale = quantize_rows(v)
    out = sparse_attention(kq * 0 + q if False else q, kq, vq, qp,
                           k_scale=kscale, v_scale=vscale,
                           interpret=True, block_q=8, block_k=32)
    out_ref = ref.sparse_attention_ref(q, kq, vq, qp, k_scale=kscale,
                                       v_scale=vscale)
    np.testing.assert_allclose(out, out_ref, rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("n,d,k", [(64, 16, 8), (128, 48, 40),
                                   (32, 8, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
def test_scatter_update(n, d, k, dtype):
    rng = np.random.default_rng(0)
    if dtype == jnp.int8:
        cache = jnp.asarray(rng.integers(-100, 100, (n, d)), jnp.int8)
        rows = jnp.asarray(rng.integers(-100, 100, (k, d)), jnp.int8)
    else:
        cache = jax.random.normal(jax.random.PRNGKey(0), (n, d), dtype)
        rows = jax.random.normal(jax.random.PRNGKey(1), (k, d), dtype)
    idx = jnp.asarray(rng.choice(n, k, replace=False), jnp.int32)
    out = scatter_update(cache, idx, rows, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ref.scatter_update_ref(
            cache, idx, rows)))


def test_proxy_score_batched_grid():
    """The batch dim is a real grid axis: per-row results match the
    unbatched oracle for every batch row."""
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    x = jax.random.normal(ks[0], (3, 65, 96), jnp.bfloat16)
    w = jax.random.normal(ks[1], (96, 32), jnp.bfloat16)
    pc = jax.random.normal(ks[2], (3, 65, 32), jnp.bfloat16)
    s, p = proxy_score(x, w, pc, interpret=True, block_n=16)
    assert s.shape == (3, 65) and p.shape == (3, 65, 32)
    for i in range(3):
        s_r, p_r = ref.proxy_score_ref(x[i], w, pc[i])
        np.testing.assert_allclose(s[i], s_r, rtol=4e-2, atol=4e-2)
        np.testing.assert_array_equal(np.asarray(p[i], np.float32),
                                      np.asarray(p_r, np.float32))


def test_cosine_drift_matches_cosine_similarity():
    """Score-only kernel (attn_in / incremental rescore) is bitwise the
    jitted cosine_similarity."""
    from repro.core.svd_proxy import cosine_similarity
    ks = jax.random.split(jax.random.PRNGKey(6), 2)
    x = jax.random.normal(ks[0], (2, 100, 48))
    pc = jax.random.normal(ks[1], (2, 100, 48))
    out = cosine_drift(x, pc, interpret=True, block_n=32)
    expect = jax.jit(cosine_similarity)(x, pc)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gather_norm_fused_epilogue(dtype):
    """One pass emits raw gathered rows AND rms-normed rows, bitwise
    equal to gather_rows + rms_norm (incl. clip-mode OOB clamping)."""
    from repro.core import selection
    from repro.models import common
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    h = jax.random.normal(ks[0], (2, 40, 64), dtype)
    wt = jax.random.normal(ks[1], (64,), dtype)
    idx = jnp.sort(jax.random.randint(ks[2], (2, 7), 0, 45))  # OOB clamps
    rows, normed = gather_norm(h, idx, wt, 1e-6, interpret=True,
                               block_g=4)
    rows_x = selection.gather_rows(h, idx)
    normed_x = common.rms_norm(rows_x, wt, 1e-6)
    np.testing.assert_array_equal(np.asarray(rows, np.float32),
                                  np.asarray(rows_x, np.float32))
    np.testing.assert_array_equal(np.asarray(normed, np.float32),
                                  np.asarray(normed_x, np.float32))


def test_sparse_attention_batched_grid():
    ks = jax.random.split(jax.random.PRNGKey(8), 4)
    q = jax.random.normal(ks[0], (2, 24, 4, 16))
    k = jax.random.normal(ks[1], (2, 160, 2, 16))
    v = jax.random.normal(ks[2], (2, 160, 2, 16))
    qp = jnp.sort(jax.random.randint(ks[3], (2, 24), 0, 160))
    out = sparse_attention(q, k, v, qp, window=32, interpret=True,
                           block_q=8, block_k=32)
    for i in range(2):
        out_ref = ref.sparse_attention_ref(q[i], k[i], v[i], qp[i],
                                           window=32)
        np.testing.assert_allclose(out[i], out_ref, rtol=2e-3, atol=2e-3)


def test_sparse_attention_banded_matches_flash():
    """Banded path (scalar-prefetched kv starts) visits the same kv
    blocks as the XLA banded flash path at matched blocks (agreement to
    ulp-level XLA-fusion noise), and matches the dense oracle."""
    from repro.core import selection
    from repro.core.spa_layer import q_span_bound
    from repro.models.attention import flash_attention
    ks = jax.random.split(jax.random.PRNGKey(9), 4)
    n, kq, nb, window, bq, bk = 2048, 128, 8, 64, 32, 64
    q = jax.random.normal(ks[0], (1, kq, 2, 16))
    k = jax.random.normal(ks[1], (1, n, 2, 16))
    v = jax.random.normal(ks[2], (1, n, 2, 16))
    # REAL stratified selection: per-block top-(k/nb) guarantees the
    # q_span bound the banded path relies on (DESIGN.md §4)
    qp = selection.select_stratified(jax.random.uniform(ks[3], (1, n)),
                                     kq, nb)
    span = q_span_bound(n, kq, nb, block_q=bq)
    assert n > span + 2 * window + 2 * bk
    out = sparse_attention(q, k, v, qp, window=window, banded=True,
                           q_span=span, block_q=bq, block_k=bk,
                           interpret=True)
    out_flash = jax.jit(lambda *a: flash_attention(
        a[0], a[1], a[2], q_positions=a[3], window=window, banded=True,
        q_span=span, block_q=bq, block_k=bk))(q, k, v, qp)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_flash),
                               rtol=1e-6, atol=1e-6)
    out_ref = ref.sparse_attention_ref(q[0], k[0], v[0], qp[0],
                                       window=window)
    np.testing.assert_allclose(out[0], out_ref, rtol=2e-3, atol=2e-3)


def test_banded_partial_q_block_matches_oracle():
    """Regression: a partially-padded final q block (sq not a multiple of
    block_q) must keep its kv band anchored at its REAL positions — pad
    sentinels used to pull ``banded_starts``'s min to 0, masking the real
    rows' windows entirely (zero output). Both paths share the helper."""
    from repro.models.attention import flash_attention, reference_attention
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    sq, n, window, bq, bk = 33, 512, 24, 32, 32
    q = jax.random.normal(ks[0], (1, sq, 4, 16))
    k = jax.random.normal(ks[1], (1, n, 2, 16))
    v = jax.random.normal(ks[2], (1, n, 2, 16))
    out_ref = reference_attention(q, k, v, window=window)
    out_flash = flash_attention(q, k, v, window=window, banded=True,
                                block_q=bq, block_k=bk)
    qp = jnp.broadcast_to(jnp.arange(sq)[None], (1, sq))
    out_pallas = sparse_attention(q, k, v, qp, window=window, banded=True,
                                  q_span=bq, block_q=bq, block_k=bk,
                                  interpret=True)
    np.testing.assert_allclose(out_flash, out_ref, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(out_pallas, out_ref, rtol=2e-5, atol=2e-5)


def test_scatter_update_multi_buffers():
    """K/V/H/proxy-style multi-buffer commit in one aliased call: mixed
    dtypes/widths, sorted contiguous runs, and sentinel (>= N) drops."""
    rng = np.random.default_rng(3)
    ks = jax.random.split(jax.random.PRNGKey(10), 3)
    b, n, kk = 2, 64, 16
    c_f = jax.random.normal(ks[0], (b, n, 2, 8), jnp.bfloat16)
    c_i = jnp.asarray(rng.integers(-100, 100, (b, n, 12)), jnp.int8)
    c_s = jax.random.normal(ks[1], (b, n), jnp.float16)
    # sorted with a contiguous run (batched-DMA path) + sentinel pads
    idx = jnp.asarray(np.sort(np.stack([
        np.r_[rng.choice(40, 10, replace=False), 50, 51, 52, 53, n, n],
        np.r_[rng.choice(n, 14, replace=False), n, n]]), axis=-1),
        jnp.int32)
    r_f = jax.random.normal(ks[2], (b, kk, 2, 8), jnp.float32)
    r_i = jnp.asarray(rng.integers(-100, 100, (b, kk, 12)), jnp.int8)
    r_s = jax.random.normal(ks[0], (b, kk), jnp.float32)
    outs = scatter_update_multi([c_f, c_i, c_s], idx, [r_f, r_i, r_s],
                                interpret=True, block_k=8)
    for c, r, o in zip([c_f, c_i, c_s], [r_f, r_i, r_s], outs):
        expect = jax.vmap(lambda ci, ii, ri: ci.at[ii].set(
            ri.astype(ci.dtype), mode="drop"))(c, idx, r)
        assert o.dtype == c.dtype and o.shape == c.shape
        np.testing.assert_array_equal(np.asarray(o, np.float32),
                                      np.asarray(expect, np.float32))


def test_scatter_update_unsorted_endpoint_collision():
    """Regression: an unsorted run-sized chunk whose endpoints differ by
    exactly run-1 (e.g. [5,20,7,9,2,3,4,12]) must NOT take the batched
    contiguous-DMA store — every element has to sit at first + t."""
    cache = jnp.zeros((1, 32, 8))
    idx = jnp.asarray([[5, 20, 7, 9, 2, 3, 4, 12]], jnp.int32)
    rows = jax.random.normal(jax.random.PRNGKey(12), (1, 8, 8))
    (out,) = scatter_update_multi([cache], idx, [rows], interpret=True)
    expect = ref.scatter_update_ref(cache[0], idx[0], rows[0])
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(expect))


def test_scatter_update_donation_contract():
    """ops.scatter_update must NOT donate (callers re-read the cache);
    the donating form deletes its input — reading it afterwards raises."""
    cache = jnp.zeros((32, 8))
    idx = jnp.arange(4, dtype=jnp.int32)
    rows = jnp.ones((4, 8))
    out = ops.scatter_update(cache, idx, rows)
    # non-donating: the input stays readable and unchanged
    np.testing.assert_array_equal(np.asarray(cache), 0.0)
    np.testing.assert_array_equal(np.asarray(out[:4]), 1.0)
    donated = jnp.zeros((32, 8))
    out2 = ops.scatter_update_donated(donated, idx, rows)
    np.testing.assert_array_equal(np.asarray(out2[:4]), 1.0)
    assert donated.is_deleted()
    with pytest.raises(RuntimeError, match="deleted"):
        _ = donated + 1


@pytest.mark.parametrize("n,d", [(64, 32), (300, 64), (128, 8)])
def test_rglru_scan(n, d):
    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (n, d)))
    b = jax.random.normal(ks[1], (n, d)) * 0.1
    out = rglru_scan(a, b, interpret=True, chunk=32, block_d=32)
    out_ref = ref.rglru_scan_ref(a, b)
    np.testing.assert_allclose(out, out_ref, rtol=1e-4, atol=1e-4)

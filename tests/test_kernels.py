"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.proxy_score import proxy_score
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.scatter_update import scatter_update
from repro.kernels.sparse_attention import sparse_attention


@pytest.mark.parametrize("n,d,r", [(64, 32, 8), (200, 96, 32),
                                   (33, 128, 16), (8, 64, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_proxy_score(n, d, r, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(ks[0], (n, d), dtype)
    w = jax.random.normal(ks[1], (d, r), dtype)
    pc = jax.random.normal(ks[2], (n, r), dtype)
    s, p = proxy_score(x, w, pc, interpret=True)
    s_r, p_r = ref.proxy_score_ref(x, w, pc)
    tol = 1e-4 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(s, s_r, rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(p, np.float32),
                               np.asarray(p_r, np.float32),
                               rtol=tol * 10, atol=tol * 10)


@pytest.mark.parametrize("kq,n,h,kvh,hd", [
    (16, 64, 4, 4, 16),      # MHA
    (50, 300, 4, 2, 32),     # GQA, ragged
    (8, 128, 8, 1, 16),      # MQA
])
def test_sparse_attention_shapes(kq, n, h, kvh, hd):
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(ks[0], (kq, h, hd))
    k = jax.random.normal(ks[1], (n, kvh, hd))
    v = jax.random.normal(ks[2], (n, kvh, hd))
    qp = jnp.sort(jax.random.randint(ks[3], (kq,), 0, n))
    out = sparse_attention(q, k, v, qp, interpret=True, block_q=16,
                           block_k=32)
    out_ref = ref.sparse_attention_ref(q, k, v, qp)
    np.testing.assert_allclose(out, out_ref, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("window,soft_cap", [(0, 0.0), (32, 0.0),
                                             (16, 30.0), (0, 50.0)])
def test_sparse_attention_features(window, soft_cap):
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    q = jax.random.normal(ks[0], (24, 4, 16))
    k = jax.random.normal(ks[1], (160, 2, 16))
    v = jax.random.normal(ks[2], (160, 2, 16))
    qp = jnp.sort(jax.random.randint(ks[3], (24,), 0, 160))
    out = sparse_attention(q, k, v, qp, window=window,
                           soft_cap=soft_cap, interpret=True,
                           block_q=8, block_k=32)
    out_ref = ref.sparse_attention_ref(q, k, v, qp, window=window,
                                       soft_cap=soft_cap)
    np.testing.assert_allclose(out, out_ref, rtol=2e-3, atol=2e-3)


def test_sparse_attention_int8():
    from repro.core.cache import quantize_rows
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    q = jax.random.normal(ks[0], (16, 2, 16))
    k = jax.random.normal(ks[1], (96, 2, 16))
    v = jax.random.normal(ks[2], (96, 2, 16))
    qp = jnp.sort(jax.random.randint(ks[3], (16,), 0, 96))
    kq, kscale = quantize_rows(k)
    vq, vscale = quantize_rows(v)
    out = sparse_attention(kq * 0 + q if False else q, kq, vq, qp,
                           k_scale=kscale, v_scale=vscale,
                           interpret=True, block_q=8, block_k=32)
    out_ref = ref.sparse_attention_ref(q, kq, vq, qp, k_scale=kscale,
                                       v_scale=vscale)
    np.testing.assert_allclose(out, out_ref, rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("n,d,k", [(64, 16, 8), (128, 48, 40),
                                   (32, 8, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
def test_scatter_update(n, d, k, dtype):
    rng = np.random.default_rng(0)
    if dtype == jnp.int8:
        cache = jnp.asarray(rng.integers(-100, 100, (n, d)), jnp.int8)
        rows = jnp.asarray(rng.integers(-100, 100, (k, d)), jnp.int8)
    else:
        cache = jax.random.normal(jax.random.PRNGKey(0), (n, d), dtype)
        rows = jax.random.normal(jax.random.PRNGKey(1), (k, d), dtype)
    idx = jnp.asarray(rng.choice(n, k, replace=False), jnp.int32)
    out = scatter_update(cache, idx, rows, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ref.scatter_update_ref(
            cache, idx, rows)))


@pytest.mark.parametrize("n,d", [(64, 32), (300, 64), (128, 8)])
def test_rglru_scan(n, d):
    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (n, d)))
    b = jax.random.normal(ks[1], (n, d)) * 0.1
    out = rglru_scan(a, b, interpret=True, chunk=32, block_d=32)
    out_ref = ref.rglru_scan_ref(a, b)
    np.testing.assert_allclose(out, out_ref, rtol=1e-4, atol=1e-4)

"""DLM decoding loop: commits, parallel decoding, baselines, refresh."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.configs.base import SPAConfig
from repro.dlm import decoding, noise
from repro.models import transformer


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_arch("internlm2-1.8b"))
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(cfg, key)
    prompt = jax.random.randint(key, (2, 12), 0, cfg.vocab_size - 1)
    return cfg, params, prompt


def test_mask_canvas():
    prompt = jnp.asarray([[1, 2, 3]])
    canvas = noise.mask_canvas(prompt, 4, mask_id=99)
    assert canvas.shape == (1, 7)
    assert (np.asarray(canvas[0, 3:]) == 99).all()


def test_sample_masking_rate():
    key = jax.random.PRNGKey(0)
    tokens = jnp.zeros((64, 128), jnp.int32)
    noisy, mask, t = noise.sample_masking(key, tokens, mask_id=7)
    rate = np.asarray(mask).mean(axis=1)
    np.testing.assert_allclose(rate, np.asarray(t), atol=0.15)
    assert (np.asarray(noisy)[np.asarray(mask)] == 7).all()


def test_decode_commits_every_slot(setup):
    cfg, params, prompt = setup
    toks, info = decoding.decode(params, cfg, prompt, gen_len=10)
    assert int((toks == cfg.mask_id).sum()) == 0
    assert info["steps"] <= 14
    # prompt untouched
    np.testing.assert_array_equal(np.asarray(toks[:, :12]),
                                  np.asarray(prompt))


def test_parallel_decoding_fewer_steps(setup):
    cfg, params, prompt = setup
    s_seq = decoding.DecodeSettings(parallel_threshold=0.0)
    s_par = decoding.DecodeSettings(parallel_threshold=0.05,
                                    max_parallel=4)
    _, info_seq = decoding.decode(params, cfg, prompt, gen_len=12,
                                  settings=s_seq)
    _, info_par = decoding.decode(params, cfg, prompt, gen_len=12,
                                  settings=s_par)
    assert info_par["steps"] <= info_seq["steps"]


def test_vanilla_no_cache(setup):
    cfg, params, prompt = setup
    cfg_v = dataclasses.replace(cfg, spa=SPAConfig(identifier="none"))
    toks, info = decoding.decode(params, cfg_v, prompt, gen_len=6)
    assert int((toks == cfg.mask_id).sum()) == 0


def test_window_identifier_baseline(setup):
    """dKV-Cache-style locality heuristic decodes successfully."""
    cfg, params, prompt = setup
    cfg_w = dataclasses.replace(cfg, spa=SPAConfig(
        identifier="window", locality_window=8, rho_peak=0.3))
    toks, info = decoding.decode(params, cfg_w, prompt, gen_len=6)
    assert int((toks == cfg.mask_id).sum()) == 0


def test_refresh_interval(setup):
    cfg, params, prompt = setup
    cfg_r = dataclasses.replace(cfg, spa=dataclasses.replace(
        cfg.spa, refresh_interval=2))
    toks, info = decoding.decode(params, cfg_r, prompt, gen_len=5)
    assert int((toks == cfg.mask_id).sum()) == 0


def test_spa_matches_vanilla_greedy_mostly(setup):
    """SPA decoding with a generous budget should commit nearly the same
    tokens as vanilla decoding (quality-preservation claim, Table 2)."""
    cfg, params, prompt = setup
    cfg_full = dataclasses.replace(cfg, spa=SPAConfig(
        identifier="singular", rank=16, schedule="uniform",
        rho_peak=1.0))
    cfg_v = dataclasses.replace(cfg, spa=SPAConfig(identifier="none"))
    t1, _ = decoding.decode(params, cfg_full, prompt, gen_len=8)
    t2, _ = decoding.decode(params, cfg_v, prompt, gen_len=8)
    agree = (np.asarray(t1) == np.asarray(t2)).mean()
    assert agree > 0.95  # rho=1 cache == exact recompute


def test_semi_ar_block_decoding(setup):
    """Fast-dLLM-style block decoding commits every slot left-to-right."""
    cfg, params, prompt = setup
    toks, info = decoding.decode_semi_ar(params, cfg, prompt, gen_len=8,
                                         block_len=4)
    assert toks.shape == (2, 20)
    assert int((toks == cfg.mask_id).sum()) == 0
    np.testing.assert_array_equal(np.asarray(toks[:, :12]),
                                  np.asarray(prompt))

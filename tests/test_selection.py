"""Top-k selection + batched gather/scatter invariants (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import selection


@given(st.integers(1, 3), st.integers(4, 64), st.integers(1, 16),
       st.integers(0, 5))
@settings(max_examples=25, deadline=None)
def test_topk_selects_lowest(b, n, k, seed):
    k = min(k, n)
    rng = np.random.default_rng(seed)
    scores = jnp.asarray(rng.standard_normal((b, n)).astype(np.float32))
    idx = selection.select_topk_drift(scores, k)
    assert idx.shape == (b, k)
    # scores are quantized for tie stability; verify the selection
    # property on the quantized values: every selected row's score <=
    # every unselected row's score (ties allowed)
    q = np.round(np.asarray(scores) * 4096.0)
    for bi in range(b):
        chosen = np.asarray(idx[bi])
        assert len(set(chosen.tolist())) == k
        unchosen = np.setdiff1d(np.arange(n), chosen)
        if len(unchosen):
            assert q[bi][chosen].max() <= q[bi][unchosen].min()
        assert list(chosen) == sorted(chosen.tolist())


@given(st.integers(1, 2), st.integers(8, 64), st.integers(1, 12),
       st.integers(0, 3))
@settings(max_examples=25, deadline=None)
def test_scatter_gather_roundtrip(b, n, k, seed):
    k = min(k, n)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((b, n, 5)).astype(np.float32))
    idx = jnp.asarray(
        np.stack([rng.choice(n, k, replace=False) for _ in range(b)])
    ).astype(jnp.int32)
    rows = jnp.asarray(rng.standard_normal((b, k, 5)).astype(np.float32))
    out = selection.scatter_rows(x, idx, rows)
    back = selection.gather_rows(out, idx)
    np.testing.assert_allclose(back, rows, atol=1e-6)
    # untouched rows unchanged
    mask = np.asarray(selection.scatter_mask(idx, n))
    np.testing.assert_allclose(np.asarray(out)[~mask],
                               np.asarray(x)[~mask])


def test_stratified_selection_banded():
    """Stratified selection guarantees every block contributes, bounding
    any contiguous run's position span (enables banded attention)."""
    rng = np.random.default_rng(0)
    scores = jnp.asarray(rng.standard_normal((2, 64)).astype(np.float32))
    idx = selection.select_stratified(scores, k=16, n_blocks=8)
    idx_np = np.asarray(idx)
    for bi in range(2):
        per_block = np.bincount(idx_np[bi] // 8, minlength=8)
        assert (per_block == 2).all()      # 16/8 = 2 from each block
        assert (np.diff(idx_np[bi]) >= 0).all()


def test_stratified_equals_topk_when_one_block():
    rng = np.random.default_rng(1)
    scores = jnp.asarray(rng.standard_normal((1, 32)).astype(np.float32))
    a = selection.select_stratified(scores, 8, 1)
    b = selection.select_topk_drift(scores, 8)
    assert set(np.asarray(a)[0].tolist()) == set(np.asarray(b)[0].tolist())

"""Sequence-mixer correctness: SSD chunked vs sequential, RG-LRU scans,
MoE dispatch invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.configs.base import MoEConfig
from repro.models import moe, rglru, ssd


def test_ssd_chunked_matches_sequential():
    b, t, h, hd, ds = 2, 64, 2, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (b, t, h, hd))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.2)
    bm = jax.random.normal(ks[3], (b, t, ds))
    cm = jax.random.normal(ks[0], (b, t, ds))
    for chunk in (8, 16, 64):
        y = ssd.ssd_scan(x, dt, a, bm, cm, chunk)
        y_ref = ssd.ssd_scan_ref(x, dt, a, bm, cm)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-4)


def test_ssd_block_shapes():
    cfg = reduced(get_arch("mamba2-370m"))
    params = ssd.init_ssd_params(jax.random.PRNGKey(0), cfg,
                                 jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y = ssd.apply_ssd(params, x, cfg)
    assert y.shape == x.shape
    assert not bool(jnp.isnan(y).any())


def test_rglru_linear_recurrence():
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (1, 40, 8)))
    b = jax.random.normal(ks[1], (1, 40, 8))
    h = rglru.linear_recurrence(a, b)
    # sequential check
    hs = np.zeros(8)
    for t in range(40):
        hs = np.asarray(a[0, t]) * hs + np.asarray(b[0, t])
        np.testing.assert_allclose(np.asarray(h[0, t]), hs, rtol=1e-5,
                                   atol=1e-5)


def test_rglru_block():
    cfg = reduced(get_arch("recurrentgemma-9b"))
    params = rglru.init_rglru_params(jax.random.PRNGKey(0), cfg,
                                     jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, cfg.d_model))
    y = rglru.apply_rglru(params, x, cfg)
    assert y.shape == x.shape
    assert not bool(jnp.isnan(y).any())
    # bidirectional differs from causal
    y_causal = rglru.apply_rglru(params, x, cfg, bidirectional=False)
    assert float(jnp.abs(y - y_causal).max()) > 0


def test_moe_conservation_and_capacity():
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=16,
                    capacity_factor=10.0)  # ample capacity
    params = moe.init_moe_params(jax.random.PRNGKey(0), 8, cfg, "silu",
                                 jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 8))
    out, aux = moe.apply_moe(params, x, cfg, "silu")
    assert out.shape == x.shape
    assert np.isfinite(float(aux)) and float(aux) >= 1.0  # >= E * 1/E^2 * E

    # with ample capacity every token routed: output equals manual dense
    # mixture of its top-2 experts
    logits = x @ params["router"]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gv, gi = jax.lax.top_k(probs, 2)
    gv = gv / gv.sum(-1, keepdims=True)

    def expert(e, xx):
        gate = jax.nn.silu(xx @ params["w_gate"][e])
        return (gate * (xx @ params["w_up"][e])) @ params["w_down"][e]

    manual = np.zeros_like(np.asarray(out))
    for b in range(2):
        for t in range(16):
            acc = 0
            for j in range(2):
                acc = acc + float(gv[b, t, j]) * np.asarray(
                    expert(int(gi[b, t, j]), x[b, t]))
            manual[b, t] = acc
    np.testing.assert_allclose(np.asarray(out), manual, rtol=2e-3,
                               atol=2e-3)


def test_moe_capacity_drops_overflow():
    cfg = MoEConfig(n_experts=2, top_k=1, d_ff_expert=8,
                    capacity_factor=0.25)  # tiny capacity
    params = moe.init_moe_params(jax.random.PRNGKey(0), 8, cfg, "silu",
                                 jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 8))
    out, _ = moe.apply_moe(params, x, cfg, "silu")
    # overflowed tokens produce zero output rows
    row_norms = np.linalg.norm(np.asarray(out[0]), axis=-1)
    assert (row_norms < 1e-6).sum() > 0

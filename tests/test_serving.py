"""Serving engine behaviour + incremental-identifier equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.configs.base import SPAConfig
from repro.dlm.decoding import DecodeSettings, decode
from repro.models import transformer
from repro.serving.engine import ServingEngine


@pytest.fixture(scope="module")
def small():
    cfg = reduced(get_arch("internlm2-1.8b"))
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_serves_queue(small):
    cfg, params = small
    engine = ServingEngine(cfg, params, max_batch=2, canvas_len=24)
    rng = np.random.default_rng(0)
    uids = [engine.submit(rng.integers(0, cfg.vocab_size - 1, 8)
                          .astype(np.int32), gen_len=6)
            for _ in range(5)]
    stats = engine.run()
    assert stats.requests_done == 5
    assert len(engine.done) == 5
    for req in engine.done:
        assert req.output is not None and len(req.output) == 6
        assert (req.output != cfg.mask_id).all()


def test_engine_vanilla_mode(small):
    cfg, params = small
    cfg_v = dataclasses.replace(cfg, spa=SPAConfig(identifier="none"))
    engine = ServingEngine(cfg_v, params, max_batch=2, canvas_len=24)
    engine.submit(np.arange(6, dtype=np.int32), gen_len=4)
    stats = engine.run()
    assert stats.requests_done == 1


def test_incremental_identifier_matches_full(small):
    """Beyond-paper incremental identification must commit the SAME
    tokens as full identification: the proxy_now invariant guarantees
    identical drift scores."""
    cfg0, params = small
    prompt = jax.random.randint(jax.random.PRNGKey(3), (2, 10), 0,
                                cfg0.vocab_size - 1)
    outs = {}
    for inc in (False, True):
        cfg = dataclasses.replace(cfg0, spa=SPAConfig(
            identifier="singular", rank=16, schedule="uniform",
            rho_peak=0.3, incremental_ident=inc))
        toks, _ = decode(params, cfg, prompt, gen_len=8)
        outs[inc] = np.asarray(toks)
    np.testing.assert_array_equal(outs[False], outs[True])


def test_incremental_with_adaptive_schedule(small):
    cfg0, params = small
    prompt = jax.random.randint(jax.random.PRNGKey(4), (2, 10), 0,
                                cfg0.vocab_size - 1)
    cfg = dataclasses.replace(cfg0, spa=SPAConfig(
        identifier="singular", rank=16, schedule="adaptive",
        rho_peak=0.4, rho_first=0.1, rho_last=0.2,
        incremental_ident=True))
    toks, info = decode(params, cfg, prompt, gen_len=6)
    assert int((toks == cfg.mask_id).sum()) == 0

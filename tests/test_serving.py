"""Serving engine behaviour + incremental-identifier equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.configs.base import SPAConfig
from repro.dlm.decoding import DecodeSettings, decode
from repro.models import transformer
from repro.serving.engine import ServingEngine


@pytest.fixture(scope="module")
def small():
    cfg = reduced(get_arch("internlm2-1.8b"))
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_serves_queue(small):
    cfg, params = small
    engine = ServingEngine(cfg, params, max_batch=2, canvas_len=24)
    rng = np.random.default_rng(0)
    uids = [engine.submit(rng.integers(0, cfg.vocab_size - 1, 8)
                          .astype(np.int32), gen_len=6)
            for _ in range(5)]
    stats = engine.run()
    assert stats.requests_done == 5
    assert len(engine.done) == 5
    for req in engine.done:
        assert req.output is not None and len(req.output) == 6
        assert (req.output != cfg.mask_id).all()


def test_engine_vanilla_mode(small):
    cfg, params = small
    cfg_v = dataclasses.replace(cfg, spa=SPAConfig(identifier="none"))
    engine = ServingEngine(cfg_v, params, max_batch=2, canvas_len=24)
    engine.submit(np.arange(6, dtype=np.int32), gen_len=4)
    stats = engine.run()
    assert stats.requests_done == 1


def test_incremental_identifier_matches_full(small):
    """Beyond-paper incremental identification must commit the SAME
    tokens as full identification: the proxy_now invariant guarantees
    identical drift scores."""
    cfg0, params = small
    prompt = jax.random.randint(jax.random.PRNGKey(3), (2, 10), 0,
                                cfg0.vocab_size - 1)
    outs = {}
    for inc in (False, True):
        cfg = dataclasses.replace(cfg0, spa=SPAConfig(
            identifier="singular", rank=16, schedule="uniform",
            rho_peak=0.3, incremental_ident=inc))
        toks, _ = decode(params, cfg, prompt, gen_len=8)
        outs[inc] = np.asarray(toks)
    np.testing.assert_array_equal(outs[False], outs[True])


def test_incremental_with_adaptive_schedule(small):
    cfg0, params = small
    prompt = jax.random.randint(jax.random.PRNGKey(4), (2, 10), 0,
                                cfg0.vocab_size - 1)
    cfg = dataclasses.replace(cfg0, spa=SPAConfig(
        identifier="singular", rank=16, schedule="adaptive",
        rho_peak=0.4, rho_first=0.1, rho_last=0.2,
        incremental_ident=True))
    toks, info = decode(params, cfg, prompt, gen_len=6)
    assert int((toks == cfg.mask_id).sum()) == 0


# ---------------------------------------------------------------------------
# Engine bookkeeping
# ---------------------------------------------------------------------------

def test_uid_monotonic_with_inflight_requests(small):
    """Regression: uids used to derive from len(done)+len(queue), so a
    request popped from the queue but not yet done (in-flight) made the
    next submit REUSE a live uid.  The counter must be monotonic."""
    cfg, params = small
    engine = ServingEngine(cfg, params, max_batch=2, canvas_len=24)
    u0 = engine.submit(np.arange(6, dtype=np.int32), gen_len=4)
    u1 = engine.submit(np.arange(6, dtype=np.int32), gen_len=4)
    inflight = engine.queue.popleft()      # simulate an in-flight pop
    u2 = engine.submit(np.arange(6, dtype=np.int32), gen_len=4)
    assert len({u0, u1, u2}) == 3
    assert u2 > u1 > u0
    assert inflight.uid == u0


def test_engine_latency_percentiles(small):
    cfg, params = small
    engine = ServingEngine(cfg, params, max_batch=2, canvas_len=24)
    rng = np.random.default_rng(0)
    for _ in range(3):
        engine.submit(rng.integers(0, cfg.vocab_size - 1, 8)
                      .astype(np.int32), gen_len=4)
    stats = engine.run()
    pct = stats.percentiles()
    assert set(pct) == {"e2e_p50", "e2e_p95", "wait_p50", "wait_p95",
                        "ttft_p50", "ttft_p95", "tpot_p50", "tpot_p95"}
    assert pct["e2e_p95"] >= pct["e2e_p50"] > 0.0
    assert pct["e2e_p50"] >= pct["wait_p50"] >= 0.0
    assert len(stats.e2e_latencies) == 3
    # TTFT is bounded by e2e; both streaming metrics were recorded
    assert len(stats.ttft_latencies) == 3
    assert 0.0 < pct["ttft_p50"] <= pct["e2e_p50"]
    assert pct["tpot_p50"] >= 0.0


# ---------------------------------------------------------------------------
# Paged runtime (DESIGN.md §5)
# ---------------------------------------------------------------------------

PAGE, CANVAS = 4, 16


def _paged_engine(cfg, params, pool_pages, max_batch=2, **kw):
    from repro.core.strategy import SPACache
    return ServingEngine(
        cfg, params, max_batch=max_batch, canvas_len=CANVAS,
        strategy=SPACache(rank=16, schedule="uniform", rho_peak=0.3,
                          **kw.pop("strategy_kw", {})),
        pool_pages=pool_pages, page_size=PAGE, **kw)


def _outputs(engine):
    return {r.uid: np.asarray(r.output) for r in engine.done}


def test_paged_engine_matches_dense_engine(tiny_cfg, tiny_params):
    """Acceptance: the paged engine serves full-length requests with
    byte-identical outputs to the dense-slab engine."""
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, tiny_cfg.vocab_size - 1, 8)
               .astype(np.int32) for _ in range(4)]

    def serve(pool_pages):
        eng = _paged_engine(tiny_cfg, tiny_params, pool_pages)
        for p in prompts:
            eng.submit(p, gen_len=CANVAS - 8)   # row_len == canvas
        eng.run()
        return _outputs(eng)

    dense, paged = serve(0), serve(1 + 2 * (CANVAS // PAGE))
    assert set(dense) == set(paged)
    for uid in dense:
        np.testing.assert_array_equal(dense[uid], paged[uid])


def test_paged_mixed_gen_len_matches_alone(tiny_cfg, tiny_params):
    """Heterogeneous gen_len requests share a lane without padding to
    the lane max; each output is byte-identical to serving it alone."""
    rng = np.random.default_rng(2)
    reqs = [(rng.integers(0, tiny_cfg.vocab_size - 1, 4)
             .astype(np.int32), g) for g in (4, 8, 12, 4)]

    def serve(batch):
        eng = _paged_engine(tiny_cfg, tiny_params, 1 + 3 * (CANVAS // PAGE))
        uids = [eng.submit(p, gen_len=g) for p, g in batch]
        eng.run()
        outs = _outputs(eng)
        return [outs[u] for u in uids]

    together = serve(reqs)
    for i, (p, g) in enumerate(reqs):
        alone = serve([(p, g)])[0]
        np.testing.assert_array_equal(together[i], alone)


def test_oversubscribed_pool_completes(tiny_cfg, tiny_params):
    """Acceptance: aggregate cache footprint >= 2x the pool completes
    via admission control (requests wait for pages, never fail)."""
    rng = np.random.default_rng(3)
    n_log = CANVAS // PAGE
    eng = _paged_engine(tiny_cfg, tiny_params, 1 + 2 * n_log)
    demand = 0
    for _ in range(6):
        eng.submit(rng.integers(0, tiny_cfg.vocab_size - 1, 8)
                   .astype(np.int32), gen_len=CANVAS - 8)
        demand += n_log
    assert demand >= 2 * eng.pool.capacity   # >= 2x oversubscription
    stats = eng.run()
    assert stats.requests_done == 6
    assert all((r.output != tiny_cfg.mask_id).all() for r in eng.done)
    assert stats.peak_pool_util <= 1.0
    assert stats.steady_pool_util > 0.0
    assert eng.pool.available == eng.pool.capacity  # all pages returned


def test_preemption_engine_byte_identical(tiny_cfg, tiny_params):
    """A high-priority arrival preempts the lowest-priority running
    request (pages released, request requeued) and the preempted request
    still decodes byte-identically: with refresh_interval=1 the cache is
    canvas-Markovian, so the resume re-prefill IS the refresh the
    never-preempted twin performs anyway."""
    rng = np.random.default_rng(4)
    smalls = [rng.integers(0, tiny_cfg.vocab_size - 1, 4)
              .astype(np.int32) for _ in range(2)]
    big = rng.integers(0, tiny_cfg.vocab_size - 1, 8).astype(np.int32)

    def serve(pool_pages, arrival_step, max_batch=2):
        eng = _paged_engine(tiny_cfg, tiny_params, pool_pages,
                            max_batch=max_batch,
                            strategy_kw=dict(refresh_interval=1))
        uids = [eng.submit(p, gen_len=4) for p in smalls]   # 2 pages each
        fired = {"done": False}

        def on_step(e):
            if not fired["done"] and e.stats.steps >= arrival_step:
                fired["done"] = True
                uids.append(e.submit(big, gen_len=8, priority=5))

        eng.run(on_step=on_step)
        return {r.uid: np.asarray(r.output) for r in eng.done}, eng

    # tight pool: the big arrival (4 pages) must preempt the smalls
    tight, et = serve(1 + 4, arrival_step=2)
    assert et.stats.preemptions > 0
    assert any(r.preemptions > 0 for r in et.done)
    # roomy twin: pages AND slots to spare, nothing preempted
    roomy, er = serve(1 + 3 * (CANVAS // PAGE), arrival_step=2,
                      max_batch=3)
    assert er.stats.preemptions == 0
    assert set(tight) == set(roomy)
    for uid in tight:
        np.testing.assert_array_equal(tight[uid], roomy[uid])


def test_streaming_continuity_across_preemption(tiny_cfg, tiny_params):
    """DESIGN.md §8: a preempted-then-resumed request's event stream
    has no duplicated and no lost committed tokens — each gen-span
    position is emitted exactly once — and reassembling the stream
    yields tokens byte-identical to an unpreempted run."""
    rng = np.random.default_rng(4)
    smalls = [rng.integers(0, tiny_cfg.vocab_size - 1, 4)
              .astype(np.int32) for _ in range(2)]
    big = rng.integers(0, tiny_cfg.vocab_size - 1, 8).astype(np.int32)

    def serve(pool_pages, arrival_step, max_batch=2):
        eng = _paged_engine(tiny_cfg, tiny_params, pool_pages,
                            max_batch=max_batch,
                            strategy_kw=dict(refresh_interval=1))
        events = []
        uids = [eng.submit(p, gen_len=4, stream=True, sink=events.append)
                for p in smalls]
        fired = {"done": False}

        def on_step(e):
            if not fired["done"] and e.stats.steps >= arrival_step:
                fired["done"] = True
                uids.append(e.submit(big, gen_len=8, priority=5,
                                     stream=True, sink=events.append))

        eng.run(on_step=on_step)
        streams = {u: {} for u in uids}
        for ev in events:
            if ev.kind != "token":
                continue
            for pos, tok in zip(ev.positions, ev.tokens):
                assert pos not in streams[ev.uid], \
                    f"uid {ev.uid}: position {pos} emitted twice"
                streams[ev.uid][pos] = tok
        out = {}
        for r in eng.done:
            got = streams[r.uid]
            assert sorted(got) == list(range(len(r.output))), \
                f"uid {r.uid}: stream lost positions"
            out[r.uid] = np.asarray([got[i] for i in sorted(got)])
            np.testing.assert_array_equal(out[r.uid], r.output)
        return out, eng

    tight, et = serve(1 + 4, arrival_step=2)
    assert et.stats.preemptions > 0            # stream crossed a resume
    roomy, er = serve(1 + 3 * (CANVAS // PAGE), arrival_step=2,
                      max_batch=3)
    assert er.stats.preemptions == 0
    assert set(tight) == set(roomy)
    for uid in tight:
        np.testing.assert_array_equal(tight[uid], roomy[uid])

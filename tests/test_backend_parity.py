"""KernelBackend parity: PallasBackend (interpret mode) must decode
byte-identically to XlaBackend (DESIGN.md §4.5).

The Pallas kernels mirror the XLA serve path op-for-op (same block
structure, same f32 accumulation order, projections rounded through the
storage dtype before scoring), so — post ``_SCORE_QUANTUM`` tie-breaking
in selection — every registered CacheStrategy must produce bit-identical
TOKEN streams and step counts on either backend, in both the host loop
(``run``) and the device-resident loop (``run_compiled``).  Cache
buffers are additionally pinned to ulp-level agreement (see
``_assert_cache_close`` for why bitwise is not achievable there).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.core import spa_layer
from repro.core.strategy import (AttnOutCache, NoCache, SPACache,
                                 ValueProxyCache, WindowCache)
from repro.dlm.session import DecodeSession
from repro.kernels.backend import (PALLAS_BACKEND, XLA_BACKEND,
                                   PallasBackend, XlaBackend,
                                   resolve_backend)
from repro.models import transformer

STRATEGIES = {
    "spa": SPACache(rank=16, schedule="uniform", rho_peak=0.3),
    "spa_incremental": SPACache(rank=16, schedule="uniform", rho_peak=0.3,
                                incremental_ident=True),
    "value": ValueProxyCache(rho=0.3),
    "attn_in": ValueProxyCache(projection="attn_in", rho=0.3),
    "window": WindowCache(locality_window=8, rho=0.3),
    "attn_out": AttnOutCache(rho=0.5),
    "none": NoCache(),
}

PALLAS = PallasBackend(interpret=True)


def _assert_cache_close(c_x, c_p):
    """Caches must agree to ulp-level noise.  Bitwise equality is NOT
    guaranteed for intermediate buffers: XLA fuses the norm/matmul
    chains around a pallas_call differently than it fuses the pure-jnp
    graph, reordering f32 reductions by a few ulps (~1e-6 on O(1)
    values).  Token streams stay byte-identical because selection
    quantizes scores (_SCORE_QUANTUM) and commits argmax over logits."""
    def close(a, b):
        if np.issubdtype(a.dtype, np.integer):
            assert np.abs(a.astype(np.int32)
                          - b.astype(np.int32)).max() <= 1
        else:
            np.testing.assert_allclose(a.astype(np.float32),
                                       b.astype(np.float32),
                                       rtol=1e-4, atol=1e-4)
    jax.tree.map(close, c_x, c_p)


@pytest.fixture(scope="module")
def small():
    cfg = reduced(get_arch("internlm2-1.8b"))
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0,
                                cfg.vocab_size - 1)
    return cfg, params, prompt


def _decode(cfg, params, prompt, strategy, backend, mode):
    sess = DecodeSession(params, cfg, strategy=strategy, backend=backend)
    sess.prefill(prompt, gen_len=6)
    toks, info = getattr(sess, mode)()
    return np.asarray(toks), info["steps"], jax.tree.map(
        np.asarray, sess.state.cache)


@pytest.mark.parametrize("mode", ["run", "run_compiled"])
@pytest.mark.parametrize("name", sorted(STRATEGIES))
def test_decode_parity(small, name, mode):
    """Byte-identical tokens, steps, and final cache per strategy/mode."""
    cfg, params, prompt = small
    strat = STRATEGIES[name]
    t_x, s_x, c_x = _decode(cfg, params, prompt, strat, None, mode)
    t_p, s_p, c_p = _decode(cfg, params, prompt, strat, PALLAS, mode)
    np.testing.assert_array_equal(t_x, t_p)
    assert s_x == s_p
    _assert_cache_close(c_x, c_p)


def test_decode_parity_int8(small):
    """Quantized caches: scatters carry int8 rows + f16 scales — the
    multi-buffer kernel must commit all four KV buffers identically."""
    cfg, params, prompt = small
    cfg8 = dataclasses.replace(cfg, cache_dtype="int8")
    strat = STRATEGIES["spa"]
    t_x, s_x, c_x = _decode(cfg8, params, prompt, strat, None, "run")
    t_p, s_p, c_p = _decode(cfg8, params, prompt, strat, PALLAS, "run")
    np.testing.assert_array_equal(t_x, t_p)
    assert s_x == s_p
    _assert_cache_close(c_x, c_p)


def test_stratified_long_context_parity():
    """n > 8192 engages stratified selection + the banded attention path
    (scalar-prefetched kv starts in the Pallas kernel)."""
    cfg = reduced(get_arch("gemma2-2b"), n_layers=2, d_model=32,
                  n_heads=1, n_kv_heads=1, head_dim=32, d_ff=64,
                  vocab_size=64)
    n, gen = 16384, 32
    k = 2048                       # rho=0.125: per-stratum 512 rows
    nb = spa_layer.stratify_blocks_for(n, k)
    span = spa_layer.q_span_bound(n, k, nb)
    assert nb > 1 and n > span + 2 * cfg.window + 2 * 512, \
        "shape must engage the banded path"
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, n - gen), 0,
                                cfg.vocab_size - 1)
    strat = SPACache(rank=16, schedule="uniform", rho_peak=0.125)
    outs = {}
    for backend in [None, PALLAS]:
        sess = DecodeSession(params, cfg, strategy=strat, backend=backend)
        sess.prefill(prompt, gen)
        for _ in range(2):
            sess.step()
        outs[backend] = (np.asarray(sess.state.tokens),
                         jax.tree.map(np.asarray, sess.state.cache))
    np.testing.assert_array_equal(outs[None][0], outs[PALLAS][0])
    _assert_cache_close(outs[None][1], outs[PALLAS][1])


def test_backend_is_static_jit_key(small):
    """Backends are frozen/hashable and part of the strategy identity, so
    engine lanes and jitted steps key on them."""
    assert XlaBackend() == XLA_BACKEND
    assert PallasBackend() == PALLAS_BACKEND
    assert hash(PallasBackend(interpret=True)) == hash(
        PallasBackend(interpret=True))
    strat = STRATEGIES["spa"]
    assert strat.with_backend(PALLAS) != strat
    assert strat.with_backend(PALLAS).with_backend(XLA_BACKEND) == strat
    assert resolve_backend("pallas") is PALLAS_BACKEND
    assert resolve_backend("xla") is XLA_BACKEND
    with pytest.raises(ValueError):
        resolve_backend("mosaic")
    # spec round-trip stays backend-free (serializable policy only)
    assert strat.with_backend(PALLAS).spec == strat.spec


def test_spa_forward_backend_override(small):
    """spa_forward accepts backend= directly (call-time selection)."""
    cfg, params, prompt = small
    strat = STRATEGIES["spa"]
    sess = DecodeSession(params, cfg, strategy=strat)
    sess.prefill(prompt, gen_len=6)
    state = sess.state
    proxies = sess.spa_proxies
    h = transformer.embed_inputs(params, cfg, {"tokens": state.tokens})
    outs = []
    for backend in ["xla", "pallas" if jax.default_backend() == "tpu"
                    else PALLAS]:
        h_out, cache, _ = jax.jit(
            lambda c, hh, be=backend: spa_layer.spa_forward(
                params, cfg, c, hh, spa_proxies=proxies, strategy=strat,
                backend=be))(state.cache, h)
        outs.append((np.asarray(h_out), jax.tree.map(np.asarray, cache)))
    np.testing.assert_allclose(outs[0][0], outs[1][0], rtol=1e-4,
                               atol=1e-4)
    _assert_cache_close(outs[0][1], outs[1][1])

"""Strategy-parity suite for the CacheStrategy / DecodeSession redesign.

(a) every registered CacheStrategy completes a 2-layer reduced-model
    decode with all masks committed,
(b) SPACache at rho=1.0 matches NoCache logits within tolerance,
(c) continuous batching yields byte-identical outputs to the
    static-batch path for the same request set.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.core import strategy as strategy_lib
from repro.core.strategy import (AttnOutCache, NoCache, SPACache,
                                 ValueProxyCache, WindowCache)
from repro.dlm import decoding
from repro.dlm.session import DecodeSession
from repro.models import transformer
from repro.serving.engine import ServingEngine


@pytest.fixture(scope="module")
def small():
    cfg = reduced(get_arch("internlm2-1.8b"))
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0,
                                cfg.vocab_size - 1)
    return cfg, params, prompt


def _default_instance(ident: str):
    """A small test-sized instance of the registered strategy class."""
    cls = strategy_lib.REGISTRY[ident]
    if cls is SPACache:
        return SPACache(rank=16, schedule="uniform", rho_peak=0.3)
    if cls is ValueProxyCache:
        return ValueProxyCache(projection=ident, rho=0.3)
    if cls is WindowCache:
        return WindowCache(locality_window=8, rho=0.3)
    if cls is AttnOutCache:
        return AttnOutCache(rho=0.5)
    return cls()


def test_registry_covers_all_identifiers():
    assert set(strategy_lib.REGISTRY) == {
        "none", "singular", "value", "query", "key", "attn_in",
        "window", "attn_out"}
    # spec round-trips through the registry
    for ident in strategy_lib.REGISTRY:
        strat = _default_instance(ident)
        assert strategy_lib.strategy_from_spec(strat.spec) == strat


@pytest.mark.parametrize("ident", sorted(strategy_lib.REGISTRY))
def test_every_strategy_completes_decode(small, ident):
    """(a) full decode with every registered strategy, all masks committed.

    The strategy is passed at CALL time — cfg.spa (singular) never
    changes, proving policy is decoupled from the model config."""
    cfg, params, prompt = small
    strat = _default_instance(ident)
    sess = DecodeSession(params, cfg, strategy=strat)
    sess.prefill(prompt, gen_len=6)
    toks, info = sess.run()
    assert int((toks == cfg.mask_id).sum()) == 0
    assert info["steps"] <= 10
    np.testing.assert_array_equal(np.asarray(toks[:, :10]),
                                  np.asarray(prompt))


def test_spa_rho1_matches_nocache_logits(small):
    """(b) at rho=1.0 every row refreshes, so the cached forward must
    reproduce the dense forward's logits."""
    cfg, params, prompt = small
    strat = SPACache(rank=16, schedule="uniform", rho_peak=1.0)
    sess = DecodeSession(params, cfg, strategy=strat)
    state = sess.prefill(prompt, gen_len=6)

    h0 = transformer.embed_inputs(params, cfg, {"tokens": state.tokens})
    from repro.core import spa_layer
    h_spa, _, _ = spa_layer.spa_forward(
        params, cfg, state.cache, h0, spa_proxies=sess.spa_proxies,
        strategy=strat)
    h_dense, _, _ = transformer.forward_hidden(params, cfg, h0)
    logits_spa = transformer.logits_from_hidden(params, cfg, h_spa)
    logits_dense = transformer.logits_from_hidden(params, cfg, h_dense)
    np.testing.assert_allclose(np.asarray(logits_spa),
                               np.asarray(logits_dense),
                               rtol=1e-4, atol=1e-4)


def test_value_proxy_incremental_matches_full(small):
    """incremental_ident is supported for the projection baselines too
    (it is not SPACache-only)."""
    cfg, params, prompt = small
    outs = {}
    for inc in (False, True):
        strat = ValueProxyCache(rho=0.3, incremental_ident=inc)
        toks, _ = decoding.decode(params, cfg, prompt, gen_len=6,
                                  strategy=strat)
        outs[inc] = np.asarray(toks)
    np.testing.assert_array_equal(outs[False], outs[True])


def test_spa_rho1_commits_same_tokens_as_nocache(small):
    cfg, params, prompt = small
    outs = {}
    for name, strat in (("spa", SPACache(rank=16, schedule="uniform",
                                         rho_peak=1.0)),
                        ("none", NoCache())):
        toks, _ = decoding.decode(params, cfg, prompt, gen_len=8,
                                  strategy=strat)
        outs[name] = np.asarray(toks)
    agree = (outs["spa"] == outs["none"]).mean()
    assert agree > 0.95


def _serve(cfg, params, prompts, gen_lens, *, continuous, max_batch,
           strategy):
    engine = ServingEngine(cfg, params, max_batch=max_batch,
                           canvas_len=24, strategy=strategy,
                           continuous=continuous)
    for p, g in zip(prompts, gen_lens):
        engine.submit(p, g)
    engine.run()
    return {r.uid: np.asarray(r.output) for r in engine.done}, engine


def test_continuous_batching_byte_identical(small):
    """(c) step-granular slot swapping must not change ANY request's
    output vs the static-batch path (rows are independent)."""
    cfg, params, _ = small
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size - 1, 8).astype(np.int32)
               for _ in range(5)]
    # unequal gen lengths force mid-loop completion -> real swaps
    gen_lens = [4, 7, 5, 6, 4]
    strat = SPACache(rank=16, schedule="uniform", rho_peak=0.3)
    out_static, _ = _serve(cfg, params, prompts, gen_lens,
                           continuous=False, max_batch=2, strategy=strat)
    out_cont, eng = _serve(cfg, params, prompts, gen_lens,
                           continuous=True, max_batch=2, strategy=strat)
    assert eng.stats.swaps > 0
    assert set(out_static) == set(out_cont)
    for uid in out_static:
        np.testing.assert_array_equal(out_static[uid], out_cont[uid])


def test_engine_per_request_settings(small):
    """Requests with different DecodeSettings are lane-partitioned and
    all served."""
    cfg, params, _ = small
    engine = ServingEngine(cfg, params, max_batch=2, canvas_len=24,
                           strategy=NoCache())
    rng = np.random.default_rng(0)
    par = decoding.DecodeSettings(parallel_threshold=0.05, max_parallel=2)
    for i in range(4):
        engine.submit(rng.integers(0, cfg.vocab_size - 1, 6)
                      .astype(np.int32), gen_len=4,
                      settings=par if i % 2 else None)
    stats = engine.run()
    assert stats.requests_done == 4
    for req in engine.done:
        assert (req.output != cfg.mask_id).all()

"""Singular proxy (paper §3.3) — Theorem 3.4 bound checked numerically."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import svd_proxy


def test_full_rank_proxy_exact():
    rng = np.random.default_rng(0)
    w = rng.standard_normal((32, 32)).astype(np.float32)
    proxy, bound = svd_proxy.build_proxy(w, 32)
    h = rng.standard_normal((8, 32)).astype(np.float32)
    v = h @ w
    p = h @ proxy
    # full-rank proxy preserves cosine similarities exactly
    s_v = svd_proxy.cosine_similarity(jnp.asarray(v[:4]), jnp.asarray(v[4:]))
    s_p = svd_proxy.cosine_similarity(jnp.asarray(p[:4]), jnp.asarray(p[4:]))
    np.testing.assert_allclose(s_v, s_p, atol=1e-5)
    assert bound == 0.0


@given(st.integers(4, 24), st.integers(1, 4))
@settings(max_examples=15, deadline=None)
def test_theorem_3_4_bound(r, seed):
    """|S_cos(v1,v2) - S_cos(p1,p2)| <= 2 (s_{r+1}/s_r)^2 for inputs in
    span(V_r) — verified on random matrices with decaying spectra."""
    rng = np.random.default_rng(seed)
    d = 32
    u, _ = np.linalg.qr(rng.standard_normal((d, d)))
    vt, _ = np.linalg.qr(rng.standard_normal((d, d)))
    s = np.exp(-np.arange(d) * 0.4)           # decaying spectrum
    w = (u * s) @ vt.T
    proxy, bound = svd_proxy.build_proxy(w.astype(np.float32), r)

    # inputs restricted to the retained left subspace of W (= span of the
    # top-r right singular vectors of W_paper = W^T)
    u_r = np.linalg.svd(w, full_matrices=False)[0][:, :r]
    h = rng.standard_normal((6, r)) @ u_r.T
    v = h @ w
    p = h @ np.asarray(proxy)
    for i in range(3):
        s_v = float(svd_proxy.cosine_similarity(
            jnp.asarray(v[i]), jnp.asarray(v[i + 3])))
        s_p = float(svd_proxy.cosine_similarity(
            jnp.asarray(p[i]), jnp.asarray(p[i + 3])))
        assert abs(s_v - s_p) <= bound + 1e-4


def test_bound_monotone_in_rank():
    rng = np.random.default_rng(0)
    d = 48
    u, _ = np.linalg.qr(rng.standard_normal((d, d)))
    # super-exponential spectrum: consecutive ratios strictly shrink
    s = np.exp(-0.01 * np.arange(d) ** 2)
    w = (u * s) @ u.T
    bounds = [svd_proxy.build_proxy(w.astype(np.float32), r)[1]
              for r in (4, 16, 40)]
    assert bounds[0] >= bounds[1] >= bounds[2]


def test_proxy_stack_shapes():
    rng = np.random.default_rng(1)
    stack = jnp.asarray(rng.standard_normal((3, 16, 8)).astype(np.float32))
    out = svd_proxy.build_proxy_stack(stack, 4)
    assert out.shape == (3, 16, 4)

"""Cache state + int8 quantization tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.configs import get_arch, reduced
from repro.core import cache as cache_lib
from repro.core.cache import CachePolicy


@given(st.integers(0, 5), st.floats(0.01, 100.0))
@settings(max_examples=20, deadline=None)
def test_quantize_roundtrip_error(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray((rng.standard_normal((8, 32)) * scale)
                    .astype(np.float32))
    q, s = cache_lib.quantize_rows(x)
    back = cache_lib.dequantize_rows(q, s)
    amax = np.abs(np.asarray(x)).max(axis=-1, keepdims=True)
    err = np.abs(np.asarray(back) - np.asarray(x))
    assert (err <= amax / 127.0 + 1e-6).all()
    assert q.dtype == jnp.int8


def test_init_model_cache_shapes():
    cfg = reduced(get_arch("internlm2-1.8b"))
    cache = cache_lib.init_model_cache(cfg, batch=2, n=32)
    assert set(cache) == {"attn"}
    c = cache["attn"]
    assert c["k"].shape == (2, 2, 32, cfg.n_kv_heads, cfg.head_dim)
    assert c["h"].shape == (2, 2, 32, cfg.d_model)
    assert c["proxy"].shape == (2, 2, 32, cfg.spa.rank)


def test_int8_cache_write_read():
    cfg = reduced(get_arch("internlm2-1.8b"), cache_dtype="int8")
    policy = CachePolicy.from_config(cfg)
    c = cache_lib.init_attn_layer_cache(cfg, 2, 16, policy)
    rng = np.random.default_rng(0)
    idx = jnp.asarray([[1, 5, 9], [0, 7, 15]], jnp.int32)
    k_rows = jnp.asarray(rng.standard_normal(
        (2, 3, cfg.n_kv_heads, cfg.head_dim)).astype(np.float32))
    v_rows = k_rows * 2
    c = cache_lib.write_kv(c, idx, k_rows, v_rows, policy)
    kf, vf, ks, vs = cache_lib.read_kv_for_attention(c, policy)
    assert kf.dtype == jnp.int8 and ks is not None
    k_back = cache_lib.dequantize_rows(
        jnp.take(kf[0], idx[0], axis=0), jnp.take(ks[0], idx[0], axis=0))
    np.testing.assert_allclose(k_back, k_rows[0], atol=0.05, rtol=0.05)

    h_rows = jnp.asarray(rng.standard_normal(
        (2, 3, cfg.d_model)).astype(np.float32))
    c = cache_lib.write_h(c, idx, h_rows, policy)
    back = cache_lib.read_h_rows(c, idx, policy, jnp.float32)
    np.testing.assert_allclose(back, h_rows, atol=0.05, rtol=0.05)
    # untouched rows stay zero
    full = cache_lib.read_h_full(c, policy, jnp.float32)
    assert float(jnp.abs(full[0, 2]).max()) == 0.0


def test_fill_from_prefill_matches_write():
    cfg = reduced(get_arch("internlm2-1.8b"), cache_dtype="int8")
    policy = CachePolicy.from_config(cfg)
    rng = np.random.default_rng(1)
    k = jnp.asarray(rng.standard_normal(
        (2, 8, cfg.n_kv_heads, cfg.head_dim)).astype(np.float32))
    h = jnp.asarray(rng.standard_normal((2, 8, cfg.d_model))
                    .astype(np.float32))
    c = cache_lib.fill_from_prefill(cfg, k, k, h, None, policy)
    back = cache_lib.read_h_full(c, policy, jnp.float32)
    np.testing.assert_allclose(back, h, atol=0.05, rtol=0.05)

"""Compute-path profiling (DESIGN.md §12).

The contracts the profiling PR makes:

* zero interference — decode with a StepProfiler attached is
  byte-identical to its profiler-off twin, per strategy × run mode ×
  kernel backend (everything is host-side, fenced BETWEEN jitted
  calls);
* exact tiling — the fenced host-loop segments (refresh / dispatch /
  device_wait) share their perf_counter boundaries, so per step they
  sum to the independently recorded total;
* off means off — a run without a profiler adds zero ``spa_profile_*``
  series to the registry;
* retrace accounting — the trace-count wrapper counts (re)traces
  exactly and the ``spa_runtime_*`` / ``spa_pool_*`` series land in a
  valid Prometheus render;
* ``/debug/pool`` — valid JSON mid-churn (preemption + demotion
  traffic live);
* ProfileStore — round-trips through JSON and short-circuits the
  hillclimb re-search on a warm-start hit.
"""
import asyncio
import json
import re

import jax
import numpy as np
import pytest

from repro.core import runtime
from repro.core.strategy import NoCache, SPACache, ValueProxyCache
from repro.dlm.session import DecodeSession
from repro.kernels.backend import PallasBackend
from repro.serving.engine import ServingEngine
from repro.serving.profiling import (KernelPhaseProbes, ProfileStore,
                                     StepProfiler, time_compile_steady)
from repro.serving.telemetry import Telemetry

PAGE, CANVAS = 4, 16
PALLAS = PallasBackend(interpret=True)

STRATEGIES = {
    "spa": SPACache(rank=16, schedule="uniform", rho_peak=0.3),
    "value": ValueProxyCache(rho=0.3),
    "none": NoCache(),
}


@pytest.fixture(scope="module")
def small():
    from repro.configs import get_arch, reduced
    from repro.models import transformer
    cfg = reduced(get_arch("internlm2-1.8b"))
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0,
                                cfg.vocab_size - 1)
    return cfg, params, prompt


def _decode(cfg, params, prompt, strategy, backend, mode, profiler):
    sess = DecodeSession(params, cfg, strategy=strategy, backend=backend,
                         profiler=profiler, label="test-lane")
    sess.prefill(prompt, gen_len=6)
    toks, info = getattr(sess, mode)()
    return np.asarray(toks), info["steps"]


# ---------------------------------------------------------------------------
# Zero interference: profiling on == profiling off, byte for byte
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["run", "run_compiled"])
@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("name", sorted(STRATEGIES))
def test_profiling_on_is_byte_identical(small, name, backend, mode):
    cfg, params, prompt = small
    strat = STRATEGIES[name]
    bk = None if backend == "xla" else PALLAS
    prof = StepProfiler(Telemetry.enabled(dynamics_every=0))
    t_off, s_off = _decode(cfg, params, prompt, strat, bk, mode, None)
    t_on, s_on = _decode(cfg, params, prompt, strat, bk, mode, prof)
    np.testing.assert_array_equal(t_off, t_on)
    assert s_off == s_on
    # and the profiler actually saw the run
    if mode == "run":
        assert prof.steps_observed == s_on
    else:
        assert prof.loops_observed == 1


# ---------------------------------------------------------------------------
# Segment tiling: per-step segments sum to the recorded total
# ---------------------------------------------------------------------------

def test_step_segments_tile_total(small):
    cfg, params, prompt = small
    prof = StepProfiler(Telemetry.enabled(dynamics_every=0))
    _decode(cfg, params, prompt, STRATEGIES["spa"], None, "run", prof)
    assert prof.steps_observed > 0
    snap = prof.registry.snapshot()
    seg_sum = sum(
        snap[f'spa_profile_step_seconds{{segment="{seg}"}}']["sum"]
        for seg in StepProfiler.SEGMENTS)
    total = snap['spa_profile_step_seconds{segment="total"}']["sum"]
    # boundaries are SHARED perf_counter reads, so the telescoping sum
    # is exact up to float summation noise (+ snapshot rounding)
    assert seg_sum == pytest.approx(total, rel=1e-6, abs=1e-7)
    bd = prof.step_breakdown()
    assert set(StepProfiler.SEGMENTS) <= set(bd)
    assert sum(bd[s]["share"] for s in StepProfiler.SEGMENTS) \
        == pytest.approx(1.0, abs=1e-6)
    assert "step-time decomposition" in prof.format_summary()


def test_compiled_loop_records_loop_level_only(small):
    cfg, params, prompt = small
    prof = StepProfiler(Telemetry.enabled(dynamics_every=0))
    _decode(cfg, params, prompt, STRATEGIES["spa"], None, "run_compiled",
            prof)
    snap = prof.registry.snapshot()
    assert snap["spa_profile_loop_seconds"]["count"] == 1
    assert snap["spa_profile_loop_steps_total"] > 0
    # phases are not attributable inside the while_loop: no fenced
    # step segments may appear
    assert not any(k.startswith("spa_profile_step_seconds")
                   for k in snap)


def test_sample_every_skips_steps(small):
    cfg, params, prompt = small
    prof = StepProfiler(Telemetry.enabled(dynamics_every=0),
                        sample_every=2)
    _, steps = _decode(cfg, params, prompt, STRATEGIES["spa"], None,
                       "run", prof)
    assert 0 < prof.steps_observed < steps


def test_profiler_summary_safe_when_empty():
    prof = StepProfiler()
    assert "no profiled steps" in prof.format_summary()
    assert prof.step_breakdown() == {}


# ---------------------------------------------------------------------------
# Off means off: no spa_profile_* series without a profiler
# ---------------------------------------------------------------------------

def test_disabled_profiling_adds_no_registry_entries(small):
    cfg, params, prompt = small
    tel = Telemetry.enabled(dynamics_every=1)
    eng = ServingEngine(cfg, params, max_batch=2, canvas_len=CANVAS,
                        strategy=STRATEGIES["spa"], pool_pages=9,
                        page_size=PAGE, telemetry=tel)
    rng = np.random.default_rng(0)
    eng.submit(rng.integers(0, cfg.vocab_size - 1, 8).astype(np.int32),
               gen_len=8)
    eng.run()
    assert not any(k.startswith("spa_profile_")
                   for k in tel.registry.snapshot())


# ---------------------------------------------------------------------------
# Retrace accounting + Prometheus exposition
# ---------------------------------------------------------------------------

def test_compile_tracker_counts_traces_exactly():
    tracker = runtime.CompileTracker()

    def f(x):
        return x * 2

    jf = jax.jit(tracker.wrap(f, name="f", lane="laneA"))
    jf(np.ones((2,), np.float32))
    jf(np.ones((2,), np.float32))          # cache hit: no retrace
    jf(np.ones((3,), np.float32))          # new shape: one retrace
    assert tracker.trace_count("f") == 2
    assert tracker.top_retraced(1) == [("laneA", 2)]
    snap = tracker.snapshot()
    assert snap["traces"] == {"f": 2}


def test_session_trace_counts_are_shape_stable(small):
    """A second identically shaped decode through the SAME session adds
    zero retraces; the bench_serving Part 6 budget gate relies on this
    invariant."""
    cfg, params, prompt = small
    tracker = runtime.compile_tracker()
    sess = DecodeSession(params, cfg, strategy=STRATEGIES["spa"])
    sess.prefill(prompt, gen_len=6)
    sess.run()
    before = tracker.trace_count("serve_step")
    assert before > 0
    sess.prefill(prompt, gen_len=6)
    sess.run()
    assert tracker.trace_count("serve_step") == before


def test_metrics_render_includes_runtime_and_pool_series(small):
    from test_telemetry import _assert_prometheus_text
    cfg, params, prompt = small
    tel = Telemetry.enabled(dynamics_every=0)
    eng = ServingEngine(cfg, params, max_batch=2, canvas_len=CANVAS,
                        strategy=STRATEGIES["spa"], pool_pages=9,
                        page_size=PAGE, telemetry=tel,
                        profiler=StepProfiler(tel))
    rng = np.random.default_rng(1)
    eng.submit(rng.integers(0, cfg.vocab_size - 1, 8).astype(np.int32),
               gen_len=8)
    eng.run()
    text = tel.registry.render()
    _assert_prometheus_text(text)
    for series in ("spa_runtime_trace_total",
                   "spa_runtime_live_executables",
                   "spa_pool_peak_pages_used",
                   "spa_pool_max_contiguous_free_run",
                   "spa_pool_arena_bytes_total",
                   "spa_profile_step_seconds"):
        assert series in text, f"missing {series} in /metrics render"


def test_retrace_budget_file_parses():
    import os
    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "retrace_budget.json")
    with open(path) as f:
        budgets = json.load(f)
    for key in ("quick", "full"):
        assert {"serve_step", "prefill_partial", "decode_loop"} \
            <= set(budgets[key])
        assert all(v > 0 for v in budgets[key].values())


# ---------------------------------------------------------------------------
# /debug/pool: valid JSON mid-churn
# ---------------------------------------------------------------------------

def test_debug_pool_json_mid_churn(small):
    """pool_debug_state() stays JSON-serializable at EVERY step of a
    preempting + demoting workload, and the live /debug/pool endpoint
    serves it mid-stream."""
    from repro.serving.frontend import AsyncFrontend, fetch_debug_pool
    cfg, params, prompt = small
    eng = ServingEngine(cfg, params, max_batch=2, canvas_len=CANVAS,
                        strategy=SPACache(rank=16, schedule="uniform",
                                          rho_peak=0.3,
                                          refresh_interval=1),
                        pool_pages=9, page_size=PAGE, prefix_cache=True,
                        host_pages=16, host_dtype="f32",
                        telemetry=Telemetry.enabled(dynamics_every=0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size - 1, 8).astype(np.int32)
               for _ in range(4)]
    eng.submit(prompts[0], gen_len=8)
    eng.run()
    for p in prompts[1:3]:
        eng.submit(p, gen_len=8)
    s0 = eng.stats.steps
    states = []

    def on_step(e):
        if e.stats.steps == s0 + 2:
            e.submit(prompts[3], gen_len=8, priority=5)
        states.append(json.loads(json.dumps(e.pool_debug_state())))

    eng.run(on_step=on_step)
    assert eng.stats.preemptions > 0, "churn never preempted"
    assert states
    for st in states:
        assert st["paged"] is True
        assert st["pool"]["used"] <= st["pool"]["capacity"]
        frag = st["pool"]["fragmentation"]
        assert frag["max_contiguous_run"] <= frag["free_pages"]
        assert st["live_executables"] >= 0
    assert any(st["tier"]["demoted_pages"] > 0 for st in states), \
        "churn never demoted"

    # live endpoint, scraped while a request streams
    async def main():
        from repro.serving.frontend import stream_request
        front = AsyncFrontend(eng, max_steps=2048)
        await front.start(serve_http=True)
        try:
            mid = None
            async for ev in stream_request(front.host, front.port,
                                           prompts[0], 6):
                if ev["kind"] == "token" and mid is None:
                    mid = await fetch_debug_pool(front.host, front.port)
        finally:
            await front.stop()
        return mid

    mid = asyncio.run(main())
    assert mid is not None and mid["paged"] is True
    assert set(mid["pool"]) >= {"capacity", "used", "fragmentation",
                                "arena_bytes"}
    assert mid["host_pool"]["unit_budget"] > 0


# ---------------------------------------------------------------------------
# Kernel-phase probes
# ---------------------------------------------------------------------------

def test_kernel_phase_probes_smoke(small):
    from repro.serving.telemetry import MetricsRegistry
    cfg, _, _ = small
    reg = MetricsRegistry()
    probes = KernelPhaseProbes(cfg, strategy=STRATEGIES["spa"],
                               batch=1, seq=32, n_selected=8, page=8,
                               registry=reg)
    out = probes.run(reps=1)
    assert {"identify", "gather", "attend", "scatter",
            "page_gather"} <= set(out)
    for rec in out.values():
        assert rec["compile_s"] > 0 and rec["steady_s"] > 0
    snap = reg.snapshot()
    assert any(k.startswith("spa_profile_phase_seconds") for k in snap)
    # cache-less strategies have no proxy to score
    out2 = KernelPhaseProbes(cfg, strategy=NoCache(), batch=1, seq=32,
                             n_selected=8, page=8).run(reps=1)
    assert "identify" not in out2


def test_time_compile_steady_orders():
    f = jax.jit(lambda x: x * x + 1.0)
    compile_s, steady_s = time_compile_steady(
        f, np.ones((64,), np.float32), reps=3)
    assert compile_s > 0 and steady_s > 0
    assert compile_s > steady_s            # first call paid the compile


# ---------------------------------------------------------------------------
# ProfileStore + hillclimb warm start
# ---------------------------------------------------------------------------

def test_profile_store_round_trip(tmp_path):
    path = tmp_path / "profiles.json"
    store = ProfileStore(str(path))
    assert len(store) == 0
    store.put({"steady_us": 12.5}, kind="kernel", kernel="gather_norm",
              shape="b2n256", backend="xla", block="bq512")
    store.save()
    again = ProfileStore(str(path))
    rec = again.get(kernel="gather_norm", shape="b2n256", backend="xla",
                    block="bq512", kind="kernel")   # key order-free
    assert rec is not None and rec["steady_us"] == 12.5
    assert rec["key"]["kernel"] == "gather_norm"
    # corrupt stores load as empty, never raise
    path.write_text("{not json")
    assert len(ProfileStore(str(path))) == 0


def test_hillclimb_warm_start_short_circuits(tmp_path, monkeypatch):
    import os
    flags = os.environ.get("XLA_FLAGS")
    from repro.launch import hillclimb
    if flags is None:
        monkeypatch.delenv("XLA_FLAGS", raising=False)
    else:
        monkeypatch.setenv("XLA_FLAGS", flags)
    calls = []

    def fake_run_one(arch, shape, mesh, cfg_override=None, tag=""):
        calls.append(tag)
        return {"arch": arch, "shape": shape, "mesh": mesh, "tag": tag,
                "status": "ok", "step_ms": 1.25}

    monkeypatch.setattr(hillclimb, "run_one", fake_run_one)
    store = tmp_path / "profiles.json"
    out = tmp_path / "hillclimb.jsonl"
    argv = ["--arch", "internlm2-1.8b", "--shape", "decode_32k",
            "--variant", "baseline", "--out", str(out),
            "--profile-store", str(store)]
    assert hillclimb.main(argv) == 0
    assert calls == ["baseline"]           # cold: searched + persisted
    assert hillclimb.main(argv) == 0
    assert calls == ["baseline"], "warm start must skip the re-search"
    recs = [json.loads(ln) for ln in
            out.read_text().strip().split("\n")]
    assert len(recs) == 2
    assert "warm_start" not in recs[0]
    assert recs[1]["warm_start"] is True
    assert recs[1]["step_ms"] == recs[0]["step_ms"]
    # a different variant misses the cache and searches again
    argv2 = argv[:5] + ["rank_64"] + argv[6:]
    assert hillclimb.main(argv2) == 0
    assert calls == ["baseline", "rank_64"]
